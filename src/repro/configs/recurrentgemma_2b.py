"""recurrentgemma-2b [arXiv:2402.19427] — RG-LRU + local attention, 1 attn : 2 rec."""

from .base import ModelConfig, register


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
        n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256000,
        attn_window=2048, block_pattern=("rglru", "rglru", "attn"),
        rglru_dim=2560)
