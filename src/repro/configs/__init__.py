"""Config registry: one module per assigned architecture (+ shape specs)."""

from .base import (SHAPES, ModelConfig, ShapeSpec, get_config, list_archs,
                   register, shapes_for)
from . import (granite_3_8b, granite_moe_1b, internlm2_20b, internvl2_2b,
               llama3_8b, phi35_moe_42b, recurrentgemma_2b,
               seamless_m4t_medium, tinyllama_1b, xlstm_1b)
from .reduce import reduce_for_smoke

__all__ = ["SHAPES", "ModelConfig", "ShapeSpec", "get_config", "list_archs",
           "register", "shapes_for", "reduce_for_smoke"]
