"""Reduced configs for CPU smoke tests (same family, tiny dimensions)."""

import dataclasses

from .base import ModelConfig


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Shrink every axis while keeping the family's structure intact."""
    pat = cfg.block_pattern
    n_layers = max(2, len(pat)) if pat else 2
    if pat:
        n_layers = len(pat) + min(2, len(pat))  # one scanned group + a tail
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else n_heads
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        vocab_round=64,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        attn_window=min(cfg.attn_window, 16) if cfg.attn_window else 0,
        rglru_dim=32 if cfg.rglru_dim else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_frames_decode=16,
        n_patches=8 if cfg.n_patches else 0,
        remat_policy="none",
    )
