"""granite-3-8b [hf:ibm-granite/granite-3.0-8b-base family]."""

from .base import ModelConfig, register


@register("granite-3-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b", family="dense", n_layers=40, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=12800, vocab_size=49155)
