"""seamless-m4t-medium [arXiv:2308.11596] — enc-dec; audio frontend stubbed."""

from .base import ModelConfig, register


@register("seamless-m4t-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec", n_layers=12, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=256206,
        n_enc_layers=12, enc_frames_decode=4096)
