"""Config system: model + shape descriptors and the --arch registry."""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "register", "get_config",
           "list_archs", "shapes_for"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact values from the assignment table)."""

    name: str
    family: str            # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0      # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # hybrid (RG-LRU + local attention) / ssm
    attn_window: int = 0           # 0 -> full attention
    block_pattern: tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "attn")
    rglru_dim: int = 0             # recurrence width (defaults to d_model)
    # enc-dec
    n_enc_layers: int = 0
    enc_frames_decode: int = 4096  # encoder memory length for decode shapes
    # vlm
    n_patches: int = 0             # vision-prefix length (stubbed embeddings)
    # common
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    vocab_round: int = 256         # pad vocab to a shardable multiple
    tie_embeddings: bool = False
    remat_policy: str = "nothing"  # nothing | dots | none

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        r = self.vocab_round
        return (self.vocab_size + r - 1) // r * r

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md shape-skip table)."""
        return self.family in ("hybrid", "ssm")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def shapes_for(cfg: ModelConfig) -> list[ShapeSpec]:
    """The assigned shape set, with the documented long_500k skip rule."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        names.append("long_500k")
    return [SHAPES[n] for n in names]
