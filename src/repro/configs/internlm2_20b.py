"""internlm2-20b [arXiv:2403.17297]."""

from .base import ModelConfig, register


@register("internlm2-20b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense", n_layers=48, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=92544)
