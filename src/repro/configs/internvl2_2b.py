"""internvl2-2b [arXiv:2404.16821] — InternViT frontend stubbed; InternLM2 backbone."""

from .base import ModelConfig, register


@register("internvl2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
        n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92553,
        n_patches=256)
