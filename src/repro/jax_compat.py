"""Version shims for jax API renames used across the package.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
namespace around jax 0.5, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma``.  Import ``shard_map`` from here and always
pass ``check_vma=``; the shim forwards to whichever spelling the installed
jax understands.
"""

from __future__ import annotations

import inspect

try:  # jax >= 0.5 exports shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - depends on jax version
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")

__all__ = ["shard_map", "set_mesh"]


def shard_map(*args, check_vma: bool | None = None, **kwargs):
    if check_vma is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map(*args, **kwargs)


def set_mesh(mesh):
    """``jax.set_mesh`` on new jax; older jax uses the mesh itself as the
    ambient-mesh context manager (``with mesh:``)."""
    import jax

    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
