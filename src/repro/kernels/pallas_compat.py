"""Version shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` around
0.5; the kernels in this package are written against the new name.  Import
``CompilerParams`` from here so they run on both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None)
if CompilerParams is None:  # pragma: no cover - depends on jax version
    CompilerParams = pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
