"""Pallas TPU kernels for the paper's perf-critical matching loops."""

from . import ops, ref
from .ops import (flash_attn, lvec_compose, onehot_block_maps, spec_match,
                  token_mask)

__all__ = ["ops", "ref", "spec_match", "lvec_compose", "onehot_block_maps",
           "token_mask", "flash_attn"]
