"""Pallas TPU kernel: MXU (one-hot matmul) DFA block maps — beyond-paper.

The paper's matching loop is a serial chain of gathers: L-deep dependency,
VPU-bound.  The TPU has a 128x128 systolic MXU sitting idle during that loop.
This kernel re-expresses a block of L symbols as a product of one-hot
transition matrices:

    M_block = P_{s_1} @ P_{s_2} @ ... @ P_{s_L},   P_c[q, q'] = [table[q,c] = q']

Each row of ``P_c`` (and of any product of such matrices) has exactly one 1,
so bf16 storage and fp32 accumulation are *exact* — the argmax recovers the
integer map.  Blocks are independent (grid "parallel"), so the serial chain
shrinks from L to L/blocks composed in log-depth outside — the Ladner–Fischer
prefix idea [26] made MXU-native, hybridized with the paper's speculation:
ops.py picks gather vs MXU by the roofline crossover (S lanes vs Q^2 flops).

VMEM: acc [Q, Q] bf16 + one P_c tile; Q <= 256 fits comfortably (256^2 * 2B *
2 = 256 KiB).  Larger Q falls back to the gather kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

__all__ = ["onehot_match_kernel", "onehot_block_maps_pallas", "build_pmats"]


def build_pmats(table: jnp.ndarray) -> jnp.ndarray:
    """Per-class one-hot transition matrices, flattened [n_cls * Q, Q] bf16."""
    q, n_cls = table.shape
    eye = jnp.eye(q, dtype=jnp.bfloat16)
    pmats = eye[table.T.astype(jnp.int32)]  # [n_cls, Q, Q]; row q = onehot(table[q,c])
    return pmats.reshape(n_cls * q, q)


def onehot_match_kernel(syms_ref, pmats_ref, out_ref, *, q: int):
    """One symbol-block: compose P matrices on the MXU, emit the int map.

    syms_ref  : [l_blk] int32 symbol classes of this block
    pmats_ref : [n_cls * Q, Q] bf16 one-hot transition matrices (whole, VMEM)
    out_ref   : [1, Q] int32 block map
    """
    syms = syms_ref[...]

    def body(l, acc):
        c = jax.lax.dynamic_slice_in_dim(syms, l, 1)[0]
        p_c = pmats_ref[pl.ds(c * q, q), :]  # dynamic-slice load [Q, Q]
        nxt = jnp.dot(acc, p_c, preferred_element_type=jnp.float32)
        return nxt.astype(jnp.bfloat16)

    acc = jax.lax.fori_loop(0, syms.shape[0], body, jnp.eye(q, dtype=jnp.bfloat16))
    out_ref[...] = jnp.argmax(acc, axis=1).astype(jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("l_blk", "interpret"))
def onehot_block_maps_pallas(table: jnp.ndarray, symbols: jnp.ndarray, *,
                             l_blk: int = 256, interpret: bool = True) -> jnp.ndarray:
    """Pallas-backed equivalent of ``ref.onehot_block_maps_ref``.

    table [Q, n_cls] int32, symbols [L] int32 with L % l_blk == 0.
    Returns [L / l_blk, Q] int32 block maps (compose with lvec_compose).
    """
    q, n_cls = table.shape
    (l,) = symbols.shape
    assert l % l_blk == 0, (l, l_blk)
    pmats = build_pmats(table)
    kernel = functools.partial(onehot_match_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=(l // l_blk,),
        in_specs=[
            pl.BlockSpec((l_blk,), lambda b: (b,)),
            pl.BlockSpec((n_cls * q, q), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((l // l_blk, q), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(symbols.astype(jnp.int32), pmats)
