"""Pallas TPU kernel: fused constrained-decoding logit mask.

Serving integration of the DFA engine (DESIGN.md §3.2): each sequence in the
decode batch carries a grammar-DFA state; the per-state allowed-token table
``allowed[Q, V]`` gives the legal next tokens.  This kernel fuses the row
gather with the logit masking epilogue so the [B, V] mask tensor never
round-trips through HBM — at V = 128K and B = 128 that saves a 16 MB
materialization per decode step.

Grid: (B, V / v_blk); the allowed table streams one [Q, v_blk] tile per
column block (grammar DFAs are small: Q ~ 10^2..10^3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

__all__ = ["token_mask_kernel", "token_mask_pallas"]


def token_mask_kernel(states_ref, allowed_ref, logits_ref, out_ref, *, neg: float):
    """states [B] int32; allowed tile [Q, v_blk] uint8; logits tile [1, v_blk]."""
    b = pl.program_id(0)
    s = jax.lax.dynamic_slice_in_dim(states_ref[...], b, 1)[0]
    row = allowed_ref[pl.ds(s, 1), :]  # dynamic-slice row load [1, v_blk]
    logits = logits_ref[...]
    out_ref[...] = jnp.where(row > 0, logits, jnp.asarray(neg, logits.dtype))


@functools.partial(jax.jit, static_argnames=("v_blk", "neg", "interpret"))
def token_mask_pallas(states: jnp.ndarray, allowed: jnp.ndarray,
                      logits: jnp.ndarray, *, v_blk: int = 2048,
                      neg: float = -1e30, interpret: bool = True) -> jnp.ndarray:
    """Pallas-backed equivalent of ``ref.token_mask_ref``.

    states [B] int32; allowed [Q, V] uint8/bool; logits [B, V] float.
    V % v_blk == 0 (ops.py pads the vocab tail).
    """
    b, v = logits.shape
    q = allowed.shape[0]
    assert v % v_blk == 0, (v, v_blk)
    kernel = functools.partial(token_mask_kernel, neg=neg)
    return pl.pallas_call(
        kernel,
        grid=(b, v // v_blk),
        in_specs=[
            pl.BlockSpec((b,), lambda i, j: (0,)),
            pl.BlockSpec((q, v_blk), lambda i, j: (0, j)),
            pl.BlockSpec((1, v_blk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, v_blk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, v), logits.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(states.astype(jnp.int32), allowed.astype(jnp.uint8), logits)
