"""Pallas TPU kernels: L-vector composition (paper Eq. 9 reduction leaf).

Two families live here:

* ``lvec_compose_*`` — the original full-map leaf: composes a block of
  [C, Q] state maps left-to-right (``acc <- m_i[acc]``, one VMEM gather per
  map).  This is the leaf reduction of the hierarchical 2-tier merge
  (DESIGN.md §2).

* ``spec_compose_lanes_*`` — the OOO gap-close fold: composes ragged-padded
  [N, K*S] candidate-keyed lane-map runs (``Matcher.compose_lane_maps``,
  the Eq. 9 monoid restricted to speculative candidate lanes with Eq. 13
  boundary keys).  Per batch element the combine is exactly
  ``core.lvector.merge_scan_lanes_jnp``'s: gather the carry states through
  the next element's candidate index, fall back to the per-pattern sink on
  a candidate miss, and pass the carry through unchanged under the
  ``pad_key`` identity.  Two lowerings, measured against each other in
  ``benchmarks --only ooo_throughput``:

  - block-sequential grid carry (``spec_compose_lanes_pallas``): grid
    (B, N/n_blk), the [K, S] carry lives in VMEM scratch across the
    sequential N dimension — O(N) combines but each is one VPU gather.
  - in-kernel Blelloch tree (``spec_compose_lanes_tree_pallas``): grid (B,),
    the whole pow2-padded run reduces pairwise in log2(N) unrolled levels.

The map dimension is sequential (grid "arbitrary"); carries live in
VMEM scratch.  Q / K*S ride the lane dimension (pad to 128 on hardware).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

__all__ = ["lvec_compose_kernel", "lvec_compose_pallas",
           "spec_compose_lanes_kernel", "spec_compose_lanes_pallas",
           "spec_compose_lanes_tree_kernel",
           "spec_compose_lanes_tree_pallas"]


def lvec_compose_kernel(maps_ref, out_ref, carry_ref, *, c_blocks: int):
    """maps_ref [c_blk, Q]; carry/out [Q] — fold maps into the carry."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jax.lax.broadcasted_iota(
            jnp.int32, (carry_ref.shape[0],), 0)

    maps = maps_ref[...]
    acc = carry_ref[...]

    def body(i, acc):
        row = jax.lax.dynamic_slice_in_dim(maps, i, 1, axis=0)[0]
        return jnp.take(row, acc, axis=0)

    acc = jax.lax.fori_loop(0, maps.shape[0], body, acc)
    carry_ref[...] = acc

    @pl.when(j == c_blocks - 1)
    def _done():
        out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("c_blk", "interpret"))
def lvec_compose_pallas(maps: jnp.ndarray, *, c_blk: int = 8,
                        interpret: bool = True) -> jnp.ndarray:
    """Pallas-backed equivalent of ``ref.lvec_compose_ref``.

    maps [C, Q] int32 with C % c_blk == 0; returns the composed map [Q].
    """
    c, q = maps.shape
    assert c % c_blk == 0, (c, c_blk)
    c_blocks = c // c_blk
    kernel = functools.partial(lvec_compose_kernel, c_blocks=c_blocks)
    return pl.pallas_call(
        kernel,
        grid=(c_blocks,),
        in_specs=[pl.BlockSpec((c_blk, q), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((q,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((q,), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(maps.astype(jnp.int32))


def spec_compose_lanes_kernel(lanes_ref, keys_ref, cidx_ref, sinks_ref,
                              out_ref, carry_ref, *, n_blocks: int,
                              pad_key: int):
    """Grid-carry fold of one doc's keyed lane-map run.

    lanes_ref [1, n_blk, K, S]; keys_ref [1, n_blk]; cidx_ref [n_keys+1, Q];
    sinks_ref [K]; out/carry [K, S].  Element 0 seeds the carry (its key is
    never read — the scan's first prefix IS its lanes); every later element
    folds in with the ``merge_scan_lanes_jnp`` combine.
    """
    j = pl.program_id(1)
    lanes = lanes_ref[0]
    keys = keys_ref[0]
    cidx = cidx_ref[...]
    sk = sinks_ref[...][:, None]                        # [K, 1]

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = lanes[0]

    acc = carry_ref[...]

    def body(i, acc):
        lv = jax.lax.dynamic_slice_in_dim(lanes, i, 1, axis=0)[0]   # [K, S]
        key = jax.lax.dynamic_slice_in_dim(keys, i, 1, axis=0)[0]
        lane = jnp.take(jnp.take(cidx, key, axis=0), acc)           # [K, S]
        hit = jnp.take_along_axis(lv, jnp.maximum(lane, 0), axis=-1)
        nxt = jnp.where(lane < 0, jnp.where(sk >= 0, sk, acc), hit)
        return jnp.where(key == pad_key, acc, nxt)

    start = jnp.where(j == 0, 1, 0)
    acc = jax.lax.fori_loop(start, lanes.shape[0], body, acc)
    carry_ref[...] = acc

    @pl.when(j == n_blocks - 1)
    def _done():
        out_ref[0] = acc


@functools.partial(jax.jit,
                   static_argnames=("n_blk", "pad_key", "interpret"))
def spec_compose_lanes_pallas(lanes: jnp.ndarray, keys: jnp.ndarray,
                              cand_index: jnp.ndarray, sinks: jnp.ndarray, *,
                              pad_key: int, n_blk: int = 8,
                              interpret: bool = True) -> jnp.ndarray:
    """Block-sequential grid-carry compose of [B, N, K, S] lane-map runs.

    N % n_blk == 0 (pad trailing elements with ``pad_key`` keys — identity).
    Returns the final composition [B, K, S]; semantics of
    ``ref.spec_compose_lanes_ref``.
    """
    b, n, k, s = lanes.shape
    assert n % n_blk == 0, (n, n_blk)
    n_blocks = n // n_blk
    kernel = functools.partial(spec_compose_lanes_kernel,
                               n_blocks=n_blocks, pad_key=pad_key)
    nk, q = cand_index.shape
    return pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, n_blk, k, s), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, n_blk), lambda i, j: (i, j)),
            pl.BlockSpec((nk, q), lambda i, j: (0, 0)),
            pl.BlockSpec((k,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, k, s), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k, s), jnp.int32),
        scratch_shapes=[pltpu.VMEM((k, s), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lanes.astype(jnp.int32), keys.astype(jnp.int32),
      cand_index.astype(jnp.int32), sinks.astype(jnp.int32))


def spec_compose_lanes_tree_kernel(lanes_ref, keys_ref, cidx_ref, sinks_ref,
                                   out_ref, *, pad_key: int):
    """Blelloch-style in-kernel tree reduce of one doc's keyed run.

    lanes_ref [1, N, K, S] with N a power of two; each unrolled level
    combines adjacent pairs (the combine is associative — it backs
    ``lax.associative_scan`` in the jnp lowering), halving N until one
    composed [K, S] map remains.  A combined pair keeps the LEFT key, so
    ``pad_key`` tail padding stays a right identity at every level.
    """
    lanes = lanes_ref[0]                                # [N, K, S]
    keys = keys_ref[0]                                  # [N]
    cidx = cidx_ref[...]
    q = cidx.shape[1]
    sk = sinks_ref[...][:, None]                        # [K, 1]
    n = lanes.shape[0]
    while n > 1:
        half = n // 2
        pairs = lanes.reshape(half, 2, *lanes.shape[1:])
        a, bl = pairs[:, 0], pairs[:, 1]                # [half, K, S]
        kp = keys.reshape(half, 2)
        ak, bk = kp[:, 0], kp[:, 1]                     # [half]
        lane = jnp.take(cidx.reshape(-1), bk[:, None, None] * q + a)
        hit = jnp.take_along_axis(bl, jnp.maximum(lane, 0), axis=-1)
        out = jnp.where(lane < 0, jnp.where(sk >= 0, sk, a), hit)
        lanes = jnp.where((bk == pad_key)[:, None, None], a, out)
        keys = ak
        n = half
    out_ref[0] = lanes[0]


@functools.partial(jax.jit, static_argnames=("pad_key", "interpret"))
def spec_compose_lanes_tree_pallas(lanes: jnp.ndarray, keys: jnp.ndarray,
                                   cand_index: jnp.ndarray,
                                   sinks: jnp.ndarray, *, pad_key: int,
                                   interpret: bool = True) -> jnp.ndarray:
    """Tree-reduce compose of [B, N, K, S] runs; N must be a power of two."""
    b, n, k, s = lanes.shape
    assert n >= 1 and (n & (n - 1)) == 0, n
    kernel = functools.partial(spec_compose_lanes_tree_kernel,
                               pad_key=pad_key)
    nk, q = cand_index.shape
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n, k, s), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((nk, q), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, k, s), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k, s), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(lanes.astype(jnp.int32), keys.astype(jnp.int32),
      cand_index.astype(jnp.int32), sinks.astype(jnp.int32))
