"""Pallas TPU kernel: L-vector composition (paper Eq. 9 reduction leaf).

Composes a block of full state maps left-to-right:
``acc <- m_i[acc]`` — one VMEM gather per map.  This is the leaf reduction of
the hierarchical 2-tier merge (DESIGN.md §2): each device folds its local
chunk maps with this kernel, then the cross-device composition runs over the
``("pod", "data")`` mesh axes in distributed/collectives.py.

The map dimension is sequential (grid "arbitrary"); the carry map lives in
VMEM scratch.  Q rides the lane dimension (pad to 128 on hardware).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

__all__ = ["lvec_compose_kernel", "lvec_compose_pallas"]


def lvec_compose_kernel(maps_ref, out_ref, carry_ref, *, c_blocks: int):
    """maps_ref [c_blk, Q]; carry/out [Q] — fold maps into the carry."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jax.lax.broadcasted_iota(
            jnp.int32, (carry_ref.shape[0],), 0)

    maps = maps_ref[...]
    acc = carry_ref[...]

    def body(i, acc):
        row = jax.lax.dynamic_slice_in_dim(maps, i, 1, axis=0)[0]
        return jnp.take(row, acc, axis=0)

    acc = jax.lax.fori_loop(0, maps.shape[0], body, acc)
    carry_ref[...] = acc

    @pl.when(j == c_blocks - 1)
    def _done():
        out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("c_blk", "interpret"))
def lvec_compose_pallas(maps: jnp.ndarray, *, c_blk: int = 8,
                        interpret: bool = True) -> jnp.ndarray:
    """Pallas-backed equivalent of ``ref.lvec_compose_ref``.

    maps [C, Q] int32 with C % c_blk == 0; returns the composed map [Q].
    """
    c, q = maps.shape
    assert c % c_blk == 0, (c, c_blk)
    c_blocks = c // c_blk
    kernel = functools.partial(lvec_compose_kernel, c_blocks=c_blocks)
    return pl.pallas_call(
        kernel,
        grid=(c_blocks,),
        in_specs=[pl.BlockSpec((c_blk, q), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((q,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((q,), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(maps.astype(jnp.int32))
