"""Public jit'd wrappers around the Pallas kernels.

Each op pads/blocks its inputs to kernel-legal shapes, dispatches to the
Pallas kernel (interpret mode off-TPU, compiled on TPU), and exposes the same
semantics as its ``ref.py`` oracle.  ``spec_match`` additionally implements
the gather-vs-MXU crossover (DESIGN.md §2, beyond-paper): wide speculation
(S approaching Q) on small-Q DFAs is cheaper as one-hot matmuls with
log-depth composition than as an L-deep serial gather chain.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import ref
from .dfa_match import spec_match_merge_pallas, spec_match_pallas
from .flash_attn import flash_attn_pallas
from .lvec_compose import lvec_compose_pallas
from .onehot_match import onehot_block_maps_pallas
from .token_mask import token_mask_pallas

__all__ = ["on_tpu", "spec_match", "spec_match_merge", "lvec_compose",
           "onehot_block_maps", "token_mask", "mxu_profitable", "flash_attn"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (>=1)."""
    best = 1
    for d in range(1, int(math.isqrt(n)) + 1):
        if n % d == 0:
            for cand in (d, n // d):
                if cand <= target and cand > best:
                    best = cand
    return best


def mxu_profitable(q: int, s: int, *, vpu_lanes: int = 1024,
                   mxu_dim: int = 128) -> bool:
    """Roofline crossover for gather vs one-hot-matmul matching.

    Gather path: per symbol, ceil(S / vpu_lanes) VPU gather steps.
    MXU path: per symbol, (Q/128)^2 MXU issue slots, but removes the L-deep
    serial chain (blocks compose in log depth).  Profitable when the DFA is
    small enough that a [Q, Q] matmul costs about one issue slot and the
    speculation is wide (S close to Q) — i.e. gamma ~ 1 DFAs, where the
    paper's lookahead optimization helps least.  Heuristic, tuned in §Perf.
    """
    return q <= mxu_dim * 2 and s >= q // 2 and s > vpu_lanes // mxu_dim


def spec_match(table: jnp.ndarray, chunks: jnp.ndarray,
               init_states: jnp.ndarray, *, use_mxu: bool | None = None,
               interpret: bool | None = None) -> jnp.ndarray:
    """Match [C] chunks x [S] lanes; semantics of ``ref.spec_match_ref``."""
    interpret = _interpret() if interpret is None else interpret
    c, l = chunks.shape
    q = table.shape[0]
    s = init_states.shape[1]
    if use_mxu is None:
        use_mxu = mxu_profitable(q, s)
    if use_mxu:
        l_blk = _pick_block(l, 256)
        def per_chunk(syms):
            maps = onehot_block_maps_pallas(table, syms, l_blk=l_blk,
                                            interpret=interpret)
            full = lvec_compose(maps, interpret=interpret)  # [Q]
            return full
        full_maps = jax.vmap(per_chunk)(chunks)             # [C, Q]
        return jnp.take_along_axis(full_maps, init_states.astype(jnp.int32), axis=1)
    c_blk = _pick_block(c, 8)
    l_blk = _pick_block(l, 512)
    return spec_match_pallas(table, chunks, init_states, c_blk=c_blk,
                             l_blk=l_blk, interpret=interpret)


def spec_match_merge(table: jnp.ndarray, chunks: jnp.ndarray,
                     init_states: jnp.ndarray, lookahead: jnp.ndarray,
                     cand_index: jnp.ndarray, sinks: jnp.ndarray, *,
                     pad_cls: int,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Fused batch classify-stream match + merge; see ``ref.spec_match_merge_ref``.

    One kernel launch covers a whole document bucket: grid over documents,
    Eq. 8 merge fused into the last symbol block, output [B, K] finals only.
    """
    interpret = _interpret() if interpret is None else interpret
    l = chunks.shape[-1]
    l_blk = _pick_block(l, 512)
    return spec_match_merge_pallas(table, chunks, init_states, lookahead,
                                   cand_index, sinks, pad_cls=pad_cls,
                                   l_blk=l_blk, interpret=interpret)


def lvec_compose(maps: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    """Compose [C, Q] maps left-to-right -> [Q]; see ``ref.lvec_compose_ref``."""
    interpret = _interpret() if interpret is None else interpret
    c = maps.shape[0]
    c_blk = _pick_block(c, 8)
    return lvec_compose_pallas(maps, c_blk=c_blk, interpret=interpret)


def onehot_block_maps(table: jnp.ndarray, symbols: jnp.ndarray, *,
                      block_l: int = 256,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Block maps via the MXU formulation; see ``ref.onehot_block_maps_ref``."""
    interpret = _interpret() if interpret is None else interpret
    l = symbols.shape[0]
    block_l = _pick_block(l, block_l)
    return onehot_block_maps_pallas(table, symbols, l_blk=block_l,
                                    interpret=interpret)


def token_mask(states: jnp.ndarray, allowed: jnp.ndarray, logits: jnp.ndarray,
               *, neg: float = -1e30,
               interpret: bool | None = None) -> jnp.ndarray:
    """Fused grammar mask; see ``ref.token_mask_ref``.  Pads V to the tile."""
    interpret = _interpret() if interpret is None else interpret
    b, v = logits.shape
    v_blk = 2048 if v % 2048 == 0 else _pick_block(v, 2048)
    if v_blk < 128 and v >= 128:  # ragged vocab: pad to the tile boundary
        pad = (-v) % 2048
        logits_p = jnp.pad(logits, ((0, 0), (0, pad)))
        allowed_p = jnp.pad(allowed.astype(jnp.uint8), ((0, 0), (0, pad)))
        out = token_mask_pallas(states, allowed_p, logits_p, v_blk=2048,
                                neg=neg, interpret=interpret)
        return out[:, :v]
    return token_mask_pallas(states, allowed, logits, v_blk=v_blk, neg=neg,
                             interpret=interpret)


def flash_attn(q, k, v, *, causal: bool = True, window: int = 0,
               q_blk: int = 256, kv_blk: int = 256,
               interpret: bool | None = None):
    """Fused flash-attention forward; see ``ref.flash_attn_ref``.

    The TPU deployment path for the attention memory bottleneck identified in
    EXPERIMENTS.md §Perf (tiles stay in VMEM).  The XLA path
    (models.attention_core.flash_attention) remains the autodiff/dry-run path.
    """
    interpret = _interpret() if interpret is None else interpret
    t, st = q.shape[1], k.shape[1]
    return flash_attn_pallas(q, k, v, q_blk=_pick_block(t, q_blk),
                             kv_blk=_pick_block(st, kv_blk), causal=causal,
                             window=window, interpret=interpret)
