"""Public jit'd wrappers around the Pallas kernels.

Each op pads/blocks its inputs to kernel-legal shapes, dispatches to the
Pallas kernel (interpret mode off-TPU, compiled on TPU), and exposes the same
semantics as its ``ref.py`` oracle.  ``spec_match`` additionally implements
the gather-vs-MXU crossover (DESIGN.md §2, beyond-paper): wide speculation
(S approaching Q) on small-Q DFAs is cheaper as one-hot matmuls with
log-depth composition than as an L-deep serial gather chain.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import ref
from .dfa_match import (spec_match_merge_lanes_pallas,
                        spec_match_merge_pallas, spec_match_pallas)
from .flash_attn import flash_attn_pallas
from .lvec_compose import (lvec_compose_pallas, spec_compose_lanes_pallas,
                           spec_compose_lanes_tree_pallas)
from .onehot_match import onehot_block_maps_pallas
from .token_mask import token_mask_pallas

__all__ = ["on_tpu", "spec_match", "spec_match_merge",
           "spec_match_merge_lanes", "spec_compose_lanes", "lvec_compose",
           "onehot_block_maps", "token_mask", "mxu_profitable", "flash_attn"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (>=1)."""
    best = 1
    for d in range(1, int(math.isqrt(n)) + 1):
        if n % d == 0:
            for cand in (d, n // d):
                if cand <= target and cand > best:
                    best = cand
    return best


def _pad_to_block(n: int, target: int) -> tuple[int, int]:
    """Block size and padded extent for a length-``n`` axis.

    Returns ``(block, n_padded)`` with ``block = min(n, target)`` and
    ``n_padded`` the next multiple of ``block``.  This replaces the old
    exact-divisor search (``_pick_block``), which degenerated to block size
    1 for prime/odd ``n`` — turning the kernels into symbol-at-a-time grids.
    Callers pad the axis with identity-class symbols (or identity maps), so
    the extra tail is a semantic no-op.
    """
    blk = max(1, min(n, target))
    return blk, n + (-n) % blk


def _identity_padded_table(table: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    """Append an identity class column (state q maps to itself).

    Raw transition tables have no reserved padding class; this returns a
    widened table plus the new class index, giving padded symbols a sound
    no-op transition.  (Packed ``table_pad`` variants already carry an
    identity ``pad_cls`` column, so they never need this.)
    """
    q = table.shape[0]
    ident = jnp.arange(q, dtype=table.dtype)[:, None]
    return jnp.concatenate([table, ident], axis=1), table.shape[1]


def mxu_profitable(q: int, s: int, *, vpu_lanes: int = 1024,
                   mxu_dim: int = 128) -> bool:
    """Roofline crossover for gather vs one-hot-matmul matching.

    Gather path: per symbol, ceil(S / vpu_lanes) VPU gather steps.
    MXU path: per symbol, (Q/128)^2 MXU issue slots, but removes the L-deep
    serial chain (blocks compose in log depth).  Profitable when the DFA is
    small enough that a [Q, Q] matmul costs about one issue slot and the
    speculation is wide (S close to Q) — i.e. gamma ~ 1 DFAs, where the
    paper's lookahead optimization helps least.  Heuristic, tuned in §Perf.
    """
    return q <= mxu_dim * 2 and s >= q // 2 and s > vpu_lanes // mxu_dim


def spec_match(table: jnp.ndarray, chunks: jnp.ndarray,
               init_states: jnp.ndarray, *, use_mxu: bool | None = None,
               interpret: bool | None = None) -> jnp.ndarray:
    """Match [C] chunks x [S] lanes; semantics of ``ref.spec_match_ref``."""
    interpret = _interpret() if interpret is None else interpret
    c, l = chunks.shape
    q = table.shape[0]
    s = init_states.shape[1]
    if use_mxu is None:
        use_mxu = mxu_profitable(q, s)
    if use_mxu:
        l_blk, l_pad = _pad_to_block(l, 256)
        if l_pad != l:
            table, id_cls = _identity_padded_table(table)
            chunks = jnp.pad(chunks, ((0, 0), (0, l_pad - l)),
                             constant_values=id_cls)
        def per_chunk(syms):
            maps = onehot_block_maps_pallas(table, syms, l_blk=l_blk,
                                            interpret=interpret)
            full = lvec_compose(maps, interpret=interpret)  # [Q]
            return full
        full_maps = jax.vmap(per_chunk)(chunks)             # [C, Q]
        return jnp.take_along_axis(full_maps, init_states.astype(jnp.int32), axis=1)
    c_blk, c_pad = _pad_to_block(c, 8)
    l_blk, l_pad = _pad_to_block(l, 512)
    if (c_pad, l_pad) != (c, l):
        table, id_cls = _identity_padded_table(table)
        chunks = jnp.pad(chunks, ((0, c_pad - c), (0, l_pad - l)),
                         constant_values=id_cls)
        init_states = jnp.pad(init_states, ((0, c_pad - c), (0, 0)))
        return spec_match_pallas(table, chunks, init_states, c_blk=c_blk,
                                 l_blk=l_blk, interpret=interpret)[:c]
    return spec_match_pallas(table, chunks, init_states, c_blk=c_blk,
                             l_blk=l_blk, interpret=interpret)


def _pad_merge_chunks(chunks: jnp.ndarray, pad_cls: int,
                      l_blk_target: int) -> tuple[jnp.ndarray, int]:
    """Pad the symbol axis of [B, C, L] chunks with the identity pad class."""
    l = chunks.shape[-1]
    l_blk, l_pad = _pad_to_block(l, l_blk_target)
    if l_pad != l:
        chunks = jnp.pad(chunks, ((0, 0), (0, 0), (0, l_pad - l)),
                         constant_values=pad_cls)
    return chunks, l_blk


def spec_match_merge(table: jnp.ndarray, chunks: jnp.ndarray,
                     init_states: jnp.ndarray, lookahead: jnp.ndarray,
                     cand_index: jnp.ndarray, sinks: jnp.ndarray,
                     absorbing: jnp.ndarray, *, pad_cls: int,
                     pad_key: int | None = None, early_exit: bool = True,
                     l_blk: int = 512, interpret: bool | None = None
                     ) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """Fused batch classify-stream match + merge; see ``ref.spec_match_merge_ref``.

    One kernel launch covers a whole document bucket: grid over documents,
    Eq. 8 merge fused into the last symbol block, output [B, K] finals only.
    ``table`` must be the padded packed table (identity ``pad_cls`` column);
    L is padded with ``pad_cls`` symbols up to the block multiple.
    ``pad_key`` is the merge fold's passthrough boundary key — it equals
    ``pad_cls`` for r=1 lookahead tables (the default) but is ``n_classes**2``
    under r=2 pair keys.  Returns ``(finals [B, K], skipped [B], l_blk)`` —
    per-document symbol blocks skipped by the in-kernel all-absorbed early
    exit, and the block size the lowering needs to convert that count into an
    exit position.
    """
    interpret = _interpret() if interpret is None else interpret
    pad_key = pad_cls if pad_key is None else pad_key
    chunks, l_blk = _pad_merge_chunks(chunks, pad_cls, l_blk)
    out, skipped = spec_match_merge_pallas(
        table, chunks, init_states, lookahead, cand_index, sinks, absorbing,
        pad_cls=pad_key, l_blk=l_blk, early_exit=early_exit,
        interpret=interpret)
    return out, skipped, l_blk


def spec_match_merge_lanes(table: jnp.ndarray, chunks: jnp.ndarray,
                           init_states: jnp.ndarray, lookahead: jnp.ndarray,
                           cand_index: jnp.ndarray, sinks: jnp.ndarray,
                           absorbing: jnp.ndarray, *, pad_cls: int,
                           pad_key: int | None = None,
                           early_exit: bool = True, l_blk: int = 512,
                           interpret: bool | None = None
                           ) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """Fused lane-carrying match + merge; see ``ref.spec_match_merge_lanes_ref``.

    The streaming-tick variant: the full [K, S] candidate lane axis survives
    the in-kernel Eq. 8 fold, so the output is each document's restricted
    transition map rather than a single final per pattern.  Returns
    ``(lanes [B, K, S], skipped [B], l_blk)``.  ``pad_key`` as in
    ``spec_match_merge``.
    """
    interpret = _interpret() if interpret is None else interpret
    pad_key = pad_cls if pad_key is None else pad_key
    chunks, l_blk = _pad_merge_chunks(chunks, pad_cls, l_blk)
    out, skipped = spec_match_merge_lanes_pallas(
        table, chunks, init_states, lookahead, cand_index, sinks, absorbing,
        pad_cls=pad_key, l_blk=l_blk, early_exit=early_exit,
        interpret=interpret)
    k = sinks.shape[0]
    return out.reshape(out.shape[0], k, -1), skipped, l_blk


def spec_compose_lanes(lane_maps: jnp.ndarray, entry_keys: jnp.ndarray,
                       cand_index: jnp.ndarray, sinks: jnp.ndarray, *,
                       pad_key: int, mode: str = "carry", n_blk: int = 8,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Fold [B, N, K, S] keyed lane-map runs in one kernel launch.

    The OOO gap-close compose (``Matcher.compose_lane_maps``): per batch
    element, element 0's lanes seed the carry and elements 1..N-1 fold in
    keyed by ``entry_keys`` (``pad_key`` elements are identities, so ragged
    runs arrive right-padded).  ``mode="carry"`` rides the block-sequential
    grid-carry kernel (N padded to an ``n_blk`` multiple); ``mode="tree"``
    rides the in-kernel Blelloch reduce (N padded to a power of two).
    Returns the final composition [B, K, S]; semantics of
    ``ref.spec_compose_lanes_ref`` == ``spec_merge_lanes_scan_ref[:, -1]``.

    Contract caveat: the combine is associative on *real* candidate lanes
    (the only lanes ``cand_index`` can ever select for a consumer), where
    every lowering is bit-identical.  Pad lanes — filler states a key's
    candidate row repeats to reach width S — pass through the acc-fallback
    and so carry evaluation-order-dependent values: sequential ``"carry"``
    matches the oracle everywhere, ``"tree"`` may differ from it on pad
    lanes only.  No decision path reads a pad lane.
    """
    interpret = _interpret() if interpret is None else interpret
    b, n, k, s = lane_maps.shape
    assert n >= 1, "empty runs are the caller's fast path"
    if mode == "tree":
        n_pad = 1 << max(0, n - 1).bit_length() if n > 1 else 1
        if n_pad != n:
            lane_maps = jnp.pad(lane_maps,
                                ((0, 0), (0, n_pad - n), (0, 0), (0, 0)))
            entry_keys = jnp.pad(entry_keys, ((0, 0), (0, n_pad - n)),
                                 constant_values=pad_key)
        return spec_compose_lanes_tree_pallas(
            lane_maps, entry_keys, cand_index, sinks, pad_key=pad_key,
            interpret=interpret)
    if mode != "carry":
        raise ValueError(f"unknown compose mode {mode!r}")
    n_blk, n_pad = _pad_to_block(n, n_blk)
    if n_pad != n:  # pad_key tail elements compose as identities
        lane_maps = jnp.pad(lane_maps,
                            ((0, 0), (0, n_pad - n), (0, 0), (0, 0)))
        entry_keys = jnp.pad(entry_keys, ((0, 0), (0, n_pad - n)),
                             constant_values=pad_key)
    return spec_compose_lanes_pallas(
        lane_maps, entry_keys, cand_index, sinks, pad_key=pad_key,
        n_blk=n_blk, interpret=interpret)


def lvec_compose(maps: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    """Compose [C, Q] maps left-to-right -> [Q]; see ``ref.lvec_compose_ref``."""
    interpret = _interpret() if interpret is None else interpret
    c, q = maps.shape
    c_blk, c_pad = _pad_to_block(c, 8)
    if c_pad != c:  # identity maps compose as no-ops
        ident = jnp.broadcast_to(jnp.arange(q, dtype=maps.dtype),
                                 (c_pad - c, q))
        maps = jnp.concatenate([maps, ident], axis=0)
    return lvec_compose_pallas(maps, c_blk=c_blk, interpret=interpret)


def onehot_block_maps(table: jnp.ndarray, symbols: jnp.ndarray, *,
                      block_l: int = 256,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Block maps via the MXU formulation; see ``ref.onehot_block_maps_ref``.

    Non-multiple L is padded with an appended identity class, so any extra
    trailing block maps are identity permutations (no-ops under
    composition).
    """
    interpret = _interpret() if interpret is None else interpret
    l = symbols.shape[0]
    l_blk, l_pad = _pad_to_block(l, block_l)
    if l_pad != l:
        table, id_cls = _identity_padded_table(table)
        symbols = jnp.pad(symbols, (0, l_pad - l), constant_values=id_cls)
    return onehot_block_maps_pallas(table, symbols, l_blk=l_blk,
                                    interpret=interpret)


def token_mask(states: jnp.ndarray, allowed: jnp.ndarray, logits: jnp.ndarray,
               *, neg: float = -1e30,
               interpret: bool | None = None) -> jnp.ndarray:
    """Fused grammar mask; see ``ref.token_mask_ref``.  Pads V to the tile."""
    interpret = _interpret() if interpret is None else interpret
    b, v = logits.shape
    v_blk, v_pad = _pad_to_block(v, 2048)
    if v_pad != v:  # ragged vocab: pad to the tile boundary (masked -> neg)
        logits_p = jnp.pad(logits, ((0, 0), (0, v_pad - v)))
        allowed_p = jnp.pad(allowed.astype(jnp.uint8),
                            ((0, 0), (0, v_pad - v)))
        out = token_mask_pallas(states, allowed_p, logits_p, v_blk=v_blk,
                                neg=neg, interpret=interpret)
        return out[:, :v]
    return token_mask_pallas(states, allowed, logits, v_blk=v_blk, neg=neg,
                             interpret=interpret)


def flash_attn(q, k, v, *, causal: bool = True, window: int = 0,
               q_blk: int = 256, kv_blk: int = 256,
               interpret: bool | None = None):
    """Fused flash-attention forward; see ``ref.flash_attn_ref``.

    The TPU deployment path for the attention memory bottleneck identified in
    EXPERIMENTS.md §Perf (tiles stay in VMEM).  The XLA path
    (models.attention_core.flash_attention) remains the autodiff/dry-run path.
    """
    interpret = _interpret() if interpret is None else interpret
    t, st = q.shape[1], k.shape[1]
    return flash_attn_pallas(q, k, v, q_blk=_pick_block(t, q_blk),
                             kv_blk=_pick_block(st, kv_blk), causal=causal,
                             window=window, interpret=interpret)
