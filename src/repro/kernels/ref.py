"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the exact semantics its kernel must reproduce;
tests sweep shapes/dtypes and assert exact equality (all outputs are integer /
boolean, so tolerance is zero).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["spec_match_ref", "spec_merge_ref", "spec_merge_lanes_ref",
           "spec_match_merge_ref", "spec_match_merge_lanes_ref",
           "cursor_merge_ref", "spec_merge_lanes_scan_ref",
           "classify_ref", "classify_pad_ref",
           "lvec_compose_ref", "onehot_block_maps_ref", "token_mask_ref"]


def classify_ref(byte_to_class: np.ndarray, data: bytes | np.ndarray) -> np.ndarray:
    """Host-side numpy byte -> class classification (paper ``IBase`` gather).

    This was the production path before classification moved on-device (the
    jitted per-bucket call now folds the gather in); it is kept here as the
    reference oracle for the fused path.
    """
    arr = (np.frombuffer(data, dtype=np.uint8)
           if isinstance(data, (bytes, bytearray)) else np.asarray(data))
    return np.asarray(byte_to_class)[arr.astype(np.int64)].astype(np.int32)


def classify_pad_ref(byte_to_class: np.ndarray, bytes_buf: np.ndarray,
                     lengths: np.ndarray, pad_cls: int) -> np.ndarray:
    """Batched padded classification: positions >= length become ``pad_cls``.

    bytes_buf [B, W] uint8 (pad bytes arbitrary); lengths [B]; returns
    [B, W] int32 class ids — the semantics the executors' on-device classify
    must reproduce exactly.
    """
    cls = np.asarray(byte_to_class)[np.asarray(bytes_buf).astype(np.int64)]
    pos = np.arange(cls.shape[1])[None, :]
    return np.where(pos < np.asarray(lengths)[:, None], cls,
                    pad_cls).astype(np.int32)


def spec_match_ref(table: jnp.ndarray, chunks: jnp.ndarray,
                   init_states: jnp.ndarray) -> jnp.ndarray:
    """Match [C] chunks x [S] speculative lanes; table [Q, n_cls] int32.

    chunks [C, L] int32 class ids; init_states [C, S] int32.
    Returns [C, S] final states — the semantics of paper Listing 2.
    """

    def step(states, cls_row):  # states [C, S], cls_row [C]
        return table[states, cls_row[:, None]], None

    final, _ = jax.lax.scan(step, init_states.astype(jnp.int32), chunks.T)
    return final


def spec_match_merge_ref(table: jnp.ndarray, chunks: jnp.ndarray,
                         init_states: jnp.ndarray, lookahead: jnp.ndarray,
                         cand_index: jnp.ndarray, sinks: jnp.ndarray, *,
                         pad_cls: int) -> jnp.ndarray:
    """Batched fused classify-stream match + Eq. 8 merge over packed patterns.

    table       [Q_total, n_cls_pad] int32 packed transition table whose last
                column (``pad_cls``) is the identity transition used for
                document padding.
    chunks      [B, C, L] int32 joint class ids (uniform chunking, padding is
                a suffix of the document).
    init_states [B, C, K * S] int32 candidate initial packed states; chunk 0's
                lanes all hold the pattern starts (lane layout [K, S]).
    lookahead   [B, C] int32 reverse-lookahead class per chunk (entry 0 is
                ignored — chunk 0 is exact from the start states).
    cand_index  [n_cls_pad, Q_total] int32 lane of a packed state inside its
                pattern's candidate row, -1 if absent (row ``pad_cls`` unused).
    sinks       [K] int32 packed sink per pattern (-1 if none).

    Returns [B, K] final packed states per document per pattern.  Merge rules:
    a ``pad_cls`` lookahead means the entire next chunk is padding (identity),
    so the carried state passes through; a carried state missing from the
    candidate row is the pattern's (absorbing) sink.
    """
    b, c, l = chunks.shape
    k = sinks.shape[0]
    s = init_states.shape[-1] // k

    lvecs, _ = jax.lax.scan(
        lambda st, cls_row: (table[st, cls_row[:, None]], None),
        init_states.reshape(b * c, k * s).astype(jnp.int32),
        chunks.reshape(b * c, l).T)
    return spec_merge_ref(lvecs.reshape(b, c, k, s), lookahead, cand_index,
                          sinks, pad_cls=pad_cls)


def spec_match_merge_lanes_ref(table: jnp.ndarray, chunks: jnp.ndarray,
                               init_states: jnp.ndarray,
                               lookahead: jnp.ndarray,
                               cand_index: jnp.ndarray, sinks: jnp.ndarray, *,
                               pad_cls: int) -> jnp.ndarray:
    """Lane-carrying twin of ``spec_match_merge_ref`` (the streaming tick).

    Same chunk scan, but chunk 0's lanes are the Eq. 11 candidate entries of
    a boundary key (not an exact state), and the Eq. 8 fold keeps the full
    ``[K, S]`` carry (``spec_merge_lanes_ref`` semantics) — the output
    ``[B, K * S]`` is each document's restricted transition map, ready to
    compose with a streaming cursor (``cursor_merge_ref``).  This is the
    oracle of the fused lanes kernel (``dfa_match
    .spec_match_merge_lanes_pallas``).
    """
    b, c, l = chunks.shape
    k = sinks.shape[0]
    s = init_states.shape[-1] // k

    lvecs, _ = jax.lax.scan(
        lambda st, cls_row: (table[st, cls_row[:, None]], None),
        init_states.reshape(b * c, k * s).astype(jnp.int32),
        chunks.reshape(b * c, l).T)
    out = spec_merge_lanes_ref(lvecs.reshape(b, c, k, s), lookahead,
                               cand_index, sinks, pad_cls=pad_cls)
    return out.reshape(b, k * s)


def _merge_fold(start: jnp.ndarray, lvecs: jnp.ndarray, lookahead: jnp.ndarray,
                exact: jnp.ndarray, cand_index: jnp.ndarray,
                sinks: jnp.ndarray, *, pad_cls: int,
                exact_lane0: bool) -> jnp.ndarray:
    """The one Eq. 8 fold shared by every merge entry point.

    ``start [K, Sc]`` is the carried lane set (``Sc == 1`` for an exact
    carry); each later chunk maps every carried state through its candidate
    lanes (``lvecs [C-1, K, S]``, ``lookahead``/``exact`` ``[C-1]``).  A
    carried state missing from the candidate row is the pattern's absorbing
    sink; a ``pad_cls`` lookahead means the whole chunk is padding (identity).
    ``exact_lane0`` picks the rule for chunks matched exactly from the entry
    states: their lanes all agree, so an exact carry reads lane 0, while a
    candidate-keyed carry (``Sc == S``) composes lane-for-lane (identity on
    the lane axis).
    """

    def step(st, xs):  # st [K, Sc]
        lv_i, la_i, ex_i = xs
        lane = cand_index[la_i, st]                              # [K, Sc]
        hit = jnp.take_along_axis(lv_i, jnp.maximum(lane, 0), axis=1)
        sk = sinks[:, None]
        nxt = jnp.where(lane < 0, jnp.where(sk >= 0, sk, st), hit)
        nxt = jnp.where(la_i == pad_cls, st, nxt)
        ex_val = (jnp.broadcast_to(lv_i[:, :1], st.shape) if exact_lane0
                  else lv_i)
        nxt = jnp.where(ex_i, ex_val, nxt)
        return nxt.astype(jnp.int32), None

    out, _ = jax.lax.scan(step, start.astype(jnp.int32),
                          (lvecs, lookahead, exact))
    return out


def spec_merge_ref(lvecs: jnp.ndarray, lookahead: jnp.ndarray,
                   cand_index: jnp.ndarray, sinks: jnp.ndarray, *,
                   pad_cls: int, exact: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq. 8 merge of batched per-chunk lane states (the second half of
    ``spec_match_merge_ref``, factored so every executor — the early-exit
    segmented scan, the mesh-sharded backend — shares one merge definition).

    lvecs [B, C, K, S]; lookahead [B, C]; returns [B, K] final packed states.
    ``exact`` [C] optionally marks chunks matched exactly from the start
    states (all their lanes agree; lane 0 carries the result).  Chunk 0 is
    always exact; flags for later chunks arise only from degenerate
    zero-length leading chunks in weighted layouts.
    """
    if exact is None:
        exact = jnp.zeros((lvecs.shape[1],), bool)

    def merge_doc(lv, la):  # lv [C, K, S], la [C]
        return _merge_fold(lv[0, :, :1], lv[1:], la[1:], exact[1:],
                           cand_index, sinks, pad_cls=pad_cls,
                           exact_lane0=True)[:, 0]

    return jax.vmap(merge_doc)(lvecs, lookahead.astype(jnp.int32))


def spec_merge_lanes_ref(lvecs: jnp.ndarray, lookahead: jnp.ndarray,
                         cand_index: jnp.ndarray, sinks: jnp.ndarray, *,
                         pad_cls: int,
                         exact: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq. 8 merge carrying the *full* candidate lane axis: [B, C, K, S] per-
    chunk lane states fold to [B, K, S] — chunk 0's lanes are candidate
    entries of a boundary class (not an exact state), so the fold keeps one
    carried state per entry lane.  This is the segment-map half of the
    streaming device merge: the result is the segment's restricted transition
    map (``streaming.cursor.segment_result`` computed on device, batched).
    ``exact`` chunks (stream position 0) compose lane-for-lane — their lanes
    were seeded from the same candidate row as the carry.
    """
    if exact is None:
        exact = jnp.zeros((lvecs.shape[1],), bool)

    def merge_doc(lv, la):  # lv [C, K, S], la [C]
        return _merge_fold(lv[0], lv[1:], la[1:], exact[1:], cand_index,
                           sinks, pad_cls=pad_cls, exact_lane0=False)

    return jax.vmap(merge_doc)(lvecs, lookahead.astype(jnp.int32))


def cursor_merge_ref(cursor_lanes: np.ndarray, seg_lanes: np.ndarray,
                     entry_cls: np.ndarray, cand_index: np.ndarray,
                     sinks: np.ndarray, *, pad_cls: int) -> np.ndarray:
    """Batched Eq. 8 cursor x segment composition — the numpy host reference
    of the streaming device merge (``Matcher.advance_cursors``).

    ``cursor_lanes [B, K, Sc]`` holds each stream's prefix exit states per
    entry lane (``Sc == 1`` for collapsed exact cursors); ``seg_lanes
    [B, K, S]`` is each stream's next segment matched independently, keyed by
    the Eq. 11 candidates of ``entry_cls [B]`` — the class of the byte just
    before the segment (the cursor's ``last_class``).  For every carried
    state ``q``: ``cand_index[entry_cls, q]`` selects the segment lane that
    assumed entry ``q``; a missing ``q`` is the pattern's absorbing sink (a
    prefix exit state reached by a byte of class ``c`` is in ``I_c`` unless
    it is the sink — the paper's exactness argument); rows whose
    ``entry_cls == pad_cls`` pass through unchanged (zero-byte segments).

    This is ``streaming.cursor.merge`` vectorized over streams; the device
    lowering in ``core.engine.executors`` must be bit-identical
    (tests/test_device_merge.py).
    """
    q = np.asarray(cursor_lanes, np.int32)
    ec = np.asarray(entry_cls, np.int32)
    cand_index = np.asarray(cand_index)
    # clamp the row index so an unpadded [n_cls, Q] table also works: the
    # pad_cls passthrough below overrides whatever the clamped gather reads
    safe_ec = np.minimum(ec, np.int32(cand_index.shape[0] - 1))
    lane = cand_index[safe_ec[:, None, None], q]                # [B, K, Sc]
    hit = np.take_along_axis(np.asarray(seg_lanes, np.int32),
                             np.maximum(lane, 0), axis=2)
    sk = np.asarray(sinks, np.int32)[None, :, None]
    out = np.where(lane < 0, np.where(sk >= 0, sk, q), hit)
    out = np.where((ec == pad_cls)[:, None, None], q, out)
    return out.astype(np.int32)


def spec_merge_lanes_scan_ref(lane_maps: np.ndarray, entry_keys: np.ndarray,
                              cand_index: np.ndarray, sinks: np.ndarray,
                              *, pad_cls: int) -> np.ndarray:
    """Sequential-fold oracle of the associative lane-map scan.

    ``lane_maps [B, N, K, S]`` holds, per batch row, a run of candidate-keyed
    segment transition maps (leftmost first); ``entry_keys [B, N]`` the
    boundary key selecting each map's Eq. 11 candidate entry row.  Returns
    all prefixes ``out[:, i] = m_0 ; ... ; m_i`` by repeated
    :func:`cursor_merge_ref` — the semantics ``core.lvector
    .merge_scan_lanes_jnp`` must reproduce in log depth (keys equal to
    ``pad_cls`` compose as the identity; element 0's key is never read).
    """
    lanes = np.asarray(lane_maps, np.int32)
    keys = np.asarray(entry_keys, np.int32)
    out = np.empty_like(lanes)
    if lanes.shape[1] == 0:
        return out
    out[:, 0] = lanes[:, 0]
    for i in range(1, lanes.shape[1]):
        out[:, i] = cursor_merge_ref(out[:, i - 1], lanes[:, i], keys[:, i],
                                     cand_index, sinks, pad_cls=pad_cls)
    return out


def spec_compose_lanes_ref(lane_maps: np.ndarray, entry_keys: np.ndarray,
                           cand_index: np.ndarray, sinks: np.ndarray,
                           *, pad_cls: int) -> np.ndarray:
    """Final composition of each keyed lane-map run: the gap-close fold.

    The oracle for the ``spec_compose_lanes`` Pallas kernel and the
    ``("compose_kernel", N)`` executor lowering — the last prefix of
    :func:`spec_merge_lanes_scan_ref` (``Matcher.compose_lane_maps``
    consumes only the whole-run composition).  Returns [B, K, S].
    """
    return spec_merge_lanes_scan_ref(lane_maps, entry_keys, cand_index,
                                     sinks, pad_cls=pad_cls)[:, -1]


def lvec_compose_ref(maps: jnp.ndarray) -> jnp.ndarray:
    """Left-to-right composition of full maps: out = m_{C-1} o ... o m_0.

    maps [C, Q] int32; out [Q] with out[q] = delta*(q, chunk_0 ... chunk_{C-1}).
    """

    def step(acc, m):
        return m[acc], None

    acc0 = jnp.arange(maps.shape[1], dtype=jnp.int32)
    out, _ = jax.lax.scan(step, acc0, maps)
    return out


def onehot_block_maps_ref(table: jnp.ndarray, symbols: jnp.ndarray,
                          block_l: int) -> jnp.ndarray:
    """Per-block transition maps for the MXU formulation.

    symbols [L] (L divisible by block_l).  Block b's map is
    delta*(q, symbols[b*block_l:(b+1)*block_l]) for every q — returned as
    int32 [L // block_l, Q].
    """
    q = table.shape[0]
    blocks = symbols.reshape(-1, block_l)

    def one_block(syms):
        def step(acc, s):
            return table[acc, s], None
        out, _ = jax.lax.scan(step, jnp.arange(q, dtype=jnp.int32), syms)
        return out

    return jax.vmap(one_block)(blocks)


def token_mask_ref(states: jnp.ndarray, allowed: jnp.ndarray,
                   logits: jnp.ndarray, neg: float = -1e30) -> jnp.ndarray:
    """Constrained-decoding logit masking.

    states [B] int32 DFA states; allowed [Q, V] bool; logits [B, V] float.
    Returns logits with disallowed tokens set to ``neg``.
    """
    mask = allowed[states]  # [B, V]
    return jnp.where(mask, logits, jnp.asarray(neg, logits.dtype))


def flash_attn_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Oracle for the fused flash-attention kernel: q/k/v [BH, T|S, D]."""
    d = q.shape[-1]
    logits = jnp.einsum("htd,hsd->hts", q, k).astype(jnp.float32) * d ** -0.5
    t, s = q.shape[1], k.shape[1]
    q_pos = jnp.arange(t)[:, None]
    k_pos = jnp.arange(s)[None, :]
    ok = jnp.ones((t, s), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    logits = jnp.where(ok[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("hts,hsd->htd", probs, v)
