"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the exact semantics its kernel must reproduce;
tests sweep shapes/dtypes and assert exact equality (all outputs are integer /
boolean, so tolerance is zero).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["spec_match_ref", "lvec_compose_ref", "onehot_block_maps_ref",
           "token_mask_ref"]


def spec_match_ref(table: jnp.ndarray, chunks: jnp.ndarray,
                   init_states: jnp.ndarray) -> jnp.ndarray:
    """Match [C] chunks x [S] speculative lanes; table [Q, n_cls] int32.

    chunks [C, L] int32 class ids; init_states [C, S] int32.
    Returns [C, S] final states — the semantics of paper Listing 2.
    """

    def step(states, cls_row):  # states [C, S], cls_row [C]
        return table[states, cls_row[:, None]], None

    final, _ = jax.lax.scan(step, init_states.astype(jnp.int32), chunks.T)
    return final


def lvec_compose_ref(maps: jnp.ndarray) -> jnp.ndarray:
    """Left-to-right composition of full maps: out = m_{C-1} o ... o m_0.

    maps [C, Q] int32; out [Q] with out[q] = delta*(q, chunk_0 ... chunk_{C-1}).
    """

    def step(acc, m):
        return m[acc], None

    acc0 = jnp.arange(maps.shape[1], dtype=jnp.int32)
    out, _ = jax.lax.scan(step, acc0, maps)
    return out


def onehot_block_maps_ref(table: jnp.ndarray, symbols: jnp.ndarray,
                          block_l: int) -> jnp.ndarray:
    """Per-block transition maps for the MXU formulation.

    symbols [L] (L divisible by block_l).  Block b's map is
    delta*(q, symbols[b*block_l:(b+1)*block_l]) for every q — returned as
    int32 [L // block_l, Q].
    """
    q = table.shape[0]
    blocks = symbols.reshape(-1, block_l)

    def one_block(syms):
        def step(acc, s):
            return table[acc, s], None
        out, _ = jax.lax.scan(step, jnp.arange(q, dtype=jnp.int32), syms)
        return out

    return jax.vmap(one_block)(blocks)


def token_mask_ref(states: jnp.ndarray, allowed: jnp.ndarray,
                   logits: jnp.ndarray, neg: float = -1e30) -> jnp.ndarray:
    """Constrained-decoding logit masking.

    states [B] int32 DFA states; allowed [Q, V] bool; logits [B, V] float.
    Returns logits with disallowed tokens set to ``neg``.
    """
    mask = allowed[states]  # [B, V]
    return jnp.where(mask, logits, jnp.asarray(neg, logits.dtype))


def flash_attn_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Oracle for the fused flash-attention kernel: q/k/v [BH, T|S, D]."""
    d = q.shape[-1]
    logits = jnp.einsum("htd,hsd->hts", q, k).astype(jnp.float32) * d ** -0.5
    t, s = q.shape[1], k.shape[1]
    q_pos = jnp.arange(t)[:, None]
    k_pos = jnp.arange(s)[None, :]
    ok = jnp.ones((t, s), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    logits = jnp.where(ok[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("hts,hsd->htd", probs, v)
