"""Pallas TPU kernel: fused flash attention forward (causal/windowed, GQA).

§Perf identified the XLA-lowered attention tiles as the dominant memory term
of every train/prefill cell: XLA materializes each [q_blk, kv_blk] logit/prob
tile in HBM between fusions.  This kernel is the deployment fix — the online-
softmax recurrence runs entirely in VMEM (m/l/acc scratch carried across the
kv grid dimension), so HBM traffic drops to Q/K/V reads + O output writes:
arithmetic intensity rises from O(1) to O(block) — the same HBM->VMEM
blocking the paper's AVX2 gather loop applies to the DFA table.

Layout: heads are flattened into the leading grid dim (GQA expansion happens
in ops.py by indexing, not copying); grid = (BH, nq, ns) with the kv dim
sequential ("arbitrary") and scratch carries per (head, q-block).  Causal /
window masks are applied in-tile from program ids; fully-dead tiles are
skipped with ``pl.when`` (the valid-pair pruning of §Perf iteration 1b,
expressed at kernel level).

Forward-only: the backward runs the XLA path (remat recomputes through this
kernel on TPU).  Validated against models.attention_core.direct_attention in
interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

__all__ = ["flash_attn_kernel", "flash_attn_pallas"]

NEG = -1e30


def flash_attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      q_blk: int, kv_blk: int, ns: int, causal: bool,
                      window: int, scale: float):
    """One (head, q-block, kv-block) grid step.

    q_ref [1, q_blk, D]; k_ref/v_ref [1, kv_blk, D]; o_ref [1, q_blk, D];
    scratch: m/l [q_blk], acc [q_blk, D] — carried across the kv dimension.
    """
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = i * q_blk
    k_lo = j * kv_blk
    # static-shape positions; block-level liveness decided per step
    live = True
    if causal:
        live = k_lo <= q_lo + q_blk - 1
    if window > 0:
        live = jnp.logical_and(live, k_lo + kv_blk - 1 > q_lo - window)

    @pl.when(live)
    def _tile():
        q = q_ref[0]                       # [q_blk, D]
        k = k_ref[0]                       # [kv_blk, D]
        v = v_ref[0]
        logit = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
        ok = jnp.ones((q_blk, kv_blk), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window > 0:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        logit = jnp.where(ok, logit, NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logit.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logit - m_new[:, None]).astype(q.dtype)   # bf16 tile, VMEM
        l_ref[...] = l_ref[...] * alpha + p.astype(jnp.float32).sum(axis=-1)
        pv = jnp.dot(p, v, preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(j == ns - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("q_blk", "kv_blk", "causal",
                                             "window", "interpret"))
def flash_attn_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      q_blk: int = 256, kv_blk: int = 256,
                      causal: bool = True, window: int = 0,
                      interpret: bool = True) -> jnp.ndarray:
    """q [BH, T, D]; k, v [BH, S, D] -> out [BH, T, D].

    BH = batch x heads (GQA callers index k/v per group before the call).
    T % q_blk == 0 and S % kv_blk == 0 (ops-level padding as usual).
    """
    bh, t, d = q.shape
    s = k.shape[1]
    q_blk = min(q_blk, t)
    kv_blk = min(kv_blk, s)
    assert t % q_blk == 0 and s % kv_blk == 0, (t, s, q_blk, kv_blk)
    nq, ns = t // q_blk, s // kv_blk
    kernel = functools.partial(
        flash_attn_kernel, q_blk=q_blk, kv_blk=kv_blk, ns=ns, causal=causal,
        window=window, scale=d ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, ns),
        in_specs=[
            pl.BlockSpec((1, q_blk, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, kv_blk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, kv_blk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
