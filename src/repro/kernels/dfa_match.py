"""Pallas TPU kernel: vectorized speculative DFA chunk matching.

TPU adaptation of the paper's AVX2 gather loop (Listing 2).  Design:

  * The flattened transition table (the paper's ``SBase``, with next-state
    values *pre-scaled* by n_classes so the hot loop is add+gather, Listing 1)
    is pinned whole in **VMEM** — grammar/scan DFAs are small (Q·n_cls·4B;
    1288 states x 32 classes = 165 KiB, far under the ~16 MiB working-set
    budget in DESIGN.md §2.1).
  * Lanes = chunks x speculative candidate states.  AVX2 gave the paper 8
    lanes; one TPU core's VPU is 8x128 int32 lanes, so a (8, 128) block of
    (chunk, state-lane) pairs advances per step.
  * The symbol dimension is a sequential recurrence, so it rides the grid's
    trailing ("arbitrary") dimension with the state carried in VMEM scratch;
    chunk blocks ride the leading ("parallel") dimension.

Grid: ``(C / c_blk, L / l_blk)``; BlockSpecs stream symbol blocks HBM->VMEM
while the carry stays resident.  On real Mosaic the in-kernel ``jnp.take``
lowers to the TPU dynamic-gather unit; correctness is validated against
``ref.spec_match_ref`` in interpret mode (this container is CPU-only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

__all__ = ["spec_match_kernel", "spec_match_pallas",
           "spec_match_merge_kernel", "spec_match_merge_pallas",
           "spec_match_merge_lanes_kernel", "spec_match_merge_lanes_pallas"]


def spec_match_kernel(table_ref, chunks_ref, init_ref, out_ref, carry_ref, *,
                      n_classes: int, l_blocks: int):
    """One (chunk-block, symbol-block) grid step.

    table_ref : [Q * n_classes] int32, pre-scaled flat table (VMEM, whole)
    chunks_ref: [c_blk, l_blk] int32 symbol classes for this block
    init_ref  : [c_blk, S] int32 candidate initial states
    out_ref   : [c_blk, S] int32 final states (written on the last l-block)
    carry_ref : [c_blk, S] int32 VMEM scratch carrying pre-scaled states
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = init_ref[...] * n_classes

    table = table_ref[...]            # resident VMEM vector [Q * n_classes]
    syms = chunks_ref[...]            # [c_blk, l_blk]
    states = carry_ref[...]           # [c_blk, S] pre-scaled

    def body(l, states):
        # idx = state * n_classes + class  (the paper's 1-D SBase lookup);
        # values are already pre-scaled so no multiply in the loop.
        idx = states + jax.lax.dynamic_slice_in_dim(syms, l, 1, axis=1)
        return jnp.take(table, idx, axis=0)

    states = jax.lax.fori_loop(0, syms.shape[1], body, states)
    carry_ref[...] = states

    @pl.when(j == l_blocks - 1)
    def _done():
        out_ref[...] = carry_ref[...] // n_classes


@functools.partial(jax.jit, static_argnames=("c_blk", "l_blk", "interpret"))
def spec_match_pallas(table: jnp.ndarray, chunks: jnp.ndarray,
                      init_states: jnp.ndarray, *, c_blk: int = 8,
                      l_blk: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Pallas-backed equivalent of ``ref.spec_match_ref``.

    table [Q, n_cls] int32; chunks [C, L]; init_states [C, S].
    C must divide by c_blk and L by l_blk (ops.py pads/chooses blocks).
    """
    q, n_cls = table.shape
    c, l = chunks.shape
    s = init_states.shape[1]
    assert c % c_blk == 0 and l % l_blk == 0, (c, l, c_blk, l_blk)
    flat = (table.astype(jnp.int32) * n_cls).reshape(-1)  # pre-scaled SBase
    l_blocks = l // l_blk

    kernel = functools.partial(spec_match_kernel, n_classes=n_cls,
                               l_blocks=l_blocks)
    return pl.pallas_call(
        kernel,
        grid=(c // c_blk, l_blocks),
        in_specs=[
            pl.BlockSpec((q * n_cls,), lambda i, j: (0,)),       # whole table
            pl.BlockSpec((c_blk, l_blk), lambda i, j: (i, j)),   # symbol block
            pl.BlockSpec((c_blk, s), lambda i, j: (i, 0)),       # init states
        ],
        out_specs=pl.BlockSpec((c_blk, s), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, s), jnp.int32),
        scratch_shapes=[pltpu.VMEM((c_blk, s), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(flat, chunks.astype(jnp.int32), init_states.astype(jnp.int32))


# --------------------------------------------------------------------------
# Batched multi-pattern kernel: grid over documents, merge fused in-kernel
# --------------------------------------------------------------------------

def _scan_block_with_exit(table_ref, chunks_ref, init_ref, absorb_ref,
                          skip_ref, carry_ref, done_ref, *, n_cls_pad: int,
                          early_exit: bool):
    """Shared symbol-block scan of the fused merge kernels, with the
    in-flight all-absorbed early exit.

    The per-document done flag lives in SMEM scratch and is read *before*
    the block body, so the block that discovers the condition still runs and
    every later grid step along the "arbitrary" dimension is a no-op (the
    skipped-step counter accumulates into ``skip_ref``).  Freezing the carry
    is bit-exact: absorbing states self-loop on every class including the
    identity pad column, so the remaining symbol blocks could not have moved
    any lane.  The probe itself is one [C, K*S] gather + reduction per block
    — amortized over ``l_blk`` symbol steps.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = init_ref[0] * n_cls_pad
        skip_ref[0, 0] = 0
        done_ref[0] = 0

    live = done_ref[0] == 0

    @pl.when(live)
    def _scan():
        table = table_ref[...]
        syms = chunks_ref[0]          # [C, l_blk]
        states = carry_ref[...]       # [C, K * S] pre-scaled

        def body(l, states):
            # idx = state * n_cls_pad + class (the paper's 1-D SBase lookup)
            idx = states + jax.lax.dynamic_slice_in_dim(syms, l, 1, axis=1)
            return jnp.take(table, idx, axis=0)

        states = jax.lax.fori_loop(0, syms.shape[1], body, states)
        carry_ref[...] = states
        if early_exit:
            absorbed = jnp.take(absorb_ref[...], states // n_cls_pad, axis=0)
            done_ref[0] = absorbed.all().astype(jnp.int32)

    @pl.when(jnp.logical_not(live))
    def _skip():
        skip_ref[0, 0] = skip_ref[0, 0] + 1


def spec_match_merge_kernel(table_ref, chunks_ref, init_ref, la_ref, cidx_ref,
                            sinks_ref, absorb_ref, out_ref, skip_ref,
                            carry_ref, done_ref, *, n_cls_pad: int,
                            l_blocks: int, n_patterns: int, pad_cls: int,
                            early_exit: bool):
    """One (document, symbol-block) grid step of the fused batch pipeline.

    table_ref : [Q_total * n_cls_pad] int32 pre-scaled flat packed table (VMEM)
    chunks_ref: [1, C, l_blk] int32 joint classes for this doc/symbol block
    init_ref  : [1, C, K * S] int32 candidate initial packed states.  Chunk
                0's lanes are *exact* entry states and its merge reads lane
                0 — the pattern starts for whole documents, or a streaming
                cursor's resumed states (the ``LanePlan`` entry-seed stage,
                ``engine.executors.LaneExecutor._seed_chunk0``; the kernel
                is agnostic to which, by construction).
    la_ref    : [1, C] int32 per-chunk boundary key (entry 0 unused)
    cidx_ref  : [n_keys_pad, Q_total] int32 candidate-lane index (VMEM, whole)
    sinks_ref : [K] int32 packed sink per pattern (-1 if none)
    absorb_ref: [Q_total] int32 absorbing-state flags (the early-exit probe)
    out_ref   : [1, K] int32 final packed state per pattern (last block only)
    skip_ref  : [1, 1] int32 symbol blocks skipped by the in-kernel exit
    carry_ref : [C, K * S] int32 VMEM scratch carrying pre-scaled states
    done_ref  : [1] int32 SMEM scratch — the per-document all-absorbed flag

    The Eq. 8 fold over chunks runs *inside* the kernel on the final symbol
    block, so one grid pass emits the per-document answer — no host-driven
    ``lax.scan`` over chunk L-vectors and no intermediate [B, C, S] output.
    With ``early_exit`` the symbol-block body is guarded on the SMEM done
    flag (``_scan_block_with_exit``): once every lane of the document sits
    in an absorbing state, the remaining grid steps along the "arbitrary"
    dimension only bump the skipped counter.  The merge still runs on the
    last block, reading the frozen (exact) carry.
    """
    _scan_block_with_exit(table_ref, chunks_ref, init_ref, absorb_ref,
                          skip_ref, carry_ref, done_ref, n_cls_pad=n_cls_pad,
                          early_exit=early_exit)

    @pl.when(pl.program_id(1) == l_blocks - 1)
    def _merge():
        states = carry_ref[...]
        c = states.shape[0]
        lv = (states // n_cls_pad).reshape(c, n_patterns, -1)
        la = la_ref[0]
        cidx = cidx_ref[...]
        sinks = sinks_ref[...]

        def fold(i, s):  # s [K] packed states
            la_i = jax.lax.dynamic_index_in_dim(la, i, 0, keepdims=False)
            lv_i = jax.lax.dynamic_index_in_dim(lv, i, 0, keepdims=False)
            lane = jnp.take(jnp.take(cidx, la_i, axis=0), s)
            hit = jnp.take_along_axis(
                lv_i, jnp.maximum(lane, 0)[:, None], axis=1)[:, 0]
            nxt = jnp.where(lane < 0, jnp.where(sinks >= 0, sinks, s), hit)
            nxt = jnp.where(la_i == pad_cls, s, nxt)
            return nxt.astype(jnp.int32)

        out_ref[0, :] = jax.lax.fori_loop(1, c, fold, lv[0, :, 0])


def spec_match_merge_lanes_kernel(table_ref, chunks_ref, init_ref, la_ref,
                                  cidx_ref, sinks_ref, absorb_ref, out_ref,
                                  skip_ref, carry_ref, done_ref, *,
                                  n_cls_pad: int, l_blocks: int,
                                  n_patterns: int, pad_cls: int,
                                  early_exit: bool):
    """Lane-carrying variant of ``spec_match_merge_kernel`` (streaming tick).

    Same operands and scan, but chunk 0's lanes are the Eq. 11 candidate
    entries of each document's boundary key — not an exact state — and the
    in-kernel Eq. 8 fold keeps the full ``[K, S]`` carry, composing later
    chunks lane-for-lane (``ref.spec_merge_lanes_ref`` semantics).
    ``out_ref [1, K * S]`` is the document's restricted transition map; the
    lowering composes it with the caller's cursor lanes in one tiny jnp op
    (``LaneExecutor._compose_cursor``).  This is what puts
    ``Matcher.advance_cursors`` — the streaming hot path — on the fused
    kernel instead of staged jnp.
    """
    _scan_block_with_exit(table_ref, chunks_ref, init_ref, absorb_ref,
                          skip_ref, carry_ref, done_ref, n_cls_pad=n_cls_pad,
                          early_exit=early_exit)

    @pl.when(pl.program_id(1) == l_blocks - 1)
    def _merge():
        states = carry_ref[...]
        c = states.shape[0]
        s = states.shape[1] // n_patterns
        lv = (states // n_cls_pad).reshape(c, n_patterns, s)
        la = la_ref[0]
        cidx = cidx_ref[...]
        sinks = sinks_ref[...]

        def fold(i, st):  # st [K, S] carried lane states
            la_i = jax.lax.dynamic_index_in_dim(la, i, 0, keepdims=False)
            lv_i = jax.lax.dynamic_index_in_dim(lv, i, 0, keepdims=False)
            lane = jnp.take(jnp.take(cidx, la_i, axis=0), st)      # [K, S]
            hit = jnp.take_along_axis(lv_i, jnp.maximum(lane, 0), axis=1)
            sk = sinks[:, None]
            nxt = jnp.where(lane < 0, jnp.where(sk >= 0, sk, st), hit)
            nxt = jnp.where(la_i == pad_cls, st, nxt)
            return nxt.astype(jnp.int32)

        out_ref[0, :] = jax.lax.fori_loop(1, c, fold, lv[0]).reshape(-1)


def _merge_pallas_call(kernel_fn, table, chunks, init_states, lookahead,
                       cand_index, sinks, absorbing, *, pad_cls, l_blk,
                       out_width, early_exit, interpret):
    """Shared ``pallas_call`` plumbing of the two fused merge kernels."""
    q, n_cls_pad = table.shape
    b, c, l = chunks.shape
    s_tot = init_states.shape[-1]
    k = sinks.shape[0]
    n_keys_pad = cand_index.shape[0]
    assert l % l_blk == 0, (l, l_blk)
    flat = (table.astype(jnp.int32) * n_cls_pad).reshape(-1)
    l_blocks = l // l_blk

    kernel = functools.partial(kernel_fn, n_cls_pad=n_cls_pad,
                               l_blocks=l_blocks, n_patterns=k,
                               pad_cls=pad_cls, early_exit=early_exit)
    out, skipped = pl.pallas_call(
        kernel,
        grid=(b, l_blocks),
        in_specs=[
            pl.BlockSpec((q * n_cls_pad,), lambda i, j: (0,)),     # flat table
            pl.BlockSpec((1, c, l_blk), lambda i, j: (i, 0, j)),   # symbols
            pl.BlockSpec((1, c, s_tot), lambda i, j: (i, 0, 0)),   # init lanes
            pl.BlockSpec((1, c), lambda i, j: (i, 0)),             # lookahead
            pl.BlockSpec((n_keys_pad, q), lambda i, j: (0, 0)),    # cand index
            pl.BlockSpec((k,), lambda i, j: (0,)),                 # sinks
            pl.BlockSpec((q,), lambda i, j: (0,)),                 # absorbing
        ],
        out_specs=[pl.BlockSpec((1, out_width), lambda i, j: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, out_width), jnp.int32),
                   jax.ShapeDtypeStruct((b, 1), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((c, s_tot), jnp.int32),
                        pltpu.SMEM((1,), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(flat, chunks.astype(jnp.int32), init_states.astype(jnp.int32),
      lookahead.astype(jnp.int32), cand_index.astype(jnp.int32),
      sinks.astype(jnp.int32), absorbing.astype(jnp.int32))
    return out, skipped[:, 0]


@functools.partial(jax.jit, static_argnames=("pad_cls", "l_blk", "early_exit",
                                             "interpret"))
def spec_match_merge_pallas(table: jnp.ndarray, chunks: jnp.ndarray,
                            init_states: jnp.ndarray, lookahead: jnp.ndarray,
                            cand_index: jnp.ndarray, sinks: jnp.ndarray,
                            absorbing: jnp.ndarray, *, pad_cls: int,
                            l_blk: int = 512, early_exit: bool = True,
                            interpret: bool = True
                            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pallas-backed equivalent of ``ref.spec_match_merge_ref``.

    table [Q_total, n_cls_pad] (identity pad column included); chunks
    [B, C, L]; init_states [B, C, K*S]; lookahead [B, C] boundary keys;
    cand_index [n_keys_pad, Q_total]; sinks [K]; absorbing [Q_total].
    L must divide by l_blk (ops.py pads/picks the block).  Grid:
    (B, L / l_blk) — documents ride the parallel grid dimension, the symbol
    recurrence rides the arbitrary one.  Returns ``(finals [B, K],
    skipped [B])`` — symbol blocks skipped per document by the in-kernel
    all-absorbed early exit (0 when ``early_exit=False``).
    """
    return _merge_pallas_call(spec_match_merge_kernel, table, chunks,
                              init_states, lookahead, cand_index, sinks,
                              absorbing, pad_cls=pad_cls, l_blk=l_blk,
                              out_width=sinks.shape[0],
                              early_exit=early_exit, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("pad_cls", "l_blk", "early_exit",
                                             "interpret"))
def spec_match_merge_lanes_pallas(table: jnp.ndarray, chunks: jnp.ndarray,
                                  init_states: jnp.ndarray,
                                  lookahead: jnp.ndarray,
                                  cand_index: jnp.ndarray, sinks: jnp.ndarray,
                                  absorbing: jnp.ndarray, *, pad_cls: int,
                                  l_blk: int = 512, early_exit: bool = True,
                                  interpret: bool = True
                                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pallas-backed equivalent of ``ref.spec_match_merge_lanes_ref``.

    Same operands as ``spec_match_merge_pallas`` but the output keeps the
    candidate lane axis: ``(lanes [B, K * S], skipped [B])`` — each
    document's restricted transition map under every Eq. 11 candidate entry
    of its boundary key.
    """
    return _merge_pallas_call(spec_match_merge_lanes_kernel, table, chunks,
                              init_states, lookahead, cand_index, sinks,
                              absorbing, pad_cls=pad_cls, l_blk=l_blk,
                              out_width=init_states.shape[-1],
                              early_exit=early_exit, interpret=interpret)
