"""Serving: batched prefill/decode engine + grammar-constrained decoding."""

from .constrained import GrammarConstraint
from .engine import ServeConfig, ServingEngine

__all__ = ["GrammarConstraint", "ServeConfig", "ServingEngine"]
