"""Serving: batched prefill/decode engine + grammar-constrained decoding."""

from .constrained import DecodeStream, GrammarConstraint
from .engine import ServeConfig, ServingEngine

__all__ = ["DecodeStream", "GrammarConstraint", "ServeConfig", "ServingEngine"]
