"""Grammar-constrained decoding backed by the paper's DFA machinery.

A regex/grammar is compiled to a DFA over bytes; during decoding each
sequence carries its DFA state, the per-state allowed-token mask is gathered
(kernels/token_mask fuses this with logit masking on TPU), and states advance
with the chosen tokens.

Draft verification (speculative decoding's accept step) is the paper's
algorithm verbatim: K draft tokens form a chunk matched from the sequence's
current state in one shot, with the per-position state trajectory recovered
from the L-vector prefix scan — parallel in K instead of K sequential steps.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..core import DFA, Matcher
from ..kernels import ops as kops

__all__ = ["GrammarConstraint", "DecodeStream"]


class DecodeStream:
    """Incremental grammar state over streaming cursors (one per sequence).

    The pre-streaming prefill (``advance_tokens``) re-scans the whole prompt
    from the start states on every call — fine once, wrong for prompts that
    arrive in chunks (chunked uploads, multi-turn).  A ``DecodeStream``
    instead holds one resumable ``StreamSession`` per batch row: each
    ``feed_tokens`` call scans *only the new tokens*, and the B per-row
    segments coalesce into one micro-batched device tick (the stream's
    matcher tiles its batch to cover all B rows — the constraint's own
    single-row matcher would dispatch per row).  Special (non-byte) tokens
    are identity moves, exactly as in ``advance_tokens``, so the states are
    bit-identical to a one-shot prefill of the concatenation.

    Division of labor with the decode loop: ``feed_tokens`` is for *segment*
    arrivals (prompt chunks, accepted draft runs); the per-token inner loop
    should keep using ``GrammarConstraint.advance`` — a single fused [B]
    gather with states resident on device — and sync back with
    ``feed_tokens`` only when a stream-level view is needed.
    """

    def __init__(self, constraint: "GrammarConstraint", batch: int):
        from ..core.engine.plan import next_pow2
        from ..streaming import StreamMatcher, TickPolicy

        self.constraint = constraint
        # ticks only on explicit flush: feed_tokens admits all B rows first,
        # then dispatches them as one coalesced round.  One device tile
        # covers the whole decode batch (the constraint's own matcher has
        # batch_tile=1 for single-row advance and would dispatch B times).
        self.stream = StreamMatcher(
            constraint.matcher.packed,
            batch_tile=next_pow2(batch),
            policy=TickPolicy(max_batch=1 << 30, max_delay=1 << 30))
        self.sessions = [self.stream.open() for _ in range(batch)]

    @property
    def batch(self) -> int:
        return len(self.sessions)

    @property
    def states(self) -> jnp.ndarray:
        """[B] current DFA states (grammar DFAs are packed alone, so packed
        state ids are plain state ids)."""
        return jnp.asarray(
            np.stack([s.cursor.states[0] for s in self.sessions]), jnp.int32)

    def feed_tokens(self, tokens: np.ndarray | jnp.ndarray) -> jnp.ndarray:
        """Advance every row by its new tokens [B, T]; returns the states.

        Byte-valued tokens (< 256) feed the row's cursor; special tokens are
        identity moves and are simply skipped (same semantics as the pad
        class in ``advance_tokens``).
        """
        toks = np.asarray(tokens)
        if toks.ndim != 2 or toks.shape[0] != self.batch:
            raise ValueError(f"expected [{self.batch}, T] tokens, "
                             f"got {toks.shape}")
        for row, sess in zip(toks, self.sessions):
            data = row[(row >= 0) & (row < 256)].astype(np.uint8).tobytes()
            if data:
                sess.feed(data)
        self.stream.flush()  # one coalesced tick for all B rows
        return self.states


class GrammarConstraint:
    """Per-state token masks + batched state advance for byte-level vocabs.

    State advance rides the matching runtime facade (``core.engine.Matcher``
    with ``num_chunks=1``): special (non-byte) tokens map to the padded
    table's identity column, so no masking branch exists, and every advance
    is bit-identical to stepping the raw DFA token by token.  Shapes:
    ``states`` are [B] int32 DFA state ids, token blocks are [B, T], logits
    [B, V].  The mesh/backend options of ``Matcher`` do not apply here — a
    grammar DFA advances one state per sequence, which is row-parallel
    already; ``open_decode`` (incremental prefill over streaming cursors) is
    the batched path.
    """

    def __init__(self, dfa: DFA, vocab_size: int, *, use_kernel: bool = True,
                 allow_specials: tuple[int, ...] = (), eos_id: int = 258):
        self.dfa = dfa
        self.vocab_size = vocab_size
        self.use_kernel = use_kernel
        self._allow_specials = tuple(allow_specials)
        self._eos_id = eos_id
        # the matching runtime facade: its padded transition table has an
        # identity column at matcher.pad_cls, so state advance runs through
        # the same engine layers as corpus scanning
        self.matcher = Matcher(dfa, num_chunks=1, batch_tile=1)
        self._build_tables()

    def _build_tables(self) -> None:
        """(Re)build the token mask + token->class tables for ``self.dfa``."""
        dfa, vocab_size = self.dfa, self.vocab_size
        q = dfa.n_states
        allowed = np.zeros((q, vocab_size), np.uint8)
        byte_cls = dfa.byte_to_class
        nxt = dfa.table  # [Q, n_cls]
        for v in range(min(vocab_size, 256)):
            cls = int(byte_cls[v])
            tgt = nxt[:, cls]
            ok = (tgt != dfa.sink) if dfa.sink >= 0 else np.ones(q, bool)
            allowed[:, v] = ok
        for v in self._allow_specials:
            if v < vocab_size:
                allowed[:, v] = 1
        # termination semantics: accepting states may emit EOS; states with no
        # legal continuation MUST emit EOS (grammar exhausted)
        if self._eos_id is not None and self._eos_id < vocab_size:
            allowed[dfa.accepting, self._eos_id] = 1
            dead = allowed.sum(axis=1) == 0
            allowed[dead, self._eos_id] = 1
        self.allowed = jnp.asarray(allowed)
        packed_cls = self.matcher.packed.byte_to_class  # facade class ids
        # token -> class map for state advance; special (non-byte) tokens map
        # to the identity pad class, so they advance no DFA with no masking
        tok_cls = np.full((vocab_size,), self.matcher.pad_cls, np.int32)
        nb = min(vocab_size, 256)
        tok_cls[:nb] = packed_cls[:nb]
        self.tok_cls = jnp.asarray(tok_cls)
        self.table_j = self.matcher.dev.table_pad_j

    def swap_grammar(self, dfa: DFA) -> bool:
        """Swap the constraint grammar in place (a new response schema
        between requests) without rebuilding the engine stack.

        Rides ``Matcher.swap_patterns``: a signature-equal grammar is a
        no-op (returns False, every compiled lowering kept); otherwise the
        facade retables under a bumped plan ``table_epoch`` and the token
        mask / token->class tables rebuild for the new DFA.  Sequences
        decoded under the old grammar hold stale states — restart them with
        ``init_states`` / a fresh ``open_decode``.
        """
        if not self.matcher.swap_patterns(dfa):
            return False
        self.dfa = dfa
        self._build_tables()
        return True

    def init_states(self, batch: int) -> jnp.ndarray:
        return jnp.full((batch,), self.dfa.start, jnp.int32)

    def open_decode(self, batch: int) -> DecodeStream:
        """Open resumable per-sequence cursors for incremental prefill/decode
        (see ``DecodeStream``); used by ``ServingEngine.generate`` so prompt
        chunks and decode steps never re-prefill from the start states."""
        return DecodeStream(self, batch)

    def mask_logits(self, states: jnp.ndarray, logits: jnp.ndarray) -> jnp.ndarray:
        """[B] states x [B, V] logits -> masked logits."""
        v = logits.shape[-1]
        allowed = self.allowed
        if v > allowed.shape[1]:  # padded model vocab: pad table (disallowed)
            allowed = jnp.pad(allowed, ((0, 0), (0, v - allowed.shape[1])))
        if self.use_kernel:
            return kops.token_mask(states, allowed, logits)
        mask = allowed[states] > 0
        return jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))

    def advance(self, states: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
        """Advance each sequence's DFA state by its chosen token [B].

        Special tokens map to the pad class, whose padded-table column is the
        identity — no branch needed.
        """
        return self.table_j[states, self.tok_cls[tokens]].astype(jnp.int32)

    def advance_tokens(self, states: jnp.ndarray,
                       tokens: np.ndarray | jnp.ndarray) -> jnp.ndarray:
        """Advance [B] states through [B, T] tokens in one vectorized scan.

        Column-wise replay of ``advance`` (specials are identity moves via
        the pad class) delegated to the matching runtime's
        ``Matcher.advance_classes`` — the batched prompt-prefill path: one
        device call for the whole batch instead of a per-request host loop
        over prompt bytes.
        """
        tokens = jnp.asarray(tokens, jnp.int32)
        if tokens.ndim != 2:
            raise ValueError("advance_tokens expects [B, T] tokens")
        return self.matcher.advance_classes(states, self.tok_cls[tokens])

    def verify_draft(self, state: int, draft_bytes: np.ndarray) -> tuple[int, np.ndarray]:
        """Speculative-decoding accept test for one sequence's K draft bytes.

        Returns (n_accepted, state_trajectory[K]); a draft byte is accepted
        while the DFA stays out of the sink.  Chunked membership semantics:
        the trajectory is the L-vector prefix of the draft chunk.
        """
        classes = self.dfa.classes_of(draft_bytes.astype(np.uint8))
        states = np.zeros(len(classes), np.int32)
        s = state
        for i, c in enumerate(classes):
            s = int(self.dfa.table[s, int(c)])
            states[i] = s
        if self.dfa.sink >= 0:
            bad = states == self.dfa.sink
            n_ok = int(np.argmax(bad)) if bad.any() else len(states)
        else:
            n_ok = len(states)
        return n_ok, states
