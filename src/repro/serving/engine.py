"""Batched serving engine: prefill + decode with optional grammar constraint.

Production-shaped loop: requests are padded into a fixed decode batch, the
prompt is prefetched in one prefill call, then tokens stream out of jitted
``decode_step`` calls.  Constrained requests carry DFA states advanced by
``GrammarConstraint`` (masks fused into the logits on TPU via the token_mask
kernel).  Greedy and temperature sampling are supported.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import api
from ..models import transformer as TF
from .constrained import GrammarConstraint

__all__ = ["ServeConfig", "ServingEngine"]


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 -> greedy
    eos_id: int = 258


class ServingEngine:
    """Decode-batch server for the transformer families (dense/moe/vlm)."""

    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig = ServeConfig(),
                 constraint: Optional[GrammarConstraint] = None, mesh=None):
        assert cfg.family in ("dense", "moe", "vlm")
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.constraint = constraint
        self.mesh = mesh
        self._decode = jax.jit(
            lambda p, c, t, pos: TF.decode_step(p, cfg, c, t, pos, mesh=mesh))

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        logits = logits[:, -1].astype(jnp.float32)  # [B, V]
        v = logits.shape[-1]
        # never sample padding ids beyond the real vocab
        if v > self.cfg.vocab_size:
            pad = jnp.arange(v) >= self.cfg.vocab_size
            logits = jnp.where(pad[None, :], -1e30, logits)
        if self.serve.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.serve.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, *, seed: int = 0,
                 decode_stream=None) -> np.ndarray:
        """prompts [B, T_prompt] int32 -> generated tokens [B, max_new].

        Grammar prefill rides resumable cursors (``GrammarConstraint.
        open_decode``): the prompt is fed once — in chunks, if it arrived
        that way — and never re-scanned.  Pass ``decode_stream`` (a
        ``DecodeStream`` already fed with the prompt, e.g. from a chunked
        streaming endpoint) to skip the prompt prefill entirely.  The
        per-token inner loop advances states with the fused single-gather
        ``constraint.advance`` (states stay device-resident; the cursors are
        a prefill/segment-level view and are not mutated per token).
        """
        b, t_prompt = prompts.shape
        max_len = t_prompt + self.serve.max_new_tokens
        cache = TF.init_cache(self.cfg, b, max_len)
        logits, cache, _ = TF.forward(self.params, self.cfg,
                                      jnp.asarray(prompts), cache=cache,
                                      mesh=self.mesh)
        key = jax.random.PRNGKey(seed)
        states = None
        stream = decode_stream
        if self.constraint is not None:
            if stream is None:
                stream = self.constraint.open_decode(b)
                states = stream.feed_tokens(prompts)
            else:
                if stream.batch != b:
                    raise ValueError(f"decode_stream holds {stream.batch} "
                                     f"sessions for a batch of {b}")
                states = stream.states  # prompt already fed incrementally
        elif decode_stream is not None:
            raise ValueError("decode_stream requires a grammar constraint")

        out = np.full((b, self.serve.max_new_tokens), self.serve.eos_id,
                      np.int32)
        last = logits[:, -1:]
        finished = np.zeros(b, bool)
        for i in range(self.serve.max_new_tokens):
            key, sub = jax.random.split(key)
            step_logits = last
            if states is not None:
                step_logits = self.constraint.mask_logits(
                    states, step_logits[:, -1]).reshape(step_logits.shape)
            tok = self._sample(step_logits, sub)             # [B]
            out[:, i] = np.where(finished, self.serve.eos_id, np.asarray(tok))
            finished |= np.asarray(tok) == self.serve.eos_id
            if finished.all():
                break
            if states is not None:
                states = self.constraint.advance(states, tok)
            last, cache = self._decode(self.params, cache, tok[:, None],
                                       jnp.int32(t_prompt + i))
        return out
