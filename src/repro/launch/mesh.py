"""Production mesh construction.

Axis semantics (DESIGN.md §5):
  pod    — inter-pod data parallelism (DCI links; gradients all-reduced here)
  data   — intra-pod DP/FSDP + DFA chunk groups
  model  — TP/SP/EP (tensor, sequence, and expert sharding)

``make_production_mesh`` is a function, not a module constant, so importing
this module never touches jax device state (the dry-run must set XLA_FLAGS
before any device query).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_matcher_mesh",
           "dp_axes", "mesh_info"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — tests and smoke."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


def make_matcher_mesh(devices: int | None = None) -> jax.sharding.Mesh:
    """Data-only mesh for the sharded matching executor (engine/sharded.py).

    The matcher shards its chunk axis over "data" and keeps no model
    parallelism, so the mesh is (D, 1) over all (or the first ``devices``)
    local devices.
    """
    d = len(jax.devices()) if devices is None else int(devices)
    return make_local_mesh(data=d, model=1)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The data-parallel axes present in this mesh (pod first)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_info(mesh: jax.sharding.Mesh) -> dict:
    return {
        "axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
        "dp": int(
            __import__("math").prod(mesh.shape[a] for a in dp_axes(mesh))),
        "tp": int(mesh.shape.get("model", 1)),
    }
