"""Production mesh construction.

Axis semantics (DESIGN.md §5):
  pod    — inter-pod data parallelism (DCI links; gradients all-reduced here)
  data   — intra-pod DP/FSDP + DFA chunk groups
  model  — TP/SP/EP (tensor, sequence, and expert sharding)

``make_production_mesh`` is a function, not a module constant, so importing
this module never touches jax device state (the dry-run must set XLA_FLAGS
before any device query).
"""

from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_matcher_mesh",
           "factor_matcher_mesh", "matcher_mesh_extents", "dp_axes",
           "mesh_info"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — tests and smoke."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


def factor_matcher_mesh(devices: int) -> tuple[int, int]:
    """Auto-factor a device count into a near-square (doc, chunk) shape.

    The doc extent is the largest divisor of ``devices`` at most
    ``sqrt(devices)`` and the chunk extent takes the rest, so e.g. 8 -> 2x4,
    16 -> 4x4, 6 -> 2x3, and primes degrade to 1xN (pure chunk sharding).
    Biasing the larger extent toward chunks keeps the all_gather volume (the
    only cross-device traffic, per-chunk lane states over "chunk") spread
    over more links while still splitting document rows across hosts.
    """
    if devices < 1:
        raise ValueError("devices must be >= 1")
    doc = max(d for d in range(1, math.isqrt(devices) + 1) if devices % d == 0)
    return doc, devices // doc


def make_matcher_mesh(devices: int | None = None, *,
                      shape: tuple[int, int] | str | None = None
                      ) -> jax.sharding.Mesh:
    """("doc", "chunk") mesh for the sharded matching executor.

    The speculative path shards chunk lanes over "chunk" (the only axis that
    communicates — one all_gather of per-chunk lane states) and document rows
    over "doc" (doc shards never exchange anything), so batches larger than
    one host's memory scale along "doc" while chunk matching stays balanced
    along "chunk".

    shape=None        -> (1, D): every device on the chunk axis (the 1-D
                         layout of the original sharded backend).
    shape="auto"      -> ``factor_matcher_mesh``: near-square, e.g. 8 -> 2x4.
    shape=(doc, chunk)-> explicit extents (``devices`` may be omitted).
    """
    n_avail = len(jax.devices())
    d = n_avail if devices is None else int(devices)
    if shape is None:
        doc, chunk = 1, d
    elif shape == "auto":
        doc, chunk = factor_matcher_mesh(d)
    else:
        doc, chunk = int(shape[0]), int(shape[1])
        if devices is not None and doc * chunk != d:
            raise ValueError(f"mesh shape {doc}x{chunk} does not use "
                             f"devices={d}")
    if doc < 1 or chunk < 1:
        raise ValueError(f"mesh extents must be >= 1, got {doc}x{chunk}")
    if doc * chunk > n_avail:
        raise ValueError(f"mesh {doc}x{chunk} needs {doc * chunk} devices, "
                         f"have {n_avail}")
    return jax.make_mesh((doc, chunk), ("doc", "chunk"),
                         devices=jax.devices()[: doc * chunk])


def matcher_mesh_extents(mesh: jax.sharding.Mesh) -> tuple[int, int]:
    """(doc, chunk) extents of a matcher mesh.

    Legacy 1-D matcher meshes (a "data" axis from older ``make_local_mesh``
    setups) count as (1, data) — pure chunk sharding.
    """
    if "chunk" in mesh.axis_names:
        return int(mesh.shape.get("doc", 1)), int(mesh.shape["chunk"])
    if "data" in mesh.axis_names:
        return 1, int(mesh.shape["data"])
    raise ValueError(f"not a matcher mesh (axes {mesh.axis_names}); expected "
                     "('doc', 'chunk') from launch.mesh.make_matcher_mesh")


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The data-parallel axes present in this mesh (pod first)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_info(mesh: jax.sharding.Mesh) -> dict:
    return {
        "axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
        "dp": int(math.prod(mesh.shape[a] for a in dp_axes(mesh))),
        "tp": int(mesh.shape.get("model", 1)),
    }
