"""Trip-count-weighted census of a partitioned HLO module.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, so for a
scan-over-layers + scan-over-microbatches program it under-reports flops by
``n_layers * num_microbatches`` (verified empirically; see EXPERIMENTS.md
§Dry-run methodology).  This module re-derives roofline inputs from
``compiled.as_text()``:

  * dot flops        — 2 * |out| * K per dot, K = prod(lhs contracting dims)
  * approx HBM bytes — per top-level op: output bytes (+ operand bytes for
                       dot/fusion/collective), a standard post-fusion proxy
  * collective bytes — per kind, with ring-cost factors applied later in
                       roofline.py (group sizes recorded here)

All quantities are multiplied by the product of enclosing while-loop trip
counts (extracted from each loop's condition constant).  Shapes in
partitioned HLO are per-device, so every number is per-device.
"""

from __future__ import annotations

import re

__all__ = ["hlo_census"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4, "u64": 8,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast", "while",
    "conditional", "copy-start", "copy-done", "after-all", "iota",
    "partition-id", "replica-id",
}

_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_OPLINE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[\w\[\],{}]+))\s+([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_elems_bytes(shape_txt: str) -> tuple[int, int]:
    elems = 0
    total = 0
    for m in _SHAPE.finditer(shape_txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _split(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    depth = 0
    for line in hlo.splitlines():
        if cur is None:
            m = _HEADER.match(line)
            if m and "=" not in line.split("(")[0]:
                cur = m.group(1)
                comps[cur] = []
                depth = 1
        else:
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                cur = None
            else:
                comps[cur].append(line)
    return comps


def hlo_census(hlo: str, n_devices: int) -> dict:
    comps = _split(hlo)

    # per-computation op tables
    tables: dict[str, list[tuple[str, str, str, str]]] = {}
    shapes: dict[str, dict[str, str]] = {}
    for name, lines in comps.items():
        rows = []
        smap = {}
        for line in lines:
            m = _OPLINE.match(line)
            if not m:
                continue
            var, shape_txt, op = m.group(1), m.group(2), m.group(3)
            smap[var] = shape_txt
            rows.append((var, shape_txt, op, line))
        tables[name] = rows
        shapes[name] = smap

    # fusion-parameter slice analysis: if a fused computation consumes its
    # parameter N only through dynamic-slice ops, the fusion reads just the
    # slice from HBM — charging the full operand would bill a 32K-step scan
    # for the whole loop-carried array at every step (census v2 fix).
    param_read_bytes: dict[str, dict[int, int]] = {}
    for name, lines in comps.items():
        pmap: dict[str, int] = {}
        reads: dict[int, int] = {}
        body = lines
        for line in body:
            m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\S+)\s+parameter\((\d+)\)",
                         line)
            if m:
                pmap[m.group(1)] = int(m.group(3))
        for var, ordinal in pmap.items():
            uses = [ln for ln in body
                    if re.search(r"[(,]\s*%?" + re.escape(var) + r"[),]", ln)
                    and not re.search(r"%?" + re.escape(var) + r"\s*=", ln)]
            if uses and all("dynamic-slice(" in u for u in uses):
                sliced = 0
                for u in uses:
                    mm = _OPLINE.match(u)
                    if mm:
                        sliced += _shape_elems_bytes(mm.group(2))[1]
                reads[ordinal] = sliced
        if reads:
            param_read_bytes[name] = reads

    # while edges with trip counts
    edges: dict[str, list[tuple[str, int]]] = {n: [] for n in comps}
    for name, lines in comps.items():
        body_txt = "\n".join(lines)
        # the operand may carry its tuple type (older jax HLO printer):
        #   while((s32[], f32[8,64]{1,0}) %tuple.1), condition=..., body=...
        for m in re.finditer(
                r"while\((?:\([^)]*\)\s*)?%?[\w.\-]+\),\s*"
                r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)",
                body_txt):
            cond, wbody = m.group(1), m.group(2)
            cond_txt = "\n".join(comps.get(cond, []))
            consts = [int(c) for c in
                      re.findall(r"s32\[\]\s+constant\((\d+)\)", cond_txt)]
            trip = max(consts) if consts else 1
            edges[name].append((wbody, trip))

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _HEADER.match(line)
            if m:
                entry = m.group(1)
                break
    if entry is None:
        entry = next(iter(comps), None)

    mult: dict[str, int] = {}

    def visit(name: str, m: int):
        if name not in comps or mult.get(name, 0) >= m:
            return
        mult[name] = m
        for child, trip in edges.get(name, []):
            visit(child, m * max(trip, 1))

    if entry:
        visit(entry, 1)

    out = {
        "dot_flops": 0.0,
        "approx_hbm_bytes": 0.0,
        "collectives": {k: {"bytes": 0.0, "count": 0, "static_count": 0,
                            "group_sizes": set()} for k in _COLLECTIVES},
        "n_computations": len(comps),
        "n_while": sum(len(e) for e in edges.values()),
        "max_multiplier": max(mult.values(), default=1),
        "bytes_by_op": {},
    }

    for name, rows in tables.items():
        m = mult.get(name)
        if m is None:
            continue  # unreached (fusion bodies handled via their call sites)
        smap = shapes[name]
        for var, shape_txt, op, line in rows:
            _, out_bytes = _shape_elems_bytes(shape_txt)
            if op == "dot":
                out_elems, _ = _shape_elems_bytes(shape_txt)
                # operands may be typed inline (older jax HLO printer):
                #   dot(f32[8,64]{1,0} %gte.5, f32[64,64]{1,0} %gte.9)
                lhs = re.search(r"dot\((?:([\w\[\],{}]+)\s+)?%?([\w.\-]+)", line)
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                k_total = 1
                if lhs and cdims:
                    lhs_txt = lhs.group(1) or smap.get(lhs.group(2), "")
                    lhs_dims = _SHAPE.search(lhs_txt)
                    if lhs_dims:
                        dims = [int(d) for d in lhs_dims.group(2).split(",") if d]
                        for ci in cdims.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                k_total *= dims[int(ci)]
                out["dot_flops"] += 2.0 * out_elems * k_total * m
            if op in _COLLECTIVES:
                gsz = n_devices
                g = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
                if g:
                    gsz = len(g.group(1).split(","))
                else:
                    g = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
                    if g:
                        gsz = int(g.group(2))
                    elif op == "collective-permute":
                        gsz = 2
                c = out["collectives"][op]
                c["bytes"] += out_bytes * m
                c["count"] += m
                c["static_count"] += 1
                c["group_sizes"].add(gsz)
            if op not in _SKIP_BYTES_OPS:
                total = out_bytes
                if op == "dynamic-update-slice":
                    # in-place slice write: count the update operand, not the
                    # whole buffer (carry/accumulator updates)
                    args = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^=]*?\)|\S+)\s+[\w\-]+\(([^)]*)\)", line)
                    if args:
                        ops_list = re.findall(r"%?([\w.\-]+)", args.group(1))
                        if len(ops_list) >= 2 and ops_list[1] in smap:
                            total = _shape_elems_bytes(smap[ops_list[1]])[1]
                elif op in ("fusion", "dot") or op in _COLLECTIVES:
                    args = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^=]*?\)|\S+)\s+[\w\-]+\(([^)]*)\)", line)
                    callee = re.search(r"calls=%?([\w.\-]+)", line)
                    reads = param_read_bytes.get(callee.group(1), {}) if callee else {}
                    if args:
                        for ordinal, a in enumerate(
                                re.findall(r"%?([\w.\-]+)", args.group(1))):
                            if a in smap:
                                total += reads.get(
                                    ordinal, _shape_elems_bytes(smap[a])[1])
                out["approx_hbm_bytes"] += total * m
                hist = out["bytes_by_op"]
                hist[op] = hist.get(op, 0.0) + total * m

    for k in out["collectives"]:
        out["collectives"][k]["group_sizes"] = \
            sorted(out["collectives"][k]["group_sizes"])
    return out
