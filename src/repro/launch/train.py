"""End-to-end training driver.

Wires every substrate together: synthetic corpus -> DFA block-list filter
(the paper's engine as a pipeline stage) -> packed batches -> sharded
jit train step -> async checkpoints -> restart-on-failure.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
      --steps 200 --batch 8 --seq 256

``--smoke`` shrinks the config for CPU; drop it on a real pod and pass
--mesh-data/--mesh-model for the production layout.
"""

from __future__ import annotations

import argparse
import itertools
import time

import numpy as np

import jax

from ..configs import get_config, reduce_for_smoke
from ..jax_compat import set_mesh
from ..data import CorpusConfig, CorpusFilter, LoaderConfig, data_stream, generate_documents
from ..distributed import sharding as shr
from ..training import AdamWConfig, CheckpointManager, TrainOptions
from ..training.train_loop import (init_train_state_sharded, jit_train_step,
                                   make_train_step, init_train_state)
from ..distributed.fault_tolerance import RestartManager
from ..launch.mesh import make_local_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--filter-patterns", nargs="*", default=[r"SECRET-[0-9]+"])
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    mesh = make_local_mesh(args.mesh_data, args.mesh_model)

    # data: filtered + packed
    corpus = CorpusConfig(n_documents=10_000, doc_len=args.seq * 4, seed=1)
    filt = CorpusFilter(args.filter_patterns, num_chunks=8)
    stream = data_stream(generate_documents(corpus),
                         LoaderConfig(batch_size=args.batch, seq_len=args.seq),
                         corpus_filter=filt)
    batches = ({"tokens": b["tokens"] % cfg.vocab_size,
                "labels": b["labels"] % cfg.vocab_size} for b in stream)

    opts = TrainOptions(
        num_microbatches=args.microbatches,
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps))
    with set_mesh(mesh):
        state = init_train_state_sharded(cfg, jax.random.PRNGKey(0), mesh, opts)
        first = next(batches)
        bspecs = shr.batch_specs(first, mesh, args.batch)
        step_fn = jit_train_step(cfg, mesh, state, bspecs, opts)

        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        start = 0
        if args.resume:
            like = jax.tree.map(np.asarray, state)
            from ..training.train_loop import state_shardings
            state, start = mgr.restore(like, state_shardings(state, mesh))
            print(f"resumed from step {start}")

        it = itertools.chain([first], batches)

        def one_step(st, i):
            batch = next(it)
            st, metrics = step_fn(st, batch)
            if i % 10 == 0:
                print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}")
            return st

        rm = RestartManager(
            save_fn=mgr.save,
            restore_fn=lambda: mgr.restore(jax.tree.map(np.asarray, state)))
        t0 = time.time()
        state, at = rm.run(state, start, args.steps, one_step,
                           checkpoint_every=args.ckpt_every)
        mgr.save(state, at)
        mgr.wait()
    dt = time.time() - t0
    print(f"done: {at} steps in {dt:.1f}s "
          f"({args.batch * args.seq * (at - start) / max(dt, 1e-9):.0f} tok/s); "
          f"filter dropped {filt.stats.dropped}/{filt.stats.scanned} docs, "
          f"model-speedup {filt.stats.model_speedup:.2f}x")


if __name__ == "__main__":
    main()
