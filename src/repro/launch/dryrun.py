import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init); smoke tests and benchmarks import other modules and
keep seeing one device.

Per cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs the *production* step function — the same
     training/train_loop or models/api entry real runs use,
  3. ``lower()``s it on ShapeDtypeStruct inputs (no allocation),
  4. ``compile()``s, proving the sharding config is coherent,
  5. records memory_analysis / cost_analysis / a collective-traffic census
     parsed from the partitioned HLO (while-loop trip counts folded in)
     into artifacts/dryrun/<mesh>/<arch>--<shape>.json.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--timeout 3600]
The --all driver runs each cell in a subprocess (compile crashes and OOMs
must not kill the sweep) and tolerates per-cell failure, recording it.
"""

import argparse
import json
import re
import subprocess
import sys
import time

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")

# --------------------------------------------------------------------------
# Per-cell dry-run
# --------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, multi_pod: bool):
    """Returns (jit_fn, example_args_shapes) for lower()."""
    import math

    import jax

    from ..configs import SHAPES, get_config
    from ..distributed import sharding as shr
    from ..launch.mesh import make_production_mesh
    from ..models import api
    from ..training.train_loop import (TrainOptions, init_train_state,
                                       state_shardings)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = math.prod(mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names)

    batch_shapes = api.input_specs(cfg, shape)

    def batch_shardings(batch):
        specs = {}
        for k, v in batch.items():
            if k == "cache":
                specs[k] = shr.cache_specs(v, mesh, shape.global_batch)
            elif k == "state":
                specs[k] = shr.state_specs(v, mesh, shape.global_batch)
            else:
                specs[k] = shr.batch_specs({k: v}, mesh, shape.global_batch)[k]
        return shr.named(specs, mesh)

    if shape.kind == "train":
        nm = max(1, shape.global_batch // dp)  # 1 sequence/device/microbatch
        if os.environ.get("REPRO_NM"):
            nm = int(os.environ["REPRO_NM"])
        opts = TrainOptions(
            num_microbatches=nm,
            grad_compression=os.environ.get("REPRO_COMPRESS", "none"))
        from ..training.train_loop import make_train_step
        state_shapes = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0), None, opts))
        st_sh = state_shardings(state_shapes, mesh)
        b_sh = batch_shardings(batch_shapes)
        step = make_train_step(cfg, mesh, opts)
        fn = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
                     donate_argnums=(0,))
        args = (state_shapes, batch_shapes)
        extra = {"num_microbatches": nm}
    else:
        params_shapes = jax.eval_shape(lambda: api.init(cfg, jax.random.PRNGKey(0)))
        p_sh = shr.named(shr.param_specs(params_shapes, mesh), mesh)
        b_sh = batch_shardings(batch_shapes)
        if shape.kind == "prefill":
            fn = jax.jit(lambda p, b: api.prefill(p, cfg, b, mesh=mesh),
                         in_shardings=(p_sh, b_sh))
        else:
            fn = jax.jit(lambda p, b: api.decode(p, cfg, b, mesh=mesh),
                         in_shardings=(p_sh, b_sh), donate_argnums=(1,))
        args = (params_shapes, batch_shapes)
        extra = {}
    return mesh, fn, args, extra


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str) -> dict:
    import jax

    from ..jax_compat import set_mesh

    multi = mesh_kind == "multi"
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "ok": False}
    try:
        mesh, fn, args, extra = build_cell(arch, shape_name, multi)
        record.update(extra, n_devices=int(mesh.devices.size))
        with set_mesh(mesh):
            lowered = fn.lower(*args)
            record["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t1, 2)

            ma = compiled.memory_analysis()
            record["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "code_bytes": int(ma.generated_code_size_in_bytes),
            }
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):  # older jax: one dict per device
                ca = ca[0] if ca else {}
            record["cost"] = {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))} if ca else {}
            hlo = compiled.as_text()
            record["hlo_bytes"] = len(hlo)
            from .hlo_census import hlo_census
            census = hlo_census(hlo, int(mesh.devices.size))
            record["collectives"] = census.pop("collectives")
            record["census"] = census
            record["ok"] = True
    except Exception as exc:  # noqa: BLE001
        record["error"] = f"{type(exc).__name__}: {exc}"[:2000]
    record["total_s"] = round(time.time() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}--{shape_name}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


# --------------------------------------------------------------------------
# Sweep driver
# --------------------------------------------------------------------------

def all_cells() -> list[tuple[str, str]]:
    from ..configs import get_config, list_archs, shapes_for
    cells = []
    for arch in list_archs():
        for shape in shapes_for(get_config(arch)):
            cells.append((arch, shape.name))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACTS)
    ap.add_argument("--timeout", type=int, default=7200)
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if not args.all:
        assert args.arch and args.shape
        for mk in meshes:
            rec = run_cell(args.arch, args.shape, mk,
                           os.path.join(args.out, mk))
            print(json.dumps({k: rec[k] for k in
                              ("arch", "shape", "mesh", "ok", "total_s")
                              if k in rec}
                             | ({"error": rec["error"]} if "error" in rec else {})))
        return 0

    # sweep: one subprocess per cell so a crash cannot kill the sweep
    failures = 0
    for mk in meshes:
        for arch, shape in all_cells():
            out_json = os.path.join(args.out, mk, f"{arch}--{shape}.json")
            if args.skip_done and os.path.exists(out_json):
                with open(out_json) as f:
                    if json.load(f).get("ok"):
                        print(f"[skip] {mk} {arch} {shape}")
                        continue
            t0 = time.time()
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--mesh", mk, "--out", args.out]
            try:
                proc = subprocess.run(cmd, capture_output=True, text=True,
                                      timeout=args.timeout)
                ok = proc.returncode == 0 and os.path.exists(out_json)
                if ok:
                    with open(out_json) as f:
                        ok = json.load(f).get("ok", False)
                if not ok:
                    failures += 1
                    err = (proc.stderr or "")[-500:]
                    os.makedirs(os.path.dirname(out_json), exist_ok=True)
                    if not os.path.exists(out_json):
                        with open(out_json, "w") as f:
                            json.dump({"arch": arch, "shape": shape, "mesh": mk,
                                       "ok": False, "error": err}, f)
                print(f"[{'ok' if ok else 'FAIL'}] {mk} {arch} {shape} "
                      f"({time.time() - t0:.0f}s)")
            except subprocess.TimeoutExpired:
                failures += 1
                print(f"[TIMEOUT] {mk} {arch} {shape}")
    print(f"sweep done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
