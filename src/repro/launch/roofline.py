"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  All dry-run census quantities are per-device (partitioned
HLO shapes), so the three terms are directly:

  t_compute    = dot_flops / 197e12
  t_memory     = approx_hbm_bytes / 819e9
  t_collective = sum_k wire_bytes_k / 50e9,  ring-cost factors per kind:
                   all-gather        out * (n-1)/n
                   all-reduce        2 * out * (n-1)/n
                   reduce-scatter    out * (n-1)        (out is the shard)
                   all-to-all        out * (n-1)/n
                   collective-permute out

MODEL_FLOPS = f * N * D per chip (f = 6 train, 2 prefill/decode;
N = active params for MoE), giving the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs that catches remat/redundancy waste.

Roofline fraction (the §Perf score) = t_compute / max(all three terms):
1.0 means the cell is compute-bound at peak; lower means the dominant
non-compute term caps utilization at that fraction.

Usage:  python -m repro.launch.roofline [--artifacts DIR] [--csv]
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def count_params(arch: str) -> tuple[float, float]:
    """(total, active) parameter counts from the real init (eval_shape)."""
    import jax

    from ..configs import get_config
    from ..models import api

    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: api.init(cfg, jax.random.PRNGKey(0)))
    total = active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        names = [str(getattr(k, "key", k)) for k in path]
        n = math.prod(leaf.shape)
        total += n
        if "moe" in names and any(s in names[-1] for s in ("wi_gate", "wi_up", "wo")):
            active += n * cfg.top_k / max(cfg.n_experts, 1)
        else:
            active += n
    return total, active


def wire_bytes(collectives: dict) -> float:
    total = 0.0
    for kind, rec in collectives.items():
        b = float(rec.get("bytes", 0))
        gss = rec.get("group_sizes") or []
        n = max(gss) if gss else 2
        if n <= 1:
            continue
        if kind == "all-gather":
            total += b * (n - 1) / n
        elif kind == "all-reduce":
            total += 2 * b * (n - 1) / n
        elif kind == "reduce-scatter":
            total += b * (n - 1)
        elif kind == "all-to-all":
            total += b * (n - 1) / n
        else:  # collective-permute
            total += b
    return total


def tokens_of(shape_name: str, kind_factor_out: list | None = None) -> tuple[float, float]:
    """(tokens per step, model-flops factor) for a shape."""
    from ..configs import SHAPES
    s = SHAPES[shape_name]
    if s.kind == "train":
        return s.seq_len * s.global_batch, 6.0
    if s.kind == "prefill":
        return s.seq_len * s.global_batch, 2.0
    return 1.0 * s.global_batch, 2.0  # decode: one token per sequence


def analyze_record(rec: dict, n_params: tuple[float, float]) -> dict:
    census = rec.get("census", {})
    flops = float(census.get("dot_flops", 0.0))
    hbm = float(census.get("approx_hbm_bytes", 0.0))
    coll = wire_bytes(rec.get("collectives", {}))
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_l = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    dominant = max(terms, key=terms.get)
    total, active = n_params
    toks, factor = tokens_of(rec["shape"])
    chips = rec.get("n_devices", 1)
    model_flops_chip = factor * active * toks / max(chips, 1)
    ratio = model_flops_chip / flops if flops else 0.0
    bound = max(terms.values()) or 1e-12
    frac = t_c / bound
    mfu_proxy = model_flops_chip / (PEAK_FLOPS * bound) if bound else 0.0
    suggest = {
        "compute": "compute-bound: reduce redundant flops (remat policy, "
                   "causal block skipping) or accept — this is the roofline",
        "memory": "HBM-bound: raise arithmetic intensity (fuse, bigger "
                  "microbatch per device, bf16 master grads, cache layout)",
        "collective": "ICI-bound: reshard to cut all-gather volume (FSDP "
                      "prefetch, 2-tier pod-local reduce, overlap with compute)",
    }[dominant]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "ok": rec.get("ok", False),
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_l,
        "dominant": dominant,
        "hlo_flops_chip": flops,
        "model_flops_chip": model_flops_chip,
        "useful_ratio": ratio,
        "roofline_fraction": frac,
        "mfu_proxy": mfu_proxy,
        "temp_bytes": rec.get("memory", {}).get("temp_bytes"),
        "suggestion": suggest,
    }


def analyze_all(artifacts: str = ARTIFACTS) -> list[dict]:
    params_cache: dict[str, tuple[float, float]] = {}
    out = []
    for path in sorted(glob.glob(os.path.join(artifacts, "*", "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            out.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                        "mesh": rec.get("mesh"), "ok": False,
                        "error": rec.get("error", "")[:160]})
            continue
        arch = rec["arch"]
        if arch not in params_cache:
            params_cache[arch] = count_params(arch)
        out.append(analyze_record(rec, params_cache[arch]))
    return out


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bound | useful | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - "
                         f"| - | FAILED | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s'] * 1e3:.2f} | {r['t_memory_s'] * 1e3:.2f} "
            f"| {r['t_collective_s'] * 1e3:.2f} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return hdr + "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=ARTIFACTS)
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = analyze_all(args.artifacts)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)
    if args.csv:
        print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
              "dominant,useful_ratio,roofline_fraction")
        for r in rows:
            if r.get("ok"):
                print(f"{r['arch']},{r['shape']},{r['mesh']},"
                      f"{r['t_compute_s']:.6g},{r['t_memory_s']:.6g},"
                      f"{r['t_collective_s']:.6g},{r['dominant']},"
                      f"{r['useful_ratio']:.4f},{r['roofline_fraction']:.4f}")
    else:
        print(to_markdown(rows))


if __name__ == "__main__":
    main()
