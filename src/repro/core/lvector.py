"""L-vector algebra (paper Sec. 4.1, Eqs. 8–9) and merge strategies.

An L-vector for chunk i is the map ``L_i[j] = delta*(q_j, chunk_i)``.  L-vectors
compose associatively: ``(L_i ; L_j)[q] = L_j[L_i[q]]`` (function composition,
Eq. 9), with identity ``L_id[q] = q``.  This monoid is what makes every merge
strategy — sequential (Eq. 8), binary-tree reduction, the paper's 2-tier
hierarchical EC2 scheme, and ``jax.lax.associative_scan`` — produce the same
result; associativity is property-tested in tests/.

Two representations:
  * full maps   [Q]        — compose with a gather; used by merges.
  * compressed  [I_max]    — per-chunk result for candidate initial states only
                              (the lookahead-optimized matcher's output).
Compressed vectors merge with ``merge_compressed`` which walks chunks carrying
one state, using the candidate inverse index (sink-safe).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "identity_lvec", "compose", "compose_jnp", "merge_sequential",
    "merge_tree", "merge_scan_jnp", "merge_scan_lanes_jnp",
    "merge_compressed",
]


def identity_lvec(q: int) -> np.ndarray:
    return np.arange(q, dtype=np.int32)


def compose(l1: np.ndarray, l2: np.ndarray) -> np.ndarray:
    """Eq. 9: first apply l1 then l2 (numpy host form)."""
    return l2[l1]


def compose_jnp(l1: jnp.ndarray, l2: jnp.ndarray) -> jnp.ndarray:
    """Eq. 9 on device; supports leading batch dims on both operands."""
    return jnp.take_along_axis(l2, l1, axis=-1)


def merge_sequential(lvecs: np.ndarray, start: int) -> int:
    """Eq. 8: fold full maps left-to-right from the known start state."""
    s = int(start)
    for i in range(lvecs.shape[0]):
        s = int(lvecs[i, s])
    return s


def merge_tree(lvecs: np.ndarray) -> np.ndarray:
    """Binary-tree reduction of full maps (the parallel reduction of [19])."""
    maps = [lvecs[i] for i in range(lvecs.shape[0])]
    if not maps:
        raise ValueError("no maps")
    while len(maps) > 1:
        nxt = []
        for i in range(0, len(maps) - 1, 2):
            nxt.append(compose(maps[i], maps[i + 1]))
        if len(maps) % 2:
            nxt.append(maps[-1])
        maps = nxt
    return maps[0]


def merge_scan_jnp(lvecs: jnp.ndarray) -> jnp.ndarray:
    """All-prefix composition via associative scan: out[i] = L_0;...;L_i.

    out[-1] is the whole-input map.  This is the TPU-native replacement for
    the paper's binary tree, and doubles as the parallel-scan primitive shared
    with the RG-LRU / mLSTM recurrences (DESIGN.md §3.3).
    """
    return jax.lax.associative_scan(lambda a, b: compose_jnp(a, b), lvecs, axis=0)


def merge_scan_lanes_jnp(
    lane_maps: jnp.ndarray,   # [..., N, K, S] candidate-keyed lane maps
    entry_keys: jnp.ndarray,  # [..., N] boundary key of each map's entry row
    cand_index: jnp.ndarray,  # [n_keys + 1, Q] inverse candidate map (pad row -1)
    sinks: jnp.ndarray,       # [K] per-pattern sink state (-1 = none)
    *,
    pad_key: int,
    axis: int = 0,
) -> jnp.ndarray:
    """All-prefix composition of candidate-keyed [K, S] lane maps.

    The compressed-representation analogue of :func:`merge_scan_jnp`: each
    scan element is a segment's restricted transition map (lane s of pattern
    k holds delta*(candidates[key][k, s], segment)) together with the
    boundary key that selects its candidate entry row.  Composition locates
    the left map's carried states inside the right map's candidate row via
    ``cand_index`` (Eq. 11); a missing candidate is the pattern's sink by
    construction.  Keys equal to ``pad_key`` compose as the identity, so
    runs may be padded on the right to a fixed N.  ``out[..., i, :, :]`` is
    the composition of maps 0..i; element 0's key is never read (prefixes
    start there), letting callers seed the scan with an exact cursor
    broadcast to lane width.
    """
    lanes = jnp.asarray(lane_maps, jnp.int32)
    keys = jnp.asarray(entry_keys, jnp.int32)
    cidx = jnp.asarray(cand_index, jnp.int32)
    sk = jnp.asarray(sinks, jnp.int32)[:, None]  # [K, 1]

    def combine(a, b):
        al, ak = a
        bl, bk = b
        lane = cidx[bk[..., None, None], al]
        hit = jnp.take_along_axis(bl, jnp.maximum(lane, 0), axis=-1)
        out = jnp.where(lane < 0, jnp.where(sk >= 0, sk, al), hit)
        out = jnp.where((bk == pad_key)[..., None, None], al, out)
        return out.astype(jnp.int32), ak

    out, _ = jax.lax.associative_scan(combine, (lanes, keys), axis=axis)
    return out


def merge_compressed(
    lvecs: np.ndarray,        # [C, I_max] final state per candidate lane
    cand_index: np.ndarray,   # [n_classes, Q] inverse candidate map
    lookahead_cls: np.ndarray,  # [C] reverse-lookahead class per chunk (c>=1)
    start: int,
    sink: int,
) -> int:
    """Fold compressed per-chunk results from the known start state.

    Chunk 0's result lives in lane 0.  For chunk i>0 the carried state q is
    located inside the chunk's candidate list via cand_index; by construction
    (Eq. 11) q is always a candidate unless q is the sink, which is absorbing.
    """
    s = int(lvecs[0, 0]) if lvecs.shape[0] else int(start)
    for i in range(1, lvecs.shape[0]):
        if sink >= 0 and s == sink:
            return sink
        lane = int(cand_index[int(lookahead_cls[i]), s])
        if lane < 0:
            raise AssertionError(
                "carried state not in candidate set — lookahead tables are wrong")
        s = int(lvecs[i, lane])
    return s
