"""Benchmark pattern libraries standing in for the paper's suites.

The paper evaluates 299 PCRE-library regexes and 110 PROSITE protein patterns.
Those exact corpora are not redistributable here, so we ship representative
public patterns of both families: the PROSITE entries below are real database
patterns (prosite.expasy.org accession ids noted), and the PCRE-style set
covers the usual syntactic range (classes, alternation, bounded repeats).
Benchmarks sweep these libraries plus random DFAs to reach the paper's |Q|
ranges (up to ~1288 states).
"""

from __future__ import annotations

from .automata import DFA, make_search_dfa
from .determinize import compile_prosite, compile_regex

__all__ = ["PROSITE_PATTERNS", "PCRE_PATTERNS", "compile_pattern_suite"]

# Real PROSITE patterns (public database, accession in comment).
PROSITE_PATTERNS: dict[str, str] = {
    "PS00001_ASN_GLYCOSYLATION": "N-{P}-[ST]-{P}",
    "PS00004_CAMP_PHOSPHO_SITE": "[RK](2)-x-[ST]",
    "PS00005_PKC_PHOSPHO_SITE": "[ST]-x-[RK]",
    "PS00006_CK2_PHOSPHO_SITE": "[ST]-x(2)-[DE]",
    "PS00007_TYR_PHOSPHO_SITE": "[RK]-x(2,3)-[DE]-x(2,3)-Y",
    "PS00008_MYRISTYL": "G-{EDRKHPFYW}-x(2)-[STAGCN]-{P}",
    "PS00009_AMIDATION": "x-G-[RK]-[RK]",
    "PS00016_RGD": "R-G-D",
    "PS00017_ATP_GTP_A": "[AG]-x(4)-G-K-[ST]",
    "PS00018_EF_HAND_1": "D-x-[DNS]-{ILVFYW}-[DENSTG]-[DNQGHRK]-{GP}-[LIVMC]-[DENQSTAGC]-x(2)-[DE]-[LIVMFYW]",
    "PS00028_ZINC_FINGER_C2H2": "C-x(2,4)-C-x(3)-[LIVMFYWC]-x(8)-H-x(3,5)-H",
    "PS00029_LEUCINE_ZIPPER": "L-x(6)-L-x(6)-L-x(6)-L",
    "PS00134_TRYPSIN_HIS": "[LIVM]-[ST]-A-[STAG]-H-C",
    "PS00135_TRYPSIN_SER": "[DNSTAGC]-[GSTAPIMVQH]-x(2)-G-[DE]-S-G-[GS]-[SAPHV]-[LIVMFYWH]-[LIVMFYSTANQH]",
}

# PCRE-style regex suite (classes, alternation, bounded repeats, escapes).
PCRE_PATTERNS: dict[str, str] = {
    "ipv4": r"\d{1,3}\.\d{1,3}\.\d{1,3}\.\d{1,3}",
    "email": r"[a-zA-Z0-9_.]+@[a-zA-Z0-9]+\.[a-z]{2,4}",
    "iso_date": r"\d{4}-\d{2}-\d{2}",
    "hex_color": r"#[0-9a-fA-F]{6}",
    "float": r"[0-9]+\.[0-9]+([eE][+\-]?[0-9]+)?",
    "uri_scheme": r"(http|https|ftp)://[a-zA-Z0-9./_\-]+",
    "c_ident": r"[a-zA-Z_][a-zA-Z0-9_]{3,8}",
    "quoted": r'"[^"]*"',
    "html_tag": r"<[a-z]{1,6}( [a-z]+=[a-z0-9]+)*>",
    "uuid_like": r"[0-9a-f]{8}-[0-9a-f]{4}-[0-9a-f]{4}",
    "phone": r"\+?[0-9]{1,3}[ \-][0-9]{2,4}[ \-][0-9]{4,6}",
    "keyword_alt": r"(for|while|if|else|return|break|continue)",
    "base64ish": r"[A-Za-z0-9+/]{12,16}=?=?",
    "repeat_ab": r"(ab|ba){2,6}",
}


def compile_pattern_suite(kind: str = "prosite", *, search: bool = True) -> dict[str, DFA]:
    """Compile a suite name -> minimal DFA map; search semantics by default."""
    if kind == "prosite":
        items = {k: compile_prosite(v) for k, v in PROSITE_PATTERNS.items()}
    elif kind == "pcre":
        items = {k: compile_regex(v) for k, v in PCRE_PATTERNS.items()}
    else:
        raise ValueError(f"unknown suite {kind!r}")
    if search:
        # search semantics: Sigma* R — prefix the DFA by allowing restarts.
        # Implemented by compiling .*(pattern) directly for correctness.
        if kind == "prosite":
            from .regex import prosite_to_regex
            items = {k: compile_regex(".*(" + prosite_to_regex(v) + ")")
                     for k, v in PROSITE_PATTERNS.items()}
        else:
            items = {k: compile_regex(".*(" + v + ")") for k, v in PCRE_PATTERNS.items()}
        items = {k: make_search_dfa(d) for k, d in items.items()}
    return items
