"""Benchmark pattern libraries standing in for the paper's suites.

The paper evaluates 299 PCRE-library regexes and 110 PROSITE protein patterns.
Those exact corpora are not redistributable here, so we ship representative
public patterns of both families: the PROSITE entries below are real database
patterns (prosite.expasy.org accession ids noted), and the PCRE-style set
covers the usual syntactic range (classes, alternation, bounded repeats).
Benchmarks sweep these libraries plus random DFAs to reach the paper's |Q|
ranges (up to ~1288 states).
"""

from __future__ import annotations

import hashlib
from typing import Mapping, Optional, Sequence, Union

import numpy as np

from .automata import (DFA, PackedDFA, make_search_dfa, pack_dfas,
                       packed_signature)
from .determinize import compile_prosite, compile_regex

__all__ = ["PROSITE_PATTERNS", "PCRE_PATTERNS", "PatternSet",
           "compile_pattern_suite"]

# Real PROSITE patterns (public database, accession in comment).
PROSITE_PATTERNS: dict[str, str] = {
    "PS00001_ASN_GLYCOSYLATION": "N-{P}-[ST]-{P}",
    "PS00004_CAMP_PHOSPHO_SITE": "[RK](2)-x-[ST]",
    "PS00005_PKC_PHOSPHO_SITE": "[ST]-x-[RK]",
    "PS00006_CK2_PHOSPHO_SITE": "[ST]-x(2)-[DE]",
    "PS00007_TYR_PHOSPHO_SITE": "[RK]-x(2,3)-[DE]-x(2,3)-Y",
    "PS00008_MYRISTYL": "G-{EDRKHPFYW}-x(2)-[STAGCN]-{P}",
    "PS00009_AMIDATION": "x-G-[RK]-[RK]",
    "PS00016_RGD": "R-G-D",
    "PS00017_ATP_GTP_A": "[AG]-x(4)-G-K-[ST]",
    "PS00018_EF_HAND_1": "D-x-[DNS]-{ILVFYW}-[DENSTG]-[DNQGHRK]-{GP}-[LIVMC]-[DENQSTAGC]-x(2)-[DE]-[LIVMFYW]",
    "PS00028_ZINC_FINGER_C2H2": "C-x(2,4)-C-x(3)-[LIVMFYWC]-x(8)-H-x(3,5)-H",
    "PS00029_LEUCINE_ZIPPER": "L-x(6)-L-x(6)-L-x(6)-L",
    "PS00134_TRYPSIN_HIS": "[LIVM]-[ST]-A-[STAG]-H-C",
    "PS00135_TRYPSIN_SER": "[DNSTAGC]-[GSTAPIMVQH]-x(2)-G-[DE]-S-G-[GS]-[SAPHV]-[LIVMFYWH]-[LIVMFYSTANQH]",
    "PS00010_ASX_HYDROXYL": "C-x-[DN]-x(4)-[FY]-x-C-x-C",
    "PS00013_PROKAR_LIPOPROTEIN": "{DERK}(6)-[LIVMFWSTAG](2)-[LIVMFYSTAGCQ]-[AGS]-C",
    "PS00027_HOMEOBOX_1": "[LIVMFYG]-[ASLVR]-x(2)-[LIVMSTACN]-x-[LIVM]-{Y}-x(2)-{L}-[LIV]-[RKNQESTAIY]-[LIVFSTNKH]-W-[FYVC]-x-[NDQTAH]-x(5)-[RKNAIMW]",
    "PS00190_CYTOCHROME_P450": "[FW]-[SGNH]-x-[GD]-{F}-[RKHPT]-{P}-C-[LIVMFAP]-[GAD]",
    "PS00342_MICROBODIES_CTER": "[STAGCN]-[RKH]-[LIVMAFY]",
    "PS00383_TYR_PHOSPHATASE": "[LIVMF]-H-C-x(2)-G-x(3)-[STC]-[STAGP]-x-[LIVMFY]",
}

# PCRE-style regex suite (classes, alternation, bounded repeats, escapes).
PCRE_PATTERNS: dict[str, str] = {
    "ipv4": r"\d{1,3}\.\d{1,3}\.\d{1,3}\.\d{1,3}",
    "email": r"[a-zA-Z0-9_.]+@[a-zA-Z0-9]+\.[a-z]{2,4}",
    "iso_date": r"\d{4}-\d{2}-\d{2}",
    "hex_color": r"#[0-9a-fA-F]{6}",
    "float": r"[0-9]+\.[0-9]+([eE][+\-]?[0-9]+)?",
    "uri_scheme": r"(http|https|ftp)://[a-zA-Z0-9./_\-]+",
    "c_ident": r"[a-zA-Z_][a-zA-Z0-9_]{3,8}",
    "quoted": r'"[^"]*"',
    "html_tag": r"<[a-z]{1,6}( [a-z]+=[a-z0-9]+)*>",
    "uuid_like": r"[0-9a-f]{8}-[0-9a-f]{4}-[0-9a-f]{4}",
    "phone": r"\+?[0-9]{1,3}[ \-][0-9]{2,4}[ \-][0-9]{4,6}",
    "keyword_alt": r"(for|while|if|else|return|break|continue)",
    "base64ish": r"[A-Za-z0-9+/]{12,16}=?=?",
    "repeat_ab": r"(ab|ba){2,6}",
}


PatternSource = Union[Mapping[str, str], Sequence[str], Sequence[DFA]]


class PatternSet:
    """K patterns split into independently-determinized blocks of ``k_blk``.

    Each block is its own ``PackedDFA`` (and, downstream, its own
    ``DeviceTables``), so table memory and rebuild cost scale linearly in
    blocks instead of super-linearly in K — the pattern-axis analogue of the
    paper's input chunking.  Packed state ids are local per block;
    ``state_bases[b]`` re-offsets them to the global id space, and because
    ``pack_dfas`` offsets are a plain cumsum of per-pattern state counts, the
    re-offset block ids are *bit-identical* to what one unblocked
    ``pack_dfas`` over all K patterns would assign.

    ``patterns`` is a name->regex mapping, a sequence of regex strings, or a
    sequence of prebuilt ``DFA``s (no regexes retained — such blocks are
    never prefilter-gated).  ``search=True`` compiles ``.*(pat)`` with
    absorbing accepts (``re.search`` semantics); ``search=False`` compiles
    the bare pattern (``re.fullmatch`` semantics).
    """

    def __init__(self, patterns: PatternSource, *, k_blk: int = 32,
                 search: bool = True,
                 names: Optional[Sequence[str]] = None):
        if k_blk < 1:
            raise ValueError("k_blk must be >= 1")
        self.k_blk = int(k_blk)
        self.search = bool(search)
        if isinstance(patterns, Mapping):
            if names is not None:
                raise ValueError("names= conflicts with a mapping source")
            names = list(patterns.keys())
            patterns = list(patterns.values())
        else:
            patterns = list(patterns)
        if not patterns:
            raise ValueError("PatternSet needs at least one pattern")
        self.regexes: tuple[Optional[str], ...]
        self.dfas: tuple[DFA, ...]
        if isinstance(patterns[0], DFA):
            if not all(isinstance(p, DFA) for p in patterns):
                raise TypeError("mixed DFA / regex sources are not supported")
            self.regexes = (None,) * len(patterns)
            self.dfas = tuple(patterns)
        else:
            self.regexes = tuple(str(p) for p in patterns)
            self.dfas = tuple(self._compile(r) for r in self.regexes)
        self.names = tuple(names) if names is not None else tuple(
            f"p{i:04d}" for i in range(len(self.dfas)))
        if len(self.names) != len(self.dfas):
            raise ValueError("names length does not match pattern count")
        self.blocks: tuple[PackedDFA, ...] = tuple(
            pack_dfas(self.dfas[i:i + self.k_blk])
            for i in range(0, len(self.dfas), self.k_blk))
        self.block_signatures: tuple[str, ...] = tuple(
            packed_signature(b) for b in self.blocks)
        # global state-id base per block: cumsum of block sizes == the
        # unblocked pack's offsets at each block boundary (fan-in identity)
        sizes = [b.n_states for b in self.blocks]
        self.state_bases = np.concatenate(
            [[0], np.cumsum(sizes)[:-1]]).astype(np.int32)

    def _compile(self, regex: str) -> DFA:
        if self.search:
            return make_search_dfa(compile_regex(".*(" + regex + ")"))
        return compile_regex(regex)

    # -- shape -----------------------------------------------------------

    @property
    def n_patterns(self) -> int:
        return len(self.dfas)

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def block_slice(self, b: int) -> slice:
        """Global pattern-index range covered by block ``b``."""
        lo = b * self.k_blk
        return slice(lo, min(lo + self.k_blk, self.n_patterns))

    def block_regexes(self, b: int) -> tuple[Optional[str], ...]:
        sl = self.block_slice(b)
        return self.regexes[sl]

    def block_names(self, b: int) -> tuple[str, ...]:
        return self.names[self.block_slice(b)]

    # -- identity --------------------------------------------------------

    @property
    def signature(self) -> str:
        """Full-set content hash (blocking layout + every block's tables)."""
        h = hashlib.sha1()
        h.update(f"k_blk={self.k_blk};search={self.search};".encode())
        for sig in self.block_signatures:
            h.update(sig.encode())
        return h.hexdigest()

    # -- editing ---------------------------------------------------------

    def with_patterns(self, updates: Mapping[Union[str, int], str]
                      ) -> "PatternSet":
        """A new set with some patterns replaced (by name or index) — the
        hot-swap constructor: unchanged blocks keep identical signatures, so
        ``swap_patterns`` reuses their compiled lowerings."""
        if any(r is None for r in self.regexes):
            raise ValueError("with_patterns requires a regex-sourced set")
        regexes = list(self.regexes)
        for key, regex in updates.items():
            idx = self.names.index(key) if isinstance(key, str) else int(key)
            regexes[idx] = regex
        return PatternSet(regexes, k_blk=self.k_blk, search=self.search,
                          names=self.names)

    def __repr__(self) -> str:
        return (f"PatternSet(K={self.n_patterns}, k_blk={self.k_blk}, "
                f"n_blocks={self.n_blocks}, search={self.search})")


def compile_pattern_suite(kind: str = "prosite", *, search: bool = True) -> dict[str, DFA]:
    """Compile a suite name -> minimal DFA map; search semantics by default."""
    if kind == "prosite":
        items = {k: compile_prosite(v) for k, v in PROSITE_PATTERNS.items()}
    elif kind == "pcre":
        items = {k: compile_regex(v) for k, v in PCRE_PATTERNS.items()}
    else:
        raise ValueError(f"unknown suite {kind!r}")
    if search:
        # search semantics: Sigma* R — prefix the DFA by allowing restarts.
        # Implemented by compiling .*(pattern) directly for correctness.
        if kind == "prosite":
            from .regex import prosite_to_regex
            items = {k: compile_regex(".*(" + prosite_to_regex(v) + ")")
                     for k, v in PROSITE_PATTERNS.items()}
        else:
            items = {k: compile_regex(".*(" + v + ")") for k, v in PCRE_PATTERNS.items()}
        items = {k: make_search_dfa(d) for k, d in items.items()}
    return items
