"""Offline capacity profiling (paper Sec. 4.1 step 1, Eq. 1) + shape autotuner.

On heterogeneous fleets (the paper's EC2 scenario; for us, mixed-generation
TPU pods or cloud VMs) the partitioner needs per-worker matching capacities
``m_k`` (symbols/us).  The paper measures several partial matching runs and
takes the *median* — we do the same, against a benchmark DFA, using the jit'd
sequential matcher.  Profiling is re-run at cluster (re)start, which is also
our straggler-mitigation hook: a persistently slow host simply receives a
proportionally smaller shard (Eq. 5).

The same measure-then-choose discipline drives ``autotune_spec_shapes``, the
opt-in shape autotuner behind ``Matcher(autotune=True)``: instead of the
near-square ``mesh_shape="auto"`` heuristic and fixed kernel block sizes, it
times candidate ``(num_chunks, mesh_shape, l_blk)`` configurations on a
synthetic probe corpus and applies the measured winner.  Results cache per
(DFA, candidates, fleet, backend) key — in-process by default, on disk when
``$REPRO_AUTOTUNE_CACHE`` names a JSON path (so repeated cold starts on the
same host skip the measurement entirely).

The synthetic probe is only the cold-start guess.  ``TrafficProfile``
accumulates the (batch fill, document length) distribution of *real*
dispatches; its ``snapshot()`` — an ``ObservedTraffic`` signature — can be
fed back through ``autotune_spec_shapes(observed=...)`` so the probe corpus
mirrors what the matcher actually serves, and ``ObservedTraffic.drift``
tells ``Matcher.maybe_retune`` when the live distribution has moved far
enough from the one the current shapes were tuned on to justify re-timing.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from .automata import DFA, random_dfa
from .engine import sequential_state
from .partition import capacity_weights

__all__ = ["profile_capacity", "profile_workers", "synthetic_capacities",
           "calibrated_capacities", "clear_calibration_cache",
           "TunedShape", "autotune_spec_shapes", "clear_autotune_cache",
           "ObservedTraffic", "TrafficProfile", "synthetic_traffic"]


def profile_capacity(dfa: DFA | None = None, *, n_symbols: int = 200_000,
                     repeats: int = 5, seed: int = 0,
                     devices=None) -> float | np.ndarray:
    """Median symbols/us of the sequential matcher (paper Sec. 4.1 step 1).

    With ``devices=None`` (the default): one measurement on the default
    device, returned as a float — the original single-host behavior.

    With ``devices=`` a sequence of jax devices: the same benchmark run is
    timed *per device* (tables and symbol stream placed there explicitly)
    and a [D] symbols/us array comes back — ready to feed
    ``Matcher(capacities=...)`` / ``profile_workers`` as the Eq. 1 inputs.
    On a 2-D ("doc", "chunk") matcher mesh, pass the mesh devices flattened
    row-major — ``Matcher`` consumes capacities in that order and weights
    each mesh row's chunk axis by its own devices.  This is the multi-worker
    hook ``Matcher(..., calibrate=True)`` and ``StreamMatcher`` run at
    start; re-running it at cluster (re)start is the straggler-mitigation
    path (a persistently slow device simply gets a proportionally smaller
    chunk of every bucket, Eq. 5).
    """
    rng = np.random.default_rng(seed)
    if dfa is None:
        dfa = random_dfa(64, 16, rng=rng)
    table_np = dfa.table
    classes_np = rng.integers(0, dfa.n_classes, size=n_symbols, dtype=np.int32)

    def measure(device) -> float:
        if device is None:
            table = jnp.asarray(table_np)
            classes = jnp.asarray(classes_np)
        else:
            table = jax.device_put(jnp.asarray(table_np), device)
            classes = jax.device_put(jnp.asarray(classes_np), device)
        start = jnp.int32(dfa.start)
        sequential_state(table, classes, start).block_until_ready()  # warmup
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            sequential_state(table, classes, start).block_until_ready()
            times.append(time.perf_counter() - t0)
        return n_symbols / (float(np.median(times)) * 1e6)

    if devices is None:
        return measure(None)
    return np.array([measure(d) for d in devices], dtype=np.float64)


# (device set, benchmark signature) -> measured [D] capacities.  Calibration
# is a timed benchmark per device: constructing several Matcher(calibrate=
# True) instances over the same fleet must not pay it repeatedly, and only
# the rebalance path (Matcher.recalibrate) owns an explicit refresh.
_CALIBRATION_CACHE: dict[tuple, np.ndarray] = {}


def _calibration_key(devices, dfa: DFA | None, n_symbols: int, repeats: int,
                     seed: int) -> tuple:
    # a custom benchmark DFA changes what is being measured -> its content
    # hashes into the key; the default benchmark is pinned by its parameters
    sig = ("default",) if dfa is None else (
        dfa.table.shape, dfa.table.tobytes(), int(dfa.start))
    return (tuple(str(d) for d in devices), sig, int(n_symbols),
            int(repeats), int(seed))


def calibrated_capacities(devices, dfa: DFA | None = None, *,
                          n_symbols: int = 200_000, repeats: int = 5,
                          seed: int = 0, refresh: bool = False) -> np.ndarray:
    """Cached ``profile_capacity`` over a device set (one measurement per
    (device set, benchmark) pair per process).

    ``refresh=True`` forces a re-measurement and replaces the cache entry —
    the hook ``Matcher.recalibrate`` uses when observed degradation says the
    cached profile no longer reflects reality.  Returns a copy; mutating the
    result never corrupts the cache.
    """
    key = _calibration_key(devices, dfa, n_symbols, repeats, seed)
    if refresh or key not in _CALIBRATION_CACHE:
        _CALIBRATION_CACHE[key] = np.asarray(
            profile_capacity(dfa, n_symbols=n_symbols, repeats=repeats,
                             seed=seed, devices=list(devices)), np.float64)
    return _CALIBRATION_CACHE[key].copy()


def clear_calibration_cache() -> None:
    """Drop every cached calibration (tests; full cluster restart)."""
    _CALIBRATION_CACHE.clear()


def profile_workers(capacities: np.ndarray | list[float]) -> np.ndarray:
    """Eq. 1 weights from measured capacities (one entry per worker)."""
    return capacity_weights(np.asarray(capacities, dtype=np.float64))


# --------------------------------------------------------------------------
# observed traffic (autotune feedback loop)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ObservedTraffic:
    """Compact signature of dispatch traffic: the probe corpus to tune on.

    ``batch`` is the median real-document fill of a dispatched tile;
    ``lengths`` a sorted quantile sample of real document lengths (one probe
    document per entry).  Hashable, so it extends the autotune cache key.
    """

    batch: int
    lengths: tuple

    def drift(self, other: "ObservedTraffic") -> float:
        """Symmetric distribution distance, in doublings.

        The max of |log2| ratios of the median document length and of the
        tile fill — 1.0 means the traffic halved or doubled on some axis,
        which is the scale at which a different ``l_blk`` / ``num_chunks``
        starts winning.
        """
        def ratio(a: float, b: float) -> float:
            return abs(float(np.log2(max(a, 1.0) / max(b, 1.0))))

        med_a = float(np.median(self.lengths)) if self.lengths else 1.0
        med_b = float(np.median(other.lengths)) if other.lengths else 1.0
        return max(ratio(med_a, med_b),
                   ratio(float(self.batch), float(other.batch)))


def synthetic_traffic(probe_docs: int = 8,
                      probe_bytes: int = 2048) -> ObservedTraffic:
    """The traffic signature of the default synthetic probe corpus.

    ``Matcher(autotune=True)`` seeds its drift baseline with this, so the
    first ``maybe_retune`` compares real traffic against what the cold-start
    tuning actually measured.
    """
    return ObservedTraffic(batch=int(probe_docs),
                           lengths=(int(probe_bytes),) * int(probe_docs))


class TrafficProfile:
    """Bounded reservoir of observed (tile fill, document length) samples.

    ``Matcher._dispatch`` records every dispatched tile; ``snapshot()``
    collapses the reservoir into an ``ObservedTraffic`` signature (median
    fill + length quantiles).  Bounded deques keep long-running servers at
    O(max_samples) memory while tracking the *recent* distribution — which
    is exactly what drift detection wants.
    """

    def __init__(self, max_samples: int = 4096):
        if max_samples < 1:
            raise ValueError("max_samples must be >= 1")
        self.max_samples = int(max_samples)
        self._lengths: deque = deque(maxlen=self.max_samples)
        self._batches: deque = deque(maxlen=self.max_samples)
        self.n_tiles = 0
        self.n_docs = 0

    @property
    def n_samples(self) -> int:
        return len(self._lengths)

    def record(self, batch: int, lengths) -> None:
        """One dispatched tile: ``batch`` real docs with these lengths."""
        self.n_tiles += 1
        self.n_docs += int(batch)
        self._batches.append(int(batch))
        self._lengths.extend(int(x) for x in np.asarray(lengths).ravel())

    def snapshot(self, probe_docs: int = 8) -> Optional[ObservedTraffic]:
        """Current signature, or None before any traffic was recorded."""
        if not self._lengths:
            return None
        lens = np.asarray(self._lengths, dtype=np.float64)
        qs = np.quantile(lens, np.linspace(0.0, 1.0, int(probe_docs)))
        lengths = tuple(int(max(1, round(q))) for q in qs)
        batch = int(max(1, round(float(np.median(self._batches)))))
        return ObservedTraffic(batch=batch, lengths=lengths)


# --------------------------------------------------------------------------
# shape autotuner (Matcher(autotune=True))
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TunedShape:
    """A measured shape choice for the speculative path.

    ``mesh_shape`` is the winning (doc, chunk) extents when the search ran
    over an ``"auto"`` sharded mesh (else the caller's value echoed back);
    ``l_blk`` is the winning kernel symbol-block length (0 = not searched —
    only the pallas backend scans symbols in L-blocks).  ``source`` records
    provenance: "measured", "cache" (in-process) or "disk" (the
    ``$REPRO_AUTOTUNE_CACHE`` file).
    """

    num_chunks: int
    mesh_shape: Optional[tuple]
    l_blk: int
    us_per_call: float
    source: str


_AUTOTUNE_CACHE: dict[str, TunedShape] = {}
_AUTOTUNE_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"


def clear_autotune_cache() -> None:
    """Drop every in-process autotune result (tests; never touches disk)."""
    _AUTOTUNE_CACHE.clear()


def _autotune_key(packed, backend: str, nc_cands, lb_cands, mesh_shape,
                  devices, lookahead_r, observed=None) -> str:
    h = hashlib.sha256()
    h.update(packed.table.tobytes())
    h.update(packed.starts.tobytes())
    obs_sig = None if observed is None else (int(observed.batch),
                                             tuple(observed.lengths))
    h.update(repr((backend, tuple(nc_cands), tuple(lb_cands),
                   mesh_shape, devices, lookahead_r, obs_sig,
                   tuple(str(d) for d in jax.devices()))).encode())
    return h.hexdigest()[:24]


def _disk_cache_load(path: str) -> dict:
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return {}


def _disk_cache_store(path: str, key: str, tuned: TunedShape) -> None:
    data = _disk_cache_load(path)
    row = dataclasses.asdict(tuned)
    row["mesh_shape"] = list(tuned.mesh_shape) if isinstance(
        tuned.mesh_shape, tuple) else tuned.mesh_shape
    data[key] = row
    try:
        with open(path, "w") as fh:
            json.dump(data, fh, indent=1, sort_keys=True)
    except OSError:
        pass  # unwritable cache path degrades to in-process caching


def _probe_corpus(num_docs: int, doc_bytes: int, n_alpha: int = 8):
    rng = np.random.default_rng(0)
    return [rng.integers(0, n_alpha, size=doc_bytes).astype(np.uint8)
            for _ in range(num_docs)]


def _observed_corpus(observed: ObservedTraffic, n_alpha: int = 8):
    """Synthetic bytes shaped like the observed traffic (deterministic)."""
    rng = np.random.default_rng(0)
    return [rng.integers(0, n_alpha, size=max(1, int(n))).astype(np.uint8)
            for n in observed.lengths]


def _measure_config(packed, probe, *, backend: str, num_chunks: int,
                    mesh_shape, devices, l_blk: int, lookahead_r,
                    repeats: int, batch_tile: Optional[int] = None) -> float:
    from .engine.facade import Matcher  # lazy: facade imports this module
    kw = {}
    if backend == "sharded":
        kw.update(mesh_shape=mesh_shape, devices=devices)
    if batch_tile is None:
        batch_tile = max(8, len(probe))
    m = Matcher(packed, num_chunks=num_chunks, backend=backend,
                batch_tile=int(batch_tile), lookahead_r=lookahead_r, **kw)
    if l_blk:
        m.executor.spec_l_blk[0] = int(l_blk)
    m.membership_batch(probe)  # warmup: trace + compile outside the clock
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        m.membership_batch(probe)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def autotune_spec_shapes(packed, *, backend: str = "local",
                         num_chunks_candidates: Sequence[int] = (4, 8),
                         mesh_shape=None, devices: Optional[int] = None,
                         lookahead_r="auto",
                         l_blk_candidates: Sequence[int] = (128, 256, 512),
                         probe_docs: int = 8, probe_bytes: int = 2048,
                         repeats: int = 2,
                         time_fn: Optional[Callable[[dict], float]] = None,
                         observed: Optional[ObservedTraffic] = None,
                         refresh: bool = False) -> TunedShape:
    """Measure candidate speculative shapes and return the fastest.

    Greedy coordinate descent over three axes — ``num_chunks`` (every
    backend), mesh (doc, chunk) extents (sharded backend with
    ``mesh_shape="auto"``: all divisor factorings of the fleet, near-square
    first), and the kernel symbol-block length ``l_blk`` (pallas backend
    only) — each axis tuned while the others hold their incumbent, so a
    3-axis search costs sums of candidates, not products.  Each candidate is
    one ``Matcher`` construction timed on a deterministic synthetic corpus
    (median of ``repeats`` post-warmup ``membership_batch`` calls).

    ``time_fn`` replaces the measurement: it receives the candidate config
    as a dict (``backend`` / ``num_chunks`` / ``mesh_shape`` / ``l_blk``)
    and returns a cost in microseconds — deterministic unit testing without
    timing noise or device work.  Results cache per (DFA, candidates,
    fleet, backend) key: in-process always, and through the JSON file named
    by ``$REPRO_AUTOTUNE_CACHE`` when set (``refresh=True`` re-measures and
    overwrites both).

    ``observed`` replaces the synthetic ``probe_docs`` x ``probe_bytes``
    corpus with one shaped like real traffic (an ``ObservedTraffic``
    snapshot from ``TrafficProfile`` — document-length quantiles become the
    probe documents, the median tile fill becomes the probe batch tile).
    The bytes stay synthetic; only the *shape* of the traffic is observed.
    The signature extends the cache key, so re-tuning after drift never
    reuses a stale measurement.
    """
    nc_cands = [int(c) for c in num_chunks_candidates if int(c) >= 1]
    if not nc_cands:
        raise ValueError("need at least one num_chunks candidate")
    lb_cands = ([int(b) for b in l_blk_candidates if int(b) >= 1]
                if backend == "pallas" else [])
    key = _autotune_key(packed, backend, nc_cands, lb_cands, mesh_shape,
                        devices, lookahead_r, observed)
    cache_path = os.environ.get(_AUTOTUNE_CACHE_ENV)
    if not refresh:
        if key in _AUTOTUNE_CACHE:
            return dataclasses.replace(_AUTOTUNE_CACHE[key], source="cache")
        if cache_path:
            row = _disk_cache_load(cache_path).get(key)
            if row is not None:
                ms = row.get("mesh_shape")
                tuned = TunedShape(
                    num_chunks=int(row["num_chunks"]),
                    mesh_shape=tuple(ms) if isinstance(ms, list) else ms,
                    l_blk=int(row["l_blk"]),
                    us_per_call=float(row["us_per_call"]), source="disk")
                _AUTOTUNE_CACHE[key] = tuned
                return tuned

    if backend == "sharded" and mesh_shape == "auto":
        n_dev = int(devices) if devices else len(jax.devices())
        mesh_cands = sorted(((d, n_dev // d) for d in range(1, n_dev + 1)
                             if n_dev % d == 0),
                            key=lambda s: abs(s[0] - s[1]))
    else:
        mesh_cands = [mesh_shape if backend == "sharded" else None]

    if observed is None:
        probe = _probe_corpus(probe_docs, probe_bytes)
        batch_tile = None
    else:
        probe = _observed_corpus(observed)
        batch_tile = max(8, len(probe), int(observed.batch))
    scores: dict[tuple, float] = {}

    def cost(nc: int, ms, lb: int) -> float:
        cfg = (nc, tuple(ms) if isinstance(ms, (tuple, list)) else ms, lb)
        if cfg not in scores:
            if time_fn is not None:
                scores[cfg] = float(time_fn(
                    {"backend": backend, "num_chunks": nc,
                     "mesh_shape": cfg[1], "l_blk": lb}))
            else:
                scores[cfg] = _measure_config(
                    packed, probe, backend=backend, num_chunks=nc,
                    mesh_shape=ms, devices=devices, l_blk=lb,
                    lookahead_r=lookahead_r, repeats=repeats,
                    batch_tile=batch_tile)
        return scores[cfg]

    base_lb = lb_cands[-1] if lb_cands else 0
    best_nc = min(nc_cands, key=lambda nc: cost(nc, mesh_cands[0], base_lb))
    best_ms = (min(mesh_cands, key=lambda ms: cost(best_nc, ms, base_lb))
               if len(mesh_cands) > 1 else mesh_cands[0])
    best_lb = (min(lb_cands, key=lambda lb: cost(best_nc, best_ms, lb))
               if lb_cands else 0)
    tuned = TunedShape(
        num_chunks=best_nc,
        mesh_shape=(tuple(best_ms) if isinstance(best_ms, (tuple, list))
                    else best_ms),
        l_blk=best_lb, us_per_call=cost(best_nc, best_ms, best_lb),
        source="measured")
    _AUTOTUNE_CACHE[key] = tuned
    if cache_path:
        _disk_cache_store(cache_path, key, tuned)
    return tuned


def synthetic_capacities(n_workers: int, *, ratio: float = 1.41,
                         n_fast: int | None = None) -> np.ndarray:
    """Deliberately skewed capacity profile for benchmarks and tests.

    ``n_fast`` workers run at ``ratio``x the base speed — 1.41 is the paper's
    measured gap between its two EC2 instance generations (Table 3); the
    default skews half the fleet.  Feed the result to ``profile_workers`` /
    ``Matcher(capacities=...)`` to exercise the capacity-balanced planner
    without a real heterogeneous fleet.
    """
    if n_workers < 1:
        raise ValueError("need at least one worker")
    if n_fast is None:
        n_fast = n_workers // 2
    if not 0 <= n_fast <= n_workers:
        raise ValueError("n_fast out of range")
    return np.array([ratio] * n_fast + [1.0] * (n_workers - n_fast),
                    dtype=np.float64)
