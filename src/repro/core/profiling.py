"""Offline capacity profiling (paper Sec. 4.1 step 1, Eq. 1).

On heterogeneous fleets (the paper's EC2 scenario; for us, mixed-generation
TPU pods or cloud VMs) the partitioner needs per-worker matching capacities
``m_k`` (symbols/us).  The paper measures several partial matching runs and
takes the *median* — we do the same, against a benchmark DFA, using the jit'd
sequential matcher.  Profiling is re-run at cluster (re)start, which is also
our straggler-mitigation hook: a persistently slow host simply receives a
proportionally smaller shard (Eq. 5).
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from .automata import DFA, random_dfa
from .engine import sequential_state
from .partition import capacity_weights

__all__ = ["profile_capacity", "profile_workers", "synthetic_capacities",
           "calibrated_capacities", "clear_calibration_cache"]


def profile_capacity(dfa: DFA | None = None, *, n_symbols: int = 200_000,
                     repeats: int = 5, seed: int = 0,
                     devices=None) -> float | np.ndarray:
    """Median symbols/us of the sequential matcher (paper Sec. 4.1 step 1).

    With ``devices=None`` (the default): one measurement on the default
    device, returned as a float — the original single-host behavior.

    With ``devices=`` a sequence of jax devices: the same benchmark run is
    timed *per device* (tables and symbol stream placed there explicitly)
    and a [D] symbols/us array comes back — ready to feed
    ``Matcher(capacities=...)`` / ``profile_workers`` as the Eq. 1 inputs.
    On a 2-D ("doc", "chunk") matcher mesh, pass the mesh devices flattened
    row-major — ``Matcher`` consumes capacities in that order and weights
    each mesh row's chunk axis by its own devices.  This is the multi-worker
    hook ``Matcher(..., calibrate=True)`` and ``StreamMatcher`` run at
    start; re-running it at cluster (re)start is the straggler-mitigation
    path (a persistently slow device simply gets a proportionally smaller
    chunk of every bucket, Eq. 5).
    """
    rng = np.random.default_rng(seed)
    if dfa is None:
        dfa = random_dfa(64, 16, rng=rng)
    table_np = dfa.table
    classes_np = rng.integers(0, dfa.n_classes, size=n_symbols, dtype=np.int32)

    def measure(device) -> float:
        if device is None:
            table = jnp.asarray(table_np)
            classes = jnp.asarray(classes_np)
        else:
            table = jax.device_put(jnp.asarray(table_np), device)
            classes = jax.device_put(jnp.asarray(classes_np), device)
        start = jnp.int32(dfa.start)
        sequential_state(table, classes, start).block_until_ready()  # warmup
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            sequential_state(table, classes, start).block_until_ready()
            times.append(time.perf_counter() - t0)
        return n_symbols / (float(np.median(times)) * 1e6)

    if devices is None:
        return measure(None)
    return np.array([measure(d) for d in devices], dtype=np.float64)


# (device set, benchmark signature) -> measured [D] capacities.  Calibration
# is a timed benchmark per device: constructing several Matcher(calibrate=
# True) instances over the same fleet must not pay it repeatedly, and only
# the rebalance path (Matcher.recalibrate) owns an explicit refresh.
_CALIBRATION_CACHE: dict[tuple, np.ndarray] = {}


def _calibration_key(devices, dfa: DFA | None, n_symbols: int, repeats: int,
                     seed: int) -> tuple:
    # a custom benchmark DFA changes what is being measured -> its content
    # hashes into the key; the default benchmark is pinned by its parameters
    sig = ("default",) if dfa is None else (
        dfa.table.shape, dfa.table.tobytes(), int(dfa.start))
    return (tuple(str(d) for d in devices), sig, int(n_symbols),
            int(repeats), int(seed))


def calibrated_capacities(devices, dfa: DFA | None = None, *,
                          n_symbols: int = 200_000, repeats: int = 5,
                          seed: int = 0, refresh: bool = False) -> np.ndarray:
    """Cached ``profile_capacity`` over a device set (one measurement per
    (device set, benchmark) pair per process).

    ``refresh=True`` forces a re-measurement and replaces the cache entry —
    the hook ``Matcher.recalibrate`` uses when observed degradation says the
    cached profile no longer reflects reality.  Returns a copy; mutating the
    result never corrupts the cache.
    """
    key = _calibration_key(devices, dfa, n_symbols, repeats, seed)
    if refresh or key not in _CALIBRATION_CACHE:
        _CALIBRATION_CACHE[key] = np.asarray(
            profile_capacity(dfa, n_symbols=n_symbols, repeats=repeats,
                             seed=seed, devices=list(devices)), np.float64)
    return _CALIBRATION_CACHE[key].copy()


def clear_calibration_cache() -> None:
    """Drop every cached calibration (tests; full cluster restart)."""
    _CALIBRATION_CACHE.clear()


def profile_workers(capacities: np.ndarray | list[float]) -> np.ndarray:
    """Eq. 1 weights from measured capacities (one entry per worker)."""
    return capacity_weights(np.asarray(capacities, dtype=np.float64))


def synthetic_capacities(n_workers: int, *, ratio: float = 1.41,
                         n_fast: int | None = None) -> np.ndarray:
    """Deliberately skewed capacity profile for benchmarks and tests.

    ``n_fast`` workers run at ``ratio``x the base speed — 1.41 is the paper's
    measured gap between its two EC2 instance generations (Table 3); the
    default skews half the fleet.  Feed the result to ``profile_workers`` /
    ``Matcher(capacities=...)`` to exercise the capacity-balanced planner
    without a real heterogeneous fleet.
    """
    if n_workers < 1:
        raise ValueError("need at least one worker")
    if n_fast is None:
        n_fast = n_workers // 2
    if not 0 <= n_fast <= n_workers:
        raise ValueError("n_fast out of range")
    return np.array([ratio] * n_fast + [1.0] * (n_workers - n_fast),
                    dtype=np.float64)
