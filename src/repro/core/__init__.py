"""Core library: the paper's speculative parallel DFA membership test."""

from .automata import (DFA, NFA, PackedDFA, make_search_dfa, pack_dfas,
                       packed_signature, random_dfa)
from .determinize import compile_prosite, compile_regex, minimize, nfa_to_dfa
from .engine import (BatchMatcher, BatchResult, BlockedMatcher, ChunkLayout,
                     DeviceTables, Matcher, MatchPlan, MatchResult,
                     MeshLayout, Planner, SegmentBatchResult, ShardedExecutor,
                     SpecDFAEngine, match_chunks_lanes, sequential_state)
from .lookahead import (LookaheadTables, PackedLookaheadTables,
                        build_lookahead_tables, build_packed_lookahead_tables,
                        i_max_r, i_sigma_sets)
from .lvector import (compose, compose_jnp, identity_lvec, merge_compressed,
                      merge_scan_jnp, merge_sequential, merge_tree)
from .partition import Partition, capacity_weights, uniform_partition, weighted_partition
from .patterns import (PCRE_PATTERNS, PROSITE_PATTERNS, PatternSet,
                       compile_pattern_suite)
from .prefilter import Prefilter, required_literal, window_fingerprints
from .profiling import profile_capacity, profile_workers, synthetic_capacities
from .regex import parse_regex, prosite_to_regex, regex_to_nfa

__all__ = [
    "DFA", "NFA", "PackedDFA", "make_search_dfa", "pack_dfas",
    "packed_signature", "random_dfa",
    "compile_regex", "compile_prosite", "minimize", "nfa_to_dfa",
    "MatchResult", "BatchResult", "SegmentBatchResult", "SpecDFAEngine",
    "BatchMatcher", "Matcher", "BlockedMatcher",
    "MatchPlan", "Planner", "ChunkLayout", "MeshLayout", "DeviceTables",
    "ShardedExecutor",
    "match_chunks_lanes", "sequential_state",
    "LookaheadTables", "PackedLookaheadTables", "build_lookahead_tables",
    "build_packed_lookahead_tables", "i_max_r", "i_sigma_sets",
    "compose", "compose_jnp", "identity_lvec", "merge_compressed",
    "merge_scan_jnp", "merge_sequential", "merge_tree",
    "Partition", "capacity_weights", "uniform_partition", "weighted_partition",
    "PCRE_PATTERNS", "PROSITE_PATTERNS", "PatternSet",
    "compile_pattern_suite",
    "Prefilter", "required_literal", "window_fingerprints",
    "profile_capacity", "profile_workers", "synthetic_capacities",
    "parse_regex", "prosite_to_regex", "regex_to_nfa",
]
