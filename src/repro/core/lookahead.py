"""Structural-DFA lookahead analysis (paper Sec. 4.2/4.3).

``I_sigma`` (Eq. 11): states with an incoming transition labelled sigma,
excluding the sink q_e.  ``I_max = max_sigma |I_sigma|`` (Eq. 12).

For ``r`` reverse-lookahead symbols, ``I_{s1..sr}`` (Eq. 13) is the image of Q
under the suffix string.  The paper's Algorithm 4 enumerates all |Sigma|^r
suffixes — O(|Sigma|^r · |Q|).  We additionally implement an exact *deduped
image BFS* (beyond-paper): level k holds the set of **distinct** images
``delta*(Q, w), |w| = k``; distinct-image counts are typically tiny, so the
cost is O(levels · distinct_images · |Sigma| · |Q|) independent of |Sigma|^r.
Lemma 1 (monotone non-increase of I_max,r) is property-tested in tests/.

Runtime tables: ``candidates[sigma, I_max]`` padded candidate lists used by the
speculative matcher to decide which states to match per chunk, given the chunk's
reverse lookahead symbol.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .automata import DFA, PackedDFA

__all__ = ["LookaheadTables", "PackedLookaheadTables", "i_sigma_sets",
           "i_sigma2_sets", "i_max_r", "build_lookahead_tables",
           "build_packed_lookahead_tables"]


def i_sigma_sets(dfa: DFA) -> list[set[int]]:
    """Eq. 11 for every class sigma; the sink is excluded per the paper."""
    sets: list[set[int]] = []
    for c in range(dfa.n_classes):
        tgts = set(int(t) for t in dfa.table[:, c])
        tgts.discard(dfa.sink)
        sets.append(tgts)
    return sets


def _image(dfa: DFA, states: frozenset[int], cls: int) -> frozenset[int]:
    return frozenset(int(dfa.table[s, cls]) for s in states)


def i_max_r(dfa: DFA, r: int, *, method: str = "dedup",
            max_images: int = 100_000) -> list[int]:
    """Return [I_max,1 .. I_max,r].

    method="enum" is the paper's Algorithm 4 (exponential in r);
    method="dedup" is the exact distinct-image BFS (beyond-paper).
    Both exclude the sink from counts.
    """
    sink = dfa.sink

    def count(s: frozenset[int]) -> int:
        return len(s - {sink}) if sink >= 0 else len(s)

    if method == "enum":
        out: list[int] = []
        level: list[frozenset[int]] = [frozenset(range(dfa.n_states))]
        for _ in range(r):
            nxt: list[frozenset[int]] = []
            for s in level:
                for c in range(dfa.n_classes):
                    nxt.append(_image(dfa, s, c))
            out.append(max(1, max(count(s) for s in nxt)))
            level = nxt
        return out

    if method != "dedup":
        raise ValueError(f"unknown method {method!r}")
    # Bitmask images + exact subset pruning.  Applying delta_sigma to a set
    # never grows it, and images of subsets stay subsets, so only inclusion-
    # maximal image sets can realize the level maximum — pruning them is
    # EXACT, and collapses the level width from |Sigma|^r to typically a
    # handful of sets (the beyond-paper improvement over Algorithm 4).
    q = dfa.n_states
    sink_bit = (1 << dfa.sink) if dfa.sink >= 0 else 0

    def popcount_no_sink(mask: int) -> int:
        return (mask & ~sink_bit).bit_count()

    # per class: state -> target bit
    tgt_bits = [[1 << int(dfa.table[s, c]) for s in range(q)]
                for c in range(dfa.n_classes)]

    def image_mask(mask: int, c: int) -> int:
        out_m = 0
        bits = tgt_bits[c]
        m = mask
        while m:
            low = m & -m
            out_m |= bits[low.bit_length() - 1]
            m ^= low
        return out_m

    def prune_maximal(masks: set[int]) -> list[int]:
        ordered = sorted(masks, key=lambda m: -m.bit_count())
        kept: list[int] = []
        for m in ordered:
            if not any(m & ~k == 0 for k in kept):
                kept.append(m)
            if len(kept) >= max_images:
                break
        return kept

    out = []
    level = [(1 << q) - 1]
    for _ in range(r):
        nxt = {image_mask(m, c) for m in level for c in range(dfa.n_classes)}
        level = prune_maximal(nxt)
        # clamp to 1: a chunk always matches at least one state, even for
        # degenerate DFAs whose every symbol leads to the sink
        out.append(max(1, max(popcount_no_sink(m) for m in level)))
    return out


@dataclasses.dataclass
class LookaheadTables:
    """Device-ready candidate tables for the speculative matcher (r = 1).

    candidates[c, j]  : j-th candidate initial state for lookahead class c,
                        padded with the sink (or state 0 if no sink) to I_max.
    cand_count[c]     : |I_c|.
    i_max             : max_c |I_c|  (the paper's I_max).
    cand_index[c, q]  : inverse map — position of state q in candidates[c],
                        or -1 if q not in I_c.  Used by the merge step to look
                        up the propagated state inside a chunk's L-vector.
    """

    candidates: np.ndarray  # [n_classes, i_max] int32
    cand_count: np.ndarray  # [n_classes] int32
    cand_index: np.ndarray  # [n_classes, Q] int32
    i_max: int
    gamma: float  # I_max / |Q|, the paper's structural property


def i_sigma2_sets(dfa: DFA) -> list[set[int]]:
    """Eq. 13 for every 2-symbol suffix (paper Algorithm 4, r = 2).

    Index layout: suffix (c1, c2) -> c1 * n_classes + c2, where c2 is the
    chunk's last symbol (matched second).
    """
    n = dfa.n_classes
    sets: list[set[int]] = [set() for _ in range(n * n)]
    tbl = dfa.table
    for c1 in range(n):
        mid = np.unique(tbl[:, c1])
        for c2 in range(n):
            tg = set(int(t) for t in tbl[mid, c2])
            tg.discard(dfa.sink)
            sets[c1 * n + c2] = tg
    return sets


@dataclasses.dataclass
class PackedLookaheadTables:
    """Eq. 11/13 candidate tables for a ``PackedDFA``, keyed by boundary keys.

    A *boundary key* generalizes the paper's reverse-lookahead class to
    ``r`` symbols of suffix context: for ``r = 1`` the key is the joint class
    of the boundary byte itself (Eq. 11, ``n_keys == n_classes``); for
    ``r = 2`` it is the pair index ``c_prev * n_classes + c_last`` (Eq. 13,
    ``n_keys == n_classes ** 2``) whose feasible image is typically far
    smaller, shrinking the shared lane width ``i_max`` — the dominant
    ``[B, K, S]`` streaming cost (PaREM, arXiv:1412.1741).

    The candidate axis is per *pattern*: lanes in the batched matcher are laid
    out ``[K, i_max]`` per chunk, and ``cand_index`` maps a packed state id to
    its lane inside its own pattern's candidate row (-1 if not a candidate —
    notably each pattern's sink).

    candidates[key, k, j] : j-th candidate packed state of pattern k for
                            boundary key ``key``, padded with pattern k's sink
                            (or its start if it has no dead state).
    cand_count[key, k]    : |I_key^k|.
    cand_index[key, q]    : lane of packed state q in its pattern's row, or -1.
    i_max                 : max_{key,k} |I_key^k| — the shared lane width.
    gamma                 : worst per-pattern I_max / (|Q_k| - has_sink).
    r                     : reverse-lookahead depth of the key space (1 or 2).
    n_keys                : boundary-key count (``n_classes ** r``); the pad
                            key (identity merge) is ``n_keys`` itself.
    """

    candidates: np.ndarray  # [n_keys, K, i_max] int32
    cand_count: np.ndarray  # [n_keys, K] int32
    cand_index: np.ndarray  # [n_keys, Q_total] int32
    i_max: int
    gamma: float
    r: int = 1
    n_keys: int = 0  # derived from candidates when left at 0

    def __post_init__(self):
        if self.n_keys == 0:
            self.n_keys = int(self.candidates.shape[0])


def _packed_candidate_sets(packed: PackedDFA, r: int) -> list[list[list[int]]]:
    """[n_keys][K] sorted candidate state lists for boundary keys of depth r.

    r=1: ``I_c^k`` = targets of pattern k's states under class c (Eq. 11).
    r=2: ``I_{c1,c2}^k`` = the image of pattern k's states under the suffix
    string (c1, c2) — mirror of ``i_sigma2_sets`` per pattern slice (Eq. 13).
    Sinks are excluded per the paper.
    """
    n_cls, k_pat = packed.n_classes, packed.n_patterns
    slices = [packed.pattern_slice(k) for k in range(k_pat)]
    sets: list[list[list[int]]] = []
    if r == 1:
        for c in range(n_cls):
            per_key = []
            for k in range(k_pat):
                tgts = set(int(t) for t in packed.table[slices[k], c])
                tgts.discard(int(packed.sinks[k]))
                per_key.append(sorted(tgts))
            sets.append(per_key)
        return sets
    # r == 2: key layout c1 * n_classes + c2 (c2 is the boundary byte itself,
    # matched second) — packed transitions never leave a pattern's slice, so
    # the one-step image ``mid`` stays per-pattern
    mids = [[np.unique(packed.table[slices[k], c1]) for k in range(k_pat)]
            for c1 in range(n_cls)]
    for c1 in range(n_cls):
        for c2 in range(n_cls):
            per_key = []
            for k in range(k_pat):
                tgts = set(int(t) for t in packed.table[mids[c1][k], c2])
                tgts.discard(int(packed.sinks[k]))
                per_key.append(sorted(tgts))
            sets.append(per_key)
    return sets


def build_packed_lookahead_tables(packed: PackedDFA,
                                  r: int = 1) -> PackedLookaheadTables:
    if r not in (1, 2):
        raise ValueError("packed runtime lookahead supports r in (1, 2); "
                         "use i_max_r for analysis at larger r")
    n_cls, k_pat, q_tot = packed.n_classes, packed.n_patterns, packed.n_states
    n_keys = n_cls ** r
    sets = _packed_candidate_sets(packed, r)
    i_max = max(1, max((len(s) for per in sets for s in per), default=1))
    pad = np.array([packed.sinks[k] if packed.sinks[k] >= 0 else packed.starts[k]
                    for k in range(k_pat)], np.int32)
    candidates = np.broadcast_to(pad[None, :, None],
                                 (n_keys, k_pat, i_max)).copy()
    cand_count = np.zeros((n_keys, k_pat), np.int32)
    cand_index = np.full((n_keys, q_tot), -1, np.int32)
    for key in range(n_keys):
        for k in range(k_pat):
            ordered = sets[key][k]
            cand_count[key, k] = len(ordered)
            for j, st in enumerate(ordered):
                candidates[key, k, j] = st
                cand_index[key, st] = j
    gamma = 0.0
    for k in range(k_pat):
        q_k = int(packed.offsets[k + 1] - packed.offsets[k])
        live = max(q_k - (1 if packed.sinks[k] >= 0 else 0), 1)
        k_imax = max(1, int(cand_count[:, k].max(initial=0)))
        gamma = max(gamma, min(float(k_imax) / float(live), 1.0))
    return PackedLookaheadTables(candidates=candidates, cand_count=cand_count,
                                 cand_index=cand_index, i_max=i_max,
                                 gamma=gamma, r=r, n_keys=n_keys)


def build_lookahead_tables(dfa: DFA, *, r: int = 1) -> LookaheadTables:
    if r == 2:
        sets = i_sigma2_sets(dfa)
    elif r == 1:
        sets = i_sigma_sets(dfa)
    else:
        raise ValueError("runtime lookahead supports r in (1, 2); use "
                         "i_max_r for analysis at larger r")
    i_max = max((len(s) for s in sets), default=1)
    i_max = max(i_max, 1)
    n_rows, q = len(sets), dfa.n_states
    pad_state = dfa.sink if dfa.sink >= 0 else 0
    candidates = np.full((n_rows, i_max), pad_state, dtype=np.int32)
    cand_count = np.zeros(n_rows, dtype=np.int32)
    cand_index = np.full((n_rows, q), -1, dtype=np.int32)
    for c, s in enumerate(sets):
        ordered = sorted(s)
        cand_count[c] = len(ordered)
        for j, st in enumerate(ordered):
            candidates[c, j] = st
            cand_index[c, st] = j
    # count the real number of matched states; gamma per Eq. (18)
    gamma = float(i_max) / float(max(q - (1 if dfa.sink >= 0 else 0), 1))
    return LookaheadTables(candidates=candidates, cand_count=cand_count,
                           cand_index=cand_index, i_max=i_max, gamma=min(gamma, 1.0))
