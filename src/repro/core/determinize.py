"""NFA -> DFA (subset construction) and Hopcroft minimization.

The paper builds its benchmark DFAs with Grail+ (regex -> NFA -> DFA -> minimal
DFA); this module is our Grail+ replacement, built in-repo per the "implement
every substrate" rule.  Output DFAs are *complete* (explicit sink q_e) to match
the paper's assumption of a unique error state.
"""

from __future__ import annotations

import numpy as np

from .automata import DFA, NFA
from .regex import prosite_to_regex, regex_to_nfa

__all__ = ["nfa_to_dfa", "minimize", "compile_regex", "compile_prosite"]


def nfa_to_dfa(nfa: NFA, *, max_states: int = 100_000) -> DFA:
    """Subset construction; always emits a complete DFA with an explicit sink."""
    start_set = nfa.eps_closure([nfa.start])
    index: dict[frozenset[int], int] = {start_set: 0}
    worklist = [start_set]
    rows: list[list[int]] = []
    accepting: list[bool] = []
    empty = frozenset()

    while worklist:
        cur = worklist.pop()
        # deterministic exploration order keeps state numbering stable
        i = index[cur]
        while len(rows) <= i:
            rows.append([0] * nfa.n_classes)
            accepting.append(False)
        accepting[i] = bool(cur & nfa.accepts)
        for cls in range(nfa.n_classes):
            nxt = nfa.step(cur, cls)
            key = frozenset(nxt) if nxt else empty
            if key not in index:
                if len(index) >= max_states:
                    raise RuntimeError(
                        f"subset construction exceeded {max_states} states — "
                        "bounded-repeat pattern under search prefix explodes; "
                        "rewrite the pattern or raise max_states")
                index[key] = len(index)
                worklist.append(key)
            rows[i][cls] = index[key]

    n = len(index)
    table = np.zeros((n, nfa.n_classes), dtype=np.int32)
    acc = np.zeros(n, dtype=bool)
    for i, row in enumerate(rows):
        table[i] = row
        acc[i] = accepting[i]
    # rows for states discovered but never popped before loop end are filled:
    # (worklist pops everything, so all rows are filled; assert for safety)
    assert all(len(r) == nfa.n_classes for r in rows) and len(rows) == n

    sink = index.get(frozenset(), -1)
    dfa = DFA(table=table, accepting=acc, start=0, sink=sink,
              byte_to_class=nfa.byte_to_class.copy())
    return dfa


def minimize(dfa: DFA) -> DFA:
    """Hopcroft's algorithm on the complete DFA; preserves the sink state."""
    q, n_cls = dfa.n_states, dfa.n_classes
    # build reverse transitions: rev[cls][target] -> list of sources
    rev: list[list[list[int]]] = [[[] for _ in range(q)] for _ in range(n_cls)]
    for s in range(q):
        for c in range(n_cls):
            rev[c][int(dfa.table[s, c])].append(s)

    accepting = set(np.flatnonzero(dfa.accepting).tolist())
    non_accepting = set(range(q)) - accepting
    partition: list[set[int]] = [p for p in (accepting, non_accepting) if p]
    # block id per state
    block_of = np.zeros(q, dtype=np.int64)
    for b, blk in enumerate(partition):
        for s in blk:
            block_of[s] = b
    work = {(b, c) for b in range(len(partition)) for c in range(n_cls)}

    while work:
        b, c = work.pop()
        splitter = partition[b]
        # states with a c-transition into the splitter
        x: set[int] = set()
        for t in splitter:
            x.update(rev[c][t])
        if not x:
            continue
        touched: dict[int, set[int]] = {}
        for s in x:
            touched.setdefault(int(block_of[s]), set()).add(s)
        for bid, inter in touched.items():
            blk = partition[bid]
            if len(inter) == len(blk):
                continue
            rest = blk - inter
            partition[bid] = inter
            new_id = len(partition)
            partition.append(rest)
            for s in rest:
                block_of[s] = new_id
            for cc in range(n_cls):
                if (bid, cc) in work:
                    work.add((new_id, cc))
                else:
                    smaller = bid if len(inter) <= len(rest) else new_id
                    work.add((smaller, cc))

    # rebuild with start-state-first numbering for stable tests
    order = sorted(range(len(partition)), key=lambda b: (b != block_of[dfa.start], b))
    remap = {old: new for new, old in enumerate(order)}
    m = len(partition)
    table = np.zeros((m, n_cls), dtype=np.int32)
    acc = np.zeros(m, dtype=bool)
    for old_bid, blk in enumerate(partition):
        rep = next(iter(blk))
        new_bid = remap[old_bid]
        acc[new_bid] = bool(dfa.accepting[rep])
        for c in range(n_cls):
            table[new_bid, c] = remap[int(block_of[int(dfa.table[rep, c])])]
    new = DFA(table=table, accepting=acc, start=remap[int(block_of[dfa.start])],
              sink=-1, byte_to_class=dfa.byte_to_class.copy())
    new.sink = new.find_sink()
    return new


def compile_regex(pattern: str, *, minimize_dfa: bool = True) -> DFA:
    """regex string -> minimal complete DFA (the Grail+ pipeline of Sec. 5)."""
    dfa = nfa_to_dfa(regex_to_nfa(pattern))
    return minimize(dfa) if minimize_dfa else dfa


def compile_prosite(pattern: str, *, minimize_dfa: bool = True) -> DFA:
    return compile_regex(prosite_to_regex(pattern), minimize_dfa=minimize_dfa)
