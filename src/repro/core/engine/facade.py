"""``Matcher`` facade: one entry point over the plan/executor layers.

The facade wires a packed pattern table, a ``Planner`` (bucketing, chunk
partitioning, capacity weighting) and an executor backend together behind
the pre-refactor ``BatchMatcher`` API:

    Matcher(dfas, backend="local")                      # jitted jnp path
    Matcher(dfas, backend="pallas")                     # fused Pallas kernel
    Matcher(dfas, backend="sharded", capacities=[...])  # mesh-sharded,
                                                        # capacity-balanced
    Matcher(dfas, backend="sharded", mesh_shape=(2, 4)) # 2-D doc x chunk

``BatchMatcher`` remains as a compatibility shim (``use_kernel=True`` maps to
the ``pallas`` backend).  Decisions stay bit-identical to per-document
sequential matching on every backend, mesh shape and capacity profile.
See README.md for the backend/mesh support matrix and docs/architecture.md
for the layer map.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..automata import DFA, PackedDFA, pack_dfas, packed_signature
from ..partition import capacity_weights
from .executors import LocalExecutor
from .plan import (ENTRY_LANES, ENTRY_STARTS, ENTRY_STATES, DeviceTables,
                   MeshLayout, Planner, layout_device_work, next_pow2)

__all__ = ["BatchResult", "SegmentBatchResult", "CursorBatchResult",
           "Matcher", "BatchMatcher"]

BACKENDS = ("local", "pallas", "sharded")


@dataclasses.dataclass
class BatchResult:
    """Per-batch outcome of ``Matcher.membership_batch``.

    ``accepted``/``final_states`` are [B, K] (K = packed pattern count);
    work arrays are per-document model quantities mirroring ``MatchResult``.
    ``early_exits`` counts documents retired by the absorbing-state early
    exit before their real end; ``device_work`` (sharded backend) is the [D]
    real symbols assigned per device by the plan's chunk layouts, in mesh
    row-major order (device (doc r, chunk c) at index ``r * Dc + c``).
    """

    accepted: np.ndarray        # [B, K] bool
    final_states: np.ndarray    # [B, K] int32 packed state ids
    work_parallel: np.ndarray   # [B] scalar-model work
    work_sequential: np.ndarray # [B] n * K
    time_steps: np.ndarray      # [B] lane-parallel matching steps
    bucket_calls: int           # device dispatches consumed by this batch
    early_exits: int = 0        # docs fully absorbed before their last symbol
    device_work: Optional[np.ndarray] = None  # [D] real symbols per device

    @property
    def model_speedup(self) -> float:
        return float(self.work_sequential.sum()) / max(float(self.work_parallel.sum()), 1.0)

    @property
    def lane_speedup(self) -> float:
        return float(self.work_sequential.sum()) / max(float(self.time_steps.sum()), 1.0)


@dataclasses.dataclass
class SegmentBatchResult:
    """Outcome of ``Matcher.advance_segments`` (the streaming tick call).

    ``final_states[i]`` is the exact [K] packed states after advancing
    segment ``i`` from its entry states — i.e. the next cursor states.
    ``absorbed`` marks patterns that landed in absorbing states (further
    bytes cannot move them; the scheduler's stream-level early exit).
    ``padded_rows`` counts the device rows actually dispatched (tile-padded)
    — the denominator of the scheduler's batch-occupancy metric.
    """

    final_states: np.ndarray  # [B, K] int32 packed states after the segment
    absorbed: np.ndarray      # [B, K] bool
    lengths: np.ndarray       # [B] int64 segment byte lengths
    bucket_calls: int         # fused device dispatches consumed
    padded_rows: int          # batch_tile rows dispatched across all tiles
    early_exits: int          # segments retired by the absorbing early exit


@dataclasses.dataclass
class CursorBatchResult:
    """Outcome of ``Matcher.advance_cursors`` (the candidate-keyed tick).

    ``lane_states[i]`` is stream ``i``'s [K, S] cursor lane map extended by
    its segment — the exit state per Eq. 11 candidate entry of the stream's
    *original* boundary class, composed on device with the segment's
    independent lane map (``kernels.ref.cursor_merge_ref`` is the host
    reference).  ``absorbed`` marks patterns whose every lane is absorbing.
    """

    lane_states: np.ndarray   # [B, K, S] int32 composed cursor lanes
    absorbed: np.ndarray      # [B, K] bool — all lanes absorbing
    lengths: np.ndarray       # [B] int64 segment byte lengths
    bucket_calls: int         # fused device dispatches consumed
    padded_rows: int          # batch_tile rows dispatched across all tiles
    early_exits: int          # segments retired by the absorbing early exit


class Matcher:
    """Batched, multi-pattern membership over padded shape buckets.

    Accepts a single ``DFA``, a pre-built ``PackedDFA``, or a sequence of
    DFAs (packed on the fly).  The planner owns the bucketing / padding /
    retracing policy (see ``engine.plan``); the executor owns the device
    dispatch (see ``engine.executors`` / ``engine.sharded``).

    **Bit-identity guarantee**: every public decision — ``membership_batch``,
    ``accepts_batch``, ``advance_segments``, ``advance_classes`` — is
    bit-identical to per-document sequential matching, on every backend,
    mesh shape and capacity profile.

    Parameters
    ----------
    source       : DFA | PackedDFA | sequence of DFA.
    num_chunks   : uniform chunk count C per document (rounded up to a
                   multiple of the mesh chunk extent on the sharded backend).
    max_buckets  : lifetime compiled-shape budget for the speculative path.
    batch_tile   : fixed row count of every device call (rounded up to a
                   power of two; must be a multiple of the mesh doc extent
                   on a 2-D sharded mesh).
    backend      : "local" | "pallas" | "sharded".
    mesh         : sharded backend only — a ("doc", "chunk") mesh from
                   ``launch.mesh.make_matcher_mesh`` (legacy 1-D "data"
                   meshes count as doc extent 1).
    mesh_shape   : sharded backend only, alternative to ``mesh`` — passed to
                   ``make_matcher_mesh(devices, shape=mesh_shape)``: ``None``
                   for the 1-D (1, D) chunk layout, ``"auto"`` for
                   near-square auto-factoring (8 devices -> 2x4), or an
                   explicit ``(doc, chunk)`` tuple.
    devices      : sharded backend only, with ``mesh_shape`` — how many local
                   devices the built mesh uses (default: all).
    capacities   : sharded backend only — measured per-device capacities
                   (symbols/us, e.g. from ``core.profiling.profile_capacity``
                   with ``devices=``), one per mesh device in row-major
                   (doc, chunk) order; normalized to Eq. 1 weights *per doc
                   row* for the planner's capacity-balanced chunk layouts.
                   ``None`` = uniform.
    spec_m       : weighted-layout work model: 1 = lane-parallel chunk sizes
                   proportional to capacity (default); ``i_max`` reproduces
                   the paper's scalar-worker Eqs. 2–7.
    calibrate    : sharded backend only — when True and no ``capacities``
                   were passed, measure per-device symbols/sec at
                   construction (``core.profiling.profile_capacity`` with
                   ``devices=``, the paper's Sec. 4.1 step 1 run at cluster
                   start) and feed the measurements into the
                   capacity-weighted chunk layout automatically.
    early_exit_segments : absorbing-state early-exit granularity per scan
                   (1 disables; pow2, local/seq paths only).
    lookahead_r  : boundary-key lookahead depth of the candidate tables:
                   1 (the paper's Eq. 11 last-byte class), 2 (Eq. 13 pair
                   keys — smaller feasible candidate sets shrink the lane
                   width S), or "auto" (default: r=2 exactly when it strictly
                   shrinks S and its tables fit the memory cap; static per
                   DFA).
    autotune     : opt-in shape autotuner (``core.profiling
                   .autotune_spec_shapes``): times candidate ``(num_chunks,
                   l_blk, mesh_shape)`` configurations on a synthetic probe
                   workload at construction and applies the winner —
                   replacing the near-square ``mesh_shape="auto"`` heuristic
                   with measured choices.  Results cache per (dfa, shape,
                   devices, backend) key, on disk when
                   ``$REPRO_AUTOTUNE_CACHE`` points at a JSON path.
    """

    def __init__(self, source, *, num_chunks: int = 8, max_buckets: int = 2,
                 batch_tile: int = 64, backend: str = "local", mesh=None,
                 mesh_shape=None, devices: Optional[int] = None,
                 capacities: Optional[Sequence[float]] = None,
                 spec_m: int = 1, calibrate: bool = False,
                 early_exit_segments: int = 4,
                 lookahead_r: int | str = "auto", autotune: bool = False):
        packed = self._pack_source(source)
        if num_chunks < 1:
            raise ValueError("num_chunks must be >= 1")
        if max_buckets < 1:
            raise ValueError("max_buckets must be >= 1")
        if batch_tile < 1:
            raise ValueError("batch_tile must be >= 1")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; pick from {BACKENDS}")
        self.packed = packed
        self.backend = backend
        self.max_buckets = int(max_buckets)
        self.batch_tile = next_pow2(int(batch_tile))
        self._lookahead_r = lookahead_r  # swap_patterns rebuilds with it
        self.dev = DeviceTables.build(packed, lookahead_r=lookahead_r)
        self.pad_cls = self.dev.pad_cls
        self.autotune = bool(autotune)
        self._tuned = None
        if self.autotune:
            from ..profiling import autotune_spec_shapes
            self._tuned = autotune_spec_shapes(
                packed, backend=backend,
                num_chunks_candidates=sorted({4, 8, int(num_chunks)}),
                mesh_shape=mesh_shape, devices=devices,
                lookahead_r=lookahead_r)
            num_chunks = self._tuned.num_chunks
            if backend == "sharded" and mesh is None and mesh_shape == "auto":
                mesh_shape = self._tuned.mesh_shape

        if backend == "sharded":
            from ...launch.mesh import make_matcher_mesh, matcher_mesh_extents
            if mesh is None:
                mesh = make_matcher_mesh(devices, shape=mesh_shape)
            elif mesh_shape is not None or devices is not None:
                raise ValueError("pass either mesh= or mesh_shape=/devices=, "
                                 "not both")
            doc_shards, chunk_shards = matcher_mesh_extents(mesh)
            n_dev = doc_shards * chunk_shards
            if self.batch_tile % doc_shards:
                raise ValueError(
                    f"batch_tile={self.batch_tile} must be a multiple of the "
                    f"mesh doc extent {doc_shards}")
            self._doc_shards, self._chunk_shards = doc_shards, chunk_shards
            self._mesh_devices = list(np.asarray(mesh.devices).reshape(-1))[:n_dev]
            if calibrate and capacities is None:
                # cached per (device set, benchmark): repeated construction
                # over the same fleet measures once; Matcher.recalibrate owns
                # the explicit refresh
                from ..profiling import calibrated_capacities
                capacities = calibrated_capacities(self._mesh_devices,
                                                   n_symbols=20_000, repeats=3)
            if capacities is None:
                self.capacities = weights = row_weights = None
            else:
                caps = np.asarray(capacities, np.float64)
                if caps.size != n_dev:
                    raise ValueError(f"need {n_dev} capacities (one per mesh "
                                     f"device), got {caps.size}")
                self.capacities = caps
                weights = self._row_weights(caps)
                row_weights = self._doc_row_weights(caps)
            self.planner = Planner(num_chunks=num_chunks,
                                   max_buckets=max_buckets,
                                   devices=chunk_shards, weights=weights,
                                   spec_m=spec_m, doc_shards=doc_shards,
                                   row_weights=row_weights)
            from .sharded import ShardedExecutor
            self.executor = ShardedExecutor(
                self.dev, num_chunks=self.planner.num_chunks, mesh=mesh,
                early_exit_segments=early_exit_segments)
            self.n_devices = n_dev
        else:
            if capacities is not None:
                raise ValueError("capacities only apply to the sharded backend")
            if mesh is not None or mesh_shape is not None or devices is not None:
                raise ValueError("mesh/mesh_shape/devices only apply to the "
                                 "sharded backend")
            if spec_m != 1:
                raise ValueError("spec_m only applies to the sharded backend")
            if calibrate:
                raise ValueError("calibrate only applies to the sharded "
                                 "backend (single-device layouts are uniform)")
            self.capacities = None
            self.planner = Planner(num_chunks=num_chunks,
                                   max_buckets=max_buckets, devices=1)
            self.executor = LocalExecutor(
                self.dev, num_chunks=self.planner.num_chunks,
                use_kernel=(backend == "pallas"),
                early_exit_segments=early_exit_segments)
            self.n_devices = 1
        self.num_chunks = self.planner.num_chunks
        if self._tuned is not None and self._tuned.l_blk:
            self.executor.spec_l_blk[0] = int(self._tuned.l_blk)  # default key
        self._advance_fn = jax.jit(self._advance_impl)
        # scan-compose dispatch counter: one per compose_lane_maps device
        # call — lets the OOO tier assert "one associative_scan per
        # contiguous run", the same way merge_calls() guards the tick path
        self.compose_calls = 0
        # observed-traffic accounting: every dispatched tile feeds a bounded
        # (fill, length) reservoir; maybe_retune re-runs the autotuner on a
        # probe shaped like this traffic once it drifts from what the
        # current shapes were tuned on (the synthetic probe at cold start)
        from ..profiling import TrafficProfile, synthetic_traffic
        self.traffic = TrafficProfile()
        self._tuned_traffic = (synthetic_traffic()
                               if self._tuned is not None else None)
        self.retunes = 0

    @staticmethod
    def _pack_source(source) -> PackedDFA:
        """Normalize every accepted pattern source to one ``PackedDFA``.

        A multi-block ``PatternSet`` is refused here on purpose: one Matcher
        runs exactly one table, and silently flattening the blocks would
        defeat the set's whole point (``core.engine.BlockedMatcher`` is the
        multi-block front end).
        """
        from ..patterns import PatternSet
        if isinstance(source, PatternSet):
            if source.n_blocks != 1:
                raise ValueError(
                    f"PatternSet has {source.n_blocks} blocks; a Matcher "
                    "runs exactly one — use core.engine.BlockedMatcher for "
                    "multi-block sets (or raise k_blk to cover all patterns)")
            return source.blocks[0]
        if isinstance(source, PackedDFA):
            return source
        if isinstance(source, DFA):
            return pack_dfas([source])
        return pack_dfas(list(source))

    def swap_patterns(self, source) -> bool:
        """Hot-swap the pattern tables in place; True iff anything changed.

        An identical table content (``automata.packed_signature``) is a
        guaranteed no-op and returns False — in-flight streaming cursors
        carry over bit-identically.  On a real change the planner keeps its
        sticky buckets and compiled seq width (*shapes* survive the swap),
        but every compiled lowering baked the old device tables as trace
        constants, so the executor cache clears (``LaneExecutor.retable``)
        and programs re-lower lazily on next dispatch; ``Planner
        .table_epoch`` stamps every post-swap plan so a stale program can
        never be served.  Block-granular lowering *reuse* lives one level up
        — ``BlockedMatcher.swap_patterns`` leaves unchanged blocks' matchers
        untouched.  Streaming callers must swap at a tick boundary
        (``StreamMatcher.swap_patterns`` owns the cursor carry rules).
        """
        packed = self._pack_source(source)
        if packed_signature(packed) == packed_signature(self.packed):
            return False
        self.packed = packed
        self.dev = DeviceTables.build(packed, lookahead_r=self._lookahead_r)
        self.pad_cls = self.dev.pad_cls
        self.planner.table_epoch += 1
        self.executor.retable(self.dev)
        # the jitted cursor advance baked the old tables too — fresh wrapper,
        # fresh trace cache
        self._advance_fn = jax.jit(self._advance_impl)
        return True

    # -- properties ---------------------------------------------------------

    @property
    def n_patterns(self) -> int:
        return self.packed.n_patterns

    @property
    def tables(self):
        """Packed Eq. 11 lookahead tables (built lazily on first access)."""
        return self.dev.tables

    @property
    def trace_count(self) -> int:
        """Number of shapes compiled so far (increments once per retrace)."""
        return self.executor.traces

    @property
    def _spec_keys(self) -> list[int]:
        """Compiled speculative bucket keys (compat alias for the planner's)."""
        return self.planner.spec_keys

    # -- capacity rebalancing (sharded backend) ------------------------------

    def _row_weights(self, caps: np.ndarray) -> np.ndarray:
        # Eq. 1 weights per doc row-block: each mesh row balances its own
        # chunk axis; rows split documents, not symbols
        caps2 = caps.reshape(self._doc_shards, self._chunk_shards)
        return np.stack([capacity_weights(caps2[r])
                         for r in range(self._doc_shards)])

    def _doc_row_weights(self, caps: np.ndarray) -> Optional[np.ndarray]:
        # Eq. 1 on the doc axis: a mesh row's aggregate capacity (its chunk
        # devices matching in parallel) sets how many *documents* it should
        # host per tile — the ragged doc-tiling weights
        if self._doc_shards <= 1:
            return None
        caps2 = caps.reshape(self._doc_shards, self._chunk_shards)
        return capacity_weights(caps2.sum(axis=1))

    def rebalance(self, capacities: Sequence[float]) -> None:
        """Re-derive the capacity-weighted chunk layouts from new measured
        capacities (sharded backend only).

        The straggler-mitigation hook (paper Eq. 5): when observed per-device
        times drift — a degraded host, a corrupted capacity profile — the
        planner's weights update and its cached layouts drop; the executor's
        layout epoch bumps so sharded spec lowerings (which bake chunk
        boundaries as static slices) re-lower lazily while every
        layout-independent compiled program survives.  Decisions stay
        bit-identical across any rebalance — only *where* chunks are matched
        moves, never the answer.  Callers must never rebalance mid-dispatch
        (the scheduler applies it strictly between ticks).
        """
        if self.backend != "sharded":
            raise ValueError("rebalance applies to the sharded backend only "
                             "(single-device layouts are uniform)")
        caps = np.asarray(capacities, np.float64).reshape(-1)
        if caps.size != self.n_devices:
            raise ValueError(f"need {self.n_devices} capacities (one per "
                             f"mesh device), got {caps.size}")
        if not np.all(np.isfinite(caps)) or (caps <= 0).any():
            raise ValueError("capacities must be finite and > 0")
        self.capacities = caps
        self.planner.set_weights(self._row_weights(caps),
                                 row_weights=self._doc_row_weights(caps))
        self.executor.invalidate_layouts()

    def recalibrate(self, *, n_symbols: int = 20_000,
                    repeats: int = 3) -> np.ndarray:
        """Re-measure per-device capacities and rebalance onto them.

        Bypasses (and replaces) the process-wide calibration cache entry for
        this device set — the explicit refresh the rebalance path owns when
        the cached profile no longer reflects reality.  Returns the fresh
        [D] capacities.
        """
        if self.backend != "sharded":
            raise ValueError("recalibrate applies to the sharded backend "
                             "only (single-device layouts are uniform)")
        from ..profiling import calibrated_capacities
        caps = calibrated_capacities(self._mesh_devices, n_symbols=n_symbols,
                                     repeats=repeats, refresh=True)
        self.rebalance(caps)
        return caps

    # -- observed-traffic autotuning -----------------------------------------

    def traffic_profile(self):
        """Signature of the traffic dispatched so far (``ObservedTraffic``),
        or None before any dispatch."""
        return self.traffic.snapshot()

    def maybe_retune(self, *, drift_threshold: float = 1.0,
                     min_docs: int = 64, force: bool = False,
                     time_fn=None) -> bool:
        """Re-run the shape autotuner on the *observed* traffic when it has
        drifted from what the current shapes were tuned on.

        The construction-time tune measured a synthetic probe (8 x 2048-byte
        documents); once real dispatches have accumulated ``min_docs``
        documents and their ``ObservedTraffic`` signature has drifted
        ``drift_threshold`` doublings or more (median length or tile fill,
        ``ObservedTraffic.drift``) from the last-tuned traffic, the tuner
        re-times candidates on a probe corpus shaped like the real traffic
        and applies the winning ``l_blk`` — the one shape axis that can move
        post-construction (``num_chunks`` and the mesh are baked into the
        planner and executor; the tuned values still land in
        ``perf_report()["autotune"]`` for the next cold start, and the disk
        cache remembers them).  Returns True iff a retune ran.  ``force``
        skips the drift gate (not the traffic requirement); ``time_fn`` is
        the autotuner's deterministic measurement override for tests.
        Requires ``autotune=True`` at construction; callers must invoke it
        between batches, never mid-dispatch.
        """
        if not self.autotune:
            raise ValueError("maybe_retune requires Matcher(autotune=True)")
        obs = self.traffic.snapshot()
        if obs is None or self.traffic.n_docs < int(min_docs):
            return False
        if not force and self._tuned_traffic is not None \
                and self._tuned_traffic.drift(obs) < float(drift_threshold):
            return False
        from ..profiling import autotune_spec_shapes
        mesh_shape = (None if self.backend != "sharded"
                      else (self._doc_shards, self._chunk_shards))
        self._tuned = autotune_spec_shapes(
            self.packed, backend=self.backend,
            num_chunks_candidates=sorted({4, 8, int(self.num_chunks)}),
            mesh_shape=mesh_shape,
            devices=(self.n_devices if self.backend == "sharded" else None),
            lookahead_r=self._lookahead_r, observed=obs, time_fn=time_fn)
        self._tuned_traffic = obs
        self.retunes += 1
        if self._tuned.l_blk:
            self.executor.spec_l_blk[0] = int(self._tuned.l_blk)
            self.executor.invalidate_block_sizes()
        return True

    # -- public API ---------------------------------------------------------

    def classes(self, doc: bytes | np.ndarray) -> np.ndarray:
        return self.packed.classes_of(doc).astype(np.int32)

    # -- the one bucket-dispatch loop (every public path rides it) -----------

    @staticmethod
    def _as_arrays(docs) -> tuple[list[np.ndarray], np.ndarray]:
        arrs = [np.frombuffer(d, np.uint8)
                if isinstance(d, (bytes, bytearray))
                else np.asarray(d, np.uint8) for d in docs]
        return arrs, np.array([a.shape[0] for a in arrs], np.int64)

    def _dispatch(self, mplan, arrs, lengths, out, *, entry_mode: str,
                  entry: Optional[np.ndarray] = None,
                  entry_cls: Optional[np.ndarray] = None, tile_hook=None
                  ) -> tuple[int, int, int]:
        """Run every bucket tile of a ``MatchPlan`` through the lane program.

        One loop serves whole documents (``ENTRY_STARTS``), resumed segments
        (``ENTRY_STATES``) and candidate-keyed cursor ticks (``ENTRY_LANES``)
        — the planner emits the ``LanePlan``, the executor lowers it, and
        this loop only packs tiles and scatters results into ``out`` (shape
        [B, K] or [B, K, S] to match the plan's output).  Returns
        ``(bucket_calls, padded_rows, early_exits)``.
        """
        k = self.packed.n_patterns
        calls = rows = early = 0
        for bucket in mplan.buckets:
            spec = bucket.kind == "spec"
            layout = (self.planner.layout_for(bucket.chunk_len)
                      if spec else None)
            # the per-DFA r choice only matters to programs that gather from
            # the candidate tables; keying it conditionally keeps the lazy
            # lookahead analysis unforced for pure-seq exact traffic
            spec_r = (self.dev.spec_r if (spec or entry_mode == ENTRY_LANES)
                      else 1)
            lane = self.planner.lane_plan(bucket, entry=entry_mode,
                                          spec_r=spec_r)
            ragged = (spec and isinstance(layout, MeshLayout)
                      and layout.is_ragged)
            for lo in range(0, bucket.doc_idx.size, self.batch_tile):
                sel = bucket.doc_idx[lo:lo + self.batch_tile]
                # ragged doc tiling: capacity-weighted layouts place real
                # documents into mesh row-blocks proportionally (Eq. 7 on
                # the doc axis) — slow rows get more zero-length pad rows.
                # rowpos[r] is doc sel[r]'s physical tile row; results come
                # back through the same (invertible) placement, so answers
                # are bit-identical to the dense front-fill by construction
                rowpos = (layout.tile_rows(sel.size, self.batch_tile)
                          if ragged else np.arange(sel.size))
                buf = np.zeros((self.batch_tile, bucket.width), np.uint8)
                lens = np.zeros(self.batch_tile, np.int32)
                for r, i in enumerate(sel):
                    buf[rowpos[r], :lengths[i]] = arrs[i]
                    lens[rowpos[r]] = lengths[i]
                if tile_hook is not None:
                    tile_hook(bucket, layout, sel, lens)
                self.traffic.record(sel.size, lengths[sel])
                # operands stay host numpy: jit transfers them once at call
                # time, where an eager jnp.asarray per operand costs an extra
                # device round-trip each on the streaming hot path
                ent = ecls = None
                if entry_mode == ENTRY_STATES:
                    # pad rows scan from the pattern starts (ignored)
                    ent = np.tile(self.packed.starts,
                                  (self.batch_tile, 1)).astype(np.int32)
                    ent[rowpos] = entry[sel]
                elif entry_mode == ENTRY_LANES:
                    # pad rows carry in-range lanes and the pad boundary key,
                    # which the device merge composes as the identity
                    s = self.tables.i_max
                    ent = np.broadcast_to(
                        self.packed.starts.astype(np.int32)[None, :, None],
                        (self.batch_tile, k, s)).copy()
                    ent[rowpos] = entry[sel]
                    ecls = np.full(self.batch_tile, self.dev.pad_key,
                                   np.int32)
                    ecls[rowpos] = entry_cls[sel]
                res, pos = self.executor.run(
                    lane, buf, lens, layout=layout,
                    entry=ent, entry_classes=ecls)
                res, pos = np.asarray(res), np.asarray(pos)
                out[sel] = res[rowpos]
                # a doc "exited early" if all its lanes hit absorbing states
                # before its real symbols ran out (spec positions are
                # chunk-local, so compare against the per-chunk fill)
                eff = (np.minimum(bucket.chunk_len, lengths[sel]) if spec
                       else lengths[sel])
                early += int((pos[rowpos] < eff).sum())
                calls += 1
                rows += self.batch_tile
        return calls, rows, early

    def membership_batch(self, docs: Sequence[bytes | np.ndarray]) -> BatchResult:
        """Match every doc against every packed pattern; no per-doc syncs.

        ``docs`` is a ragged sequence of B byte strings / uint8 arrays.
        Returns a ``BatchResult`` whose [B, K] decisions are bit-identical to
        running each document through sequential matching per pattern — on
        every backend and mesh shape (the sharded backend's 2-D doc x chunk
        split changes only *where* chunks are matched, never the answer).
        """
        b = len(docs)
        k = self.packed.n_patterns
        if b == 0:
            z = np.zeros(0, np.int64)
            return BatchResult(np.zeros((0, k), bool), np.zeros((0, k), np.int32),
                               z, z, z, 0)
        arrs, lengths = self._as_arrays(docs)
        plan = self.planner.plan(lengths)
        finals = np.tile(self.packed.starts, (b, 1)).astype(np.int32)
        steps = np.where(plan.spec_mask, 0, lengths)
        device_work = (np.zeros(self.n_devices, np.int64)
                       if self.backend == "sharded" else None)
        seen_buckets: set[int] = set()

        def account(bucket, layout, sel, lens):
            # work-model bookkeeping per bucket (steps) and per tile (2-D
            # layouts assign work positionally: tile row-block -> mesh row;
            # pad rows carry 0 symbols)
            nonlocal device_work
            if bucket.kind != "spec":
                return
            if id(bucket) not in seen_buckets:
                seen_buckets.add(id(bucket))
                steps[bucket.doc_idx] = self.executor.steps_for(layout)
                if device_work is not None and not isinstance(layout,
                                                              MeshLayout):
                    device_work += layout_device_work(layout,
                                                      lengths[bucket.doc_idx])
            if device_work is not None and isinstance(layout, MeshLayout):
                device_work += layout.device_work(lens.astype(np.int64))

        calls, _, early = self._dispatch(plan, arrs, lengths, finals,
                                         entry_mode=ENTRY_STARTS,
                                         tile_hook=account)

        accepted = self.packed.accepting[finals]
        # lanes forces the lazy lookahead tables — only on speculative work
        lanes = k * self.tables.i_max if plan.spec_mask.any() else k
        work_par = np.where(plan.spec_mask, steps * lanes, lengths * k)
        return BatchResult(accepted, finals, work_par, lengths * k, steps,
                           calls, early_exits=early, device_work=device_work)

    def accepts_batch(self, docs: Sequence[bytes | np.ndarray]) -> np.ndarray:
        """[B, K] bool accept matrix (convenience ``membership_batch`` wrapper,
        same bit-identity guarantee)."""
        return self.membership_batch(docs).accepted

    # -- streaming hook ------------------------------------------------------

    def advance_segments(self, segments: Sequence[bytes | np.ndarray],
                         entry_states: np.ndarray) -> SegmentBatchResult:
        """Advance B independent streams by one segment each, batched.

        ``segments[i]`` is the next byte segment of stream ``i`` and
        ``entry_states[i]`` its current [K] exact packed states (a
        ``streaming.MatchCursor``'s states; the pattern starts for a fresh
        stream), so ``entry_states`` is [B, K] int32.  Segments share the
        planner's sticky shape buckets with whole-document matching, and
        each bucket tile is one fused device call through the executor's
        segment-entry path — so segments from many unrelated streams
        coalesce exactly like documents of a batch.  On the sharded backend
        the same 2-D doc x chunk mesh split applies (entry states shard over
        "doc" with their rows).  Results are bit-identical to matching each
        stream's concatenated bytes in one shot (Eq. 8 composition is
        associative), on every backend and mesh shape.
        """
        b = len(segments)
        k = self.packed.n_patterns
        entry = np.ascontiguousarray(np.asarray(entry_states, np.int32))
        if entry.shape != (b, k):
            raise ValueError(f"entry_states must be [{b}, {k}], "
                             f"got {entry.shape}")
        if b == 0:
            return SegmentBatchResult(entry.copy(), np.zeros((0, k), bool),
                                      np.zeros(0, np.int64), 0, 0, 0)
        arrs, lengths = self._as_arrays(segments)
        plan = self.planner.plan(lengths)
        finals = entry.copy()  # zero-length segments pass through unchanged
        calls, rows, early = self._dispatch(plan, arrs, lengths, finals,
                                            entry_mode=ENTRY_STATES,
                                            entry=entry)
        return SegmentBatchResult(final_states=finals,
                                  absorbed=self.dev.absorbing[finals],
                                  lengths=lengths, bucket_calls=calls,
                                  padded_rows=rows, early_exits=early)

    def advance_cursors(self, segments: Sequence[bytes | np.ndarray],
                        lane_states: np.ndarray,
                        last_classes: np.ndarray) -> CursorBatchResult:
        """Advance B candidate-keyed cursors by one segment each — the
        streaming device merge.

        Where ``advance_segments`` needs each stream's *exact* [K] states,
        this path needs only each stream's boundary class: ``lane_states[i]``
        is stream ``i``'s [K, S] cursor lane map (exit state per Eq. 11
        candidate entry of the stream's original boundary class — a
        ``streaming.MatchCursor``'s ``lane_states``, or an exact cursor
        broadcast across the lane axis) and ``last_classes[i]`` the joint
        class of the last byte the cursor absorbed.  Each bucket tile is one
        fused device call that (a) matches the segments *independently*,
        candidate-keyed on each row's boundary class, and (b) composes the
        cursor lanes with the resulting segment maps on device — the Eq. 8
        composition that ``streaming.cursor.merge`` performs per stream on
        the host, batched (``kernels.ref.cursor_merge_ref`` is the host
        reference; bit-identity is property-tested on every backend and
        mesh shape in tests/test_device_merge.py).

        Contract: every cursor must have enough absorbed history for a
        boundary key (``last_classes`` in ``[0, DeviceTables.n_keys)`` —
        under r=1 the joint class of the last byte, under r=2 the pair key
        ``DeviceTables.advance_key`` maintains) — a fresh stream's states
        are exactly the pattern starts, so it has no candidate keying and
        belongs in ``advance_segments``.  Zero-length segments compose as
        the identity.  Plans, buckets and tiles are shared with the exact
        paths, so mixed whole-document / segment / cursor traffic reuses the
        same compiled programs per shape.
        """
        b = len(segments)
        k = self.packed.n_patterns
        s = self.tables.i_max
        lanes = np.ascontiguousarray(np.asarray(lane_states, np.int32))
        if lanes.shape != (b, k, s):
            raise ValueError(f"lane_states must be [{b}, {k}, {s}], "
                             f"got {lanes.shape}")
        last = np.asarray(last_classes, np.int32).reshape(-1)
        if last.shape != (b,):
            raise ValueError(f"last_classes must be [{b}], got {last.shape}")
        if b and ((last < 0) | (last >= self.dev.n_keys)).any():
            raise ValueError(
                "last_classes must be boundary keys in [0, n_keys); fresh "
                "streams (no usable history) have exact states — advance "
                "them with advance_segments")
        if b == 0:
            return CursorBatchResult(lanes.copy(), np.zeros((0, k), bool),
                                     np.zeros(0, np.int64), 0, 0, 0)
        arrs, lengths = self._as_arrays(segments)
        plan = self.planner.plan(lengths)
        out = lanes.copy()  # zero-length segments compose as the identity
        calls, rows, early = self._dispatch(plan, arrs, lengths, out,
                                            entry_mode=ENTRY_LANES,
                                            entry=lanes, entry_cls=last)
        return CursorBatchResult(lane_states=out,
                                 absorbed=self.dev.absorbing[out].all(axis=2),
                                 lengths=lengths, bucket_calls=calls,
                                 padded_rows=rows, early_exits=early)

    def compose_lane_maps(self, lane_maps: np.ndarray,
                          entry_keys: np.ndarray) -> np.ndarray:
        """Fold B runs of candidate-keyed lane maps in ONE device scan.

        ``lane_maps [B, N, K, S]`` holds, per row, a run of transition maps
        (leftmost first — e.g. a stream's cursor broadcast to lane width
        followed by buffered segment maps); ``entry_keys [B, N]`` the
        boundary key selecting each map's Eq. 11 candidate entry row.
        Returns the ``[B, K, S]`` composition of every row via a single
        log-depth ``lax.associative_scan`` dispatch (``lvector
        .merge_scan_lanes_jnp``; ``kernels.ref.spec_merge_lanes_scan_ref``
        is the sequential oracle) — the out-of-order gap-close bulk path:
        one device call per batch of contiguous runs, not one compose per
        segment.

        Keys equal to ``DeviceTables.pad_key`` compose as the identity, so
        ragged runs are padded on the right; element 0's key is never read.
        N is padded to a power of two here to bound retraces (the compiled
        scan is cached per padded N).  ``compose_calls`` counts dispatches.

        All lowerings (jnp scan, Pallas carry/tree kernels, sharded) are
        bit-identical on real candidate lanes — the only lanes a consumer
        can address through ``cand_index``.  Pad lanes (filler states
        repeated to reach width S) hold evaluation-order-dependent
        passthrough values; see ``kernels.ops.spec_compose_lanes``.
        """
        k = self.packed.n_patterns
        s = self.tables.i_max
        lanes = np.ascontiguousarray(np.asarray(lane_maps, np.int32))
        if lanes.ndim != 4 or lanes.shape[2:] != (k, s):
            raise ValueError(f"lane_maps must be [B, N, {k}, {s}], "
                             f"got {lanes.shape}")
        b, n = lanes.shape[:2]
        keys = np.asarray(entry_keys, np.int32)
        if keys.shape != (b, n):
            raise ValueError(f"entry_keys must be [{b}, {n}], "
                             f"got {keys.shape}")
        pad_key = self.dev.pad_key
        if n and ((keys[:, 1:] < 0) | (keys[:, 1:] > pad_key)).any():
            raise ValueError("entry_keys[:, 1:] must be boundary keys in "
                             "[0, n_keys] (pad_key = identity)")
        if b == 0 or n == 0:
            return np.zeros((b, k, s), np.int32)
        if n == 1:
            return lanes[:, 0].copy()
        np2 = next_pow2(n)
        if np2 != n:
            lanes = np.concatenate(
                [lanes, np.zeros((b, np2 - n, k, s), np.int32)], axis=1)
            keys = np.concatenate(
                [keys, np.full((b, np2 - n), pad_key, np.int32)], axis=1)
        out = np.asarray(self.executor.compose_lane_maps(lanes, keys))
        self.compose_calls += 1
        return out.astype(np.int32)

    # -- serving hook -------------------------------------------------------

    def _advance_impl(self, states: jnp.ndarray, classes: jnp.ndarray) -> jnp.ndarray:
        def step(st, col):  # st [B], col [B]
            return self.dev.table_pad_j[st, col], None

        out, _ = jax.lax.scan(step, states.astype(jnp.int32), classes.T)
        return out

    def advance_classes(self, states: jnp.ndarray,
                        classes: jnp.ndarray) -> jnp.ndarray:
        """Advance [B] packed states through [B, T] class columns in one scan.

        ``pad_cls`` columns are identity moves (the padded table's extra
        column), which is how callers encode "this position advances no DFA"
        — e.g. special tokens in grammar-constrained serving.
        """
        classes = jnp.asarray(classes, jnp.int32)
        if classes.ndim != 2:
            raise ValueError("advance_classes expects [B, T] classes")
        if classes.shape[1] == 0:
            return jnp.asarray(states, jnp.int32)
        return self._advance_fn(states, classes)

    # -- introspection -------------------------------------------------------

    def perf_report(self) -> dict:
        """Raw-speed introspection for benchmark artifacts.

        Reports the lowering chosen per compiled plan (fused kernel vs jnp
        stages), the in-kernel early-exit skip counter (pallas backend), the
        resolved boundary-key depth and lane width after r=2 shrinking, and
        the autotuner's choice when one was applied — so a BENCH number
        explains *why* it moved.  Never forces the lazy lookahead analysis:
        fields stay ``None`` until the work that builds them has run.
        """
        rep: dict = {
            "backend": self.backend,
            "spec_r": None,
            "lane_width": None,
            "lowerings": {"|".join(map(str, key)): kind
                          for key, kind in
                          self.executor.lowering_kinds.items()},
            "kernel_skipped_steps": None,
            "table_epoch": self.planner.table_epoch,
            # single-table matchers have no block gate; the key exists so
            # perf consumers read one schema (BlockedMatcher fills it in)
            "prefilter_skipped_blocks": None,
            "autotune": dataclasses.asdict(self._tuned)
                        if self._tuned is not None else None,
            # which lowering compose_lane_maps (the OOO gap-close bulk path)
            # actually rode: "compose-kernel-{carry,tree}" on the pallas
            # backend, "compose-scan" (jnp associative_scan) elsewhere;
            # None until the first compose dispatch
            "compose_lowering": next(
                (kind for kind in self.executor.lowering_kinds.values()
                 if kind.startswith("compose")), None),
            "compose_calls": self.compose_calls,
            "retunes": self.retunes,
            "traffic": None,
        }
        obs = self.traffic.snapshot()
        if obs is not None:
            rep["traffic"] = {
                "n_tiles": self.traffic.n_tiles,
                "n_docs": self.traffic.n_docs,
                "batch": obs.batch,
                "median_len": int(np.median(obs.lengths)),
            }
        if "tables" in self.dev.__dict__:  # lookahead analysis already ran
            rep["spec_r"] = self.dev.spec_r
            rep["lane_width"] = self.dev.i_max
        if hasattr(self.executor, "kernel_skipped_steps"):
            rep["kernel_skipped_steps"] = self.executor.kernel_skipped_steps()
        return rep


class BatchMatcher(Matcher):
    """Compatibility shim: the pre-refactor batched engine constructor.

    ``use_kernel=True`` routes chunk matching + merge through the fused
    Pallas kernel (the ``pallas`` backend); everything else is the facade.
    Deprecated — new code should construct ``Matcher(..., backend=...)``
    directly (tests/test_compat_shims.py keeps this path covered).
    """

    def __init__(self, source, *, num_chunks: int = 8, max_buckets: int = 2,
                 batch_tile: int = 64, use_kernel: bool = False):
        import warnings
        warnings.warn("BatchMatcher is a compatibility shim; use "
                      "Matcher(..., backend='pallas'|'local') instead",
                      DeprecationWarning, stacklevel=2)
        super().__init__(source, num_chunks=num_chunks, max_buckets=max_buckets,
                         batch_tile=batch_tile,
                         backend="pallas" if use_kernel else "local")
        self.use_kernel = bool(use_kernel)
