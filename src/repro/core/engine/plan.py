"""Planner layer: everything decided *before* a device call.

The planner owns the four host-side decisions of the batched matching
pipeline and freezes them into an explicit ``MatchPlan`` that every executor
backend consumes unchanged:

  * **spec-vs-seq split** — documents shorter than ``4 * num_chunks`` take the
    batched sequential scan (one fused call for all of them), the rest take
    the speculative chunk path;
  * **shape bucketing** — speculative documents are grouped by
    ``next_pow2(ceil(n / C))`` chunk length; bucket keys are *sticky* across
    calls (``Planner`` keeps the compiled-key set) and fresh keys merge upward
    until the lifetime ``max_buckets`` shape budget is respected;
  * **chunk partitioning / capacity weighting** — a ``ChunkLayout`` maps the
    padded symbol width of a bucket onto per-device chunk boundaries, either
    uniform or capacity-weighted via the paper's Eqs. 1–7
    (``core.partition.weighted_partition`` with per-worker weights from
    ``core.profiling.profile_workers``);
  * **lookahead-table selection** — the packed Eq. 11 candidate tables plus
    the identity-pad-column device arrays are bundled once in
    ``DeviceTables`` and shared by all executors.

Nothing in this module touches a device except ``DeviceTables.build`` (which
uploads the constant tables); planning is pure numpy and therefore cheap to
re-run per batch and trivial to test.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..automata import PackedDFA
from ..lookahead import PackedLookaheadTables, build_packed_lookahead_tables
from ..partition import Partition, uniform_partition, weighted_partition

__all__ = ["next_pow2", "DeviceTables", "ChunkLayout", "MeshLayout",
           "BucketPlan", "MatchPlan", "LanePlan", "Planner",
           "ENTRY_STARTS", "ENTRY_STATES", "ENTRY_LANES",
           "expand_device_weights", "layout_device_work"]

# Entry-seed stage modes of a LanePlan (how chunk 0 / the scan rows start):
ENTRY_STARTS = "starts"  # the packed pattern start states (whole documents)
ENTRY_STATES = "states"  # caller-supplied exact [B, K] states (resumed
                         # stream segments -- Matcher.advance_segments)
ENTRY_LANES = "lanes"    # Eq. 11 candidate rows of each row's boundary
                         # class [B]; output keeps the [B, K, S] lane axis
                         # and is composed with the caller's cursor lanes on
                         # device (Matcher.advance_cursors)


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


# --------------------------------------------------------------------------
# Device-ready matcher tables (lookahead-table selection)
# --------------------------------------------------------------------------

_R2_TABLE_CAP = 1 << 22  # max int32 entries of the r=2 [n_keys+1, Q] index


class DeviceTables:
    """Constant device arrays shared by every executor backend.

    ``table_pad`` appends the identity transition column ``pad_cls`` (padding
    advances no DFA); ``cand_pad``/``cidx_pad`` append the matching pad rows
    (the pad candidates row is never merged through but must hold in-range
    states for the gather; the pad ``cand_index`` row stays -1).
    ``absorbing[q]`` marks states with only self-loops over *real* classes —
    the early-exit test (a document whose every lane is absorbing can stop
    matching).

    **Boundary keys.**  Speculative chunk entries are keyed by the *boundary
    key* of the r bytes before the chunk: for ``lookahead_r=1`` the paper's
    Eq. 11 class of the last byte (``n_keys == n_classes``), for
    ``lookahead_r=2`` the Eq. 13 pair key ``c_prev * n_classes + c_last``
    (``n_keys == n_classes ** 2``), whose feasible candidate sets are usually
    far smaller — shrinking the shared lane width S.  ``lookahead_r="auto"``
    (default) picks r=2 per DFA exactly when it strictly shrinks S and the
    r=2 index tables fit the memory cap; the choice is static per DFA and
    keyed into every ``LanePlan``.  ``pad_key == n_keys`` is the identity
    boundary key (whole-chunk padding / zero-byte segments).

    The lookahead candidate tables build lazily on first speculative use:
    consumers that only advance states through the padded table (e.g.
    grammar-constrained serving) never pay the O(n_keys * Q) analysis.
    """

    def __init__(self, packed: PackedDFA, *, lookahead_r: int | str = "auto"):
        if lookahead_r not in ("auto", 1, 2):
            raise ValueError(f"lookahead_r must be 'auto', 1 or 2, "
                             f"got {lookahead_r!r}")
        self.packed = packed
        self.lookahead_r = lookahead_r
        self.pad_cls = packed.n_classes
        q = packed.n_states
        ident = np.arange(q, dtype=np.int32).reshape(-1, 1)
        self.table_pad_j = jnp.asarray(          # [Q, n_cls + 1] int32
            np.concatenate([packed.table, ident], axis=1))
        self.starts_j = jnp.asarray(packed.starts)        # [K] int32
        self.sinks_j = jnp.asarray(packed.sinks)          # [K] int32
        self.byte_to_class_j = jnp.asarray(packed.byte_to_class)  # [256]
        # host copy kept for the streaming cursor layer (absorbed flags /
        # stream-level early exit) — the pad column is identity by
        # construction, so absorbing-over-real-classes is absorbing outright
        self.absorbing = (packed.table
                          == np.arange(q, dtype=np.int32)[:, None]).all(axis=1)
        self.absorbing_j = jnp.asarray(self.absorbing)    # [Q] bool

    @classmethod
    def build(cls, packed: PackedDFA, *,
              lookahead_r: int | str = "auto") -> "DeviceTables":
        return cls(packed, lookahead_r=lookahead_r)

    @property
    def n_patterns(self) -> int:
        return self.packed.n_patterns

    @property
    def i_max(self) -> int:
        return self.tables.i_max

    @property
    def spec_r(self) -> int:
        """Resolved reverse-lookahead depth of the boundary-key space."""
        return self.tables.r

    @property
    def n_keys(self) -> int:
        """Boundary-key count (``n_classes ** spec_r``)."""
        return self.tables.n_keys

    @property
    def pad_key(self) -> int:
        """The identity boundary key (pad row of ``cand_pad``/``cidx_pad``)."""
        return self.tables.n_keys

    @functools.cached_property
    def tables(self) -> PackedLookaheadTables:
        if self.lookahead_r != "auto":
            return build_packed_lookahead_tables(self.packed,
                                                 r=int(self.lookahead_r))
        t1 = build_packed_lookahead_tables(self.packed)
        n, q = self.packed.n_classes, self.packed.n_states
        k = self.packed.n_patterns
        # r=2 must strictly shrink S to be worth the bigger key space, and
        # its [n_keys + 1, Q] / [n_keys + 1, K, S] tables must fit the cap
        fits = (n * n + 1) * max(q, k * t1.i_max) <= _R2_TABLE_CAP
        if t1.i_max > 1 and n >= 2 and fits:
            t2 = build_packed_lookahead_tables(self.packed, r=2)
            if t2.i_max < t1.i_max:
                return t2
        return t1

    def advance_key(self, prev_key: int, data: bytes | np.ndarray) -> int:
        """Boundary key of a stream after it absorbs ``data`` (host-side).

        ``prev_key`` is the stream's key before the segment (``-1`` =
        no/insufficient history).  r=1 degrades to the class of the last
        byte — exactly the pre-r=2 ``last_class``.  r=2 shifts the 2-byte
        window: a segment of >= 2 bytes keys on its own suffix; a 1-byte
        segment reuses ``prev_key``'s last class as the new first class; a
        stream without 2 bytes of usable history returns ``-1``
        (``streaming.cursor.ENTRY_EXACT``) — sound, merely conservative (its
        next segment needs exact entry instead of candidate keying).
        """
        arr = (np.frombuffer(data, np.uint8)
               if isinstance(data, (bytes, bytearray))
               else np.asarray(data, np.uint8))
        if arr.size == 0:
            return int(prev_key)
        b2c = self.packed.byte_to_class
        if self.spec_r == 1:
            return int(b2c[arr[-1]])
        n = self.packed.n_classes
        if arr.size >= 2:
            return int(b2c[arr[-2]]) * n + int(b2c[arr[-1]])
        if 0 <= int(prev_key) < n * n:
            return (int(prev_key) % n) * n + int(b2c[arr[-1]])
        return -1

    @functools.cached_property
    def cand_pad_j(self) -> jnp.ndarray:  # [n_cls + 1, K, S] int32
        t = self.tables
        with jax.ensure_compile_time_eval():  # first touch may be mid-trace
            return jnp.asarray(
                np.concatenate([t.candidates, t.candidates[:1]], axis=0))

    @functools.cached_property
    def cidx_pad_j(self) -> jnp.ndarray:  # [n_cls + 1, Q] int32
        with jax.ensure_compile_time_eval():
            return jnp.asarray(np.concatenate(
                [self.tables.cand_index,
                 np.full((1, self.packed.n_states), -1, np.int32)], axis=0))


# --------------------------------------------------------------------------
# Chunk layouts (partitioning + capacity weighting)
# --------------------------------------------------------------------------

def expand_device_weights(weights: np.ndarray, chunks_per_device: int) -> np.ndarray:
    """Per-chunk weights from per-device weights (device d owns a contiguous
    run of ``chunks_per_device`` chunks)."""
    w = np.asarray(weights, dtype=np.float64)
    return np.repeat(w, chunks_per_device)


@dataclasses.dataclass
class ChunkLayout:
    """Static chunk boundaries of one bucket width, assigned to devices.

    ``starts``/``ends`` partition ``[0, width)`` into ``C`` contiguous chunks;
    chunk ``i`` lives on device ``device_of[i]``.  ``exact[i]`` marks chunks
    that start at stream position 0 and are therefore matched exactly from
    the start states (chunk 0, plus any chunk behind zero-length leading
    chunks).  ``lmax`` is the padded per-chunk buffer length every executor
    allocates — trailing identity-pad columns never move a lane, so padding a
    chunk's tail is free in state space.
    """

    width: int
    starts: np.ndarray     # [C] int64
    ends: np.ndarray       # [C] int64
    device_of: np.ndarray  # [C] int64
    exact: np.ndarray      # [C] bool
    lmax: int

    @property
    def num_chunks(self) -> int:
        return int(self.starts.shape[0])

    @property
    def num_devices(self) -> int:
        return int(self.device_of.max()) + 1 if self.starts.size else 1

    @property
    def sizes(self) -> np.ndarray:
        return self.ends - self.starts

    # interior chunk boundaries keep >= 2 preceding symbols so r=2 boundary
    # keys (the pair of the two bytes before the cut) always exist; moving a
    # cut from 1 to 2 only resizes neighbouring chunks (harmless for r=1)
    MIN_CUT = 2

    @classmethod
    def from_partition(cls, part: Partition, width: int, devices: int) -> "ChunkLayout":
        c = part.start.shape[0]
        if c % devices != 0:
            raise ValueError(f"{c} chunks do not divide over {devices} devices")
        starts, ends = part.start.copy(), part.end.copy()
        if (starts[1:] == ends[:-1]).all():  # contiguous: clamp cut points
            cuts = np.where((starts > 0) & (starts < cls.MIN_CUT),
                            np.int64(cls.MIN_CUT), starts)
            cuts = np.minimum(np.maximum.accumulate(cuts), width)
            starts = cuts
            ends = np.append(cuts[1:], ends[-1])
        sizes = ends - starts
        return cls(width=width, starts=starts, ends=ends,
                   device_of=np.repeat(np.arange(devices), c // devices),
                   exact=(starts == 0), lmax=int(max(sizes.max(), 1)))

    @classmethod
    def uniform(cls, width: int, num_chunks: int, devices: int = 1) -> "ChunkLayout":
        return cls.from_partition(uniform_partition(width, num_chunks, 1),
                                  width, devices)

    @classmethod
    def weighted(cls, width: int, num_chunks: int, devices: int,
                 weights: np.ndarray, m: int = 1) -> "ChunkLayout":
        """Capacity-weighted boundaries (paper Eqs. 2–7 over the bucket width).

        ``m = 1`` is the lane-parallel model (chunk sizes proportional to
        capacity; equal capacities degrade to ``uniform``); ``m = I_max``
        reproduces the paper's scalar-worker model where the exact chunk 0 is
        ``m``x longer.
        """
        w_chunks = expand_device_weights(weights, num_chunks // devices)
        return cls.from_partition(weighted_partition(width, w_chunks, m),
                                  width, devices)


@dataclasses.dataclass
class MeshLayout:
    """Per-doc-shard chunk layouts of one bucket width on a 2-D mesh.

    A ("doc", "chunk") mesh splits a bucket tile both ways: doc row-block
    ``r`` (tile rows ``[r * B/Dd, (r+1) * B/Dd)``) is owned by mesh row ``r``,
    and ``rows[r]`` is that row's own ``ChunkLayout`` — its chunk boundaries
    are capacity-weighted by *that row's* chunk-axis devices (the paper's
    Eqs. 1–7 applied per doc row-block), so a heterogeneous fleet stays
    balanced along both axes.  All rows share ``width``; ``lmax`` is the
    maximum padded chunk buffer over the rows, so the SPMD chunk buffer keeps
    a single shape (shorter chunks tail-pad with the identity class — free in
    state space).

    **Ragged doc rows.**  ``row_weights`` (Eq. 1 weights of each mesh row's
    *aggregate* capacity) makes the document axis capacity-weighted too:
    ``doc_counts`` applies Eq. 7 to the *document count* of a tile, and
    ``tile_rows`` packs real documents into the fixed physical row-blocks
    raggedly — a slow mesh row receives proportionally fewer real documents
    (its remaining slots carry zero-length pads, free in the work model and
    skipped by the early exit).  SPMD shard shapes stay uniform — only the
    doc -> tile-row *placement* moves, so results are bit-identical to the
    uniform layout by construction.  ``None`` = uniform placement.
    """

    width: int
    rows: tuple[ChunkLayout, ...]
    row_weights: Optional[tuple[float, ...]] = None

    @property
    def doc_shards(self) -> int:
        return len(self.rows)

    @property
    def is_ragged(self) -> bool:
        return self.row_weights is not None

    def doc_counts(self, n: int) -> np.ndarray:
        """Eq. 7 applied to the doc axis: documents per mesh row, summing
        to ``n`` (uniform rows split evenly)."""
        if self.row_weights is None:
            d = self.doc_shards
            return np.diff(np.linspace(0, n, d + 1).astype(np.int64))
        part = weighted_partition(n, np.asarray(self.row_weights), 1)
        return (part.end - part.start).astype(np.int64)

    def tile_rows(self, m: int, tile: int) -> np.ndarray:
        """Physical tile-row of each of ``m`` real documents ([m] int64).

        The tile keeps ``tile // doc_shards`` physical rows per mesh row
        (SPMD shard shapes are uniform); real documents pack into the
        row-blocks per ``doc_counts``, clipped to the block size with the
        overflow waterfilled into the fastest rows that still have spare
        slots.  Uniform layouts return ``arange(m)`` — the legacy positional
        packing, so the ragged path degrades to it exactly.
        """
        d = self.doc_shards
        if tile % d:
            raise ValueError(f"tile of {tile} rows does not split over "
                             f"{d} doc shards")
        if m > tile:
            raise ValueError(f"{m} documents exceed the {tile}-row tile")
        if self.row_weights is None:
            return np.arange(m, dtype=np.int64)
        rps = tile // d
        counts = np.minimum(self.doc_counts(m), rps)
        short = int(m - counts.sum())
        order = np.argsort(-np.asarray(self.row_weights, np.float64),
                           kind="stable")
        while short > 0:
            for r in order:
                if short and counts[r] < rps:
                    counts[r] += 1
                    short -= 1
        return np.concatenate(
            [r * rps + np.arange(counts[r], dtype=np.int64)
             for r in range(d)]) if m else np.zeros(0, np.int64)

    @property
    def num_chunks(self) -> int:
        return self.rows[0].num_chunks

    @property
    def lmax(self) -> int:
        return max(r.lmax for r in self.rows)

    @property
    def num_devices(self) -> int:
        return self.doc_shards * self.rows[0].num_devices

    def device_work(self, lengths: np.ndarray) -> np.ndarray:
        """Real symbols per device for one full tile of document lengths.

        ``lengths [B]`` must cover the whole tile (pad rows as zeros) since
        row-block membership is positional; returns ``[Dd * Dc]`` in mesh
        row-major order (device (r, c) at index ``r * Dc + c``)."""
        lengths = np.asarray(lengths, dtype=np.int64)
        if lengths.shape[0] % self.doc_shards:
            raise ValueError(f"tile of {lengths.shape[0]} rows does not "
                             f"split over {self.doc_shards} doc shards")
        rps = lengths.shape[0] // self.doc_shards
        return np.concatenate(
            [layout_device_work(row, lengths[r * rps:(r + 1) * rps])
             for r, row in enumerate(self.rows)])


def layout_device_work(layout: ChunkLayout, lengths: np.ndarray) -> np.ndarray:
    """Real symbols matched per device for documents of the given lengths.

    A chunk's real work on a document of length ``n`` is the overlap of its
    ``[start, end)`` span with ``[0, n)`` — trailing pad columns are free in
    the model (and on real heterogeneous fleets would not be shipped at all).
    Returns ``[D]`` summed over all documents.
    """
    lengths = np.asarray(lengths, dtype=np.int64)
    overlap = (np.minimum(layout.ends[None, :], lengths[:, None])
               - np.minimum(layout.starts[None, :], lengths[:, None]))
    per_chunk = overlap.sum(axis=0)
    d = layout.num_devices
    work = np.zeros(d, dtype=np.int64)
    np.add.at(work, layout.device_of, per_chunk)
    return work


# --------------------------------------------------------------------------
# The plan
# --------------------------------------------------------------------------

@dataclasses.dataclass
class BucketPlan:
    """One fused device dispatch group: documents sharing a compiled shape."""

    kind: str            # "seq" | "spec"
    width: int           # padded byte/symbol width of the device buffer
    chunk_len: int       # Lc for spec buckets (width == C * Lc); 0 for seq
    doc_idx: np.ndarray  # [n_docs] int64 indices into the batch


@dataclasses.dataclass(frozen=True)
class LanePlan:
    """One lane-program: the single stage pipeline every backend lowers.

    The matching inner loop is one program — **classify** bytes to joint
    classes, **entry-seed** the scan lanes, **chunk-scan** them through the
    padded transition table, **merge** per-chunk lane states (Eq. 8) — and a
    ``LanePlan`` is its complete static description.  Executor backends do
    not implement variants; they *lower* this one plan (``Executor.run``),
    so a new backend writes one lowering instead of four run-methods:

      kind       "seq" (merge stage is a no-op: rows scan start-to-end) or
                 "spec" (chunked scan + Eq. 8 merge of the lane states);
      entry      entry-seed mode — ``ENTRY_STARTS`` (pattern starts),
                 ``ENTRY_STATES`` (caller [B, K] exact states), or
                 ``ENTRY_LANES`` (Eq. 11 candidate rows keyed by each row's
                 boundary key; the merge stage then also composes the
                 caller's [B, K, S] cursor lanes on device);
      early_exit absorbing-state early exit enabled for this program;
      spec_r     reverse-lookahead depth of the boundary-key space the
                 candidate tables were built for (``DeviceTables.spec_r``;
                 static per DFA — keyed so an r change re-lowers).

    ``width``/``chunk_len`` pin the compiled buffer shape; ``key`` is the
    lowering cache key (one compiled program per distinct plan).
    """

    kind: str        # "seq" | "spec"
    width: int       # padded byte/symbol width of the device buffer
    chunk_len: int   # Lc for spec plans (width == C * Lc); 0 for seq
    entry: str       # ENTRY_STARTS | ENTRY_STATES | ENTRY_LANES
    early_exit: bool = True
    spec_r: int = 1  # boundary-key lookahead depth (DeviceTables.spec_r)
    table_epoch: int = 0  # pattern-set generation (Planner.table_epoch):
    #   bumped by hot swaps the way layout_epoch tracks boundary moves, so a
    #   compiled program that baked pre-swap tables can never be looked up
    #   again even if an executor cache entry survived

    def __post_init__(self):
        if self.kind not in ("seq", "spec"):
            raise ValueError(f"unknown plan kind {self.kind!r}")
        if self.entry not in (ENTRY_STARTS, ENTRY_STATES, ENTRY_LANES):
            raise ValueError(f"unknown entry mode {self.entry!r}")
        if self.spec_r not in (1, 2):
            raise ValueError(f"spec_r must be 1 or 2, got {self.spec_r!r}")

    @property
    def key(self) -> tuple:
        return (self.kind, self.width, self.chunk_len, self.entry,
                self.early_exit, self.spec_r, self.table_epoch)


@dataclasses.dataclass
class MatchPlan:
    """Everything an executor needs to run one batch, decided up front."""

    buckets: list[BucketPlan]
    lengths: np.ndarray      # [B] int64 document byte lengths
    spec_mask: np.ndarray    # [B] bool — True: speculative chunk path
    chunk_len: np.ndarray    # [B] int64 assigned Lc (0 for seq docs)

    @property
    def n_docs(self) -> int:
        return int(self.lengths.shape[0])


class Planner:
    """Sticky-bucket batch planner (state lives here, not in the facade).

    Parameters mirror the old ``BatchMatcher`` policy: ``max_buckets`` is the
    lifetime compiled-shape budget for the speculative path (new chunk
    lengths snap up into compiled buckets; fresh keys merge upward), and the
    short-document sequential width is fixed at ``next_pow2(4C - 1)`` so the
    seq path compiles exactly once (it grows only in the ``num_chunks <= 1``
    everything-sequential configuration).

    ``devices`` is the *chunk-axis* extent; ``doc_shards`` the doc-axis
    extent of a 2-D ("doc", "chunk") matcher mesh (1 for every single-host
    backend).  ``weights`` holds per-device capacity weights — a flat
    ``[doc_shards * devices]`` array in mesh row-major order (or an already
    2-D ``[doc_shards, devices]``); with ``doc_shards > 1`` the planner
    emits a ``MeshLayout`` whose row ``r`` applies Eqs. 1–7 with mesh row
    ``r``'s weights only.
    """

    def __init__(self, *, num_chunks: int = 8, max_buckets: int = 2,
                 devices: int = 1, weights: Optional[np.ndarray] = None,
                 spec_m: int = 1, doc_shards: int = 1,
                 row_weights: Optional[np.ndarray] = None):
        if num_chunks < 1:
            raise ValueError("num_chunks must be >= 1")
        if max_buckets < 1:
            raise ValueError("max_buckets must be >= 1")
        if devices < 1:
            raise ValueError("devices must be >= 1")
        if doc_shards < 1:
            raise ValueError("doc_shards must be >= 1")
        # round the chunk count up to a device multiple so the chunk axis
        # shards evenly (a no-op for the single-device executors)
        self.num_chunks = -(-int(num_chunks) // int(devices)) * int(devices)
        self.max_buckets = int(max_buckets)
        self.devices = int(devices)
        self.doc_shards = int(doc_shards)
        self.spec_m = int(spec_m)
        # pattern-set generation: Matcher.swap_patterns bumps it so every
        # post-swap LanePlan keys differently from pre-swap programs
        self.table_epoch = 0
        self.weights: Optional[np.ndarray] = None
        self.row_weights: Optional[np.ndarray] = None
        self.spec_keys: list[int] = []
        self.seq_width = next_pow2(max(4 * self.num_chunks - 1, 1))
        self._layouts: dict[int, ChunkLayout | MeshLayout] = {}
        if weights is not None or row_weights is not None:
            self.set_weights(weights, row_weights=row_weights)

    def set_weights(self, weights: Optional[np.ndarray], *,
                    row_weights: Optional[np.ndarray] = None) -> None:
        """Replace the per-device capacity weights; drop cached layouts.

        The between-tick rebalance path (``Matcher.rebalance``) lands here:
        cached ``ChunkLayout``/``MeshLayout`` boundaries bake the *old*
        weights, so the layout cache clears — while the sticky bucket keys
        and the compiled seq width survive (only chunk boundaries move, not
        shapes; executors that bake boundaries into lowered programs key
        their cache on a layout epoch, see ``executors.LaneExecutor``).

        ``row_weights`` are the Eq. 1 weights of each mesh row's *aggregate*
        capacity ([doc_shards]) — they make the document axis of every
        emitted ``MeshLayout`` ragged (capacity-proportional per-row document
        counts via ``MeshLayout.doc_counts``/``tile_rows``).  ``None`` keeps
        the uniform doc split.
        """
        if row_weights is None:
            self.row_weights = None
        else:
            rw = np.asarray(row_weights, np.float64).reshape(-1)
            if rw.shape != (self.doc_shards,):
                raise ValueError(f"need one row weight per doc shard: "
                                 f"expected {self.doc_shards}, got {rw.size}")
            if not np.all(np.isfinite(rw)) or (rw <= 0).any():
                raise ValueError("row weights must be finite and > 0")
            self.row_weights = rw
        if weights is None:
            self.weights = None
        else:
            w = np.asarray(weights, np.float64)
            if w.ndim == 1:
                w = w.reshape(self.doc_shards, -1)
            if w.shape != (self.doc_shards, self.devices):
                raise ValueError("need one capacity weight per (doc, chunk) "
                                 f"device: expected {self.doc_shards}x"
                                 f"{self.devices}, got {w.shape}")
            if not np.all(np.isfinite(w)) or (w <= 0).any():
                raise ValueError("capacity weights must be finite and > 0")
            self.weights = w
        self._layouts.clear()

    # -- chunk layouts ------------------------------------------------------

    def layout_for(self, chunk_len: int) -> ChunkLayout | MeshLayout:
        """Chunk boundaries for one spec bucket width (cached, deterministic).

        Returns a ``ChunkLayout`` for single-row meshes (unchanged contract
        for the local/pallas backends and the 1-D sharded layout) and a
        ``MeshLayout`` of per-doc-row-block layouts when ``doc_shards > 1``.
        """
        if chunk_len not in self._layouts:
            width = self.num_chunks * chunk_len

            def row_layout(r: int) -> ChunkLayout:
                if self.weights is None:
                    return ChunkLayout.uniform(width, self.num_chunks,
                                               self.devices)
                return ChunkLayout.weighted(width, self.num_chunks,
                                            self.devices, self.weights[r],
                                            m=self.spec_m)

            if self.doc_shards == 1:
                self._layouts[chunk_len] = row_layout(0)
            else:
                rw = (tuple(float(w) for w in self.row_weights)
                      if self.row_weights is not None else None)
                self._layouts[chunk_len] = MeshLayout(
                    width=width,
                    rows=tuple(row_layout(r)
                               for r in range(self.doc_shards)),
                    row_weights=rw)
        return self._layouts[chunk_len]

    # -- lane programs ------------------------------------------------------

    def lane_plan(self, bucket: BucketPlan, *, entry: str = ENTRY_STARTS,
                  early_exit: bool = True, spec_r: int = 1) -> LanePlan:
        """The lane program of one bucket dispatch (see ``LanePlan``).

        ``spec_r`` is the boundary-key depth of the lookahead tables the
        program will gather from (``DeviceTables.spec_r``); the facade passes
        it for plans that touch candidate tables (spec buckets and every
        ``ENTRY_LANES`` program) so the lazily-resolved per-DFA r choice is
        part of the lowering cache key.
        """
        return LanePlan(kind=bucket.kind, width=bucket.width,
                        chunk_len=bucket.chunk_len, entry=entry,
                        early_exit=early_exit, spec_r=spec_r,
                        table_epoch=self.table_epoch)

    # -- batch planning -----------------------------------------------------

    def plan(self, lengths: np.ndarray) -> MatchPlan:
        """Assign every document to a bucket, updating the sticky key set."""
        lengths = np.asarray(lengths, dtype=np.int64)
        b = lengths.shape[0]
        c = self.num_chunks
        spec = (lengths >= 4 * c) & (c > 1)
        chunk_len = np.zeros(b, np.int64)
        buckets: list[BucketPlan] = []

        seq_idx = np.flatnonzero(~spec)
        if seq_idx.size and int(lengths[seq_idx].max()) > 0:
            lmax = int(lengths[seq_idx].max())
            if lmax > self.seq_width:  # only reachable when num_chunks <= 1
                self.seq_width = next_pow2(lmax)
            buckets.append(BucketPlan("seq", self.seq_width, 0, seq_idx))

        spec_idx = np.flatnonzero(spec)
        if spec_idx.size:
            lc = np.array([next_pow2(-(-int(n) // c)) for n in lengths[spec_idx]])
            # snap each doc up into an already-compiled bucket when one fits
            known = sorted(self.spec_keys)
            for j, v in enumerate(lc):
                fit = [key for key in known if key >= v]
                if fit:
                    lc[j] = fit[0]
            # fresh keys: merge smallest upward until within the lifetime
            # shape budget (always allowing at least one new key so oversized
            # documents can still be matched)
            fresh = sorted(set(lc.tolist()) - set(known))
            allowed = max(1, self.max_buckets - len(known))
            while len(fresh) > allowed:
                lc[lc == fresh[0]] = fresh[1]
                fresh.pop(0)
            self.spec_keys = sorted(set(known) | set(fresh))
            for key in sorted(set(lc.tolist())):
                sel = spec_idx[lc == key]
                chunk_len[sel] = key
                buckets.append(BucketPlan("spec", c * key, key, sel))

        return MatchPlan(buckets=buckets, lengths=lengths, spec_mask=spec,
                         chunk_len=chunk_len)
