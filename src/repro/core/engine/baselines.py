"""Paper baselines: the per-document speculative membership test, in JAX.

This module is the faithful single-document reference of the paper's
algorithms — the figure-reproduction target (``benchmarks/paper_figs.py``)
and the oracle the production lane-program runtime (``plan.py`` /
``executors.py`` / ``facade.py``) is verified against.  The public
``SpecDFAEngine`` compatibility shim (``engine/spec.py``) delegates its
per-document modes here and its batched path to the ``Matcher`` facade; no
production code path runs through this module.

Flow (Sec. 4.1 steps 2–4):

  1. partition the class stream into chunks,
  2. derive each chunk's reverse-lookahead class (last class of the previous
     chunk) and its candidate initial states (Eq. 11 tables),
  3. match all chunks x candidate lanes in one ``lax.scan`` over symbols
     (the vectorized matching loop of Listing 2 — lanes = chunks x candidates,
     8x128-wide on the TPU VPU instead of AVX2's 8),
  4. fold the compressed L-vectors from the known start state (Eq. 8), with
     the sink absorbing.

Partition models (DESIGN.md §2):

  * ``balanced`` (paper-faithful, Eqs. 2–7): chunk 0 is ``m``x longer and is
    matched *exactly* (one state); the C-1 speculative chunks are equal-length.
    Scalar per-processor work is balanced -> failure-free on scalar cores.
  * ``uniform``: equal chunks, speculative lanes ride the vector unit.  On
    lane-parallel hardware matching m states costs the same wall time as one,
    so uniform chunks are optimal there (time = n/C steps); this is the
    SPMD/TPU-native layout and a beyond-paper observation recorded in §Perf.

Modes:
  * ``lookahead``  — paper Alg. 3 (I_max candidate lanes).      [default]
  * ``basic``      — paper Alg. 2 (all |Q| lanes, chunk 0 knows q0).
  * ``holub``      — Holub–Stekr [19] baseline: full [Q]->[Q] maps per chunk,
                     merged associatively; O(n|Q|/|P|) work, used by Fig. 11.

The matcher callable is pluggable so the Pallas kernels (kernels/ops.py) slot
in; the pure-jnp path below is their oracle.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..automata import DFA
from ..lookahead import LookaheadTables, build_lookahead_tables
from ..lvector import merge_scan_jnp

__all__ = ["MatchResult", "PaperSpecEngine", "sequential_state",
           "match_chunks_lanes", "VPU_LANES", "MatcherFn"]

VPU_LANES = 1024  # 8 sublanes x 128 lanes of int32 on a TPU core


@dataclasses.dataclass
class MatchResult:
    final_state: int
    accepted: bool
    work_parallel: int    # scalar-model: max symbols matched by any processor
    work_sequential: int  # n — the sequential matcher's symbol count
    time_steps: int       # lane-parallel model: wall-clock matching steps
    mode: str

    @property
    def model_speedup(self) -> float:
        """Scalar-work speedup proxy (the paper's time-unit model, Sec. 3)."""
        return self.work_sequential / max(self.work_parallel, 1)

    @property
    def lane_speedup(self) -> float:
        return self.work_sequential / max(self.time_steps, 1)


# --------------------------------------------------------------------------
# jit kernels (pure-jnp reference path)
# --------------------------------------------------------------------------

@jax.jit
def sequential_state(table: jnp.ndarray, classes: jnp.ndarray, start: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 1 matching loop: one gather per symbol."""

    def step(s, cls):
        return table[s, cls], None

    final, _ = jax.lax.scan(step, jnp.asarray(start, jnp.int32), classes)
    return final


def match_chunks_lanes(table: jnp.ndarray, chunk_classes: jnp.ndarray,
                       init_states: jnp.ndarray) -> jnp.ndarray:
    """Vectorized matching of [C] chunks x [S] speculative lanes.

    chunk_classes: [C, L] int32;  init_states: [C, S] int32.
    Returns final states [C, S].  One scan over L; each step is a batched
    2-D gather — the TPU analogue of the AVX2 gather loop (Listing 2).
    """
    sym_major = chunk_classes.T  # [L, C]

    def step(states, cls_row):  # states [C, S], cls_row [C]
        nxt = table[states, cls_row[:, None]]
        return nxt, None

    final, _ = jax.lax.scan(step, init_states.astype(jnp.int32), sym_major)
    return final


@functools.partial(jax.jit, static_argnames=("sink",))
def _merge_compressed_jnp(start_state: jnp.ndarray, lvecs: jnp.ndarray,
                          cand_index: jnp.ndarray, lookahead_cls: jnp.ndarray,
                          sink: int) -> jnp.ndarray:
    """Eq. 8 fold over compressed per-chunk results from a known start state.

    lvecs[i] holds chunk i's final state per candidate lane; lookahead_cls[i]
    selects the candidate list.  The carried state is always a candidate of
    the next chunk (Eq. 11) unless it is the absorbing sink.
    """

    def step(s, xs):
        lv, la = xs
        lane = cand_index[la, s]
        nxt = jnp.where(lane < 0, jnp.int32(sink if sink >= 0 else 0),
                        lv[jnp.maximum(lane, 0)])
        if sink >= 0:
            nxt = jnp.where(s == sink, jnp.int32(sink), nxt)
        return nxt.astype(jnp.int32), None

    final, _ = jax.lax.scan(step, jnp.asarray(start_state, jnp.int32),
                            (lvecs, lookahead_cls))
    return final


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------

MatcherFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]


class PaperSpecEngine:
    """End-to-end speculative membership test for one DFA (paper reference).

    Parameters
    ----------
    dfa          : complete DFA (core.automata).
    num_chunks   : processor count P (defaults to 8; the distributed wrapper
                   multiplies this by the mesh data extent).
    mode         : "lookahead" | "basic" | "holub".
    partition    : "balanced" (paper Eqs. 2–7) | "uniform" (SPMD lanes).
    weights      : optional per-processor capacity weights (Eq. 1).
    matcher      : optional replacement for the chunk matcher (Pallas kernel).
    """

    def __init__(self, dfa: DFA, *, num_chunks: int = 8, mode: str = "lookahead",
                 partition: str = "balanced", weights: Optional[np.ndarray] = None,
                 matcher: Optional[MatcherFn] = None, lookahead_r: int = 1):
        if mode not in ("lookahead", "basic", "holub"):
            raise ValueError(f"unknown mode {mode!r}")
        if partition not in ("balanced", "uniform"):
            raise ValueError(f"unknown partition {partition!r}")
        if lookahead_r not in (1, 2):
            raise ValueError("runtime lookahead_r must be 1 or 2 (Sec. 4.3)")
        self.dfa = dfa
        self.mode = mode
        self.lookahead_r = lookahead_r if mode == "lookahead" else 1
        self.partition = "uniform" if mode == "holub" else partition
        self.num_chunks = int(num_chunks)
        self.weights = (np.ones(self.num_chunks) if weights is None
                        else np.asarray(weights, dtype=np.float64))
        if self.weights.shape != (self.num_chunks,):
            raise ValueError("weights must have one entry per chunk")
        self.tables: LookaheadTables = build_lookahead_tables(
            dfa, r=self.lookahead_r)
        self.matcher: MatcherFn = matcher or match_chunks_lanes

        self._table_j = jnp.asarray(dfa.table)
        self._cand_j = jnp.asarray(self.tables.candidates)
        self._cidx_j = jnp.asarray(self.tables.cand_index)
        self._all_states = jnp.arange(dfa.n_states, dtype=jnp.int32)
        self._matcher_jit = jax.jit(self.matcher)

    # -- public API ---------------------------------------------------------

    @property
    def gamma(self) -> float:
        return self.tables.gamma

    @property
    def i_max(self) -> int:
        return self.tables.i_max

    @property
    def lanes_per_chunk(self) -> int:
        return self.dfa.n_states if self.mode in ("basic", "holub") else self.tables.i_max

    def classes(self, data: bytes | np.ndarray) -> np.ndarray:
        return self.dfa.classes_of(data).astype(np.int32)

    def membership_sequential(self, data: bytes | np.ndarray) -> MatchResult:
        cls = jnp.asarray(self.classes(data))
        final = int(sequential_state(self._table_j, cls, self.dfa.start))
        n = int(cls.shape[0])
        return MatchResult(final, bool(self.dfa.accepting[final]), n, n, n, "sequential")

    def membership(self, data: bytes | np.ndarray) -> MatchResult:
        cls_np = self.classes(data)
        n = int(cls_np.shape[0])
        p = self.num_chunks
        m = self.lanes_per_chunk
        if p <= 1 or n < 4 * p:
            return self.membership_sequential(data)
        if self.partition == "uniform":
            final, work, steps = self._run_uniform(cls_np)
        else:
            final, work, steps = self._run_balanced(cls_np, m)
        final_i = int(final)
        return MatchResult(final_i, bool(self.dfa.accepting[final_i]), work, n,
                           steps, self.mode)

    def accepts(self, data: bytes | np.ndarray) -> bool:
        return self.membership(data).accepted

    # -- partition bodies -----------------------------------------------------

    def _run_balanced(self, cls_np: np.ndarray, m: int) -> tuple[jnp.ndarray, int, int]:
        """Paper Eqs. 2–7: exact chunk 0 of length ~m*L, C-1 speculative chunks.

        Speculative chunks are forced equal-length (L_spec) for the SPMD
        matcher; chunk 0 absorbs the rounding remainder.  With capacity
        weights w, L0 follows Eq. 5 with the w-weighted denominator.
        """
        n = cls_np.shape[0]
        p = self.num_chunks
        w = self.weights
        l0 = n * m / (w[0] * m + w[1:].sum())  # Eq. 5
        l_spec = max(1, int(np.floor(l0 / m * (w[1:].mean() if p > 1 else 1.0))))
        l_spec = min(l_spec, (n - 1) // max(p - 1, 1))
        l0_int = n - (p - 1) * l_spec
        if l0_int <= 0 or l_spec <= 0:
            seq = self.membership_sequential(cls_np)
            return jnp.int32(seq.final_state), seq.work_parallel, seq.time_steps

        head = jnp.asarray(cls_np[:l0_int])
        body = jnp.asarray(cls_np[l0_int:]).reshape(p - 1, l_spec)
        final0 = sequential_state(self._table_j, head, self.dfa.start)

        la = jnp.concatenate([jnp.asarray(cls_np[l0_int - 1 : l0_int]), body[:-1, -1]])
        if self.lookahead_r == 2:
            if l0_int < 2 or l_spec < 2:
                seq = self.membership_sequential(cls_np)
                return jnp.int32(seq.final_state), seq.work_parallel, seq.time_steps
            prev = jnp.concatenate(
                [jnp.asarray(cls_np[l0_int - 2 : l0_int - 1]), body[:-1, -2]])
            la = prev * self.dfa.n_classes + la
        cand, lanes = self._candidates(la, body.shape[0])
        lvecs = self._matcher_jit(self._table_j, body, cand)  # [C-1, S]
        if self.mode == "basic":
            def step(s, lv):
                return lv[s], None
            final, _ = jax.lax.scan(step, final0, lvecs)
        else:
            final = _merge_compressed_jnp(final0, lvecs, self._cidx_j, la, self.dfa.sink)
        work = max(l0_int, l_spec * lanes)          # scalar-processor model
        steps = max(l0_int, l_spec)                 # lane-parallel model
        return final, work, steps

    def _run_uniform(self, cls_np: np.ndarray) -> tuple[jnp.ndarray, int, int]:
        n = cls_np.shape[0]
        c = self.num_chunks
        l = n // c
        body = jnp.asarray(cls_np[: l * c]).reshape(c, l)

        if self.mode == "holub":
            q = self.dfa.n_states
            init = jnp.broadcast_to(self._all_states, (c, q))
            maps = self._matcher_jit(self._table_j, body, init)
            final = merge_scan_jnp(maps)[-1][self.dfa.start]
            work, lanes = l * q, q
        else:
            la = jnp.concatenate([jnp.zeros((1,), jnp.int32), body[:-1, -1]])
            if self.lookahead_r == 2:
                if l < 2:
                    seq = self.membership_sequential(cls_np)
                    return jnp.int32(seq.final_state), seq.work_parallel, l
                prev = jnp.concatenate([jnp.zeros((1,), jnp.int32), body[:-1, -2]])
                la = prev * self.dfa.n_classes + la
            cand, lanes = self._candidates(la, c)
            # chunk 0 knows q0: all its lanes hold q0 (idle-lane duplicates)
            cand = cand.at[0].set(jnp.full((cand.shape[1],), self.dfa.start, jnp.int32))
            lvecs = self._matcher_jit(self._table_j, body, cand)
            if self.mode == "basic":
                def step(s, lv):
                    return lv[s], None
                s0 = lvecs[0, self.dfa.start]
                final, _ = jax.lax.scan(step, s0, lvecs[1:])
            else:
                final = _merge_compressed_jnp(lvecs[0, 0], lvecs[1:], self._cidx_j,
                                              la[1:], self.dfa.sink)
            work = l * lanes

        if l * c < n:  # sequential tail for the remainder
            tail = jnp.asarray(cls_np[l * c:])
            final = sequential_state(self._table_j, tail, final)
            work += n - l * c
        return final, work, l + (n - l * c)

    def _candidates(self, la: jnp.ndarray, c: int) -> tuple[jnp.ndarray, int]:
        if self.mode == "basic":
            q = self.dfa.n_states
            return jnp.broadcast_to(self._all_states, (c, q)), q
        return self._cand_j[la], self.tables.i_max
