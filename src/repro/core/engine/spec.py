"""``SpecDFAEngine`` — compatibility shim over the paper baselines + facade.

The pre-refactor entry point for single-document speculative matching.  It
holds **no matching logic of its own**: every per-document mode (lookahead /
basic / holub, balanced / uniform partitions, runtime r=2 lookahead) is the
``engine.baselines.PaperSpecEngine`` reference implementation, inherited
unchanged, and the batched path delegates to the ``Matcher`` facade — the
production lane-program runtime (``plan.py`` / ``executors.py`` /
``sharded.py`` / ``facade.py``).  New code should construct ``Matcher``
directly; tests/test_compat_shims.py keeps this shim covered.

``sequential_state`` / ``match_chunks_lanes`` / ``VPU_LANES`` / ``MatcherFn``
re-export from ``baselines`` for import stability (benchmarks and kernels
tests use them as the paper-primitive oracles).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .baselines import (VPU_LANES, MatcherFn, MatchResult, PaperSpecEngine,
                        match_chunks_lanes, sequential_state)

__all__ = ["MatchResult", "SpecDFAEngine", "sequential_state",
           "match_chunks_lanes", "VPU_LANES", "MatcherFn"]


class SpecDFAEngine(PaperSpecEngine):
    """Compatibility shim: the paper engine's public name.

    Per-document methods (``membership``, ``membership_sequential``,
    ``accepts``) come from ``baselines.PaperSpecEngine`` verbatim;
    ``membership_batch`` is pure delegation to the ``Matcher`` facade.
    """

    _batch = None  # Matcher facade, built on first use

    def membership_batch(self, docs: Sequence[bytes | np.ndarray]):
        """Batched membership for many documents in few fused device calls.

        Pure delegation: decisions are bit-identical to
        ``membership_sequential`` per document; see the ``Matcher`` facade
        for the bucketing/padding policy.  The batch path always partitions
        uniformly (lanes ride the vector unit), regardless of this engine's
        ``partition`` setting.
        """
        if self._batch is None:
            from .facade import Matcher  # local import: facade layers on us
            self._batch = Matcher(self.dfa, num_chunks=self.num_chunks)
        return self._batch.membership_batch(docs)
