"""K-blocked pattern-set execution: ``BlockedMatcher``.

One ``Matcher`` runs one packed table; a ``BlockedMatcher`` fans a
``core.patterns.PatternSet`` out over one inner ``Matcher`` per block and
fans the per-block ``[B, k_blk]`` verdicts back into a single ``[B, K]``
result, re-offsetting final states by the set's global ``state_bases`` —
bit-identical to an unblocked ``pack_dfas`` over all K patterns (the packed
offsets are a plain cumsum, so block-local id + block base == global id).

Two things blocking buys:

* **Table memory scales linearly in blocks.**  Joint-alphabet refinement
  and padded lane tables grow super-linearly in K; 2048 patterns as 64
  blocks of 32 stay at the 32-pattern table size each and compile the same
  bucket shapes, so lowering costs amortize across blocks.
* **Block-granular skipping and swapping.**  The required-literal prefilter
  (``core.prefilter``) gates whole blocks per document before any dispatch
  — a fully-gated block costs zero device calls (``prefilter_skipped_
  blocks``) and gated documents drop out of a block's tile batch.  And
  ``swap_patterns`` rebuilds only blocks whose content signature changed:
  unchanged blocks keep their inner Matcher — compiled bucket lowerings,
  device tables, traces — verbatim.

Gated documents report ``accepted=False`` with ``final_states`` pinned at
the block's start states: the gate proves no pattern of the block can
match, and the (unreached) automaton position of a skipped run is defined
as "never left the start" rather than paying the scan to learn it.
"""

from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np

from ..patterns import PatternSet
from ..prefilter import Prefilter
from .facade import BatchResult, Matcher

__all__ = ["BlockedMatcher"]


class BlockedMatcher:
    """``Matcher``-shaped front end over a multi-block ``PatternSet``.

    ``source`` is a ``PatternSet`` or anything its constructor accepts
    (name->regex mapping, regex list, DFA list — then ``k_blk``/``search``
    apply).  ``prefilter=True`` builds the required-literal gate from the
    set's regexes; DFA-sourced patterns leave their block ungated.  All
    remaining keyword arguments go to every inner ``Matcher`` (backend,
    num_chunks, batch_tile, mesh, ...), so all blocks share one bucket
    policy and their compiled shapes coincide.
    """

    def __init__(self, source: Union[PatternSet, Sequence, dict], *,
                 k_blk: Optional[int] = None, search: bool = True,
                 prefilter: bool = True, **matcher_kwargs):
        if isinstance(source, PatternSet):
            if k_blk is not None and k_blk != source.k_blk:
                raise ValueError(f"k_blk={k_blk} conflicts with the "
                                 f"PatternSet's k_blk={source.k_blk}")
            self.pattern_set = source
        else:
            self.pattern_set = PatternSet(source, k_blk=k_blk or 32,
                                          search=search)
        self._matcher_kwargs = dict(matcher_kwargs)
        self.matchers: list[Matcher] = [
            Matcher(blk, **self._matcher_kwargs)
            for blk in self.pattern_set.blocks]
        self.prefilter: Optional[Prefilter] = (
            Prefilter.from_pattern_set(self.pattern_set) if prefilter
            else None)
        self.backend = self.matchers[0].backend
        self.batch_tile = self.matchers[0].batch_tile
        # gate accounting: whole block dispatches skipped (every doc of the
        # batch gated) and total (doc, block) pairs gated off
        self.prefilter_skipped_blocks = 0
        self.prefilter_gated_docs = 0

    # -- shape ---------------------------------------------------------------

    @property
    def n_patterns(self) -> int:
        return self.pattern_set.n_patterns

    @property
    def n_blocks(self) -> int:
        return self.pattern_set.n_blocks

    # -- matching ------------------------------------------------------------

    def can_match(self, docs: Sequence[bytes | np.ndarray]) -> np.ndarray:
        """[B, n_blocks] prefilter bits (all-True when the gate is off)."""
        arrs, lengths = Matcher._as_arrays(docs)
        if self.prefilter is None:
            return np.ones((len(arrs), self.n_blocks), dtype=bool)
        return self.prefilter.can_match(arrs, lengths)

    def membership_batch(self, docs: Sequence[bytes | np.ndarray]
                         ) -> BatchResult:
        """Match every doc against every pattern of every block ([B, K]).

        Ungated traffic is bit-identical to one unblocked ``Matcher`` over
        all K patterns; gated (doc, block) pairs are guaranteed non-matches
        reported at the block's start states (see module docstring).
        """
        b = len(docs)
        k = self.n_patterns
        ps = self.pattern_set
        if b == 0:
            z = np.zeros(0, np.int64)
            return BatchResult(np.zeros((0, k), bool),
                               np.zeros((0, k), np.int32), z, z, z, 0)
        arrs, lengths = Matcher._as_arrays(docs)
        can = (self.prefilter.can_match(arrs, lengths)
               if self.prefilter is not None
               else np.ones((b, ps.n_blocks), dtype=bool))
        accepted = np.zeros((b, k), dtype=bool)
        finals = np.zeros((b, k), dtype=np.int32)
        work_par = np.zeros(b, np.int64)
        work_seq = np.zeros(b, np.int64)
        steps = np.zeros(b, np.int64)
        calls = early = 0
        device_work = None
        for bi, m in enumerate(self.matchers):
            sl = ps.block_slice(bi)
            base = int(ps.state_bases[bi])
            # default every row to the start states; live rows overwrite
            finals[:, sl] = m.packed.starts[None, :] + base
            live = np.flatnonzero(can[:, bi])
            self.prefilter_gated_docs += b - live.size
            if live.size == 0:
                self.prefilter_skipped_blocks += 1
                continue
            res = m.membership_batch([arrs[i] for i in live])
            accepted[live, sl] = res.accepted
            finals[live, sl] = res.final_states + base
            # blocks dispatch back to back on the same devices, so the
            # model quantities accumulate (work) / sum (steps) per doc
            work_par[live] += res.work_parallel
            work_seq[live] += res.work_sequential
            steps[live] += res.time_steps
            calls += res.bucket_calls
            early += res.early_exits
            if res.device_work is not None:
                device_work = (res.device_work if device_work is None
                               else device_work + res.device_work)
        return BatchResult(accepted, finals, work_par, work_seq, steps,
                           calls, early_exits=early, device_work=device_work)

    def accepts_batch(self, docs: Sequence[bytes | np.ndarray]) -> np.ndarray:
        """[B, K] bool accept matrix across all blocks."""
        return self.membership_batch(docs).accepted

    # -- hot swap ------------------------------------------------------------

    def swap_patterns(self, source, *, k_blk: Optional[int] = None,
                      search: Optional[bool] = None) -> dict:
        """Swap the pattern set, rebuilding only changed blocks.

        Blocks are compared position-wise by content signature
        (``PatternSet.block_signatures``): an unchanged block keeps its
        inner ``Matcher`` object — compiled lowerings, planner, traces —
        verbatim; a changed block swaps in place (``Matcher.swap_patterns``,
        which preserves bucket *shapes* but re-lowers against the new
        tables); new trailing blocks build fresh and removed ones drop.
        The prefilter rebuilds whenever it is enabled (literal tables are
        cheap; signatures are part of checkpoint identity).

        Returns ``{"reused": [block ids], "rebuilt": [block ids],
        "dropped": n}``.
        """
        if isinstance(source, PatternSet):
            ps = source
        else:
            ps = PatternSet(source,
                            k_blk=k_blk or self.pattern_set.k_blk,
                            search=self.pattern_set.search
                            if search is None else search)
        old_sigs = self.pattern_set.block_signatures
        reused: list[int] = []
        rebuilt: list[int] = []
        matchers: list[Matcher] = []
        for bi, blk in enumerate(ps.blocks):
            if bi < len(self.matchers):
                m = self.matchers[bi]
                if (bi < len(old_sigs)
                        and ps.block_signatures[bi] == old_sigs[bi]):
                    reused.append(bi)
                else:
                    m.swap_patterns(blk)
                    rebuilt.append(bi)
                matchers.append(m)
            else:
                matchers.append(Matcher(blk, **self._matcher_kwargs))
                rebuilt.append(bi)
        dropped = max(0, len(self.matchers) - ps.n_blocks)
        self.matchers = matchers
        self.pattern_set = ps
        if self.prefilter is not None:
            self.prefilter = Prefilter.from_pattern_set(ps)
        return {"reused": reused, "rebuilt": rebuilt, "dropped": dropped}

    # -- introspection -------------------------------------------------------

    def perf_report(self) -> dict:
        """Aggregate of the per-block ``Matcher.perf_report`` plus the gate
        counters (``prefilter_skipped_blocks`` is the headline: device
        dispatch groups that never ran because every doc was gated)."""
        return {
            "backend": self.backend,
            "n_patterns": self.n_patterns,
            "n_blocks": self.n_blocks,
            "k_blk": self.pattern_set.k_blk,
            "prefilter_skipped_blocks": self.prefilter_skipped_blocks,
            "prefilter_gated_docs": self.prefilter_gated_docs,
            "prefilter": repr(self.prefilter) if self.prefilter else None,
            "table_epochs": [m.planner.table_epoch for m in self.matchers],
            "blocks": [m.perf_report() for m in self.matchers],
        }

    def __repr__(self) -> str:
        return (f"BlockedMatcher(K={self.n_patterns}, "
                f"n_blocks={self.n_blocks}, backend={self.backend!r}, "
                f"prefilter={self.prefilter is not None})")
