"""Mesh-sharded executor: capacity-balanced chunk matching across devices.

The paper's cloud result (288 EC2 cores) comes from two ingredients: split
the input across workers, and size each worker's slice by its *measured
matching capacity* (Eq. 1, ``core.profiling.profile_workers``).  This
executor is the device-mesh version of that scheme:

  * the **chunk axis is sharded** over the mesh's ``data`` axis
    (``launch.mesh.make_matcher_mesh`` + ``jax_compat.shard_map``): each
    device matches its contiguous run of chunks x candidate lanes locally;
  * chunk boundaries come from the planner's ``ChunkLayout`` — uniform, or
    capacity-weighted via the paper's Eqs. 2–7 so a device with twice the
    measured capacity receives twice the real symbols (trailing identity-pad
    columns equalize the SPMD buffer shapes; they advance no DFA and carry no
    model work);
  * devices exchange **only the per-chunk L-vector lane states**
    (``[C, B, K, S]`` int32, independent of chunk length) in one
    ``all_gather`` before the Eq. 8 merge — the documents' bytes never cross
    devices;
  * the merge folds the gathered lane states per document, exactly as the
    single-device reference, so results are bit-identical to sequential
    matching for any device count and any capacity profile.

Axis split: the **batched sequential path shards the document axis** over
"data" (``distributed.sharding.doc_batch_spec`` — rows are independent, each
device scans B/D of them, nothing is exchanged).  The speculative path keeps
document rows replicated and shards chunks instead: the L-vector exchange
only exists *because* one document's chunks live on different devices, which
is the paper's architecture and what capacity weighting balances.  A 2-D
document x chunk mesh for batches beyond one host's memory is a recorded
ROADMAP follow-up.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .executors import NO_EXIT, _ExecutorBase
from .plan import ChunkLayout, DeviceTables

__all__ = ["ShardedExecutor"]


class ShardedExecutor(_ExecutorBase):
    """shard_map-backed executor over the mesh ``data`` axis.

    Parameters
    ----------
    tables      : shared ``DeviceTables`` bundle.
    num_chunks  : total chunk count C (a multiple of the mesh data extent;
                  the planner rounds up).
    mesh        : mesh with a ``data`` axis; defaults to
                  ``launch.mesh.make_matcher_mesh()`` over all local devices.
    """

    def __init__(self, tables: DeviceTables, *, num_chunks: int,
                 mesh=None, early_exit_segments: int = 4):
        super().__init__(tables, num_chunks=num_chunks,
                         early_exit_segments=early_exit_segments)
        if mesh is None:
            from ...launch.mesh import make_matcher_mesh
            mesh = make_matcher_mesh()
        self.mesh = mesh
        self.devices = int(mesh.shape["data"])
        if self.num_chunks % self.devices != 0:
            raise ValueError(
                f"num_chunks={self.num_chunks} must be a multiple of the mesh "
                f"data extent {self.devices} (the planner rounds up for you)")
        self._spec_fns: dict[int, object] = {}
        self._seq_fns: dict[int, object] = {}
        self._spec_entry_fns: dict[int, object] = {}
        self._seq_entry_fns: dict[int, object] = {}

    def _replicated_tables(self):
        """Pin the constant matcher tables onto every mesh device up front
        (distributed.sharding.matcher_table_specs), instead of relying on
        implicit transfer at first dispatch."""
        from jax.sharding import NamedSharding

        from ...distributed.sharding import matcher_table_specs

        t = self.t
        specs = matcher_table_specs(self.mesh)

        def repl(name, arr):
            return jax.device_put(arr, NamedSharding(self.mesh, specs[name]))

        return (repl("table_pad", t.table_pad_j),
                repl("cand_pad", t.cand_pad_j),
                repl("cidx_pad", t.cidx_pad_j))

    # -- batched sequential path: document axis sharded over "data" ---------

    def run_seq(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray):
        b = bytes_buf.shape[0]
        if self.devices == 1 or b % self.devices != 0:
            return super().run_seq(bytes_buf, lengths)
        fn = self._seq_fns.get(b)
        if fn is None:
            fn = self._build_seq_fn(b)
            self._seq_fns[b] = fn
        return fn(bytes_buf, lengths)

    def run_seq_entry(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                      entry: jnp.ndarray):
        b = bytes_buf.shape[0]
        if self.devices == 1 or b % self.devices != 0:
            return super().run_seq_entry(bytes_buf, lengths, entry)
        fn = self._seq_entry_fns.get(b)
        if fn is None:
            fn = self._build_seq_fn(b, with_entry=True)
            self._seq_entry_fns[b] = fn
        return fn(bytes_buf, lengths, entry)

    def _build_seq_fn(self, batch: int, *, with_entry: bool = False):
        """Short documents are independent rows, so the document axis shards
        cleanly over "data" (distributed.sharding.doc_batch_spec) — each
        device classifies and scans B/D rows, nothing is exchanged.  The
        entry variant also splits the [B, K] segment entry states row-wise."""
        from jax.sharding import PartitionSpec as P

        from ...distributed.sharding import doc_batch_spec
        from ...jax_compat import shard_map

        row_ax = tuple(doc_batch_spec(self.mesh, batch))
        buf_spec, len_spec = P(*row_ax, None), P(*row_ax)
        donate = (0,) if jax.default_backend() != "cpu" else ()

        if with_entry:
            body = shard_map(self._seq_entry_body, mesh=self.mesh,
                             in_specs=(buf_spec, len_spec, P(*row_ax, None)),
                             out_specs=(buf_spec, len_spec), check_vma=False)

            def impl_entry(bytes_buf, lengths, entry):
                self.traces += 1  # side effect fires at trace time only
                return body(bytes_buf, lengths, entry)

            return jax.jit(impl_entry, donate_argnums=donate)

        body = shard_map(self._seq_body, mesh=self.mesh,
                         in_specs=(buf_spec, len_spec),
                         out_specs=(buf_spec, len_spec), check_vma=False)

        def impl(bytes_buf, lengths):
            self.traces += 1  # side effect fires at trace time only
            return body(bytes_buf, lengths)

        return jax.jit(impl, donate_argnums=donate)

    def steps_for(self, layout: ChunkLayout) -> int:
        return layout.lmax  # lane-parallel wall steps = longest chunk buffer

    # -- speculative path ---------------------------------------------------

    def run_spec(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                 layout: ChunkLayout):
        fn = self._spec_fns.get(layout.width)
        if fn is None:
            fn = self._build_spec_fn(layout)
            self._spec_fns[layout.width] = fn
        return fn(bytes_buf, lengths)

    def run_spec_entry(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                       layout: ChunkLayout, entry: jnp.ndarray):
        fn = self._spec_entry_fns.get(layout.width)
        if fn is None:
            fn = self._build_spec_fn(layout, with_entry=True)
            self._spec_entry_fns[layout.width] = fn
        return fn(bytes_buf, lengths, entry)

    def _build_spec_fn(self, layout: ChunkLayout, *, with_entry: bool = False):
        """Jit one bucket width; the layout's boundaries are baked in as
        static slices (deterministic per width, so the cache key is width)."""
        from ...distributed.sharding import matcher_chunk_specs
        from ...jax_compat import shard_map

        t = self.t
        lmax = layout.lmax
        bounds = list(zip(layout.starts.tolist(), layout.ends.tolist()))
        exact_np = layout.exact.copy()
        in_specs, out_spec = matcher_chunk_specs(self.mesh)
        table_pad, cand_pad, cidx_pad = self._replicated_tables()

        def body(chunk_loc, la_loc, exact_loc, entry):
            # chunk_loc [C_loc, B, Lmax]; la_loc [C_loc, B]; exact_loc
            # [C_loc]; entry [B, K] replicated segment entry states — exact
            # chunks (stream position 0) seed from them instead of the starts
            c_loc, b = chunk_loc.shape[0], chunk_loc.shape[1]
            k, s = t.n_patterns, t.i_max
            cand = cand_pad[la_loc]                      # [C_loc, B, K, S]
            start = jnp.broadcast_to(
                entry.astype(jnp.int32)[None, :, :, None], (c_loc, b, k, s))
            init = jnp.where(exact_loc[:, None, None, None], start, cand)
            sym_t = chunk_loc.reshape(c_loc * b, lmax).T

            def step(st, row):
                return table_pad[st, row[:, None]], None

            lvecs, _ = jax.lax.scan(
                step, init.reshape(c_loc * b, k * s).astype(jnp.int32), sym_t)
            # the only cross-device exchange: lane states, not symbols
            lv_all = jax.lax.all_gather(
                lvecs.reshape(c_loc, b, k, s), "data", axis=0, tiled=True)
            la_all = jax.lax.all_gather(la_loc, "data", axis=0, tiled=True)
            ex_all = jax.lax.all_gather(exact_loc, "data", axis=0, tiled=True)
            return self._merge_gathered(lv_all, la_all, ex_all, cidx_pad)

        sharded_body = shard_map(body, mesh=self.mesh, in_specs=in_specs,
                                 out_specs=out_spec, check_vma=False)

        def run(bytes_buf, lengths, entry):
            self.traces += 1  # side effect fires at trace time only
            b = bytes_buf.shape[0]
            cls = self._classify(bytes_buf, lengths)     # [B, W]
            pieces, la_rows = [], []
            for s0, e0 in bounds:
                piece = cls[:, s0:e0]
                if e0 - s0 < lmax:  # tail-pad to the SPMD buffer length
                    piece = jnp.pad(piece, ((0, 0), (0, lmax - (e0 - s0))),
                                    constant_values=t.pad_cls)
                pieces.append(piece)
                la_rows.append(cls[:, s0 - 1] if s0 > 0
                               else jnp.zeros((b,), jnp.int32))
            chunk_buf = jnp.stack(pieces)                # [C, B, Lmax]
            la = jnp.stack(la_rows)                      # [C, B]
            finals = sharded_body(chunk_buf, la, jnp.asarray(exact_np), entry)
            return finals, jnp.full((b,), NO_EXIT, jnp.int32)

        donate = (0,) if jax.default_backend() != "cpu" else ()
        if with_entry:
            return jax.jit(run, donate_argnums=donate)

        def run0(bytes_buf, lengths):
            b = bytes_buf.shape[0]
            entry = jnp.broadcast_to(t.starts_j[None, :], (b, t.n_patterns))
            return run(bytes_buf, lengths, entry)

        return jax.jit(run0, donate_argnums=donate)

    def _merge_gathered(self, lv_all: jnp.ndarray, la_all: jnp.ndarray,
                        exact_all: jnp.ndarray,
                        cidx_pad: jnp.ndarray) -> jnp.ndarray:
        """Eq. 8 fold over gathered chunk lane states, with exact-chunk flags.

        lv_all [C, B, K, S]; la_all [C, B]; exact_all [C] — a chunk starting
        at stream position 0 is matched exactly from the start states, so the
        merge reads its lane 0 instead of a candidate lookup.  Delegates to
        the one shared merge definition (``kernels.ref.spec_merge_ref``,
        doc-major) so sharded and local stay bit-identical by construction.
        """
        from ...kernels.ref import spec_merge_ref

        t = self.t
        return spec_merge_ref(jnp.swapaxes(lv_all, 0, 1), la_all.T,
                              cidx_pad, t.sinks_j, pad_cls=t.pad_cls,
                              exact=exact_all)
