"""Mesh-sharded executor: capacity-balanced matching on a (doc, chunk) mesh.

The paper's cloud result (288 EC2 cores) comes from two ingredients: split
the input across workers, and size each worker's slice by its *measured
matching capacity* (Eq. 1, ``core.profiling.profile_workers``).  This
executor is the device-mesh version of that scheme, on a 2-D
``("doc", "chunk")`` mesh (``launch.mesh.make_matcher_mesh``):

  * the **chunk axis is sharded over "chunk"** (``jax_compat.shard_map``):
    each device matches its contiguous run of chunks x candidate lanes
    locally;
  * the **document axis is sharded over "doc"**: mesh row ``r`` owns tile
    row-block ``r`` outright, so batch sizes beyond one host's memory scale
    along "doc" with no extra traffic — speculative documents no longer
    replicate on every device;
  * chunk boundaries come from the planner's layout — uniform, or
    capacity-weighted via the paper's Eqs. 2–7 so a device with twice the
    measured capacity receives twice the real symbols.  On a 2-D mesh each
    doc row-block gets its *own* ``ChunkLayout`` weighted by that mesh row's
    devices (``plan.MeshLayout``); trailing identity-pad columns equalize the
    SPMD buffer shapes and advance no DFA;
  * devices exchange **only the per-chunk L-vector lane states**
    (``[C, B/Dd, K, S]`` int32, independent of chunk length) in one
    ``all_gather`` **over the "chunk" axis only** — doc shards never
    communicate, and the documents' bytes never cross devices;
  * each doc shard folds its gathered lane states per document (Eq. 8),
    exactly as the single-device reference, so results are bit-identical to
    sequential matching for any mesh shape and any capacity profile
    (tests/test_sharded_executor.py sweeps 1x1, 2x4, 4x2, 8x1).

The **batched sequential path** needs no exchange at all: short documents
are independent rows, so the document axis shards over *both* mesh axes
jointly (``distributed.sharding.doc_batch_spec``) and every device scans
``B / (Dd * Dc)`` rows.

See docs/architecture.md for the data-flow diagram and the "adding an
executor backend" guide.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .executors import NO_EXIT, _ExecutorBase
from .plan import ChunkLayout, DeviceTables, MeshLayout

__all__ = ["ShardedExecutor"]


class ShardedExecutor(_ExecutorBase):
    """shard_map-backed executor over a ("doc", "chunk") matcher mesh.

    Parameters
    ----------
    tables      : shared ``DeviceTables`` bundle.
    num_chunks  : total chunk count C (a multiple of the mesh chunk extent;
                  the planner rounds up).
    mesh        : mesh from ``launch.mesh.make_matcher_mesh`` (legacy 1-D
                  "data" meshes count as doc extent 1); defaults to a 1-D
                  chunk mesh over all local devices.
    """

    def __init__(self, tables: DeviceTables, *, num_chunks: int,
                 mesh=None, early_exit_segments: int = 4):
        super().__init__(tables, num_chunks=num_chunks,
                         early_exit_segments=early_exit_segments)
        from ...launch.mesh import make_matcher_mesh, matcher_mesh_extents
        if mesh is None:
            mesh = make_matcher_mesh()
        self.mesh = mesh
        self.doc_shards, self.chunk_shards = matcher_mesh_extents(mesh)
        self.chunk_axis = "chunk" if "chunk" in mesh.axis_names else "data"
        self.devices = self.doc_shards * self.chunk_shards
        if self.num_chunks % self.chunk_shards != 0:
            raise ValueError(
                f"num_chunks={self.num_chunks} must be a multiple of the mesh "
                f"chunk extent {self.chunk_shards} (the planner rounds up "
                "for you)")
        self._spec_fns: dict[int, object] = {}
        self._seq_fns: dict[int, object] = {}
        self._spec_entry_fns: dict[int, object] = {}
        self._seq_entry_fns: dict[int, object] = {}

    def _replicated_tables(self):
        """Pin the constant matcher tables onto every mesh device up front
        (distributed.sharding.matcher_table_specs), instead of relying on
        implicit transfer at first dispatch."""
        from jax.sharding import NamedSharding

        from ...distributed.sharding import matcher_table_specs

        t = self.t
        specs = matcher_table_specs(self.mesh)

        def repl(name, arr):
            return jax.device_put(arr, NamedSharding(self.mesh, specs[name]))

        return (repl("table_pad", t.table_pad_j),
                repl("cand_pad", t.cand_pad_j),
                repl("cidx_pad", t.cidx_pad_j))

    # -- batched sequential path: document axis over both mesh axes ---------

    def run_seq(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray):
        b = bytes_buf.shape[0]
        if self.devices == 1 or b % self.devices != 0:
            return super().run_seq(bytes_buf, lengths)
        fn = self._seq_fns.get(b)
        if fn is None:
            fn = self._build_seq_fn(b)
            self._seq_fns[b] = fn
        return fn(bytes_buf, lengths)

    def run_seq_entry(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                      entry: jnp.ndarray):
        b = bytes_buf.shape[0]
        if self.devices == 1 or b % self.devices != 0:
            return super().run_seq_entry(bytes_buf, lengths, entry)
        fn = self._seq_entry_fns.get(b)
        if fn is None:
            fn = self._build_seq_fn(b, with_entry=True)
            self._seq_entry_fns[b] = fn
        return fn(bytes_buf, lengths, entry)

    def _build_seq_fn(self, batch: int, *, with_entry: bool = False):
        """Short documents are independent rows, so the document axis shards
        cleanly over every mesh axis jointly (doc_batch_spec) — each device
        classifies and scans B/(Dd*Dc) rows, nothing is exchanged.  The
        entry variant also splits the [B, K] segment entry states row-wise."""
        from jax.sharding import PartitionSpec as P

        from ...distributed.sharding import doc_batch_spec
        from ...jax_compat import shard_map

        row_ax = tuple(doc_batch_spec(self.mesh, batch))
        buf_spec, len_spec = P(*row_ax, None), P(*row_ax)
        donate = (0,) if jax.default_backend() != "cpu" else ()

        if with_entry:
            body = shard_map(self._seq_entry_body, mesh=self.mesh,
                             in_specs=(buf_spec, len_spec, P(*row_ax, None)),
                             out_specs=(buf_spec, len_spec), check_vma=False)

            def impl_entry(bytes_buf, lengths, entry):
                self.traces += 1  # side effect fires at trace time only
                return body(bytes_buf, lengths, entry)

            return jax.jit(impl_entry, donate_argnums=donate)

        body = shard_map(self._seq_body, mesh=self.mesh,
                         in_specs=(buf_spec, len_spec),
                         out_specs=(buf_spec, len_spec), check_vma=False)

        def impl(bytes_buf, lengths):
            self.traces += 1  # side effect fires at trace time only
            return body(bytes_buf, lengths)

        return jax.jit(impl, donate_argnums=donate)

    def steps_for(self, layout: ChunkLayout | MeshLayout) -> int:
        return layout.lmax  # lane-parallel wall steps = longest chunk buffer

    # -- speculative path ---------------------------------------------------

    def run_spec(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                 layout: ChunkLayout | MeshLayout):
        fn = self._spec_fns.get(layout.width)
        if fn is None:
            fn = self._build_spec_fn(layout)
            self._spec_fns[layout.width] = fn
        return fn(bytes_buf, lengths)

    def run_spec_entry(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                       layout: ChunkLayout | MeshLayout, entry: jnp.ndarray):
        fn = self._spec_entry_fns.get(layout.width)
        if fn is None:
            fn = self._build_spec_fn(layout, with_entry=True)
            self._spec_entry_fns[layout.width] = fn
        return fn(bytes_buf, lengths, entry)

    def _layout_rows(self, layout: ChunkLayout | MeshLayout
                     ) -> tuple[ChunkLayout, ...]:
        """Per-doc-shard row layouts; a plain ChunkLayout broadcasts to every
        row (uniform boundaries on every row-block)."""
        if isinstance(layout, MeshLayout):
            if layout.doc_shards != self.doc_shards:
                raise ValueError(f"layout has {layout.doc_shards} doc shards, "
                                 f"mesh has {self.doc_shards}")
            return layout.rows
        return (layout,) * self.doc_shards

    def _build_spec_fn(self, layout: ChunkLayout | MeshLayout, *,
                       with_entry: bool = False):
        """Jit one bucket width; every row-block's boundaries are baked in as
        static slices (deterministic per width, so the cache key is width)."""
        from ...distributed.sharding import matcher_chunk_specs
        from ...jax_compat import shard_map

        t = self.t
        rows = self._layout_rows(layout)
        lmax = max(r.lmax for r in rows)
        n_chunks = rows[0].num_chunks
        row_bounds = [list(zip(r.starts.tolist(), r.ends.tolist()))
                      for r in rows]
        row_exact = [r.exact.copy() for r in rows]
        chunk_ax = self.chunk_axis
        in_specs, out_spec = matcher_chunk_specs(self.mesh)
        table_pad, cand_pad, cidx_pad = self._replicated_tables()

        def body(chunk_loc, la_loc, exact_loc, entry_loc):
            # chunk_loc [C_loc, B_loc, Lmax]; la_loc/exact_loc [C_loc,
            # B_loc]; entry_loc [B_loc, K] — this doc shard's segment entry
            # states; exact chunks (stream position 0) seed from them instead
            # of the Eq. 11 candidates.  All rows of this shard belong to one
            # doc row-block, so they share one set of chunk boundaries.
            c_loc, b_loc = chunk_loc.shape[0], chunk_loc.shape[1]
            k, s = t.n_patterns, t.i_max
            cand = cand_pad[la_loc]                    # [C_loc, B_loc, K, S]
            start = jnp.broadcast_to(
                entry_loc.astype(jnp.int32)[None, :, :, None],
                (c_loc, b_loc, k, s))
            init = jnp.where(exact_loc[:, :, None, None], start, cand)
            sym_t = chunk_loc.reshape(c_loc * b_loc, lmax).T

            def step(st, row):
                return table_pad[st, row[:, None]], None

            lvecs, _ = jax.lax.scan(
                step, init.reshape(c_loc * b_loc, k * s).astype(jnp.int32),
                sym_t)
            # the only cross-device exchange, and only over "chunk": lane
            # states, not symbols; doc shards stay silent
            lv_all = jax.lax.all_gather(
                lvecs.reshape(c_loc, b_loc, k, s), chunk_ax, axis=0,
                tiled=True)
            la_all = jax.lax.all_gather(la_loc, chunk_ax, axis=0, tiled=True)
            ex_all = jax.lax.all_gather(exact_loc, chunk_ax, axis=0,
                                        tiled=True)
            # every chunk device of this mesh row now folds the same gathered
            # states; return the copy behind a leading chunk-axis dim so the
            # out spec mentions every mesh axis (see matcher_chunk_specs)
            return self._merge_gathered(lv_all, la_all, ex_all,
                                        cidx_pad)[None]

        sharded_body = shard_map(body, mesh=self.mesh, in_specs=in_specs,
                                 out_specs=out_spec, check_vma=False)

        def run(bytes_buf, lengths, entry):
            self.traces += 1  # side effect fires at trace time only
            b, w = bytes_buf.shape
            if b % self.doc_shards:
                raise ValueError(f"batch of {b} rows does not split over "
                                 f"{self.doc_shards} doc shards (raise "
                                 "batch_tile to a doc-shard multiple)")
            rps = b // self.doc_shards
            cls = self._classify(bytes_buf, lengths)     # [B, W]
            # one extra identity-pad column makes column index w the "no
            # symbol here" slot — chunk tails past a boundary and the absent
            # predecessor of exact chunks both point at it
            cls_pad = jnp.pad(cls, ((0, 0), (0, 1)),
                              constant_values=t.pad_cls)
            # static (trace-time) gather maps: row-block r's documents read
            # row r's chunk boundaries.  A single gather assembles the whole
            # [C, B, Lmax] buffer — per-piece stack/concat assembly miscompiles
            # under jit-of-shard_map resharding on jax<0.5 (values arrive
            # psum-scaled by the chunk extent), a gather does not.
            col_idx = np.full((n_chunks, b, lmax), w, np.int32)
            la_idx = np.full((n_chunks, b), w, np.int32)
            ex_np = np.zeros((n_chunks, b), bool)
            for r in range(self.doc_shards):
                rows = slice(r * rps, (r + 1) * rps)
                for ci, (s0, e0) in enumerate(row_bounds[r]):
                    span = np.arange(lmax)
                    col_idx[ci, rows] = np.where(span < e0 - s0, s0 + span, w)
                    if s0 > 0:
                        la_idx[ci, rows] = s0 - 1
                    ex_np[ci, rows] = bool(row_exact[r][ci])
            rows_b = jnp.arange(b, dtype=jnp.int32)
            chunk_buf = cls_pad[rows_b[None, :, None],
                                jnp.asarray(col_idx)]    # [C, B, Lmax]
            la = cls_pad[rows_b[None, :], jnp.asarray(la_idx)]  # [C, B]
            ex = jnp.asarray(ex_np)                      # [C, B] bool
            finals = sharded_body(chunk_buf, la, ex, entry)[0]
            return finals, jnp.full((b,), NO_EXIT, jnp.int32)

        donate = (0,) if jax.default_backend() != "cpu" else ()
        if with_entry:
            return jax.jit(run, donate_argnums=donate)

        def run0(bytes_buf, lengths):
            b = bytes_buf.shape[0]
            entry = jnp.broadcast_to(t.starts_j[None, :], (b, t.n_patterns))
            return run(bytes_buf, lengths, entry)

        return jax.jit(run0, donate_argnums=donate)

    def _merge_gathered(self, lv_all: jnp.ndarray, la_all: jnp.ndarray,
                        exact_all: jnp.ndarray,
                        cidx_pad: jnp.ndarray) -> jnp.ndarray:
        """Eq. 8 fold over gathered chunk lane states, with exact-chunk flags.

        lv_all [C, B_loc, K, S]; la_all/exact_all [C, B_loc] — a chunk
        starting at stream position 0 is matched exactly from its entry
        states, so the merge reads its lane 0 instead of a candidate lookup.
        Every local row belongs to the same doc row-block (shard_map places
        whole row-blocks), so the per-chunk exact flags are constant across
        the local rows and column 0 carries them.  Delegates to the one
        shared merge definition (``kernels.ref.spec_merge_ref``, doc-major)
        so sharded and local stay bit-identical by construction.
        """
        from ...kernels.ref import spec_merge_ref

        t = self.t
        return spec_merge_ref(jnp.swapaxes(lv_all, 0, 1), la_all.T,
                              cidx_pad, t.sinks_j, pad_cls=t.pad_cls,
                              exact=exact_all[:, 0])
