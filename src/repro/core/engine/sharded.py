"""Mesh-sharded lowering: capacity-balanced matching on a (doc, chunk) mesh.

The paper's cloud result (288 EC2 cores) comes from two ingredients: split
the input across workers, and size each worker's slice by its *measured
matching capacity* (Eq. 1, ``core.profiling.profile_workers``).  This
executor is the device-mesh lowering of the one ``LanePlan`` (see
``engine.executors``), on a 2-D ``("doc", "chunk")`` mesh
(``launch.mesh.make_matcher_mesh``):

  * the **chunk axis is sharded over "chunk"** (``jax_compat.shard_map``):
    each device matches its contiguous run of chunks x candidate lanes
    locally;
  * the **document axis is sharded over "doc"**: mesh row ``r`` owns tile
    row-block ``r`` outright, so batch sizes beyond one host's memory scale
    along "doc" with no extra traffic — speculative documents no longer
    replicate on every device.  Physical row-blocks keep the uniform
    ``batch_tile / Dd`` SPMD shape even under capacity-weighted *document*
    placement: ragged doc tiling (``plan.MeshLayout.tile_rows``) assigns
    capacity-proportional document *counts* per row by routing real
    documents to row-blocks host-side — a slow row simply receives more
    zero-length pad rows, and this lowering never sees the difference (the
    facade inverts the placement when scattering results);
  * chunk boundaries come from the planner's layout — uniform, or
    capacity-weighted via the paper's Eqs. 2–7 so a device with twice the
    measured capacity receives twice the real symbols.  On a 2-D mesh each
    doc row-block gets its *own* ``ChunkLayout`` weighted by that mesh row's
    devices (``plan.MeshLayout``); trailing identity-pad columns equalize the
    SPMD buffer shapes and advance no DFA;
  * devices exchange **only the per-chunk L-vector lane states**
    (``[C, B/Dd, K, S]`` int32, independent of chunk length) in one
    ``all_gather`` **over the "chunk" axis only** — doc shards never
    communicate, and the documents' bytes never cross devices;
  * each doc shard folds its gathered lane states per document (Eq. 8),
    exactly as the single-device lowering, so results are bit-identical to
    sequential matching for any mesh shape and any capacity profile
    (tests/test_sharded_executor.py sweeps 1x1, 2x4, 4x2, 8x1).

**Entry modes** are the plan's, not the backend's: exact entry states shard
over "doc" with their rows (``ENTRY_STATES``), and lane plans
(``ENTRY_LANES``) additionally shard the ``[B, K, S]`` cursor lanes and
boundary classes over "doc" and run the device cursor merge per doc shard
after the chunk fold (``distributed.sharding.matcher_lane_specs``).

The **sequential plan** needs no exchange at all: short documents are
independent rows, so the document axis shards over *both* mesh axes
jointly (``distributed.sharding.doc_batch_spec``) and every device scans
``B / (Dd * Dc)`` rows.

See docs/architecture.md for the data-flow diagram and the "adding an
executor backend" guide.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .executors import NO_EXIT, LaneExecutor
from .plan import (ENTRY_LANES, ENTRY_STARTS, ChunkLayout, DeviceTables,
                   LanePlan, MeshLayout)

__all__ = ["ShardedExecutor"]


class ShardedExecutor(LaneExecutor):
    """shard_map-backed lowering over a ("doc", "chunk") matcher mesh.

    Parameters
    ----------
    tables      : shared ``DeviceTables`` bundle.
    num_chunks  : total chunk count C (a multiple of the mesh chunk extent;
                  the planner rounds up).
    mesh        : mesh from ``launch.mesh.make_matcher_mesh`` (legacy 1-D
                  "data" meshes count as doc extent 1); defaults to a 1-D
                  chunk mesh over all local devices.
    """

    def __init__(self, tables: DeviceTables, *, num_chunks: int,
                 mesh=None, early_exit_segments: int = 4):
        super().__init__(tables, num_chunks=num_chunks,
                         early_exit_segments=early_exit_segments)
        from ...launch.mesh import make_matcher_mesh, matcher_mesh_extents
        if mesh is None:
            mesh = make_matcher_mesh()
        self.mesh = mesh
        self.doc_shards, self.chunk_shards = matcher_mesh_extents(mesh)
        self.chunk_axis = "chunk" if "chunk" in mesh.axis_names else "data"
        self.devices = self.doc_shards * self.chunk_shards
        if self.num_chunks % self.chunk_shards != 0:
            raise ValueError(
                f"num_chunks={self.num_chunks} must be a multiple of the mesh "
                f"chunk extent {self.chunk_shards} (the planner rounds up "
                "for you)")

    # -- lowering dispatch ---------------------------------------------------

    def _plan_key(self, plan: LanePlan, batch: int) -> tuple:
        # seq programs shard the row axis, so their compiled form depends on
        # the tile row count (doc_batch_spec); spec programs do not — but
        # they *bake* the layout's chunk boundaries as static slices, so a
        # capacity rebalance (layout_epoch bump) keys them to a fresh
        # lowering while every seq entry survives the rebalance untouched
        if plan.kind == "seq":
            return plan.key + (batch,)
        return plan.key + (self.layout_epoch,)

    def _lower(self, plan: LanePlan, layout, batch: int):
        if plan.kind == "seq":
            if self.devices == 1 or batch % self.devices != 0:
                # indivisible tiles fall back to the single-device lowering
                self.lowering_kinds[self._plan_key(plan, batch)] = "seq-jnp"
                return self._lower_seq_local(plan)
            self.lowering_kinds[self._plan_key(plan, batch)] = "seq-sharded"
            return self._lower_seq_sharded(plan, batch)
        self.lowering_kinds[self._plan_key(plan, batch)] = "spec-sharded"
        return self._lower_spec_sharded(plan, layout)

    def _replicated_tables(self):
        """Pin the constant matcher tables onto every mesh device up front
        (distributed.sharding.matcher_table_specs), instead of relying on
        implicit transfer at first dispatch."""
        from jax.sharding import NamedSharding

        from ...distributed.sharding import matcher_table_specs

        t = self.t
        specs = matcher_table_specs(self.mesh)

        def repl(name, arr):
            return jax.device_put(arr, NamedSharding(self.mesh, specs[name]))

        return (repl("table_pad", t.table_pad_j),
                repl("cand_pad", t.cand_pad_j),
                repl("cidx_pad", t.cidx_pad_j))

    # -- sequential plan: document axis over both mesh axes ------------------

    def _lower_seq_sharded(self, plan: LanePlan, batch: int):
        """Short documents are independent rows, so the document axis shards
        cleanly over every mesh axis jointly (doc_batch_spec) — each device
        classifies and scans B/(Dd*Dc) rows, nothing is exchanged.  Entry
        states (and lane-plan cursor lanes + boundary classes) split
        row-wise with their documents."""
        from jax.sharding import PartitionSpec as P

        from ...distributed.sharding import doc_batch_spec
        from ...jax_compat import shard_map

        row_ax = tuple(doc_batch_spec(self.mesh, batch))
        buf_spec, len_spec = P(*row_ax, None), P(*row_ax)
        # the specs follow the plan's entry arity; the body is the shared one
        if plan.entry == ENTRY_STARTS:
            in_specs = (buf_spec, len_spec)
            out_specs = (buf_spec, len_spec)
        elif plan.entry == ENTRY_LANES:
            in_specs = (buf_spec, len_spec, P(*row_ax, None, None), len_spec)
            out_specs = (P(*row_ax, None, None), len_spec)
        else:
            in_specs = (buf_spec, len_spec, P(*row_ax, None))
            out_specs = (buf_spec, len_spec)
        body = shard_map(lambda *args: self._seq_body(plan, *args),
                         mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
        return self._jit_lowering(body)

    # -- speculative plan ----------------------------------------------------

    def _layout_rows(self, layout: ChunkLayout | MeshLayout
                     ) -> tuple[ChunkLayout, ...]:
        """Per-doc-shard row layouts; a plain ChunkLayout broadcasts to every
        row (uniform boundaries on every row-block)."""
        if isinstance(layout, MeshLayout):
            if layout.doc_shards != self.doc_shards:
                raise ValueError(f"layout has {layout.doc_shards} doc shards, "
                                 f"mesh has {self.doc_shards}")
            return layout.rows
        return (layout,) * self.doc_shards

    def _lower_spec_sharded(self, plan: LanePlan,
                            layout: ChunkLayout | MeshLayout):
        """Jit one bucket width; every row-block's boundaries are baked in as
        static slices (deterministic per width, so the cache key is the
        plan)."""
        from ...distributed.sharding import (matcher_chunk_specs,
                                             matcher_lane_specs)
        from ...jax_compat import shard_map

        t = self.t
        rows = self._layout_rows(layout)
        lmax = max(r.lmax for r in rows)
        n_chunks = rows[0].num_chunks
        row_bounds = [list(zip(r.starts.tolist(), r.ends.tolist()))
                      for r in rows]
        row_exact = [r.exact.copy() for r in rows]
        chunk_ax = self.chunk_axis
        lanes_mode = plan.entry == ENTRY_LANES
        if lanes_mode:
            in_specs, out_spec = matcher_lane_specs(self.mesh)
        else:
            in_specs, out_spec = matcher_chunk_specs(self.mesh)
        table_pad, cand_pad, cidx_pad = self._replicated_tables()

        def scan_chunks(chunk_loc, init):
            """Per-device chunk-scan stage over this shard's lanes."""
            c_loc, b_loc = chunk_loc.shape[0], chunk_loc.shape[1]
            k, s = t.n_patterns, t.i_max
            sym_t = chunk_loc.reshape(c_loc * b_loc, lmax).T

            def step(st, row):
                return table_pad[st, row[:, None]], None

            lvecs, _ = jax.lax.scan(
                step, init.reshape(c_loc * b_loc, k * s).astype(jnp.int32),
                sym_t)
            return lvecs.reshape(c_loc, b_loc, k, s)

        def gather_chunk_axis(lvecs, la_loc, exact_loc):
            # the only cross-device exchange, and only over "chunk": lane
            # states, not symbols; doc shards stay silent
            lv_all = jax.lax.all_gather(lvecs, chunk_ax, axis=0, tiled=True)
            la_all = jax.lax.all_gather(la_loc, chunk_ax, axis=0, tiled=True)
            ex_all = jax.lax.all_gather(exact_loc, chunk_ax, axis=0,
                                        tiled=True)
            return lv_all, la_all, ex_all

        def body(chunk_loc, la_loc, exact_loc, entry_loc):
            # chunk_loc [C_loc, B_loc, Lmax]; la_loc/exact_loc [C_loc,
            # B_loc]; entry_loc [B_loc, K] — this doc shard's segment entry
            # states; exact chunks (stream position 0) seed from them instead
            # of the Eq. 11 candidates.  All rows of this shard belong to one
            # doc row-block, so they share one set of chunk boundaries.
            c_loc, b_loc = chunk_loc.shape[0], chunk_loc.shape[1]
            k, s = t.n_patterns, t.i_max
            cand = cand_pad[la_loc]                    # [C_loc, B_loc, K, S]
            start = jnp.broadcast_to(
                entry_loc.astype(jnp.int32)[None, :, :, None],
                (c_loc, b_loc, k, s))
            init = jnp.where(exact_loc[:, :, None, None], start, cand)
            lv_all, la_all, ex_all = gather_chunk_axis(
                scan_chunks(chunk_loc, init), la_loc, exact_loc)
            # every chunk device of this mesh row now folds the same gathered
            # states; return the copy behind a leading chunk-axis dim so the
            # out spec mentions every mesh axis (see matcher_chunk_specs)
            return self._merge_gathered(lv_all, la_all, ex_all,
                                        cidx_pad)[None]

        def body_lanes(chunk_loc, la_loc, exact_loc, lanes_loc, ecls_loc):
            # Lane plan: exact chunks seed from the Eq. 11 candidate row of
            # each document's boundary class (``ecls_loc [B_loc]``) — the
            # segment is matched *independently* of the prefix — and after
            # the chunk fold the caller's cursor lanes compose on device
            # (the streaming device merge).
            cand = cand_pad[la_loc]
            seed = jnp.broadcast_to(cand_pad[ecls_loc][None],
                                    cand.shape)
            init = jnp.where(exact_loc[:, :, None, None], seed, cand)
            lv_all, la_all, ex_all = gather_chunk_axis(
                scan_chunks(chunk_loc, init), la_loc, exact_loc)
            seg = self._merge_gathered(lv_all, la_all, ex_all, cidx_pad,
                                       lanes=True)
            return self._compose_cursor(lanes_loc.astype(jnp.int32), seg,
                                        ecls_loc)[None]

        sharded_body = shard_map(body_lanes if lanes_mode else body,
                                 mesh=self.mesh, in_specs=in_specs,
                                 out_specs=out_spec, check_vma=False)

        def run(bytes_buf, lengths, entry, entry_cls):
            b, w = bytes_buf.shape
            if b % self.doc_shards:
                raise ValueError(f"batch of {b} rows does not split over "
                                 f"{self.doc_shards} doc shards (raise "
                                 "batch_tile to a doc-shard multiple)")
            rps = b // self.doc_shards
            cls = self._classify(bytes_buf, lengths)     # [B, W]
            # one extra identity-pad column makes column index w the "no
            # symbol here" slot — chunk tails past a boundary and the absent
            # predecessor of exact chunks both point at it
            cls_pad = jnp.pad(cls, ((0, 0), (0, 1)),
                              constant_values=t.pad_cls)
            # static (trace-time) gather maps: row-block r's documents read
            # row r's chunk boundaries.  A single gather assembles the whole
            # [C, B, Lmax] buffer — per-piece stack/concat assembly miscompiles
            # under jit-of-shard_map resharding on jax<0.5 (values arrive
            # psum-scaled by the chunk extent), a gather does not.
            col_idx = np.full((n_chunks, b, lmax), w, np.int32)
            la_idx = np.full((n_chunks, b), w, np.int32)
            la2_idx = np.full((n_chunks, b), w, np.int32)
            ex_np = np.zeros((n_chunks, b), bool)
            for r in range(self.doc_shards):
                rsel = slice(r * rps, (r + 1) * rps)
                for ci, (s0, e0) in enumerate(row_bounds[r]):
                    span = np.arange(lmax)
                    col_idx[ci, rsel] = np.where(span < e0 - s0, s0 + span, w)
                    if s0 > 0:
                        la_idx[ci, rsel] = s0 - 1
                    if s0 > 1:
                        la2_idx[ci, rsel] = s0 - 2
                    elif s0 == 1 and t.spec_r == 2:
                        # ChunkLayout.MIN_CUT keeps interior cuts >= 2
                        raise ValueError("spec_r=2 boundary keys need chunk "
                                         "cuts >= 2 symbols into the stream")
                    ex_np[ci, rsel] = bool(row_exact[r][ci])
            rows_b = jnp.arange(b, dtype=jnp.int32)
            chunk_buf = cls_pad[rows_b[None, :, None],
                                jnp.asarray(col_idx)]    # [C, B, Lmax]
            la1 = cls_pad[rows_b[None, :], jnp.asarray(la_idx)]  # [C, B]
            if t.spec_r == 2:
                la2 = cls_pad[rows_b[None, :], jnp.asarray(la2_idx)]
                la = jnp.where(la1 == t.pad_cls, jnp.int32(t.pad_key),
                               la2 * jnp.int32(t.pad_cls) + la1)
            else:
                la = la1  # r=1: the key *is* the class (pad_cls == pad_key)
            ex = jnp.asarray(ex_np)                      # [C, B] bool
            if lanes_mode:
                out = sharded_body(chunk_buf, la, ex,
                                   entry.astype(jnp.int32), entry_cls)[0]
            else:
                out = sharded_body(chunk_buf, la, ex, entry)[0]
            return out, jnp.full((b,), NO_EXIT, jnp.int32)

        if lanes_mode:
            return self._jit_lowering(run)
        if plan.entry == ENTRY_STARTS:
            def run0(bytes_buf, lengths):
                b = bytes_buf.shape[0]
                e = jnp.broadcast_to(t.starts_j[None, :], (b, t.n_patterns))
                return run(bytes_buf, lengths, e, None)

            return self._jit_lowering(run0)
        return self._jit_lowering(
            lambda bytes_buf, lengths, entry: run(bytes_buf, lengths, entry,
                                                  None))

    def _merge_gathered(self, lv_all: jnp.ndarray, la_all: jnp.ndarray,
                        exact_all: jnp.ndarray, cidx_pad: jnp.ndarray,
                        lanes: bool = False) -> jnp.ndarray:
        """Eq. 8 fold over gathered chunk lane states, with exact-chunk flags.

        lv_all [C, B_loc, K, S]; la_all/exact_all [C, B_loc] — a chunk
        starting at stream position 0 is matched exactly from its entry
        states (or candidate-keyed from the boundary class, for lane plans),
        so the merge reads its lanes instead of a candidate lookup.  Every
        local row belongs to the same doc row-block (shard_map places whole
        row-blocks), so the per-chunk exact flags are constant across the
        local rows and column 0 carries them.  Delegates to the one shared
        merge definition (``kernels.ref.spec_merge_ref`` /
        ``spec_merge_lanes_ref``, doc-major) so sharded and local stay
        bit-identical by construction.
        """
        from ...kernels.ref import spec_merge_lanes_ref, spec_merge_ref

        t = self.t
        fold = spec_merge_lanes_ref if lanes else spec_merge_ref
        return fold(jnp.swapaxes(lv_all, 0, 1), la_all.T,
                    cidx_pad, t.sinks_j, pad_cls=t.pad_key,
                    exact=exact_all[:, 0])
