"""Layered plan/executor matching runtime (the paper's engine, refactored).

Module map — how a membership query flows through the layers:

    plan.py       Planner layer: spec-vs-seq split, sticky shape bucketing,
                  chunk partitioning + capacity weighting (Eqs. 1–7 via
                  core.partition / core.profiling), lookahead-table selection
                  (``DeviceTables``) — and the ``LanePlan``: the one stage
                  description (classify -> entry-seed -> chunk-scan ->
                  merge) every backend lowers.  Pure numpy; emits an
                  explicit ``MatchPlan`` per batch.
    executors.py  ``Executor`` protocol (``run(plan, ...)``) +
                  ``LaneExecutor`` shared stage implementations +
                  ``LocalExecutor`` (jitted jnp reference and fused Pallas
                  kernel lowerings), on-device byte->class classification,
                  absorbing-state early exit, the device cursor merge.
    sharded.py    ``ShardedExecutor``: the 2-D ("doc", "chunk") mesh
                  lowering via shard_map — document rows sharded over "doc",
                  chunk lanes over "chunk", capacity-weighted boundaries per
                  doc row-block; the per-chunk L-vector lane states are
                  all_gathered over "chunk" only before the Eq. 8 merge
                  (doc shards never communicate).
    facade.py     ``Matcher``: packs patterns, owns a Planner + an executor
                  backend ("local" | "pallas" | "sharded"), exposes
                  ``membership_batch`` (whole documents),
                  ``advance_segments`` (the streaming runtime's resumable
                  segment tick) and ``advance_cursors`` (the candidate-keyed
                  device merge — see ``repro.streaming``); ``BatchMatcher``
                  compat shim.
    baselines.py  The paper's per-document reference implementations
                  (Sec. 4.1, Eqs. 2–8, Alg. 2/3 + Holub–Stekr baseline,
                  ``sequential_state`` / ``match_chunks_lanes``) — the
                  figure-reproduction targets and verification oracles.
    spec.py       ``SpecDFAEngine`` compatibility shim: per-document modes
                  inherit the baselines, batched matching delegates to the
                  facade; no logic of its own.

Adding an executor backend: see docs/architecture.md ("Adding an executor
backend") — lower the one ``LanePlan`` (subclass ``executors.LaneExecutor``
and implement ``_lower``) over the shared ``DeviceTables`` bundle and route
it from ``Matcher.__init__``; results must stay bit-identical to sequential
matching.
"""

from .baselines import PaperSpecEngine
from .blocked import BlockedMatcher
from .executors import Executor, LaneExecutor, LocalExecutor
from .facade import (BatchMatcher, BatchResult, CursorBatchResult, Matcher,
                     SegmentBatchResult)
from .plan import (ENTRY_LANES, ENTRY_STARTS, ENTRY_STATES, BucketPlan,
                   ChunkLayout, DeviceTables, LanePlan, MatchPlan,
                   MeshLayout, Planner, expand_device_weights,
                   layout_device_work, next_pow2)
from .sharded import ShardedExecutor
from .spec import (VPU_LANES, MatcherFn, MatchResult, SpecDFAEngine,
                   match_chunks_lanes, sequential_state)

__all__ = [
    "MatchResult", "BatchResult", "SegmentBatchResult", "CursorBatchResult",
    "SpecDFAEngine", "PaperSpecEngine", "BatchMatcher", "Matcher",
    "BlockedMatcher",
    "sequential_state", "match_chunks_lanes", "VPU_LANES", "MatcherFn",
    "Planner", "MatchPlan", "BucketPlan", "ChunkLayout", "MeshLayout",
    "DeviceTables", "LanePlan",
    "ENTRY_STARTS", "ENTRY_STATES", "ENTRY_LANES",
    "expand_device_weights", "layout_device_work", "next_pow2",
    "Executor", "LaneExecutor", "LocalExecutor", "ShardedExecutor",
]
