"""Layered plan/executor matching runtime (the paper's engine, refactored).

Module map — how a membership query flows through the layers:

    spec.py       SpecDFAEngine: the paper's single-document speculative
                  membership test (Sec. 4.1, Eqs. 2–8, Alg. 2/3 + Holub–Stekr
                  baseline).  Also home of the jitted primitives
                  ``sequential_state`` / ``match_chunks_lanes``.
    plan.py       Planner layer: spec-vs-seq split, sticky shape bucketing,
                  chunk partitioning + capacity weighting (Eqs. 1–7 via
                  core.partition / core.profiling), lookahead-table selection
                  (``DeviceTables``).  Pure numpy; emits an explicit
                  ``MatchPlan``.
    executors.py  Executor protocol + ``LocalExecutor`` (jitted jnp reference
                  and fused Pallas kernel backends), on-device byte->class
                  classification, absorbing-state early exit.
    sharded.py    ``ShardedExecutor``: the 2-D ("doc", "chunk") mesh backend
                  via shard_map — document rows sharded over "doc", chunk
                  lanes over "chunk", capacity-weighted boundaries per doc
                  row-block; the per-chunk L-vector lane states are
                  all_gathered over "chunk" only before the Eq. 8 merge
                  (doc shards never communicate).
    facade.py     ``Matcher``: packs patterns, owns a Planner + an executor
                  backend ("local" | "pallas" | "sharded"), exposes
                  ``membership_batch`` (whole documents) and
                  ``advance_segments`` (the streaming runtime's resumable
                  segment tick — see ``repro.streaming``); ``BatchMatcher``
                  compat shim.

Adding an executor backend: see docs/architecture.md ("Adding an executor
backend") — implement the ``executors.Executor`` protocol over the shared
``DeviceTables`` bundle and route it from ``Matcher.__init__``; results must
stay bit-identical to sequential matching.
"""

from .executors import Executor, LocalExecutor
from .facade import BatchMatcher, BatchResult, Matcher, SegmentBatchResult
from .plan import (BucketPlan, ChunkLayout, DeviceTables, MatchPlan,
                   MeshLayout, Planner, expand_device_weights,
                   layout_device_work, next_pow2)
from .sharded import ShardedExecutor
from .spec import (VPU_LANES, MatcherFn, MatchResult, SpecDFAEngine,
                   match_chunks_lanes, sequential_state)

__all__ = [
    "MatchResult", "BatchResult", "SegmentBatchResult", "SpecDFAEngine",
    "BatchMatcher", "Matcher",
    "sequential_state", "match_chunks_lanes", "VPU_LANES", "MatcherFn",
    "Planner", "MatchPlan", "BucketPlan", "ChunkLayout", "MeshLayout",
    "DeviceTables",
    "expand_device_weights", "layout_device_work", "next_pow2",
    "Executor", "LocalExecutor", "ShardedExecutor",
]
