"""Layered plan/executor matching runtime (the paper's engine, refactored).

Module map — how a membership query flows through the layers:

    spec.py       SpecDFAEngine: the paper's single-document speculative
                  membership test (Sec. 4.1, Eqs. 2–8, Alg. 2/3 + Holub–Stekr
                  baseline).  Also home of the jitted primitives
                  ``sequential_state`` / ``match_chunks_lanes``.
    plan.py       Planner layer: spec-vs-seq split, sticky shape bucketing,
                  chunk partitioning + capacity weighting (Eqs. 1–7 via
                  core.partition / core.profiling), lookahead-table selection
                  (``DeviceTables``).  Pure numpy; emits an explicit
                  ``MatchPlan``.
    executors.py  Executor protocol + ``LocalExecutor`` (jitted jnp reference
                  and fused Pallas kernel backends), on-device byte->class
                  classification, absorbing-state early exit.
    sharded.py    ``ShardedExecutor``: chunk axis sharded over the mesh
                  "data" axis via shard_map; capacity-weighted chunk
                  boundaries; devices exchange only per-chunk L-vector lane
                  states before the Eq. 8 merge.
    facade.py     ``Matcher``: packs patterns, owns a Planner + an executor
                  backend ("local" | "pallas" | "sharded"), exposes
                  ``membership_batch`` (whole documents) and
                  ``advance_segments`` (the streaming runtime's resumable
                  segment tick — see ``repro.streaming``); ``BatchMatcher``
                  compat shim.

Adding an executor backend: implement the executor protocol in
``executors.Executor`` (``run_spec``/``run_seq`` for whole documents, the
``run_spec_entry``/``run_seq_entry`` segment-entry variants for streaming,
and ``steps_for``) over the shared ``DeviceTables`` bundle — inputs are raw
byte buffers + lengths and a ``ChunkLayout``; results must stay bit-identical
to sequential matching — then route it from ``Matcher.__init__``.  See
ROADMAP.md §Plan/executor layering and §Streaming runtime.
"""

from .executors import Executor, LocalExecutor
from .facade import BatchMatcher, BatchResult, Matcher, SegmentBatchResult
from .plan import (BucketPlan, ChunkLayout, DeviceTables, MatchPlan, Planner,
                   expand_device_weights, layout_device_work, next_pow2)
from .sharded import ShardedExecutor
from .spec import (VPU_LANES, MatcherFn, MatchResult, SpecDFAEngine,
                   match_chunks_lanes, sequential_state)

__all__ = [
    "MatchResult", "BatchResult", "SegmentBatchResult", "SpecDFAEngine",
    "BatchMatcher", "Matcher",
    "sequential_state", "match_chunks_lanes", "VPU_LANES", "MatcherFn",
    "Planner", "MatchPlan", "BucketPlan", "ChunkLayout", "DeviceTables",
    "expand_device_weights", "layout_device_work", "next_pow2",
    "Executor", "LocalExecutor", "ShardedExecutor",
]
