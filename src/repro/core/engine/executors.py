"""Executor layer: one lane program, lowered per backend.

The matching operation is a single inner loop — indexed transition-table
loads over chunk lanes — and the planner describes it once as a ``LanePlan``
(classify -> entry-seed -> chunk-scan -> merge; see ``engine.plan``).  An
executor backend is a *lowering* of that one plan, not a family of
hand-rolled variants: every backend exposes exactly

    run(plan, bytes_buf, lengths, *, layout=None, entry=None,
        entry_classes=None) -> (finals, absorbed_pos)

and lowers a plan at most once (``lower``; compiled programs are cached by
``plan.key``).  All lowerings consume the same operands —

  * ``bytes_buf [B, W] uint8``  — raw document bytes, zero-padded (byte ->
    class classification happens **on device**, fused into the bucket call;
    ``kernels.ref.classify_pad_ref`` is the host oracle),
  * ``lengths [B] int32``       — real byte counts (positions beyond a
    document's length classify to the identity pad class),
  * ``layout``                  — the planner's ``ChunkLayout``/``MeshLayout``
    for spec plans,
  * ``entry``                   — per-row entry operand selected by
    ``plan.entry``: absent (``ENTRY_STARTS``), exact ``[B, K]`` states
    (``ENTRY_STATES``), or ``[B, K, S]`` cursor lanes plus ``entry_classes
    [B]`` boundary classes (``ENTRY_LANES`` — the streaming device merge),

and must be bit-identical to per-document sequential matching.  The return
is ``(finals [B, K], absorbed_pos [B])`` — or ``([B, K, S], pos)`` for lane
plans — where ``absorbed_pos`` is the scan position (chunk-local for spec,
stream for seq) at which every lane of a document became absorbing, or the
``NO_EXIT`` sentinel.

Backends (the three lowerings):

  * ``LocalExecutor``                  — pure-jnp jitted lowering (the
    oracle), with an absorbing-state early exit: the symbol scan runs in
    segments inside a ``lax.while_loop`` and stops once every lane of every
    document is absorbing.
  * ``LocalExecutor(use_kernel=True)`` — the fused Pallas kernels
    (``kernels.ops.spec_match_merge`` for exact-entry plans,
    ``kernels.ops.spec_match_merge_lanes`` for ``ENTRY_LANES`` — the
    streaming tick rides the fused kernel too, no jnp-stage fallback).
    Both carry an **in-kernel early exit** (symbol blocks after a
    document's lanes all absorb are skipped on the grid; the per-document
    skipped-block counts drain via ``kernel_skipped_steps()``), wrapped in
    an **all-absorbed bucket early exit**: when every row of the bucket is
    already absorbed (or empty), the kernel dispatch is skipped entirely —
    absorbing states self-loop, so returning the entry states (or cursor
    lanes) verbatim is exact.
  * ``engine.sharded.ShardedExecutor`` — the ("doc", "chunk") mesh lowering
    (own module).

**Entry seeding** is one stage, not separate entry points: chunk 0 (and any
chunk at stream position 0) seeds from the pattern starts, the caller's
exact states, or the Eq. 11 candidate rows of each row's boundary class.
``ENTRY_STATES`` is what makes matching *resumable* (a ``streaming
.MatchCursor`` carries states across segment boundaries); ``ENTRY_LANES``
additionally keeps the candidate lane axis and fuses the Eq. 8 cursor
composition (``kernels.ref.cursor_merge_ref``) into the same device call —
the streaming tick's device merge.  ``traces`` counts jit retraces (the
side effect fires at trace time only).
"""

from __future__ import annotations

from typing import Optional, Protocol

import numpy as np

import jax
import jax.numpy as jnp

from ..lvector import merge_scan_lanes_jnp
from .plan import (ENTRY_LANES, ENTRY_STARTS, ENTRY_STATES, DeviceTables,
                   LanePlan)

__all__ = ["Executor", "LaneExecutor", "LocalExecutor", "NO_EXIT"]

NO_EXIT = np.int32(2 ** 30)  # absorbed_pos sentinel: never fully absorbed


class Executor(Protocol):
    """The one-method backend protocol: lower and run a ``LanePlan``."""

    traces: int

    def run(self, plan: LanePlan, bytes_buf: jnp.ndarray,
            lengths: jnp.ndarray, *, layout=None,
            entry: Optional[jnp.ndarray] = None,
            entry_classes: Optional[jnp.ndarray] = None
            ) -> tuple[jnp.ndarray, jnp.ndarray]: ...

    def steps_for(self, layout) -> int: ...


def _prev_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n.bit_length() - 1)


class LaneExecutor:
    """Shared lane-program stages plus the lowering cache (all backends).

    Subclasses override ``_lower`` (and, when compiled programs depend on
    more than the plan — e.g. the sharded backend's per-batch row specs —
    ``_plan_key``).  The base class owns the stage implementations every
    lowering composes: on-device classification, the early-exit segmented
    scan, entry seeding, and the device cursor merge.
    """

    def __init__(self, tables: DeviceTables, *, num_chunks: int,
                 early_exit_segments: int = 4):
        self.t = tables
        self.num_chunks = int(num_chunks)
        # segments must divide the pow2 scan widths -> round down to a pow2
        self.early_exit_segments = _prev_pow2(max(int(early_exit_segments), 1))
        self.traces = 0
        self._lowered: dict[tuple, object] = {}
        # plan.key -> human-readable lowering name ("spec-kernel",
        # "spec-jnp", "seq-jnp", ...) for bench/introspection reporting
        self.lowering_kinds: dict[tuple, str] = {}
        # per-bucket block-size targets set by the shape autotuner
        # (core.profiling.autotune_spec_shapes); consulted at lowering time,
        # keyed by chunk_len (key 0 = tuned default) — 512 when untuned
        self.spec_l_blk: dict[int, int] = {}
        # bumped by invalidate_layouts() when chunk boundaries move (capacity
        # rebalance); only lowerings that *bake* boundaries fold it into
        # their cache key, so layout-independent programs keep their entries
        self.layout_epoch = 0

    # -- the one entry point ------------------------------------------------

    def run(self, plan: LanePlan, bytes_buf: jnp.ndarray,
            lengths: jnp.ndarray, *, layout=None,
            entry: Optional[jnp.ndarray] = None,
            entry_classes: Optional[jnp.ndarray] = None
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
        fn = self.lower(plan, layout=layout, batch=int(bytes_buf.shape[0]))
        if plan.entry == ENTRY_STARTS:
            return fn(bytes_buf, lengths)
        if plan.entry == ENTRY_STATES:
            return fn(bytes_buf, lengths, entry)
        return fn(bytes_buf, lengths, entry, entry_classes)

    def lower(self, plan: LanePlan, *, layout=None, batch: int = 0):
        """Compiled program for one plan (cached; lowering happens once)."""
        key = self._plan_key(plan, batch)
        fn = self._lowered.get(key)
        if fn is None:
            fn = self._lower(plan, layout, batch)
            self._lowered[key] = fn
        return fn

    def _plan_key(self, plan: LanePlan, batch: int) -> tuple:
        return plan.key

    def invalidate_layouts(self) -> None:
        """Signal that chunk layout boundaries changed (capacity rebalance).

        Bumps ``layout_epoch`` instead of clearing ``_lowered``: backends
        whose compiled programs bake layout boundaries (the sharded spec
        lowering) key on the epoch and re-lower lazily; every
        layout-independent program — seq scans, the local/pallas lowerings,
        which chunk uniformly — survives untouched, and returning to a
        previously-seen layout is never required to recompile what never
        depended on it.
        """
        self.layout_epoch += 1

    def invalidate_block_sizes(self) -> None:
        """Drop compiled programs that baked a ``spec_l_blk`` choice.

        The observed-traffic retune path (``Matcher.maybe_retune``) updates
        ``spec_l_blk`` after construction; only the Pallas spec lowerings
        consult it (at lowering time, as a static block shape), so only
        entries whose kind starts with ``spec-kernel`` drop — everything
        else (seq scans, jnp spec, compose lowerings) keeps its program and
        the new block size takes effect on the next dispatch of each shape.
        """
        stale = [key for key, kind in self.lowering_kinds.items()
                 if kind.startswith("spec-kernel")]
        for key in stale:
            self._lowered.pop(key, None)
            self.lowering_kinds.pop(key, None)

    def retable(self, tables: DeviceTables) -> None:
        """Swap the constant matcher tables underneath the executor (the
        hot pattern swap, ``Matcher.swap_patterns``).

        Every compiled lowering closed over the *old* ``DeviceTables``
        arrays at trace time, so — unlike ``invalidate_layouts`` — there is
        nothing table-independent to keep: the whole cache drops and
        programs re-lower lazily against the new tables.  The planner's
        bumped ``table_epoch`` is stamped into every subsequent
        ``LanePlan.key``, so even an entry that somehow escaped the clear
        could never be looked up again.  ``traces`` keeps counting
        monotonically; unchanged blocks of a ``BlockedMatcher`` swap never
        pass through here, which is what makes their lowering survival
        observable (and asserted) from outside.
        """
        self.t = tables
        self._lowered.clear()
        self.lowering_kinds.clear()

    def _jit_lowering(self, body):
        """jit a lowering body under the retrace counter and buffer donation.

        ``body`` takes the plan's runtime operands positionally —
        ``(bytes_buf, lengths[, entry[, entry_classes]])`` per
        ``plan.entry`` — which is exactly how ``run`` calls the compiled
        program, so one wrapper serves every entry mode.
        """
        donate = (0,) if jax.default_backend() != "cpu" else ()

        def impl(*args):
            self.traces += 1  # side effect fires at trace time only
            return body(*args)

        return jax.jit(impl, donate_argnums=donate)

    def _lower(self, plan: LanePlan, layout, batch: int):
        """Backend hook: build the compiled program of one plan."""
        if plan.kind == "seq":
            self.lowering_kinds[plan.key] = "seq-jnp"
            return self._lower_seq_local(plan)
        raise NotImplementedError("spec plans need a backend lowering")

    def steps_for(self, layout) -> int:
        return layout.lmax  # lane-parallel wall steps = longest chunk buffer

    # -- stage: classify (the retired host numpy path lives in
    # kernels/ref.classify_pad_ref as the oracle) ---------------------------

    def _classify(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray) -> jnp.ndarray:
        """bytes [B, W] + lengths -> [B, W] class ids, pad_cls past the end."""
        cls = self.t.byte_to_class_j[bytes_buf.astype(jnp.int32)]
        pos = jnp.arange(bytes_buf.shape[1], dtype=jnp.int32)[None, :]
        return jnp.where(pos < lengths[:, None].astype(jnp.int32), cls,
                         jnp.int32(self.t.pad_cls))

    # -- stage: entry seed --------------------------------------------------

    def _seed_rows(self, plan: LanePlan, b: int, entry, entry_cls) -> jnp.ndarray:
        """Entry-seed stage for sequential rows: [B, K] exact states, or
        [B, K, S] candidate lanes for lane plans."""
        if plan.entry == ENTRY_STARTS:
            return jnp.broadcast_to(self.t.starts_j[None, :],
                                    (b, self.t.n_patterns))
        if plan.entry == ENTRY_STATES:
            return entry.astype(jnp.int32)
        return self.t.cand_pad_j[entry_cls]            # [B, K, S]

    def _seed_chunk0(self, plan: LanePlan, b: int, entry, entry_cls) -> jnp.ndarray:
        """Entry-seed stage for spec chunk 0: [B, 1, K, S] lanes."""
        k, s = self.t.n_patterns, self.t.i_max
        if plan.entry == ENTRY_LANES:
            return self.t.cand_pad_j[entry_cls][:, None]        # [B, 1, K, S]
        e = self._seed_rows(plan, b, entry, entry_cls)          # [B, K]
        return jnp.broadcast_to(e[:, None, :, None], (b, 1, k, s))

    # -- stage: chunk scan with absorbing-state early exit -------------------

    def _segmented_match(self, sym_t: jnp.ndarray, states: jnp.ndarray,
                         eff_len: jnp.ndarray, scan_len: int,
                         early_exit: bool = True
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Scan ``states [R, S]`` through ``sym_t [L, R]`` symbol columns in
        segments, stopping once every document is *done*: all its lanes are
        absorbing, or the scan has passed its real symbols (``eff_len [B]``
        per-doc; pure-padding rows of a partial tile are done immediately,
        so they never pin the loop to the full scan).

        Rows are doc-major (R = B * rows_per_doc).  Returns (final states,
        absorbed_pos [B]) with ``absorbed_pos`` the first segment boundary at
        which a document's lanes were all absorbing (sentinel ``NO_EXIT``
        otherwise).  Exactness: absorbing states self-loop on every class and
        padding is the identity column, so skipping the remaining symbols of
        a done document is bit-identical.
        """
        table = self.t.table_pad_j
        absorbing = self.t.absorbing_j
        b = eff_len.shape[0]

        def seg_scan(st, cols):
            def step(s, row):
                return table[s, row[:, None]], None
            out, _ = jax.lax.scan(step, st, cols)
            return out

        segs = min(self.early_exit_segments if early_exit else 1, scan_len)
        pos0 = jnp.full((b,), NO_EXIT, jnp.int32)
        if segs <= 1 or scan_len == 0:
            return seg_scan(states, sym_t), pos0
        seg_len = scan_len // segs

        def cond(carry):
            _, g, _, all_done = carry
            return (g < segs) & ~all_done

        def body(carry):
            st, g, pos, _ = carry
            cols = jax.lax.dynamic_slice_in_dim(sym_t, g * seg_len, seg_len,
                                                axis=0)
            st = seg_scan(st, cols)
            doc_abs = absorbing[st].reshape(b, -1).all(axis=1)
            boundary = ((g + 1) * seg_len).astype(jnp.int32)
            pos = jnp.where(doc_abs & (pos == NO_EXIT), boundary, pos)
            done = doc_abs | (boundary >= eff_len.astype(jnp.int32))
            return st, g + 1, pos, done.all()

        states, _, pos, _ = jax.lax.while_loop(
            cond, body, (states, jnp.int32(0), pos0, jnp.bool_(False)))
        return states, pos

    # -- stage: device cursor merge (lane plans) -----------------------------

    def _compose_cursor(self, cursor_lanes: jnp.ndarray,
                        seg_lanes: jnp.ndarray,
                        entry_cls: jnp.ndarray) -> jnp.ndarray:
        """Eq. 8 composition of cursor lanes with a segment's lane map, on
        device — must stay bit-identical to ``kernels.ref.cursor_merge_ref``
        (tests/test_device_merge.py asserts so on every backend)."""
        t = self.t
        lane = t.cidx_pad_j[entry_cls[:, None, None], cursor_lanes]
        hit = jnp.take_along_axis(seg_lanes, jnp.maximum(lane, 0), axis=2)
        sk = t.sinks_j[None, :, None]
        out = jnp.where(lane < 0, jnp.where(sk >= 0, sk, cursor_lanes), hit)
        out = jnp.where((entry_cls == t.pad_key)[:, None, None],
                        cursor_lanes, out)
        return out.astype(jnp.int32)

    # -- stage: bulk scan-compose (the OOO gap-close path) -------------------

    def compose_lane_maps(self, lane_maps, entry_keys) -> jnp.ndarray:
        """Fold runs of candidate-keyed lane maps in one log-depth scan.

        ``lane_maps [B, N, K, S]`` + ``entry_keys [B, N]`` -> ``[B, K, S]``
        compositions (the last scan prefix), via ``lvector
        .merge_scan_lanes_jnp`` — one ``associative_scan`` dispatch for the
        whole batch of runs.  Keys equal to ``pad_key`` are right
        identities, so ragged runs arrive padded to a shared N; the compiled
        program is cached per N (plain jnp: the sharded backend runs it
        replicated, bit-identical by construction).
        """
        key = ("compose_scan", int(lane_maps.shape[1]))
        fn = self._lowered.get(key)
        if fn is None:
            t = self.t

            def body(lanes, keys):
                out = merge_scan_lanes_jnp(lanes, keys, t.cidx_pad_j,
                                           t.sinks_j, pad_key=t.pad_key,
                                           axis=1)
                return out[:, -1]

            fn = self._jit_lowering(body)
            self._lowered[key] = fn
            self.lowering_kinds[key] = "compose-scan"
        return fn(jnp.asarray(lane_maps, jnp.int32),
                  jnp.asarray(entry_keys, jnp.int32))

    # -- seq lowering (shared: single-device rows; also the per-shard body
    # of the sharded backend's document-axis split) --------------------------

    def _seq_body(self, plan: LanePlan, bytes_buf: jnp.ndarray,
                  lengths: jnp.ndarray, entry=None, entry_cls=None):
        """Batched Algorithm 1 as a lane program: classify -> entry-seed ->
        scan (rows are independent; the merge stage is a no-op)."""
        b, w = bytes_buf.shape
        cls = self._classify(bytes_buf, lengths)
        init = self._seed_rows(plan, b, entry, entry_cls)
        rows = init.reshape(b, -1).astype(jnp.int32)
        finals, pos = self._segmented_match(cls.T, rows,
                                            jnp.minimum(lengths, w), w,
                                            early_exit=plan.early_exit)
        if plan.entry == ENTRY_LANES:
            seg = finals.reshape(b, self.t.n_patterns, self.t.i_max)
            return self._compose_cursor(entry.astype(jnp.int32), seg,
                                        entry_cls), pos
        return finals, pos

    def _lower_seq_local(self, plan: LanePlan):
        return self._jit_lowering(
            lambda *args: self._seq_body(plan, *args))

    # -- spec stage bodies (shared by the local jnp and kernel lowerings) ----

    def _spec_stages(self, plan: LanePlan, bytes_buf: jnp.ndarray,
                     lengths: jnp.ndarray, entry, entry_cls):
        """classify + chunking + entry-seed of the uniform speculative path:
        returns (body [B, C, Lc] classes, la [B, C] boundary keys, init
        [B, C, K*S] lanes).

        Boundary keys follow ``DeviceTables.spec_r``: the class of the last
        byte before each chunk (r=1, the paper's Eq. 11), or the pair key
        ``c_prev * n_classes + c_last`` of the two preceding bytes (r=2,
        Eq. 13).  Padding is always a document suffix, so a padded last byte
        means the whole following chunk is padding — its key degrades to the
        identity ``pad_key`` and the merge passes it through.
        """
        t = self.t
        b, w = bytes_buf.shape
        c = self.num_chunks
        lc = w // c
        k, s = t.n_patterns, t.i_max
        cls = self._classify(bytes_buf, lengths)
        body = cls.reshape(b, c, lc)
        last1 = body[:, :-1, -1]                               # [B, C-1]
        if t.spec_r == 2:
            if lc < 2:
                raise ValueError(
                    f"spec_r=2 boundary keys need chunk_len >= 2, got {lc}")
            key = body[:, :-1, -2] * jnp.int32(t.pad_cls) + last1
            key = jnp.where(last1 == t.pad_cls, jnp.int32(t.pad_key), key)
        else:
            key = last1  # r=1: the key *is* the class (pad_cls == pad_key)
        la = jnp.concatenate([jnp.zeros((b, 1), jnp.int32), key], axis=1)
        cand = t.cand_pad_j[la[:, 1:]]                         # [B, C-1, K, S]
        start = self._seed_chunk0(plan, b, entry, entry_cls)   # [B, 1, K, S]
        init = jnp.concatenate([start, cand], axis=1).reshape(b, c, k * s)
        return body, la, init

    def _spec_body(self, plan: LanePlan, bytes_buf: jnp.ndarray,
                   lengths: jnp.ndarray, entry=None, entry_cls=None):
        """Fused classify/chunk/candidate-gather/match/merge, one bucket.

        Chunk 0's entry seed is exact for ``starts``/``states`` plans (all
        its lanes carry the entry state) and candidate-keyed for lane plans;
        later chunks stay speculative from the Eq. 11 candidate rows.  Lane
        plans keep the [K, S] carry through the merge fold and compose the
        caller's cursor lanes on device.
        """
        from ...kernels import ref as kref

        t = self.t
        b, w = bytes_buf.shape
        c = self.num_chunks
        lc = w // c
        k, s = t.n_patterns, t.i_max
        body, la, init = self._spec_stages(plan, bytes_buf, lengths, entry,
                                           entry_cls)
        sym_t = body.reshape(b * c, lc).T                      # [Lc, B*C]
        # per-chunk effective fill: a doc's deepest chunk-local real symbol
        lvecs, pos = self._segmented_match(sym_t, init.reshape(b * c, k * s),
                                           jnp.minimum(lengths, lc), lc,
                                           early_exit=plan.early_exit)
        lv = lvecs.reshape(b, c, k, s)
        if plan.entry == ENTRY_LANES:
            seg = kref.spec_merge_lanes_ref(lv, la, t.cidx_pad_j, t.sinks_j,
                                            pad_cls=t.pad_key)
            return self._compose_cursor(entry.astype(jnp.int32), seg,
                                        entry_cls), pos
        finals = kref.spec_merge_ref(lv, la, t.cidx_pad_j, t.sinks_j,
                                     pad_cls=t.pad_key)
        return finals, pos


class LocalExecutor(LaneExecutor):
    """Single-device lowering: pure-jnp reference or fused Pallas kernel.

    The speculative lowering fuses classification residue, uniform chunking,
    candidate gather, chunk matching, and the Eq. 8 merge in one jitted call
    per bucket (donated input buffer on accelerators); only the [B, K]
    final-state array crosses back to the host.  With ``use_kernel=True``
    every spec plan — exact-entry *and* ``ENTRY_LANES`` — dispatches a fused
    Pallas kernel behind an all-absorbed bucket early exit, and the kernel
    itself skips symbol blocks past the point a document's lanes all absorb
    (the in-kernel early exit; per-document skipped-block counts drain via
    ``kernel_skipped_steps()``).
    """

    def __init__(self, tables: DeviceTables, *, num_chunks: int,
                 use_kernel: bool = False, early_exit_segments: int = 4,
                 compose_mode: str = "carry"):
        super().__init__(tables, num_chunks=num_chunks,
                         early_exit_segments=early_exit_segments)
        self.use_kernel = bool(use_kernel)
        # which spec_compose_lanes kernel the OOO gap-close fold rides:
        # "carry" (block-sequential grid carry) or "tree" (in-kernel
        # Blelloch reduce); benchmarks measure both
        self.compose_mode = compose_mode
        # device arrays of per-doc skipped symbol blocks, appended per kernel
        # dispatch and summed lazily (no sync on the hot path)
        self._skipped_log: list = []
        self._skipped_total = 0

    def kernel_skipped_steps(self) -> int:
        """Total symbol blocks skipped by the in-kernel early exit so far.

        Draining the log syncs the pending device arrays — call this from
        tests/benchmarks, not between hot-path ticks.
        """
        while self._skipped_log:
            self._skipped_total += int(np.asarray(self._skipped_log.pop()).sum())
        return self._skipped_total

    def compose_lane_maps(self, lane_maps, entry_keys) -> jnp.ndarray:
        """OOO gap-close fold, lowered to the ``spec_compose_lanes`` Pallas
        kernel when this executor runs the kernel backend.

        Same contract as the base jnp lowering (``("compose_scan", N)``):
        ragged runs arrive right-padded with ``pad_key`` identities and only
        the whole-run composition returns.  The kernel program is cached per
        ``("compose_kernel", N)`` and shows up as ``"compose-kernel"`` in
        ``lowering_kinds`` — ``Matcher.perf_report()`` surfaces which one
        the OOO tick actually rode (CI asserts no silent jnp fallback on
        the Pallas backend).
        """
        if not self.use_kernel:
            return super().compose_lane_maps(lane_maps, entry_keys)
        key = ("compose_kernel", int(lane_maps.shape[1]))
        fn = self._lowered.get(key)
        if fn is None:
            from ...kernels import ops as kops

            t = self.t
            mode = self.compose_mode

            def body(lanes, keys):
                return kops.spec_compose_lanes(
                    lanes, keys, t.cidx_pad_j, t.sinks_j,
                    pad_key=t.pad_key, mode=mode)

            fn = self._jit_lowering(body)
            self._lowered[key] = fn
            self.lowering_kinds[key] = f"compose-kernel-{mode}"
        return fn(jnp.asarray(lane_maps, jnp.int32),
                  jnp.asarray(entry_keys, jnp.int32))

    def _lower(self, plan: LanePlan, layout, batch: int):
        if plan.kind == "seq":
            self.lowering_kinds[plan.key] = "seq-jnp"
            return self._lower_seq_local(plan)
        if self.use_kernel:
            self.lowering_kinds[plan.key] = (
                "spec-kernel-lanes" if plan.entry == ENTRY_LANES
                else "spec-kernel")
            return self._lower_spec_kernel(plan)
        self.lowering_kinds[plan.key] = "spec-jnp"
        return self._jit_lowering(
            lambda *args: self._spec_body(plan, *args))

    def _lower_spec_kernel(self, plan: LanePlan):
        """Fused Pallas lowering: bucket-level + in-kernel early exit.

        A bucket whose every row is already absorbed — or empty — cannot
        move any lane: absorbing states self-loop on every class, so
        returning the entry states (or, for lane plans, the caller's cursor
        lanes — composition through a restricted map fixes absorbing states)
        verbatim is bit-identical and the whole kernel dispatch is skipped
        (``lax.cond``).  This is the streaming case where a tick's segments
        all belong to decided streams.  Inside the kernel, the symbol-block
        grid additionally skips blocks once a single document's lanes all
        absorb mid-scan; the per-document skipped counts convert to the
        standard ``absorbed_pos`` contract here (block granularity — the jnp
        lowering reports segment granularity, both are upper bounds of the
        true absorb position).
        """
        from ...kernels import ops as kops

        t = self.t
        lanes_mode = plan.entry == ENTRY_LANES
        lc = plan.chunk_len
        l_blk, l_pad = kops._pad_to_block(
            lc, self.spec_l_blk.get(lc, self.spec_l_blk.get(0, 512)))
        l_blocks = l_pad // l_blk

        def kernel_body(plan, bytes_buf, lengths, entry=None, entry_cls=None):
            b = bytes_buf.shape[0]
            if lanes_mode:
                e = entry.astype(jnp.int32)      # [B, K, S] cursor lanes
            else:
                e = self._seed_rows(plan, b, entry, None)       # [B, K]

            def run_kernel():
                # classify/chunk/candidate-gather prep lives *inside* the
                # taken branch so an all-absorbed bucket skips it too, not
                # just the kernel dispatch
                body, la, init = self._spec_stages(plan, bytes_buf, lengths,
                                                   entry, entry_cls)
                absorbing = t.absorbing_j.astype(jnp.int32)
                if lanes_mode:
                    lanes, skipped, _ = kops.spec_match_merge_lanes(
                        t.table_pad_j, body, init, la, t.cidx_pad_j,
                        t.sinks_j, absorbing, pad_cls=t.pad_cls,
                        pad_key=t.pad_key, early_exit=plan.early_exit,
                        l_blk=l_blk)
                    return self._compose_cursor(e, lanes, entry_cls), skipped
                finals, skipped, _ = kops.spec_match_merge(
                    t.table_pad_j, body, init, la, t.cidx_pad_j, t.sinks_j,
                    absorbing, pad_cls=t.pad_cls, pad_key=t.pad_key,
                    early_exit=plan.early_exit, l_blk=l_blk)
                return finals, skipped

            if not plan.early_exit:  # same contract as the jnp lowerings
                out, skipped = run_kernel()
                return out, jnp.full((b,), NO_EXIT, jnp.int32), skipped
            doc_abs = t.absorbing_j[e].reshape(b, -1).all(axis=1)
            done = doc_abs | (lengths.astype(jnp.int32) <= 0)
            zero = jnp.zeros((b,), jnp.int32)
            out, skipped = jax.lax.cond(
                done.all(), lambda: (e.astype(jnp.int32), zero), run_kernel)
            pos = jnp.where(skipped > 0,
                            (jnp.int32(l_blocks) - skipped) * jnp.int32(l_blk),
                            NO_EXIT)
            pos = jnp.where(done.all() & doc_abs, jnp.int32(0), pos)
            return out, pos, skipped

        jit_fn = self._jit_lowering(lambda *args: kernel_body(plan, *args))

        def wrapper(*args):
            out, pos, skipped = jit_fn(*args)
            self._skipped_log.append(skipped)
            return out, pos

        return wrapper
