"""Executor layer: interchangeable device backends behind one protocol.

An executor turns one ``BucketPlan`` worth of documents into ``[B, K]`` final
packed states.  All backends consume the same inputs —

  * ``bytes_buf [B, W] uint8``  — raw document bytes, zero-padded (byte ->
    class classification happens **on device**, fused into the bucket call;
    ``kernels.ref.classify_pad_ref`` is the host oracle),
  * ``lengths [B] int32``       — real byte counts (positions beyond a
    document's length classify to the identity pad class),
  * a ``ChunkLayout``           — the planner's chunk boundaries,

and must be bit-identical to per-document sequential matching.

Backends:

  * ``LocalExecutor``                 — pure-jnp jitted path (the oracle),
    with an absorbing-state early exit: the symbol scan runs in segments
    inside a ``lax.while_loop`` and stops once every lane of every document
    is absorbing (sink or absorbing accept) — further symbols cannot change
    any state, so the remaining segments are skipped entirely.  Per-document
    absorption positions are returned so the facade can report
    ``early_exits``.
  * ``LocalExecutor(use_kernel=True)`` — the fused Pallas kernel
    (``kernels.ops.spec_match_merge``) for the speculative path (no early
    exit inside the kernel; the batched sequential path still exits early).
  * ``engine.sharded.ShardedExecutor`` — the mesh-sharded backend (own
    module).

The protocol: ``run_spec(buf, lengths, layout)`` / ``run_seq(buf, lengths)``
both return ``(finals [B, K], absorbed_pos [B])`` where ``absorbed_pos`` is
the scan position (chunk-local for spec, stream for seq) at which the
document's lanes all became absorbing, or a sentinel >= the scan length.
``traces`` counts jit retraces (side effect fires at trace time only).

**Segment entry (the streaming runtime)**: ``run_seq_entry`` /
``run_spec_entry`` additionally take per-document entry states ``[B, K]`` and
start matching there instead of at the pattern starts — chunk 0 of the
speculative path becomes "exact from the entry states".  This is what makes
matching *resumable*: a ``streaming.MatchCursor`` carries the states across
segment boundaries and the composition is bit-identical to matching the
concatenated stream in one shot (Eq. 8 is associative; cf. simultaneous-FA
transition composition, arXiv:1405.0562).
"""

from __future__ import annotations

from typing import Protocol

import numpy as np

import jax
import jax.numpy as jnp

from .plan import ChunkLayout, DeviceTables

__all__ = ["Executor", "LocalExecutor", "NO_EXIT"]

NO_EXIT = np.int32(2 ** 30)  # absorbed_pos sentinel: never fully absorbed


class Executor(Protocol):
    traces: int

    def run_spec(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                 layout: ChunkLayout) -> tuple[jnp.ndarray, jnp.ndarray]: ...

    def run_seq(self, bytes_buf: jnp.ndarray,
                lengths: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]: ...

    def run_spec_entry(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                       layout: ChunkLayout, entry: jnp.ndarray
                       ) -> tuple[jnp.ndarray, jnp.ndarray]: ...

    def run_seq_entry(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                      entry: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]: ...

    def steps_for(self, layout: ChunkLayout) -> int: ...


def _prev_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n.bit_length() - 1)


class _ExecutorBase:
    """Shared on-device classify + batched sequential scan (all backends)."""

    def __init__(self, tables: DeviceTables, *, num_chunks: int,
                 early_exit_segments: int = 4):
        self.t = tables
        self.num_chunks = int(num_chunks)
        # segments must divide the pow2 scan widths -> round down to a pow2
        self.early_exit_segments = _prev_pow2(max(int(early_exit_segments), 1))
        self.traces = 0
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._seq_fn = jax.jit(self._seq_impl, donate_argnums=donate)
        self._seq_entry_fn = jax.jit(self._seq_entry_impl, donate_argnums=donate)

    # -- fused classification (the retired host numpy path lives in
    # kernels/ref.classify_pad_ref as the oracle) ---------------------------

    def _classify(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray) -> jnp.ndarray:
        """bytes [B, W] + lengths -> [B, W] class ids, pad_cls past the end."""
        cls = self.t.byte_to_class_j[bytes_buf.astype(jnp.int32)]
        pos = jnp.arange(bytes_buf.shape[1], dtype=jnp.int32)[None, :]
        return jnp.where(pos < lengths[:, None].astype(jnp.int32), cls,
                         jnp.int32(self.t.pad_cls))

    # -- segmented scan with absorbing-state early exit ---------------------

    def _segmented_match(self, sym_t: jnp.ndarray, states: jnp.ndarray,
                         eff_len: jnp.ndarray, scan_len: int
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Scan ``states [R, S]`` through ``sym_t [L, R]`` symbol columns in
        segments, stopping once every document is *done*: all its lanes are
        absorbing, or the scan has passed its real symbols (``eff_len [B]``
        per-doc; pure-padding rows of a partial tile are done immediately,
        so they never pin the loop to the full scan).

        Rows are doc-major (R = B * rows_per_doc).  Returns (final states,
        absorbed_pos [B]) with ``absorbed_pos`` the first segment boundary at
        which a document's lanes were all absorbing (sentinel ``NO_EXIT``
        otherwise).  Exactness: absorbing states self-loop on every class and
        padding is the identity column, so skipping the remaining symbols of
        a done document is bit-identical.
        """
        table = self.t.table_pad_j
        absorbing = self.t.absorbing_j
        b = eff_len.shape[0]

        def seg_scan(st, cols):
            def step(s, row):
                return table[s, row[:, None]], None
            out, _ = jax.lax.scan(step, st, cols)
            return out

        segs = min(self.early_exit_segments, scan_len)
        pos0 = jnp.full((b,), NO_EXIT, jnp.int32)
        if segs <= 1 or scan_len == 0:
            return seg_scan(states, sym_t), pos0
        seg_len = scan_len // segs

        def cond(carry):
            _, g, _, all_done = carry
            return (g < segs) & ~all_done

        def body(carry):
            st, g, pos, _ = carry
            cols = jax.lax.dynamic_slice_in_dim(sym_t, g * seg_len, seg_len,
                                                axis=0)
            st = seg_scan(st, cols)
            doc_abs = absorbing[st].reshape(b, -1).all(axis=1)
            boundary = ((g + 1) * seg_len).astype(jnp.int32)
            pos = jnp.where(doc_abs & (pos == NO_EXIT), boundary, pos)
            done = doc_abs | (boundary >= eff_len.astype(jnp.int32))
            return st, g + 1, pos, done.all()

        states, _, pos, _ = jax.lax.while_loop(
            cond, body, (states, jnp.int32(0), pos0, jnp.bool_(False)))
        return states, pos

    # -- batched sequential path (short documents) --------------------------

    def _seq_entry_body(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                        entry: jnp.ndarray):
        """Batched Algorithm 1 from per-document entry states [B, K].  Rows
        are independent, so this body is also the per-shard program of the
        sharded backend's document-axis split."""
        w = bytes_buf.shape[1]
        cls = self._classify(bytes_buf, lengths)
        return self._segmented_match(cls.T, entry.astype(jnp.int32),
                                     jnp.minimum(lengths, w), w)

    def _seq_body(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray):
        b = bytes_buf.shape[0]
        s0 = jnp.broadcast_to(
            self.t.starts_j[None, :], (b, self.t.n_patterns))
        return self._seq_entry_body(bytes_buf, lengths, s0)

    def _seq_impl(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray):
        self.traces += 1
        return self._seq_body(bytes_buf, lengths)

    def _seq_entry_impl(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                        entry: jnp.ndarray):
        self.traces += 1
        return self._seq_entry_body(bytes_buf, lengths, entry)

    def run_seq(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray):
        return self._seq_fn(bytes_buf, lengths)

    def run_seq_entry(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                      entry: jnp.ndarray):
        return self._seq_entry_fn(bytes_buf, lengths, entry)


class LocalExecutor(_ExecutorBase):
    """Single-device jitted executor: pure-jnp reference or fused Pallas.

    The speculative body fuses classification residue, uniform chunking,
    candidate gather, chunk matching, and the Eq. 8 merge in one jitted call
    per bucket (donated input buffer on accelerators); only the [B, K]
    final-state array crosses back to the host.
    """

    def __init__(self, tables: DeviceTables, *, num_chunks: int,
                 use_kernel: bool = False, early_exit_segments: int = 4):
        super().__init__(tables, num_chunks=num_chunks,
                         early_exit_segments=early_exit_segments)
        self.use_kernel = bool(use_kernel)
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._spec_fn = jax.jit(self._spec_impl, donate_argnums=donate)
        self._spec_entry_fn = jax.jit(self._spec_entry_impl,
                                      donate_argnums=donate)

    def steps_for(self, layout: ChunkLayout) -> int:
        return layout.lmax  # uniform layout: lmax == chunk_len

    def _spec_impl(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray):
        self.traces += 1  # side effect fires at trace time only
        b = bytes_buf.shape[0]
        entry = jnp.broadcast_to(self.t.starts_j[None, :],
                                 (b, self.t.n_patterns))
        return self._spec_body(bytes_buf, lengths, entry)

    def _spec_entry_impl(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                         entry: jnp.ndarray):
        self.traces += 1
        return self._spec_body(bytes_buf, lengths, entry)

    def _spec_body(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                   entry: jnp.ndarray):
        """Fused classify/chunk/candidate-gather/match/merge, one bucket.

        ``entry [B, K]`` seeds chunk 0 exactly (all its lanes carry the entry
        state — the pattern starts for whole documents, a stream cursor's
        states for resumed segments); later chunks stay speculative from the
        Eq. 11 candidate rows.  The fused Pallas path needs no kernel change:
        the injection happens where the init lanes are built.
        """
        from ...kernels import ops as kops
        from ...kernels import ref as kref

        t = self.t
        b, w = bytes_buf.shape
        c = self.num_chunks
        lc = w // c
        k, s = t.n_patterns, t.i_max
        cls = self._classify(bytes_buf, lengths)
        body = cls.reshape(b, c, lc)
        la = jnp.concatenate(
            [jnp.zeros((b, 1), jnp.int32), body[:, :-1, -1]], axis=1)
        cand = t.cand_pad_j[la[:, 1:]]                         # [B, C-1, K, S]
        start = jnp.broadcast_to(
            entry.astype(jnp.int32)[:, None, :, None], (b, 1, k, s))
        init = jnp.concatenate([start, cand], axis=1).reshape(b, c, k * s)
        if self.use_kernel:
            finals = kops.spec_match_merge(t.table_pad_j, body, init, la,
                                           t.cidx_pad_j, t.sinks_j,
                                           pad_cls=t.pad_cls)
            return finals, jnp.full((b,), NO_EXIT, jnp.int32)
        sym_t = body.reshape(b * c, lc).T                      # [Lc, B*C]
        # per-chunk effective fill: a doc's deepest chunk-local real symbol
        lvecs, pos = self._segmented_match(sym_t, init.reshape(b * c, k * s),
                                           jnp.minimum(lengths, lc), lc)
        finals = kref.spec_merge_ref(lvecs.reshape(b, c, k, s), la,
                                     t.cidx_pad_j, t.sinks_j, pad_cls=t.pad_cls)
        return finals, pos

    def run_spec(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                 layout: ChunkLayout):
        return self._spec_fn(bytes_buf, lengths)

    def run_spec_entry(self, bytes_buf: jnp.ndarray, lengths: jnp.ndarray,
                       layout: ChunkLayout, entry: jnp.ndarray):
        return self._spec_entry_fn(bytes_buf, lengths, entry)
