"""Required-literal prefilter: per-document per-block "can-match" gating.

Thousands of patterns cannot all pay full-DFA cost on every document
(arXiv:1110.1716's insomnia argument).  The cheap gate used by production
engines (RE2 prefilters, Hyperscan literal factoring, cf. arXiv:1512.09228)
is a *required literal*: a byte string every match of a pattern must contain.
If a document does not contain the literal, the pattern's verdict is False
with no automaton run at all; if no pattern of a K-block survives the gate,
the whole block's dispatch is skipped.

The literal scan rides the streaming tier's Rabin-fingerprint algebra
(``streaming.ooo.fingerprint``): every length-L window of a document is
fingerprinted in one vectorized Horner pass mod the Mersenne prime 2^61-1
(the multiply-by-256 step splits into a shift/add pair so uint64 never
overflows), and window fingerprints are matched against the literal
fingerprints with a sorted lookup.  Collisions are one-sided: a colliding
window can only make a gated block *run* (sound false "may-match"), never
suppress a true match.

Extraction (``required_literal``) walks the parsed AST for mandatory
contiguous factors: single-byte literals chain into runs across ``Concat``,
exactly-repeated exact factors expand, alternations and optional parts
contribute nothing.  Patterns with no extractable literal leave their block
ungated — the gate is an optimization, never a semantics change.
"""

from __future__ import annotations

import hashlib
from typing import Optional, Sequence

import numpy as np

from .regex import Alt, Concat, Lit, Node, Repeat, parse_regex

__all__ = ["required_literal", "window_fingerprints", "literal_fingerprint",
           "Prefilter"]

# Same modulus as streaming.ooo.fingerprint.FP_MOD (imported lazily below to
# keep core free of a load-time dependency on the streaming package).
_M61 = np.uint64((1 << 61) - 1)
_LO53 = np.uint64((1 << 53) - 1)


# -- required-literal extraction ---------------------------------------------

def _exact_run(node: Node) -> Optional[bytes]:
    """The exact byte string ``node`` always matches, or None.

    Only nodes whose every match is one fixed string qualify — these join
    contiguously with neighbouring exact parts inside a ``Concat``.
    """
    if isinstance(node, Lit):
        if len(node.byteset) == 1:
            return bytes([next(iter(node.byteset))])
        return None
    if isinstance(node, Repeat):
        if node.hi is not None and node.hi == node.lo:
            b = _exact_run(node.child)
            return b * node.lo if b is not None else None
        return None
    if isinstance(node, Concat):
        parts = [_exact_run(p) for p in node.parts]
        if all(p is not None for p in parts):
            return b"".join(parts)  # type: ignore[arg-type]
        return None
    if isinstance(node, Alt) and len(node.options) == 1:
        return _exact_run(node.options[0])
    return None


def _factors(node: Node) -> list[bytes]:
    """Byte strings guaranteed to appear contiguously in every match."""
    if isinstance(node, Lit):
        b = _exact_run(node)
        return [b] if b else []
    if isinstance(node, Alt):
        # a factor common to every branch would be sound; we keep the gate
        # simple and let alternations contribute nothing
        return []
    if isinstance(node, Repeat):
        if node.lo < 1:
            return []
        b = _exact_run(node.child)
        if b:
            # every match is >= lo contiguous copies of the exact child
            return [b * node.lo]
        return _factors(node.child)
    if isinstance(node, Concat):
        out: list[bytes] = []
        run = bytearray()
        for part in node.parts:
            b = _exact_run(part)
            if b is not None:
                run += b
                continue
            if run:
                out.append(bytes(run))
                run = bytearray()
            out.extend(_factors(part))
        if run:
            out.append(bytes(run))
        return out
    return []


def required_literal(pattern: str) -> Optional[bytes]:
    """Longest byte string every match of ``pattern`` must contain.

    Returns None when the pattern has no mandatory literal (or does not
    parse) — such patterns leave their block ungated.  Search wrappers
    (``.*(pat)``) factor identically to the bare pattern: the ``.*`` prefix
    is an optional repeat and contributes nothing.
    """
    try:
        ast = parse_regex(pattern)
    except Exception:
        return None
    factors = _factors(ast)
    if not factors:
        return None
    return max(factors, key=len)


# -- vectorized Rabin window scan --------------------------------------------

def _mul256_mod(h: np.ndarray) -> np.ndarray:
    # h < 2^61: h*256 mod (2^61-1) == (h>>53) + ((h & (2^53-1)) << 8), folded
    # once — both terms fit uint64 and their sum is < 2^61 + 256.
    v = (h >> np.uint64(53)) + ((h & _LO53) << np.uint64(8))
    return np.where(v >= _M61, v - _M61, v)


def _add_mod(h: np.ndarray, b: np.ndarray) -> np.ndarray:
    v = h + b  # < 2^61 + 255, no uint64 overflow
    return np.where(v >= _M61, v - _M61, v)


def window_fingerprints(data: np.ndarray, length: int) -> np.ndarray:
    """Rabin fingerprints of every ``length``-byte window of ``data``.

    Bit-identical to ``streaming.ooo.fingerprint.segment_fingerprint`` of
    each window (big-endian Horner mod 2^61-1), but computed for all
    ``n - length + 1`` windows in ``length`` vectorized passes.
    """
    data = np.asarray(data, dtype=np.uint8)
    n = data.shape[0]
    if length <= 0 or n < length:
        return np.zeros(0, dtype=np.uint64)
    h = np.zeros(n - length + 1, dtype=np.uint64)
    for j in range(length):
        h = _mul256_mod(h)
        h = _add_mod(h, data[j:n - length + 1 + j].astype(np.uint64))
    return h


def literal_fingerprint(literal: bytes) -> int:
    """Fingerprint of a literal, via the streaming tier's scalar reference."""
    from ..streaming.ooo.fingerprint import segment_fingerprint
    return segment_fingerprint(literal)


# -- the per-block gate ------------------------------------------------------

class Prefilter:
    """Vectorized per-document per-block "can-possibly-match" gate.

    ``block_literals[b][i]`` is pattern i-of-block-b's required literal (or
    None).  A block is *gated* iff every one of its patterns has a literal;
    a document can possibly match a gated block only if it contains at least
    one of the block's literals.  Ungated blocks always report True.
    """

    def __init__(self, block_literals: Sequence[Sequence[Optional[bytes]]]):
        self.block_literals = tuple(tuple(ls) for ls in block_literals)
        self.n_blocks = len(self.block_literals)
        self.gated = np.array(
            [len(ls) > 0 and all(l is not None for l in ls)
             for ls in self.block_literals], dtype=bool)
        # Distinct literals of the gated blocks, grouped by length for the
        # window scan; each gated block keeps the flat indices of its own.
        lit_index: dict[bytes, int] = {}
        self._block_lit_idx: list[np.ndarray] = []
        for b, ls in enumerate(self.block_literals):
            if not self.gated[b]:
                self._block_lit_idx.append(np.zeros(0, dtype=np.int64))
                continue
            idx = [lit_index.setdefault(l, len(lit_index)) for l in ls]
            self._block_lit_idx.append(np.unique(np.array(idx, np.int64)))
        self.literals = tuple(sorted(lit_index, key=lit_index.get))
        self.n_literals = len(self.literals)
        # by length: (L, sorted unique fps, per-literal map into the uniques)
        self._by_len: list[tuple[int, np.ndarray, np.ndarray, np.ndarray]] = []
        by_len: dict[int, list[int]] = {}
        for i, lit in enumerate(self.literals):
            by_len.setdefault(len(lit), []).append(i)
        for L, ids in sorted(by_len.items()):
            fps = np.array([literal_fingerprint(self.literals[i])
                            for i in ids], dtype=np.uint64)
            uniq, inv = np.unique(fps, return_inverse=True)
            self._by_len.append((L, uniq, inv, np.array(ids, np.int64)))
        self.min_len = min(by_len) if by_len else 0

    @classmethod
    def from_pattern_set(cls, pattern_set) -> "Prefilter":
        """Build from a ``core.patterns.PatternSet`` (duck-typed: needs
        ``n_blocks`` and ``block_regexes``; DFA-sourced patterns have no
        regex and leave their block ungated)."""
        return cls([
            [required_literal(r) if r is not None else None
             for r in pattern_set.block_regexes(b)]
            for b in range(pattern_set.n_blocks)])

    def _present_literals(self, arr: np.ndarray) -> np.ndarray:
        """[n_literals] bool: which literals (by fingerprint) ``arr`` contains."""
        present = np.zeros(self.n_literals, dtype=bool)
        for L, uniq, inv, ids in self._by_len:
            wf = window_fingerprints(arr, L)
            if wf.size == 0:
                continue
            pos = np.searchsorted(uniq, wf)
            pos_c = np.minimum(pos, uniq.size - 1)
            hit_uniq = np.zeros(uniq.size, dtype=bool)
            hit_uniq[pos_c[uniq[pos_c] == wf]] = True
            present[ids] = hit_uniq[inv]
        return present

    def can_match(self, arrs: Sequence[np.ndarray],
                  lengths: np.ndarray | None = None) -> np.ndarray:
        """[B, n_blocks] bool: False only when *no* pattern of the block can
        possibly match the document (all required literals absent)."""
        b = len(arrs)
        can = np.ones((b, self.n_blocks), dtype=bool)
        if not self.gated.any():
            return can
        gated_ids = np.flatnonzero(self.gated)
        for di, arr in enumerate(arrs):
            present = self._present_literals(np.asarray(arr, dtype=np.uint8))
            for bi in gated_ids:
                idx = self._block_lit_idx[bi]
                can[di, bi] = bool(present[idx].any())
        return can

    def signature(self) -> str:
        """Content hash of the gate tables (part of the checkpoint
        pattern-set signature: a changed literal table silently re-gates
        restored traffic, so restores must refuse it)."""
        h = hashlib.sha1()
        for ls in self.block_literals:
            h.update(b"[")
            for l in ls:
                if l is None:
                    h.update(b"~;")
                else:
                    h.update(str(len(l)).encode() + b":" + l + b";")
            h.update(b"]")
        return h.hexdigest()

    def __repr__(self) -> str:
        return (f"Prefilter(n_blocks={self.n_blocks}, "
                f"gated={int(self.gated.sum())}, "
                f"n_literals={self.n_literals})")
