"""SpecDFAEngine — the paper's speculative parallel membership test, in JAX.

Flow (Sec. 4.1 steps 2–4):

  1. partition the class stream into chunks,
  2. derive each chunk's reverse-lookahead class (last class of the previous
     chunk) and its candidate initial states (Eq. 11 tables),
  3. match all chunks x candidate lanes in one ``lax.scan`` over symbols
     (the vectorized matching loop of Listing 2 — lanes = chunks x candidates,
     8x128-wide on the TPU VPU instead of AVX2's 8),
  4. fold the compressed L-vectors from the known start state (Eq. 8), with
     the sink absorbing.

Partition models (DESIGN.md §2):

  * ``balanced`` (paper-faithful, Eqs. 2–7): chunk 0 is ``m``x longer and is
    matched *exactly* (one state); the C-1 speculative chunks are equal-length.
    Scalar per-processor work is balanced -> failure-free on scalar cores.
  * ``uniform``: equal chunks, speculative lanes ride the vector unit.  On
    lane-parallel hardware matching m states costs the same wall time as one,
    so uniform chunks are optimal there (time = n/C steps); this is the
    SPMD/TPU-native layout and a beyond-paper observation recorded in §Perf.

Modes:
  * ``lookahead``  — paper Alg. 3 (I_max candidate lanes).      [default]
  * ``basic``      — paper Alg. 2 (all |Q| lanes, chunk 0 knows q0).
  * ``holub``      — Holub–Stekr [19] baseline: full [Q]->[Q] maps per chunk,
                     merged associatively; O(n|Q|/|P|) work, used by Fig. 11.

The matcher callable is pluggable so the Pallas kernels (kernels/ops.py) slot
in; the pure-jnp path below is their oracle.

Batched matching (beyond-paper; see ROADMAP "Batched matching"):

``BatchMatcher.membership_batch(docs)`` amortizes launch overhead across a
whole document batch and across K patterns packed into one table
(``core.automata.PackedDFA``).  Design decisions:

  * **Identity pad column.**  Every document is padded with a synthetic class
    ``pad_cls == n_classes`` whose transition column is the identity map, so
    padding advances no DFA and the matcher stays branch-free.  Padding is a
    suffix; a chunk whose reverse-lookahead class is ``pad_cls`` is therefore
    entirely padding and the Eq. 8 merge carries the state through unchanged.
  * **Shape buckets, bounded retracing.**  A document of length n is chunked
    uniformly into C chunks of length ``next_pow2(ceil(n / C))``; documents
    sharing that chunk length share a bucket, and every device call uses a
    fixed ``batch_tile`` row count, so a compiled shape depends only on the
    bucket's chunk length.  Bucket keys are *sticky* across calls: a new doc
    snaps up into an already-compiled bucket when one fits, and fresh keys
    merge upward (padding further) until the shape budget ``max_buckets``
    (default 2) is respected — verified by the ``trace_count`` counter.  The
    budget is strict within a call and across calls whose documents fit the
    compiled buckets; a later document longer than every compiled bucket
    necessarily compiles one extra shape (it cannot be matched in a smaller
    buffer), so feed a representative length mix early for the tightest
    bound.
  * **One fused call per bucket, one transfer.**  Classification residue,
    chunking, candidate gather, chunk matching, and the Eq. 8 merge run
    inside a single jitted call (donated input buffer on accelerators);
    only the [B, K] final-state array crosses back to the host — no
    per-document ``int()`` syncs.
  * **Short docs** (n < 4·C) take a *batched sequential* scan — still one
    device call for all of them, not one per document.
  * Lanes are ``chunks x candidates x patterns``; per-pattern candidate sets
    over the joint class alphabet come from
    ``core.lookahead.build_packed_lookahead_tables``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from .automata import DFA, PackedDFA, pack_dfas
from .lookahead import (LookaheadTables, PackedLookaheadTables,
                        build_lookahead_tables, build_packed_lookahead_tables)
from .lvector import merge_scan_jnp

__all__ = ["MatchResult", "BatchResult", "SpecDFAEngine", "BatchMatcher",
           "sequential_state", "match_chunks_lanes"]

VPU_LANES = 1024  # 8 sublanes x 128 lanes of int32 on a TPU core


@dataclasses.dataclass
class MatchResult:
    final_state: int
    accepted: bool
    work_parallel: int    # scalar-model: max symbols matched by any processor
    work_sequential: int  # n — the sequential matcher's symbol count
    time_steps: int       # lane-parallel model: wall-clock matching steps
    mode: str

    @property
    def model_speedup(self) -> float:
        """Scalar-work speedup proxy (the paper's time-unit model, Sec. 3)."""
        return self.work_sequential / max(self.work_parallel, 1)

    @property
    def lane_speedup(self) -> float:
        return self.work_sequential / max(self.time_steps, 1)


# --------------------------------------------------------------------------
# jit kernels (pure-jnp reference path)
# --------------------------------------------------------------------------

@jax.jit
def sequential_state(table: jnp.ndarray, classes: jnp.ndarray, start: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 1 matching loop: one gather per symbol."""

    def step(s, cls):
        return table[s, cls], None

    final, _ = jax.lax.scan(step, jnp.asarray(start, jnp.int32), classes)
    return final


def match_chunks_lanes(table: jnp.ndarray, chunk_classes: jnp.ndarray,
                       init_states: jnp.ndarray) -> jnp.ndarray:
    """Vectorized matching of [C] chunks x [S] speculative lanes.

    chunk_classes: [C, L] int32;  init_states: [C, S] int32.
    Returns final states [C, S].  One scan over L; each step is a batched
    2-D gather — the TPU analogue of the AVX2 gather loop (Listing 2).
    """
    sym_major = chunk_classes.T  # [L, C]

    def step(states, cls_row):  # states [C, S], cls_row [C]
        nxt = table[states, cls_row[:, None]]
        return nxt, None

    final, _ = jax.lax.scan(step, init_states.astype(jnp.int32), sym_major)
    return final


@functools.partial(jax.jit, static_argnames=("sink",))
def _merge_compressed_jnp(start_state: jnp.ndarray, lvecs: jnp.ndarray,
                          cand_index: jnp.ndarray, lookahead_cls: jnp.ndarray,
                          sink: int) -> jnp.ndarray:
    """Eq. 8 fold over compressed per-chunk results from a known start state.

    lvecs[i] holds chunk i's final state per candidate lane; lookahead_cls[i]
    selects the candidate list.  The carried state is always a candidate of
    the next chunk (Eq. 11) unless it is the absorbing sink.
    """

    def step(s, xs):
        lv, la = xs
        lane = cand_index[la, s]
        nxt = jnp.where(lane < 0, jnp.int32(sink if sink >= 0 else 0),
                        lv[jnp.maximum(lane, 0)])
        if sink >= 0:
            nxt = jnp.where(s == sink, jnp.int32(sink), nxt)
        return nxt.astype(jnp.int32), None

    final, _ = jax.lax.scan(step, jnp.asarray(start_state, jnp.int32),
                            (lvecs, lookahead_cls))
    return final


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------

MatcherFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]


class SpecDFAEngine:
    """End-to-end speculative membership test for one DFA.

    Parameters
    ----------
    dfa          : complete DFA (core.automata).
    num_chunks   : processor count P (defaults to 8; the distributed wrapper
                   multiplies this by the mesh data extent).
    mode         : "lookahead" | "basic" | "holub".
    partition    : "balanced" (paper Eqs. 2–7) | "uniform" (SPMD lanes).
    weights      : optional per-processor capacity weights (Eq. 1).
    matcher      : optional replacement for the chunk matcher (Pallas kernel).
    """

    def __init__(self, dfa: DFA, *, num_chunks: int = 8, mode: str = "lookahead",
                 partition: str = "balanced", weights: Optional[np.ndarray] = None,
                 matcher: Optional[MatcherFn] = None, lookahead_r: int = 1):
        if mode not in ("lookahead", "basic", "holub"):
            raise ValueError(f"unknown mode {mode!r}")
        if partition not in ("balanced", "uniform"):
            raise ValueError(f"unknown partition {partition!r}")
        if lookahead_r not in (1, 2):
            raise ValueError("runtime lookahead_r must be 1 or 2 (Sec. 4.3)")
        self.dfa = dfa
        self.mode = mode
        self.lookahead_r = lookahead_r if mode == "lookahead" else 1
        self.partition = "uniform" if mode == "holub" else partition
        self.num_chunks = int(num_chunks)
        self.weights = (np.ones(self.num_chunks) if weights is None
                        else np.asarray(weights, dtype=np.float64))
        if self.weights.shape != (self.num_chunks,):
            raise ValueError("weights must have one entry per chunk")
        self.tables: LookaheadTables = build_lookahead_tables(
            dfa, r=self.lookahead_r)
        self.matcher: MatcherFn = matcher or match_chunks_lanes

        self._table_j = jnp.asarray(dfa.table)
        self._cand_j = jnp.asarray(self.tables.candidates)
        self._cidx_j = jnp.asarray(self.tables.cand_index)
        self._all_states = jnp.arange(dfa.n_states, dtype=jnp.int32)
        self._matcher_jit = jax.jit(self.matcher)
        self._batch: Optional["BatchMatcher"] = None  # built on first use

    # -- public API ---------------------------------------------------------

    @property
    def gamma(self) -> float:
        return self.tables.gamma

    @property
    def i_max(self) -> int:
        return self.tables.i_max

    @property
    def lanes_per_chunk(self) -> int:
        return self.dfa.n_states if self.mode in ("basic", "holub") else self.tables.i_max

    def classes(self, data: bytes | np.ndarray) -> np.ndarray:
        return self.dfa.classes_of(data).astype(np.int32)

    def membership_sequential(self, data: bytes | np.ndarray) -> MatchResult:
        cls = jnp.asarray(self.classes(data))
        final = int(sequential_state(self._table_j, cls, self.dfa.start))
        n = int(cls.shape[0])
        return MatchResult(final, bool(self.dfa.accepting[final]), n, n, n, "sequential")

    def membership(self, data: bytes | np.ndarray) -> MatchResult:
        cls_np = self.classes(data)
        n = int(cls_np.shape[0])
        p = self.num_chunks
        m = self.lanes_per_chunk
        if p <= 1 or n < 4 * p:
            return self.membership_sequential(data)
        if self.partition == "uniform":
            final, work, steps = self._run_uniform(cls_np)
        else:
            final, work, steps = self._run_balanced(cls_np, m)
        final_i = int(final)
        return MatchResult(final_i, bool(self.dfa.accepting[final_i]), work, n,
                           steps, self.mode)

    def accepts(self, data: bytes | np.ndarray) -> bool:
        return self.membership(data).accepted

    def membership_batch(self, docs: Sequence[bytes | np.ndarray]) -> "BatchResult":
        """Batched membership for many documents in few fused device calls.

        Decisions are bit-identical to ``membership_sequential`` per document;
        see ``BatchMatcher`` for the bucketing/padding policy.  The batch path
        always partitions uniformly (lanes ride the vector unit), regardless
        of this engine's ``partition`` setting.
        """
        if self._batch is None:
            self._batch = BatchMatcher(self.dfa, num_chunks=self.num_chunks)
        return self._batch.membership_batch(docs)

    # -- partition bodies -----------------------------------------------------

    def _run_balanced(self, cls_np: np.ndarray, m: int) -> tuple[jnp.ndarray, int, int]:
        """Paper Eqs. 2–7: exact chunk 0 of length ~m*L, C-1 speculative chunks.

        Speculative chunks are forced equal-length (L_spec) for the SPMD
        matcher; chunk 0 absorbs the rounding remainder.  With capacity
        weights w, L0 follows Eq. 5 with the w-weighted denominator.
        """
        n = cls_np.shape[0]
        p = self.num_chunks
        w = self.weights
        l0 = n * m / (w[0] * m + w[1:].sum())  # Eq. 5
        l_spec = max(1, int(np.floor(l0 / m * (w[1:].mean() if p > 1 else 1.0))))
        l_spec = min(l_spec, (n - 1) // max(p - 1, 1))
        l0_int = n - (p - 1) * l_spec
        if l0_int <= 0 or l_spec <= 0:
            seq = self.membership_sequential(cls_np)
            return jnp.int32(seq.final_state), seq.work_parallel, seq.time_steps

        head = jnp.asarray(cls_np[:l0_int])
        body = jnp.asarray(cls_np[l0_int:]).reshape(p - 1, l_spec)
        final0 = sequential_state(self._table_j, head, self.dfa.start)

        la = jnp.concatenate([jnp.asarray(cls_np[l0_int - 1 : l0_int]), body[:-1, -1]])
        if self.lookahead_r == 2:
            if l0_int < 2 or l_spec < 2:
                seq = self.membership_sequential(cls_np)
                return jnp.int32(seq.final_state), seq.work_parallel, seq.time_steps
            prev = jnp.concatenate(
                [jnp.asarray(cls_np[l0_int - 2 : l0_int - 1]), body[:-1, -2]])
            la = prev * self.dfa.n_classes + la
        cand, lanes = self._candidates(la, body.shape[0])
        lvecs = self._matcher_jit(self._table_j, body, cand)  # [C-1, S]
        if self.mode == "basic":
            def step(s, lv):
                return lv[s], None
            final, _ = jax.lax.scan(step, final0, lvecs)
        else:
            final = _merge_compressed_jnp(final0, lvecs, self._cidx_j, la, self.dfa.sink)
        work = max(l0_int, l_spec * lanes)          # scalar-processor model
        steps = max(l0_int, l_spec)                 # lane-parallel model
        return final, work, steps

    def _run_uniform(self, cls_np: np.ndarray) -> tuple[jnp.ndarray, int, int]:
        n = cls_np.shape[0]
        c = self.num_chunks
        l = n // c
        body = jnp.asarray(cls_np[: l * c]).reshape(c, l)

        if self.mode == "holub":
            q = self.dfa.n_states
            init = jnp.broadcast_to(self._all_states, (c, q))
            maps = self._matcher_jit(self._table_j, body, init)
            final = merge_scan_jnp(maps)[-1][self.dfa.start]
            work, lanes = l * q, q
        else:
            la = jnp.concatenate([jnp.zeros((1,), jnp.int32), body[:-1, -1]])
            if self.lookahead_r == 2:
                if l < 2:
                    seq = self.membership_sequential(cls_np)
                    return jnp.int32(seq.final_state), seq.work_parallel, l
                prev = jnp.concatenate([jnp.zeros((1,), jnp.int32), body[:-1, -2]])
                la = prev * self.dfa.n_classes + la
            cand, lanes = self._candidates(la, c)
            # chunk 0 knows q0: all its lanes hold q0 (idle-lane duplicates)
            cand = cand.at[0].set(jnp.full((cand.shape[1],), self.dfa.start, jnp.int32))
            lvecs = self._matcher_jit(self._table_j, body, cand)
            if self.mode == "basic":
                def step(s, lv):
                    return lv[s], None
                s0 = lvecs[0, self.dfa.start]
                final, _ = jax.lax.scan(step, s0, lvecs[1:])
            else:
                final = _merge_compressed_jnp(lvecs[0, 0], lvecs[1:], self._cidx_j,
                                              la[1:], self.dfa.sink)
            work = l * lanes

        if l * c < n:  # sequential tail for the remainder
            tail = jnp.asarray(cls_np[l * c:])
            final = sequential_state(self._table_j, tail, final)
            work += n - l * c
        return final, work, l + (n - l * c)

    def _candidates(self, la: jnp.ndarray, c: int) -> tuple[jnp.ndarray, int]:
        if self.mode == "basic":
            q = self.dfa.n_states
            return jnp.broadcast_to(self._all_states, (c, q)), q
        return self._cand_j[la], self.tables.i_max


# --------------------------------------------------------------------------
# Batched multi-pattern pipeline
# --------------------------------------------------------------------------

def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


@dataclasses.dataclass
class BatchResult:
    """Per-batch outcome of ``BatchMatcher.membership_batch``.

    ``accepted``/``final_states`` are [B, K] (K = packed pattern count);
    work arrays are per-document model quantities mirroring ``MatchResult``.
    """

    accepted: np.ndarray        # [B, K] bool
    final_states: np.ndarray    # [B, K] int32 packed state ids
    work_parallel: np.ndarray   # [B] scalar-model work
    work_sequential: np.ndarray # [B] n * K
    time_steps: np.ndarray      # [B] lane-parallel matching steps
    bucket_calls: int           # device dispatches consumed by this batch

    @property
    def model_speedup(self) -> float:
        return float(self.work_sequential.sum()) / max(float(self.work_parallel.sum()), 1.0)

    @property
    def lane_speedup(self) -> float:
        return float(self.work_sequential.sum()) / max(float(self.time_steps.sum()), 1.0)


class BatchMatcher:
    """Batched, multi-pattern membership over padded shape buckets.

    Accepts a single ``DFA``, a pre-built ``PackedDFA``, or a sequence of
    DFAs (packed on the fly).  See the module docstring for the bucketing /
    padding / retracing policy.

    Parameters
    ----------
    source      : DFA | PackedDFA | sequence of DFA.
    num_chunks  : uniform chunk count C per document (the batch path always
                  uses uniform partitioning — speculative lanes ride the
                  vector unit, so equal chunks are optimal there).
    max_buckets : compiled-shape budget for the speculative path; new chunk
                  lengths snap up into compiled buckets, and fresh buckets
                  merge upward to stay under it.  A document longer than
                  every compiled bucket still forces one new shape — the
                  budget is tight only once the largest documents have been
                  seen.
    batch_tile  : fixed row count of every device call (rounded up to a power
                  of two); batches larger than the tile split into slabs,
                  smaller ones pad up, so the row dimension never retraces.
    use_kernel  : route chunk matching + merge through the fused Pallas
                  kernel (kernels.ops.spec_match_merge) instead of the
                  pure-jnp reference path.
    """

    def __init__(self, source, *, num_chunks: int = 8, max_buckets: int = 2,
                 batch_tile: int = 64, use_kernel: bool = False):
        if isinstance(source, PackedDFA):
            packed = source
        elif isinstance(source, DFA):
            packed = pack_dfas([source])
        else:
            packed = pack_dfas(list(source))
        if num_chunks < 1:
            raise ValueError("num_chunks must be >= 1")
        if max_buckets < 1:
            raise ValueError("max_buckets must be >= 1")
        if batch_tile < 1:
            raise ValueError("batch_tile must be >= 1")
        self.packed = packed
        self.num_chunks = int(num_chunks)
        self.max_buckets = int(max_buckets)
        self.batch_tile = _next_pow2(int(batch_tile))
        self.use_kernel = bool(use_kernel)
        # sticky shape state: compiled spec chunk lengths, seq scan width
        self._spec_keys: list[int] = []
        # short docs have n < 4C, so one fixed seq width covers them all
        # (grown lazily only in the num_chunks <= 1 everything-sequential case)
        self._seq_width = _next_pow2(max(4 * self.num_chunks - 1, 1))
        self.tables: PackedLookaheadTables = build_packed_lookahead_tables(packed)
        self.pad_cls = packed.n_classes  # synthetic identity class

        q = packed.n_states
        ident = np.arange(q, dtype=np.int32).reshape(-1, 1)
        self._table_pad_j = jnp.asarray(
            np.concatenate([packed.table, ident], axis=1))
        # pad rows: candidates row for pad_cls is never merged through (the
        # merge carries the state when lookahead == pad_cls) but must hold
        # in-range states for the gather; cand_index pad row stays -1.
        cand_pad = self.tables.candidates[:1]
        self._cand_pad_j = jnp.asarray(
            np.concatenate([self.tables.candidates, cand_pad], axis=0))
        self._cidx_pad_j = jnp.asarray(np.concatenate(
            [self.tables.cand_index, np.full((1, q), -1, np.int32)], axis=0))
        self._starts_j = jnp.asarray(packed.starts)
        self._sinks_j = jnp.asarray(packed.sinks)

        self._traces = 0
        # bound methods: the classes buffer is traced argument 0 (donation is
        # unsupported on CPU and would warn there)
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._spec_fn = jax.jit(self._spec_impl, donate_argnums=donate)
        self._seq_fn = jax.jit(self._seq_impl, donate_argnums=donate)

    # -- properties ---------------------------------------------------------

    @property
    def n_patterns(self) -> int:
        return self.packed.n_patterns

    @property
    def trace_count(self) -> int:
        """Number of shapes compiled so far (increments once per retrace)."""
        return self._traces

    # -- jitted bucket bodies ----------------------------------------------

    def _spec_impl(self, classes: jnp.ndarray) -> jnp.ndarray:
        """Fused chunk/candidate-gather/match/merge for one [B, C*Lc] bucket."""
        from ..kernels import ops as kops
        from ..kernels import ref as kref

        self._traces += 1  # side effect fires at trace time only
        b = classes.shape[0]
        c = self.num_chunks
        k, s = self.packed.n_patterns, self.tables.i_max
        body = classes.reshape(b, c, -1)
        la = jnp.concatenate(
            [jnp.zeros((b, 1), jnp.int32), body[:, :-1, -1]], axis=1)
        cand = self._cand_pad_j[la[:, 1:]]                     # [B, C-1, K, S]
        start = jnp.broadcast_to(
            self._starts_j[None, None, :, None], (b, 1, k, s))
        init = jnp.concatenate([start, cand], axis=1).reshape(b, c, k * s)
        fn = kops.spec_match_merge if self.use_kernel else kref.spec_match_merge_ref
        return fn(self._table_pad_j, body, init, la, self._cidx_pad_j,
                  self._sinks_j, pad_cls=self.pad_cls)

    def _seq_impl(self, classes: jnp.ndarray) -> jnp.ndarray:
        """Batched Algorithm 1 for short documents: one scan, [B, K] finals."""
        self._traces += 1
        b = classes.shape[0]
        s0 = jnp.broadcast_to(
            self._starts_j[None, :], (b, self.packed.n_patterns)).astype(jnp.int32)

        def step(st, col):  # st [B, K], col [B]
            return self._table_pad_j[st, col[:, None]], None

        out, _ = jax.lax.scan(step, s0, classes.T)
        return out

    # -- public API ---------------------------------------------------------

    def classes(self, doc: bytes | np.ndarray) -> np.ndarray:
        return self.packed.classes_of(doc).astype(np.int32)

    def membership_batch(self, docs: Sequence[bytes | np.ndarray]) -> BatchResult:
        """Match every doc against every packed pattern; no per-doc syncs.

        Returns a ``BatchResult`` whose decisions are bit-identical to running
        each document through sequential matching per pattern.
        """
        b = len(docs)
        k = self.packed.n_patterns
        if b == 0:
            z = np.zeros(0, np.int64)
            return BatchResult(np.zeros((0, k), bool), np.zeros((0, k), np.int32),
                               z, z, z, 0)
        cls_list = [self.classes(d) for d in docs]
        lengths = np.array([c.shape[0] for c in cls_list], np.int64)
        finals = np.tile(self.packed.starts, (b, 1)).astype(np.int32)
        spec = (lengths >= 4 * self.num_chunks) & (self.num_chunks > 1)
        calls = 0

        def dispatch(fn, idx: np.ndarray, width: int) -> int:
            """Run ``idx`` docs through ``fn`` in fixed [batch_tile, width]
            slabs (rows always pad to the tile, so the compiled shape depends
            only on ``width``); writes ``finals`` rows, returns call count."""
            n_calls = 0
            for lo in range(0, idx.size, self.batch_tile):
                sel = idx[lo:lo + self.batch_tile]
                buf = np.full((self.batch_tile, width), self.pad_cls, np.int32)
                for r, i in enumerate(sel):
                    buf[r, :lengths[i]] = cls_list[i]
                out = np.asarray(fn(jnp.asarray(buf)))
                finals[sel] = out[:sel.size]
                n_calls += 1
            return n_calls

        seq_idx = np.flatnonzero(~spec)
        if seq_idx.size and int(lengths[seq_idx].max()) > 0:
            lmax = int(lengths[seq_idx].max())
            if lmax > self._seq_width:  # only reachable when num_chunks <= 1
                self._seq_width = _next_pow2(lmax)
            calls += dispatch(self._seq_fn, seq_idx, self._seq_width)

        spec_idx = np.flatnonzero(spec)
        chunk_len = np.zeros(b, np.int64)
        if spec_idx.size:
            c = self.num_chunks
            lc = np.array([_next_pow2(-(-int(n) // c)) for n in lengths[spec_idx]])
            # snap each doc up into an already-compiled bucket when one fits
            known = sorted(self._spec_keys)
            for j, v in enumerate(lc):
                fit = [key for key in known if key >= v]
                if fit:
                    lc[j] = fit[0]
            # fresh keys: merge smallest upward until within the lifetime
            # shape budget (always allowing at least one new key so oversized
            # documents can still be matched)
            fresh = sorted(set(lc.tolist()) - set(known))
            allowed = max(1, self.max_buckets - len(known))
            while len(fresh) > allowed:
                lc[lc == fresh[0]] = fresh[1]
                fresh.pop(0)
            self._spec_keys = sorted(set(known) | set(fresh))
            for key in sorted(set(lc.tolist())):
                sel = spec_idx[lc == key]
                chunk_len[sel] = key
                calls += dispatch(self._spec_fn, sel, c * key)

        accepted = self.packed.accepting[finals]
        lanes = k * self.tables.i_max
        work_par = np.where(spec, chunk_len * lanes, lengths * k)
        steps = np.where(spec, chunk_len, lengths)
        return BatchResult(accepted, finals, work_par, lengths * k, steps, calls)

    def accepts_batch(self, docs: Sequence[bytes | np.ndarray]) -> np.ndarray:
        """[B, K] accept matrix (convenience wrapper)."""
        return self.membership_batch(docs).accepted
