"""Regex -> NFA (Thompson construction) over compressed byte classes.

Supported syntax (the subset needed for PCRE-style benchmark patterns and the
PROSITE protein patterns of the paper's evaluation):

  literals, ``\\`` escapes (``\\n \\t \\r \\d \\D \\w \\W \\s \\S`` + punct),
  ``.`` (any byte), character classes ``[a-z0-9]`` / negated ``[^...]``,
  grouping ``( )``, alternation ``|``, quantifiers ``* + ? {m} {m,} {m,n}``.

Anchors are intentionally not supported: the engine implements the paper's
membership / search semantics (see ``make_search_dfa``).

The parser first collects every leaf byte-set of the AST, refines a partition
of 0..255 into equivalence classes, and emits NFA transitions over class ids.
This keeps downstream DFA tables at ``[Q, n_classes]`` with n_classes usually
far below 256 — the property that lets the Pallas kernel pin the table in VMEM.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .automata import NFA

__all__ = ["parse_regex", "regex_to_nfa", "prosite_to_regex"]


# --------------------------------------------------------------------------
# AST
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Node:
    pass


@dataclasses.dataclass
class Lit(Node):
    byteset: frozenset[int]  # set of accepted byte values


@dataclasses.dataclass
class Concat(Node):
    parts: list[Node]


@dataclasses.dataclass
class Alt(Node):
    options: list[Node]


@dataclasses.dataclass
class Repeat(Node):
    child: Node
    lo: int
    hi: Optional[int]  # None = unbounded


_DIGITS = frozenset(range(ord("0"), ord("9") + 1))
_WORD = frozenset(
    set(range(ord("a"), ord("z") + 1))
    | set(range(ord("A"), ord("Z") + 1))
    | set(range(ord("0"), ord("9") + 1))
    | {ord("_")}
)
_SPACE = frozenset({ord(" "), ord("\t"), ord("\n"), ord("\r"), ord("\f"), ord("\v")})
_ALL = frozenset(range(256))

_ESCAPES = {
    "n": frozenset({ord("\n")}),
    "t": frozenset({ord("\t")}),
    "r": frozenset({ord("\r")}),
    "f": frozenset({ord("\f")}),
    "v": frozenset({ord("\v")}),
    "0": frozenset({0}),
    "d": _DIGITS,
    "D": _ALL - _DIGITS,
    "w": _WORD,
    "W": _ALL - _WORD,
    "s": _SPACE,
    "S": _ALL - _SPACE,
}


class _Parser:
    def __init__(self, pattern: str):
        self.p = pattern
        self.i = 0

    def error(self, msg: str) -> Exception:
        return ValueError(f"regex error at {self.i} in {self.p!r}: {msg}")

    def peek(self) -> str:
        return self.p[self.i] if self.i < len(self.p) else ""

    def take(self) -> str:
        ch = self.peek()
        self.i += 1
        return ch

    # alternation := concat ('|' concat)*
    def parse_alt(self) -> Node:
        opts = [self.parse_concat()]
        while self.peek() == "|":
            self.take()
            opts.append(self.parse_concat())
        return opts[0] if len(opts) == 1 else Alt(opts)

    def parse_concat(self) -> Node:
        parts: list[Node] = []
        while self.peek() not in ("", "|", ")"):
            parts.append(self.parse_repeat())
        if not parts:
            return Concat([])  # empty string
        return parts[0] if len(parts) == 1 else Concat(parts)

    def parse_repeat(self) -> Node:
        atom = self.parse_atom()
        while True:
            ch = self.peek()
            if ch == "*":
                self.take()
                atom = Repeat(atom, 0, None)
            elif ch == "+":
                self.take()
                atom = Repeat(atom, 1, None)
            elif ch == "?":
                self.take()
                atom = Repeat(atom, 0, 1)
            elif ch == "{":
                save = self.i
                rep = self._try_counted()
                if rep is None:
                    self.i = save
                    break
                atom = Repeat(atom, rep[0], rep[1])
            else:
                break
        return atom

    def _try_counted(self) -> Optional[tuple[int, Optional[int]]]:
        assert self.take() == "{"
        lo = ""
        while self.peek().isdigit():
            lo += self.take()
        if not lo:
            return None
        if self.peek() == "}":
            self.take()
            return int(lo), int(lo)
        if self.peek() != ",":
            return None
        self.take()
        hi = ""
        while self.peek().isdigit():
            hi += self.take()
        if self.peek() != "}":
            return None
        self.take()
        return int(lo), (int(hi) if hi else None)

    def parse_atom(self) -> Node:
        ch = self.take()
        if ch == "(":
            # non-capturing group marker (?: is accepted and ignored
            if self.peek() == "?" and self.i + 1 < len(self.p) and self.p[self.i + 1] == ":":
                self.take(); self.take()
            node = self.parse_alt()
            if self.take() != ")":
                raise self.error("unbalanced parenthesis")
            return node
        if ch == "[":
            return self.parse_class()
        if ch == ".":
            return Lit(_ALL)
        if ch == "\\":
            return Lit(self.parse_escape())
        if ch in ("*", "+", "?", "{", ")", "|", ""):
            raise self.error(f"unexpected {ch!r}")
        return Lit(frozenset({ord(ch)}))

    def parse_escape(self) -> frozenset[int]:
        ch = self.take()
        if not ch:
            raise self.error("dangling escape")
        if ch in _ESCAPES:
            return _ESCAPES[ch]
        if ch == "x":
            hx = self.take() + self.take()
            return frozenset({int(hx, 16)})
        return frozenset({ord(ch)})

    def parse_class(self) -> Node:
        negate = False
        if self.peek() == "^":
            self.take()
            negate = True
        members: set[int] = set()
        first = True
        while True:
            ch = self.peek()
            if ch == "":
                raise self.error("unterminated character class")
            if ch == "]" and not first:
                self.take()
                break
            first = False
            if ch == "\\":
                self.take()
                members |= self.parse_escape()
                continue
            self.take()
            lo = ord(ch)
            if self.peek() == "-" and self.i + 1 < len(self.p) and self.p[self.i + 1] != "]":
                self.take()
                hi_ch = self.take()
                if hi_ch == "\\":
                    hi_set = self.parse_escape()
                    if len(hi_set) != 1:
                        raise self.error("bad range bound")
                    hi = next(iter(hi_set))
                else:
                    hi = ord(hi_ch)
                if hi < lo:
                    raise self.error("reversed range")
                members |= set(range(lo, hi + 1))
            else:
                members.add(lo)
        byteset = frozenset(members)
        return Lit(_ALL - byteset if negate else byteset)


def parse_regex(pattern: str) -> Node:
    p = _Parser(pattern)
    node = p.parse_alt()
    if p.i != len(pattern):
        raise p.error("trailing input")
    return node


# --------------------------------------------------------------------------
# Byte-class compression
# --------------------------------------------------------------------------

def _collect_leaf_sets(node: Node, out: list[frozenset[int]]) -> None:
    if isinstance(node, Lit):
        out.append(node.byteset)
    elif isinstance(node, Concat):
        for n in node.parts:
            _collect_leaf_sets(n, out)
    elif isinstance(node, Alt):
        for n in node.options:
            _collect_leaf_sets(n, out)
    elif isinstance(node, Repeat):
        _collect_leaf_sets(node.child, out)


def _byte_classes(leaf_sets: list[frozenset[int]]) -> np.ndarray:
    """Partition 0..255 by the signature of leaf-set membership."""
    sig = np.zeros(256, dtype=np.int64)
    for k, s in enumerate(set(leaf_sets)):
        mask = np.zeros(256, dtype=bool)
        mask[list(s)] = True
        sig = sig * 2 + mask  # may overflow for >62 distinct sets -> use tuple below
    if len(set(leaf_sets)) > 60:
        sigs = [tuple(b in s for s in set(leaf_sets)) for b in range(256)]
        uniq = {t: i for i, t in enumerate(dict.fromkeys(sigs))}
        return np.array([uniq[t] for t in sigs], dtype=np.int32)
    _, inv = np.unique(sig, return_inverse=True)
    return inv.astype(np.int32)


# --------------------------------------------------------------------------
# Thompson construction
# --------------------------------------------------------------------------

class _Builder:
    def __init__(self, byte_to_class: np.ndarray, n_classes: int):
        self.b2c = byte_to_class
        self.n_classes = n_classes
        self.transitions: list[list[tuple[int, int]]] = []

    def new_state(self) -> int:
        self.transitions.append([])
        return len(self.transitions) - 1

    def add(self, s: int, cls: int, t: int) -> None:
        self.transitions[s].append((cls, t))

    def classes_for(self, byteset: frozenset[int]) -> set[int]:
        return {int(self.b2c[b]) for b in byteset}

    def build(self, node: Node) -> tuple[int, int]:
        """Return (entry, exit) fragment states."""
        if isinstance(node, Lit):
            a, b = self.new_state(), self.new_state()
            for cls in self.classes_for(node.byteset):
                self.add(a, cls, b)
            return a, b
        if isinstance(node, Concat):
            if not node.parts:
                a = self.new_state()
                return a, a
            entry, cur = self.build(node.parts[0])
            for part in node.parts[1:]:
                nxt_in, nxt_out = self.build(part)
                self.add(cur, -1, nxt_in)
                cur = nxt_out
            return entry, cur
        if isinstance(node, Alt):
            a, b = self.new_state(), self.new_state()
            for opt in node.options:
                i, o = self.build(opt)
                self.add(a, -1, i)
                self.add(o, -1, b)
            return a, b
        if isinstance(node, Repeat):
            lo, hi = node.lo, node.hi
            a = self.new_state()
            cur = a
            for _ in range(lo):
                i, o = self.build(node.child)
                self.add(cur, -1, i)
                cur = o
            if hi is None:  # unbounded tail: child*
                i, o = self.build(node.child)
                self.add(cur, -1, i)
                self.add(o, -1, cur)
                return a, cur
            end = self.new_state()
            self.add(cur, -1, end)
            for _ in range(hi - lo):
                i, o = self.build(node.child)
                self.add(cur, -1, i)
                cur = o
                self.add(cur, -1, end)
            return a, end
        raise TypeError(f"unknown node {node!r}")


def regex_to_nfa(pattern: str) -> NFA:
    ast = parse_regex(pattern)
    leaves: list[frozenset[int]] = []
    _collect_leaf_sets(ast, leaves)
    if not leaves:
        leaves = [_ALL]
    b2c = _byte_classes(leaves)
    n_classes = int(b2c.max()) + 1
    builder = _Builder(b2c, n_classes)
    entry, exit_ = builder.build(ast)
    return NFA(
        n_states=len(builder.transitions),
        start=entry,
        accepts=frozenset({exit_}),
        transitions=builder.transitions,
        n_classes=n_classes,
        byte_to_class=b2c,
    )


# --------------------------------------------------------------------------
# PROSITE pattern syntax (paper Sec. 6 benchmark suite)
# --------------------------------------------------------------------------

def prosite_to_regex(pattern: str) -> str:
    """Convert PROSITE notation to the regex subset above.

    Example: ``C-x(2,4)-C-x(3)-[LIVMFYWC]-x(8)-H-x(3,5)-H``.
    ``x`` = any amino acid, ``[..]`` class, ``{..}`` negated class, ``(n[,m])``
    repetition, ``<``/``>`` anchors (stripped: engine uses search semantics),
    trailing ``.`` terminator stripped.
    """
    pat = pattern.strip().rstrip(".")
    pat = pat.lstrip("<").rstrip(">")
    out: list[str] = []
    for element in pat.split("-"):
        element = element.strip()
        if not element:
            continue
        rep = ""
        if "(" in element:
            element, rep_body = element.split("(", 1)
            rep_body = rep_body.rstrip(")")
            rep = "{" + rep_body + "}"
        if element == "x":
            core = "[A-Z]"
        elif element.startswith("[") and element.endswith("]"):
            core = element
        elif element.startswith("{") and element.endswith("}"):
            core = "[^" + element[1:-1] + "]"
        elif len(element) == 1 and element.isalpha():
            core = element
        else:
            raise ValueError(f"bad PROSITE element {element!r} in {pattern!r}")
        out.append(core + rep)
    return "".join(out)
