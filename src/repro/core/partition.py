"""Weighted input partitioning (paper Eqs. 1–7, Sec. 4.1).

Chunk 0 is matched from q0 only; chunks 1..P-1 are matched speculatively for
``m`` states (``m = |Q|`` basic / ``I_max,r`` optimized).  To equalize work,
chunk 0 is ``m``x longer (Eq. 2); processor capacity weights ``w_k`` (Eq. 1)
scale every chunk.  This is the paper's failure-freedom mechanism: total
symbols matched per processor are equal, so the parallel run can never lose to
the sequential one by more than the merge epsilon.

Used at the *host/data-pipeline* level, where shards may be ragged.  Device-
level SPMD matching uses uniform chunks with masked speculative lanes (see
DESIGN.md §2); both partitioners live here so the equations are in one place.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Partition", "weighted_partition", "uniform_partition", "capacity_weights"]


@dataclasses.dataclass
class Partition:
    start: np.ndarray  # [P] int64, inclusive
    end: np.ndarray    # [P] int64, exclusive
    m: int             # states matched per speculative chunk

    @property
    def sizes(self) -> np.ndarray:
        return self.end - self.start

    def work(self) -> np.ndarray:
        """Symbols matched per processor (speculative chunks match m states)."""
        w = self.sizes.astype(np.float64).copy()
        w[1:] *= self.m
        return w


def capacity_weights(m_k: np.ndarray) -> np.ndarray:
    """Eq. 1: normalize measured capacities by the mean capacity."""
    m_k = np.asarray(m_k, dtype=np.float64)
    if (m_k <= 0).any():
        raise ValueError("capacities must be positive")
    return m_k / m_k.mean()


def weighted_partition(n: int, weights: np.ndarray, m: int) -> Partition:
    """Eqs. 5–7 with m = |Q| or I_max,r; returns [start, end) per processor.

    Degenerate chunks (size 0) are legal for tiny inputs; the matcher treats
    them as identity L-vectors.
    """
    w = np.asarray(weights, dtype=np.float64)
    p = w.shape[0]
    if p < 1:
        raise ValueError("need at least one processor")
    if m < 1:
        raise ValueError("m must be >= 1")
    if p == 1:
        return Partition(start=np.array([0]), end=np.array([n]), m=m)
    # Eq. 5
    l0 = n * m / (w[0] * m + w[1:].sum())
    start = np.zeros(p, dtype=np.int64)
    end = np.zeros(p, dtype=np.int64)
    # Eq. 6/7; boundary_k = L0 * (w0 + (1/m) * sum_{1<=i<=k} w_i).  Cumulate
    # the weights first and multiply by L0 once: the running-sum form
    # ``acc += l0 * w_i / m`` drifts by ulps, enough to disagree with
    # ``uniform_partition`` by one symbol under equal capacities (the
    # degradation is exact with this formulation — tests rely on it).
    bounds = l0 * (w[0] + np.concatenate([[0.0], np.cumsum(w[1:])]) / m)
    prev = 0
    for k in range(p):
        start[k] = prev
        end[k] = n if k == p - 1 else min(n, int(np.floor(bounds[k])))
        end[k] = max(end[k], start[k])
        prev = end[k]
    return Partition(start=start, end=end, m=m)


def uniform_partition(n: int, p: int, m: int) -> Partition:
    """Equal-size chunks (paper Fig. 3; also the SPMD device-level layout)."""
    edges = np.linspace(0, n, p + 1).astype(np.int64)
    return Partition(start=edges[:-1], end=edges[1:], m=m)
