"""Finite-automata data structures.

The DFA representation mirrors the paper's flattened ``SBase`` layout (Fig. 8c):
a dense row-major transition table ``table[Q, n_classes]`` of ``int32`` state ids,
plus a byte->class map (``byte_to_class``, the paper's ``IBase`` symbol mapping,
Fig. 8d) so that arbitrary byte inputs index a compressed alphabet.  Alphabet
compression (merging byte columns with identical behaviour) is standard lexer
practice (RE2/flex) and is what makes the transition table small enough to pin
in TPU VMEM; the paper uses the same idea when it maps characters to integers.

States are integers ``0..Q-1``.  ``sink`` is the unique error state q_e: a
non-accepting state whose every outgoing transition is a self-loop.  Every DFA
built by this package is *complete* (total transition function) so the matching
loop is branch-free, exactly as in the paper's Listing 1.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterable, Sequence

import numpy as np

__all__ = ["NFA", "DFA", "PackedDFA", "make_search_dfa", "pack_dfas",
           "packed_signature", "random_dfa"]


@dataclasses.dataclass
class NFA:
    """Thompson-construction NFA over compressed byte classes.

    ``transitions[s]`` is a list of ``(cls, target)`` with ``cls == -1`` for
    epsilon moves.  ``n_classes`` byte classes; ``byte_to_class`` maps raw bytes
    to class ids.
    """

    n_states: int
    start: int
    accepts: frozenset[int]
    transitions: list[list[tuple[int, int]]]
    n_classes: int
    byte_to_class: np.ndarray  # [256] int32

    def eps_closure(self, states: Iterable[int]) -> frozenset[int]:
        stack = list(states)
        seen = set(stack)
        while stack:
            s = stack.pop()
            for cls, t in self.transitions[s]:
                if cls == -1 and t not in seen:
                    seen.add(t)
                    stack.append(t)
        return frozenset(seen)

    def step(self, states: Iterable[int], cls: int) -> frozenset[int]:
        out: set[int] = set()
        for s in states:
            for c, t in self.transitions[s]:
                if c == cls:
                    out.add(t)
        return self.eps_closure(out)


@dataclasses.dataclass
class DFA:
    """Complete DFA with a dense transition table (paper Fig. 8c layout)."""

    table: np.ndarray  # [Q, n_classes] int32, complete
    accepting: np.ndarray  # [Q] bool
    start: int
    sink: int  # error state q_e; -1 if the DFA has no dead state
    byte_to_class: np.ndarray  # [256] int32

    @property
    def n_states(self) -> int:
        return int(self.table.shape[0])

    @property
    def n_classes(self) -> int:
        return int(self.table.shape[1])

    def __post_init__(self) -> None:
        self.table = np.asarray(self.table, dtype=np.int32)
        self.accepting = np.asarray(self.accepting, dtype=bool)
        self.byte_to_class = np.asarray(self.byte_to_class, dtype=np.int32)
        q, c = self.table.shape
        if not ((0 <= self.table).all() and (self.table < q).all()):
            raise ValueError("transition table references out-of-range states")
        if self.byte_to_class.shape != (256,):
            raise ValueError("byte_to_class must have shape [256]")
        if not ((0 <= self.byte_to_class).all() and (self.byte_to_class < c).all()):
            raise ValueError("byte_to_class references out-of-range classes")

    # -- host-side reference semantics (the paper's Algorithm 1) ------------

    def classes_of(self, data: bytes | np.ndarray) -> np.ndarray:
        arr = np.frombuffer(data, dtype=np.uint8) if isinstance(data, (bytes, bytearray)) else np.asarray(data)
        return self.byte_to_class[arr.astype(np.int64)]

    def run(self, data: bytes | np.ndarray, state: int | None = None) -> int:
        """delta*(state, data) computed sequentially on host (oracle)."""
        s = self.start if state is None else state
        for cls in self.classes_of(data):
            s = int(self.table[s, cls])
        return s

    def accepts(self, data: bytes | np.ndarray) -> bool:
        return bool(self.accepting[self.run(data)])

    def flat_table(self) -> np.ndarray:
        """Paper's SBase: 1-D flattened table; state ids pre-scaled by n_classes.

        ``flat[s * n_classes + cls]`` already contains ``next_state * n_classes``
        so the matching loop is a single add + gather per symbol (Listing 1).
        """
        return (self.table.astype(np.int64) * self.n_classes).astype(np.int32).reshape(-1)

    def find_sink(self) -> int:
        """Locate the error state if present (non-accepting, all self-loops)."""
        for s in range(self.n_states):
            if not self.accepting[s] and (self.table[s] == s).all():
                return s
        return -1


@dataclasses.dataclass
class PackedDFA:
    """K DFAs stacked into one transition table over a joint class alphabet.

    The packed table is the multi-pattern analogue of the paper's flattened
    ``SBase`` (Fig. 8c): pattern k's states live at ids
    ``offsets[k] .. offsets[k+1]-1`` and every table entry is already a packed
    id, so K patterns advance through one shared gather — lanes become
    chunks x candidates x patterns (cf. simultaneous-FA matching,
    arXiv:1405.0562).

    The joint alphabet is the product refinement of the per-pattern byte
    classifications (``IBase``): two bytes share a joint class iff they share
    a class under *every* pattern, so one class stream per document drives all
    K patterns.  ``n_classes`` is the refined count (<= 256).
    """

    table: np.ndarray          # [Q_total, n_classes] int32, packed state ids
    accepting: np.ndarray      # [Q_total] bool
    starts: np.ndarray         # [K] int32 packed start states
    sinks: np.ndarray          # [K] int32 packed sink ids; -1 = no dead state
    offsets: np.ndarray        # [K+1] int32 state-id offset per pattern
    byte_to_class: np.ndarray  # [256] int32 joint classes

    @property
    def n_states(self) -> int:
        return int(self.table.shape[0])

    @property
    def n_classes(self) -> int:
        return int(self.table.shape[1])

    @property
    def n_patterns(self) -> int:
        return int(self.starts.shape[0])

    def __post_init__(self) -> None:
        self.table = np.asarray(self.table, dtype=np.int32)
        self.accepting = np.asarray(self.accepting, dtype=bool)
        self.starts = np.asarray(self.starts, dtype=np.int32)
        self.sinks = np.asarray(self.sinks, dtype=np.int32)
        self.offsets = np.asarray(self.offsets, dtype=np.int32)
        self.byte_to_class = np.asarray(self.byte_to_class, dtype=np.int32)

    def pattern_slice(self, k: int) -> slice:
        return slice(int(self.offsets[k]), int(self.offsets[k + 1]))

    def classes_of(self, data: bytes | np.ndarray) -> np.ndarray:
        arr = (np.frombuffer(data, dtype=np.uint8)
               if isinstance(data, (bytes, bytearray)) else np.asarray(data))
        return self.byte_to_class[arr.astype(np.int64)]

    def run_all(self, data: bytes | np.ndarray) -> np.ndarray:
        """Host oracle: final packed state of every pattern, sequentially."""
        states = self.starts.copy()
        for cls in self.classes_of(data):
            states = self.table[states, cls]
        return states

    def accepts_all(self, data: bytes | np.ndarray) -> np.ndarray:
        return self.accepting[self.run_all(data)]


def pack_dfas(dfas: Sequence[DFA]) -> PackedDFA:
    """Stack K DFAs into one ``PackedDFA`` (joint classes + offset state ids)."""
    if not dfas:
        raise ValueError("pack_dfas needs at least one DFA")
    keys = np.stack([d.byte_to_class for d in dfas], axis=1)       # [256, K]
    uniq, joint = np.unique(keys, axis=0, return_inverse=True)     # joint ids
    byte_to_class = joint.astype(np.int32)
    offsets = np.concatenate(
        [[0], np.cumsum([d.n_states for d in dfas])]).astype(np.int32)
    tables = []
    for k, d in enumerate(dfas):
        col_map = uniq[:, k]                   # joint class -> pattern-k class
        tables.append(d.table[:, col_map].astype(np.int64) + int(offsets[k]))
    starts = np.array([int(offsets[k]) + d.start
                       for k, d in enumerate(dfas)], np.int32)
    sinks = np.array([int(offsets[k]) + d.sink if d.sink >= 0 else -1
                      for k, d in enumerate(dfas)], np.int32)
    return PackedDFA(table=np.concatenate(tables).astype(np.int32),
                     accepting=np.concatenate([d.accepting for d in dfas]),
                     starts=starts, sinks=sinks, offsets=offsets,
                     byte_to_class=byte_to_class)


def packed_signature(packed: PackedDFA) -> str:
    """Content hash of a packed pattern block.

    Two ``PackedDFA``s with equal signatures are byte-for-byte the same
    automaton: every array that determines matching behaviour (and state-id
    layout, which streaming cursors depend on) is folded in, shapes included.
    Used as the identity for block-level lowering reuse across
    ``swap_patterns`` and for checkpoint compatibility checks.
    """
    h = hashlib.sha1()
    for arr in (packed.table, packed.accepting, packed.starts, packed.sinks,
                packed.offsets, packed.byte_to_class):
        a = np.ascontiguousarray(arr)
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def make_search_dfa(dfa: DFA) -> DFA:
    """Convert membership semantics to search semantics (paper Sec. 6 usage).

    Algorithm 1 returns *true* as soon as a final state is entered — i.e. it
    tests whether any prefix matches.  Making accepting states absorbing gives
    the identical result while preserving the clean L-vector algebra (a sticky
    accept is just an absorbing accept state).
    """
    table = dfa.table.copy()
    for s in np.flatnonzero(dfa.accepting):
        table[s, :] = s
    return DFA(table=table, accepting=dfa.accepting.copy(), start=dfa.start,
               sink=dfa.sink, byte_to_class=dfa.byte_to_class.copy())


def random_dfa(n_states: int, n_classes: int, *, rng: np.random.Generator,
               accept_frac: float = 0.2, with_sink: bool = True) -> DFA:
    """Random complete DFA for property tests and capacity profiling."""
    if n_states < 2:
        raise ValueError("need at least 2 states")
    table = rng.integers(0, n_states, size=(n_states, n_classes), dtype=np.int32)
    accepting = rng.random(n_states) < accept_frac
    sink = -1
    if with_sink:
        sink = n_states - 1
        table[sink, :] = sink
        accepting[sink] = False
    accepting[0] = False  # start state non-accepting keeps tests interesting
    byte_to_class = rng.integers(0, n_classes, size=256, dtype=np.int32)
    # Guarantee every class is reachable from some byte so inputs exercise all.
    byte_to_class[:n_classes] = np.arange(n_classes, dtype=np.int32)
    return DFA(table=table, accepting=accepting, start=0, sink=sink,
               byte_to_class=byte_to_class)
