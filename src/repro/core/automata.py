"""Finite-automata data structures.

The DFA representation mirrors the paper's flattened ``SBase`` layout (Fig. 8c):
a dense row-major transition table ``table[Q, n_classes]`` of ``int32`` state ids,
plus a byte->class map (``byte_to_class``, the paper's ``IBase`` symbol mapping,
Fig. 8d) so that arbitrary byte inputs index a compressed alphabet.  Alphabet
compression (merging byte columns with identical behaviour) is standard lexer
practice (RE2/flex) and is what makes the transition table small enough to pin
in TPU VMEM; the paper uses the same idea when it maps characters to integers.

States are integers ``0..Q-1``.  ``sink`` is the unique error state q_e: a
non-accepting state whose every outgoing transition is a self-loop.  Every DFA
built by this package is *complete* (total transition function) so the matching
loop is branch-free, exactly as in the paper's Listing 1.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = ["NFA", "DFA", "make_search_dfa", "random_dfa"]


@dataclasses.dataclass
class NFA:
    """Thompson-construction NFA over compressed byte classes.

    ``transitions[s]`` is a list of ``(cls, target)`` with ``cls == -1`` for
    epsilon moves.  ``n_classes`` byte classes; ``byte_to_class`` maps raw bytes
    to class ids.
    """

    n_states: int
    start: int
    accepts: frozenset[int]
    transitions: list[list[tuple[int, int]]]
    n_classes: int
    byte_to_class: np.ndarray  # [256] int32

    def eps_closure(self, states: Iterable[int]) -> frozenset[int]:
        stack = list(states)
        seen = set(stack)
        while stack:
            s = stack.pop()
            for cls, t in self.transitions[s]:
                if cls == -1 and t not in seen:
                    seen.add(t)
                    stack.append(t)
        return frozenset(seen)

    def step(self, states: Iterable[int], cls: int) -> frozenset[int]:
        out: set[int] = set()
        for s in states:
            for c, t in self.transitions[s]:
                if c == cls:
                    out.add(t)
        return self.eps_closure(out)


@dataclasses.dataclass
class DFA:
    """Complete DFA with a dense transition table (paper Fig. 8c layout)."""

    table: np.ndarray  # [Q, n_classes] int32, complete
    accepting: np.ndarray  # [Q] bool
    start: int
    sink: int  # error state q_e; -1 if the DFA has no dead state
    byte_to_class: np.ndarray  # [256] int32

    @property
    def n_states(self) -> int:
        return int(self.table.shape[0])

    @property
    def n_classes(self) -> int:
        return int(self.table.shape[1])

    def __post_init__(self) -> None:
        self.table = np.asarray(self.table, dtype=np.int32)
        self.accepting = np.asarray(self.accepting, dtype=bool)
        self.byte_to_class = np.asarray(self.byte_to_class, dtype=np.int32)
        q, c = self.table.shape
        if not ((0 <= self.table).all() and (self.table < q).all()):
            raise ValueError("transition table references out-of-range states")
        if self.byte_to_class.shape != (256,):
            raise ValueError("byte_to_class must have shape [256]")
        if not ((0 <= self.byte_to_class).all() and (self.byte_to_class < c).all()):
            raise ValueError("byte_to_class references out-of-range classes")

    # -- host-side reference semantics (the paper's Algorithm 1) ------------

    def classes_of(self, data: bytes | np.ndarray) -> np.ndarray:
        arr = np.frombuffer(data, dtype=np.uint8) if isinstance(data, (bytes, bytearray)) else np.asarray(data)
        return self.byte_to_class[arr.astype(np.int64)]

    def run(self, data: bytes | np.ndarray, state: int | None = None) -> int:
        """delta*(state, data) computed sequentially on host (oracle)."""
        s = self.start if state is None else state
        for cls in self.classes_of(data):
            s = int(self.table[s, cls])
        return s

    def accepts(self, data: bytes | np.ndarray) -> bool:
        return bool(self.accepting[self.run(data)])

    def flat_table(self) -> np.ndarray:
        """Paper's SBase: 1-D flattened table; state ids pre-scaled by n_classes.

        ``flat[s * n_classes + cls]`` already contains ``next_state * n_classes``
        so the matching loop is a single add + gather per symbol (Listing 1).
        """
        return (self.table.astype(np.int64) * self.n_classes).astype(np.int32).reshape(-1)

    def find_sink(self) -> int:
        """Locate the error state if present (non-accepting, all self-loops)."""
        for s in range(self.n_states):
            if not self.accepting[s] and (self.table[s] == s).all():
                return s
        return -1


def make_search_dfa(dfa: DFA) -> DFA:
    """Convert membership semantics to search semantics (paper Sec. 6 usage).

    Algorithm 1 returns *true* as soon as a final state is entered — i.e. it
    tests whether any prefix matches.  Making accepting states absorbing gives
    the identical result while preserving the clean L-vector algebra (a sticky
    accept is just an absorbing accept state).
    """
    table = dfa.table.copy()
    for s in np.flatnonzero(dfa.accepting):
        table[s, :] = s
    return DFA(table=table, accepting=dfa.accepting.copy(), start=dfa.start,
               sink=dfa.sink, byte_to_class=dfa.byte_to_class.copy())


def random_dfa(n_states: int, n_classes: int, *, rng: np.random.Generator,
               accept_frac: float = 0.2, with_sink: bool = True) -> DFA:
    """Random complete DFA for property tests and capacity profiling."""
    if n_states < 2:
        raise ValueError("need at least 2 states")
    table = rng.integers(0, n_states, size=(n_states, n_classes), dtype=np.int32)
    accepting = rng.random(n_states) < accept_frac
    sink = -1
    if with_sink:
        sink = n_states - 1
        table[sink, :] = sink
        accepting[sink] = False
    accepting[0] = False  # start state non-accepting keeps tests interesting
    byte_to_class = rng.integers(0, n_classes, size=256, dtype=np.int32)
    # Guarantee every class is reachable from some byte so inputs exercise all.
    byte_to_class[:n_classes] = np.arange(n_classes, dtype=np.int32)
    return DFA(table=table, accepting=accepting, start=0, sink=sink,
               byte_to_class=byte_to_class)
