"""int8 gradient compression with error feedback for cross-pod all-reduce.

Cross-pod (DCI) bandwidth is the scarce resource at multi-pod scale; the pod
axis is pure DP so its gradient all-reduce moves full model-gradients every
step.  This module quantizes that exchange to int8 (4x less traffic) with
per-tensor scales and keeps the quantization residual in an error-feedback
buffer (Seide et al. / 1-bit SGD lineage), which restores convergence to the
uncompressed trajectory up to O(lr * residual) terms.

Used by training/train_loop.py when ``grad_compression="int8"``; the exchange
itself is an all-gather of int8 + local dequant-mean inside shard_map.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from ..jax_compat import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["init_error_state", "quantize", "dequantize",
           "compressed_pod_mean"]


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_pod_mean(grads, error, mesh, *, axis: str = "pod"):
    """Error-feedback int8 mean over ``axis``.  Returns (mean_grads, new_error).

    g_corrected = g + e;  q = Q(g_corrected);  e' = g_corrected - deQ(q);
    exchange q (int8) + scale, dequant-mean locally.
    Falls back to plain pmean when the axis is absent (single-pod mesh).
    """
    if axis not in mesh.axis_names:
        return grads, error

    def body(g, e):
        def per_leaf(gl, el):
            corrected = gl.astype(jnp.float32) + el
            q, scale = quantize(corrected)
            new_e = corrected - dequantize(q, scale)
            qs = jax.lax.all_gather(q, axis)            # [pods, ...] int8 wire
            scales = jax.lax.all_gather(scale, axis)
            mean = jnp.mean(qs.astype(jnp.float32)
                            * scales.reshape((-1,) + (1,) * gl.ndim), axis=0)
            return mean.astype(gl.dtype), new_e

        flat_g, treedef = jax.tree.flatten(g)
        flat_e = treedef.flatten_up_to(e)
        outs = [per_leaf(gl, el) for gl, el in zip(flat_g, flat_e)]
        return (treedef.unflatten([o[0] for o in outs]),
                treedef.unflatten([o[1] for o in outs]))

    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                   check_vma=False)
    return fn(grads, error)
