"""Topology-aware collectives: the paper's 2-tier merge, on the mesh hierarchy.

The paper found (Sec. 5.2) that on EC2 a flat binary-tree reduction of
L-vectors loses to a hierarchy that exploits the intra-node/inter-node
latency gap (2.7us vs 362us).  TPU pods have the same two-level structure:
ICI within a pod vs DCI across pods.  ``hierarchical_merge_lvecs`` merges
chunk maps over "data" (pod-local, ICI) first, then over "pod" (DCI) — only
one composition step crosses the slow tier, mirroring Fig. 9's node-leader /
master scheme.

``hierarchical_mean`` applies the same structure to gradient reduction:
reduce-scatter + all-gather inside the pod, single all-reduce across pods.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from ..jax_compat import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["hierarchical_merge_lvecs", "flat_merge_lvecs", "hierarchical_mean",
           "distributed_membership"]


def _fold_local(maps: jnp.ndarray) -> jnp.ndarray:
    """Compose [C_loc, Q] maps left-to-right (worker-local leaf reduction)."""

    def step(acc, m):
        return m[acc], None

    acc0 = jnp.arange(maps.shape[1], dtype=jnp.int32)
    out, _ = jax.lax.scan(step, acc0, maps)
    return out


def _fold_gathered(stacked: jnp.ndarray) -> jnp.ndarray:
    def step(acc, m):
        return m[acc], None

    acc0 = jnp.arange(stacked.shape[1], dtype=jnp.int32)
    out, _ = jax.lax.scan(step, acc0, stacked)
    return out


def hierarchical_merge_lvecs(maps: jnp.ndarray, mesh) -> jnp.ndarray:
    """maps [C_global, Q] (chunk-major, sharded over dp axes) -> global map [Q].

    Tier 0: each device folds its local chunk maps.
    Tier 1: all-gather + fold over "data"  (pod-local; paper's node leader).
    Tier 2: all-gather + fold over "pod"   (cross-pod; paper's master).
    """
    axes = [a for a in ("data", "pod") if a in mesh.axis_names]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(m_loc):
        acc = _fold_local(m_loc)
        for axis in axes:  # data (fast tier) first, pod (slow tier) last
            gathered = jax.lax.all_gather(acc, axis, axis=0, tiled=False)
            acc = _fold_gathered(gathered)
        return acc

    spec_in = P(dp, None) if dp else P(None, None)
    fn = shard_map(body, mesh=mesh, in_specs=(spec_in,), out_specs=P(None),
                   check_vma=False)
    return fn(maps)


def flat_merge_lvecs(maps: jnp.ndarray, mesh) -> jnp.ndarray:
    """Baseline: single flat all-gather over all dp axes, then fold.

    The comparison partner for the 2-tier scheme in benchmarks (Sec. 5.2).
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(m_loc):
        acc = _fold_local(m_loc)
        gathered = jax.lax.all_gather(acc, dp, axis=0, tiled=False)
        return _fold_gathered(gathered)

    spec_in = P(dp, None) if dp else P(None, None)
    fn = shard_map(body, mesh=mesh, in_specs=(spec_in,), out_specs=P(None),
                   check_vma=False)
    return fn(maps)


def hierarchical_mean(tree, mesh):
    """Two-tier gradient mean: psum over "data" (ICI) then "pod" (DCI)."""
    axes = [a for a in ("data", "pod") if a in mesh.axis_names]

    def body(t):
        for axis in axes:
            t = jax.tree.map(lambda g: jax.lax.pmean(g, axis), t)
        return t

    fn = shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                   check_vma=False)
    return fn(tree)


def distributed_membership(table: np.ndarray, classes: np.ndarray, start: int,
                           sink: int, accepting: np.ndarray, mesh,
                           num_chunks_per_device: int = 4) -> int:
    """End-to-end distributed DFA membership test (holub-style full maps).

    The corpus-scan integration point: the byte stream is chunked across all
    dp devices (uniform SPMD layout; host-level weighted partitioning happens
    in data/loader.py), each chunk's full state map is computed in parallel,
    and maps are merged with the 2-tier hierarchy.
    """
    import math

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    c = dp_size * num_chunks_per_device
    n = classes.shape[0]
    l = n // c
    body = jnp.asarray(classes[: l * c], jnp.int32).reshape(c, l)
    table_j = jnp.asarray(table)
    q = table.shape[0]

    dp_spec = P(dp, None) if dp else P(None, None)

    def chunk_maps(chunks_loc):
        init = jnp.broadcast_to(jnp.arange(q, dtype=jnp.int32),
                                (chunks_loc.shape[0], q))

        def step(states, cls_row):
            return table_j[states, cls_row[:, None]], None

        final, _ = jax.lax.scan(step, init, chunks_loc.T)
        return final

    maps = shard_map(chunk_maps, mesh=mesh, in_specs=(dp_spec,),
                     out_specs=dp_spec, check_vma=False)(body)
    total = hierarchical_merge_lvecs(maps, mesh)
    state = int(jax.device_get(total)[start])
    # sequential tail on host
    for cls in classes[l * c:]:
        state = int(table[state, int(cls)])
    return state
