"""GPipe-style pipeline parallelism over a mesh axis via collective_permute.

Optional parallelism mode (DESIGN.md §5): the layer stack is split into S
stages laid out on a ``stage`` mesh axis; microbatches stream through with
the classic (M + S - 1)-step schedule, activations hopping stages with
``ppermute``.  Bubble fraction = (S-1)/(M+S-1); compute/comm overlap comes
from XLA scheduling the permute of step t against stage compute of step t+1.

This module is self-contained so PP can be validated on small host meshes
(tests spawn an 8-device subprocess); wiring PP into the main trainer is a
config flag that reshapes (data, model) -> (data, stage, model).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from ..jax_compat import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn: Callable, stage_params, x_micro: jnp.ndarray,
                   mesh, *, axis: str = "stage") -> jnp.ndarray:
    """Run ``stage_fn(params_s, x)`` through S pipeline stages.

    stage_params : pytree with leading [S] dim (stage-major stack)
    x_micro      : [M, ...] microbatches
    Returns [M, ...] outputs of the final stage, in order.
    """
    s = mesh.shape[axis]
    m = x_micro.shape[0]
    steps = m + s - 1

    p_specs = jax.tree.map(lambda _: P(axis), stage_params)

    def body(params_loc, xs):
        params_loc = jax.tree.map(lambda a: a[0], params_loc)  # my stage
        idx = jax.lax.axis_index(axis)
        first = idx == 0
        last = idx == s - 1
        perm = [(i, i + 1) for i in range(s - 1)]

        buf = jnp.zeros_like(xs[0])              # activation held by my stage
        outs = jnp.zeros((m,) + xs.shape[1:], xs.dtype)

        def step(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (when available)
            feed = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, m - 1), axis=0, keepdims=False)
            inp = jnp.where(first, feed, buf)
            out = stage_fn(params_loc, inp)
            # the last stage banks its finished microbatch (t - (s-1))
            done_idx = t - (s - 1)
            outs = jax.lax.cond(
                jnp.logical_and(last, done_idx >= 0),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.maximum(done_idx, 0), axis=0),
                lambda o: o, outs)
            # hop activations one stage forward
            buf = jax.lax.ppermute(out, axis, perm)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, steps, step, (buf, outs))
        # only the last stage banked results; psum broadcasts them so the
        # replicated out_spec is honest (other stages hold zeros)
        return jax.lax.psum(outs, axis)

    fn = shard_map(body, mesh=mesh, in_specs=(p_specs, P()),
                   out_specs=P(), check_vma=False)
    return fn(stage_params, x_micro)
