"""Path-based PartitionSpec rules for params, optimizer state, batches, caches.

Divisibility-aware: every rule degrades to replication for any dimension the
mesh axis does not divide (e.g. recurrentgemma's 10 query heads on a 16-way
model axis fall back to head_dim sharding).  This keeps one rule set valid
across all 10 architectures and both meshes.

Conventions:
  * params: FSDP over "data" on the d_model-ish dim, TP over "model" on the
    heads/ff/vocab dim; MoE experts over "model" ONLY (must match the
    shard_map in_specs in models/moe.py); pods replicate params (pure DP).
  * stacked layer/group leading dims are never sharded.
  * activations/batches: batch over (pod, data); model-dim annotations are
    left to XLA propagation from the param shardings.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["param_specs", "batch_specs", "cache_specs", "state_specs",
           "named", "opt_state_specs", "matcher_table_specs",
           "matcher_chunk_specs", "matcher_lane_specs", "doc_batch_spec"]

STACK_KEYS = {"layers", "groups", "enc", "dec"}
MOE_EXPERT_KEYS = {"wi_gate", "wi_up", "wo"}


def _div(n: int, mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0 and n >= mesh.shape[axis]


def _dp(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fsdp_axis(mesh, n: int):
    return "data" if _div(n, mesh, "data") else None


def _tp_axis(mesh, n: int):
    return "model" if _div(n, mesh, "model") else None


def _leaf_spec(path_names: list[str], shape: tuple[int, ...], mesh,
               in_moe: bool) -> P:
    name = path_names[-1] if path_names else ""
    stacked = any(k in STACK_KEYS for k in path_names[:-1])
    core = _core_spec(name, shape[1:] if stacked else shape, mesh, in_moe)
    return P(None, *core) if stacked else P(*core)


def _core_spec(name: str, shape: tuple[int, ...], mesh, in_moe: bool) -> tuple:
    nd = len(shape)
    if in_moe and name in MOE_EXPERT_KEYS and nd == 3:
        # experts over model ONLY (shard_map contract in models/moe.py)
        return ("model" if _div(shape[0], mesh, "model") else None, None, None)
    if name == "router":
        return (None,) * nd
    if name == "table" and nd == 2:        # embedding [V, D]
        return (_tp_axis(mesh, shape[0]), _fsdp_axis(mesh, shape[1]))
    if name in ("wq", "wk", "wv") and nd == 3:   # [D, N|K, H]
        if _div(shape[1], mesh, "model"):
            return (_fsdp_axis(mesh, shape[0]), "model", None)
        if _div(shape[2], mesh, "model"):
            return (_fsdp_axis(mesh, shape[0]), None, "model")
        return (_fsdp_axis(mesh, shape[0]), None, None)
    if name == "wo" and nd == 3:                  # [N, H, D]
        if _div(shape[0], mesh, "model"):
            return ("model", None, _fsdp_axis(mesh, shape[2]))
        if _div(shape[1], mesh, "model"):
            return (None, "model", _fsdp_axis(mesh, shape[2]))
        return (None, None, _fsdp_axis(mesh, shape[2]))
    if nd == 2 and name in ("wi_gate", "wi_up", "wx", "wgate", "wz", "wi",
                            "wf", "wog", "wo_gate", "w"):
        # column-parallel [D_in, D_out]
        return (_fsdp_axis(mesh, shape[0]), _tp_axis(mesh, shape[1]))
    if nd == 2 and name in ("wo", "w_r", "w_i"):
        # row-parallel [D_inner, D_out]
        return (_tp_axis(mesh, shape[0]), _fsdp_axis(mesh, shape[1]))
    if nd == 3 and name in ("wq", "wk", "wv"):
        return (_fsdp_axis(mesh, shape[0]), None, _tp_axis(mesh, shape[2]))
    if nd == 2 and name == "conv_w":
        return (None, _tp_axis(mesh, shape[1]))
    if nd == 1:
        # vectors: shard large ones (rglru lam/bias) over model, keep norms whole
        if name in ("lam", "conv_b") and _div(shape[0], mesh, "model"):
            return ("model",)
        return (None,)
    return (None,) * nd


def param_specs(params: Any, mesh) -> Any:
    """PartitionSpec tree matching the params tree."""

    def spec_of(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        in_moe = "moe" in names
        return _leaf_spec(names, leaf.shape, mesh, in_moe)

    return jax.tree_util.tree_map_with_path(spec_of, params)


def opt_state_specs(params: Any, mesh) -> Any:
    """Adam m/v mirror the param sharding (ZeRO-style fully sharded states)."""
    return param_specs(params, mesh)


def batch_specs(batch: Any, mesh, global_batch: int) -> Any:
    """Shard the leading batch dim over (pod, data) when divisible."""
    dp = _dp(mesh)
    import math
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1

    def spec_of(leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        if leaf.shape[0] == global_batch and global_batch % max(dp_size, 1) == 0 \
                and dp_size > 1:
            return P(dp, *(None,) * (leaf.ndim - 1))
        return P(*(None,) * leaf.ndim)

    return jax.tree.map(spec_of, batch)


def cache_specs(cache: Any, mesh, batch: int) -> Any:
    """KV caches [L, B, S, K, H]: batch over dp, heads (or head_dim) over model."""
    dp = _dp(mesh)
    import math
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1

    def spec_of(leaf):
        if leaf.ndim != 5:
            return P(*(None,) * leaf.ndim)
        l, b, s, k, h = leaf.shape
        bs = dp if (b == batch and b % max(dp_size, 1) == 0 and dp_size > 1) else None
        if _div(k, mesh, "model"):
            return P(None, bs, None, "model", None)
        if _div(h, mesh, "model"):
            return P(None, bs, None, None, "model")
        return P(None, bs, None, None, None)

    return jax.tree.map(spec_of, cache)


def state_specs(state: Any, mesh, batch: int) -> Any:
    """Recurrent decode states: batch dim over dp, widest trailing dim over model."""
    dp = _dp(mesh)
    import math
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1

    def spec_of(leaf):
        nd = leaf.ndim
        spec = [None] * nd
        for i, d in enumerate(leaf.shape):
            if d == batch and d % max(dp_size, 1) == 0 and dp_size > 1:
                spec[i] = dp
                break
        # shard the last model-divisible dim not already taken
        for i in range(nd - 1, -1, -1):
            if spec[i] is None and _div(leaf.shape[i], mesh, "model"):
                spec[i] = "model"
                break
        return P(*spec)

    return jax.tree.map(spec_of, state)


def matcher_table_specs(mesh) -> dict[str, P]:
    """PartitionSpecs for the packed matcher tables (engine/plan.DeviceTables).

    Transition/candidate tables are small (VMEM-resident on TPU) and read by
    every chunk lane, so they replicate on every device regardless of mesh
    shape — the sharded executor moves lane *states*, never tables.
    """
    return {
        "table_pad": P(None, None),        # [Q, n_cls + 1]
        "cand_pad": P(None, None, None),   # [n_cls + 1, K, S]
        "cidx_pad": P(None, None),         # [n_cls + 1, Q]
        "starts": P(None),                 # [K]
        "sinks": P(None),                  # [K]
        "byte_to_class": P(None),          # [256]
        "absorbing": P(None),              # [Q]
    }


def matcher_chunk_specs(mesh) -> tuple[tuple[P, P, P, P], P]:
    """in/out specs for the mesh-sharded matcher body (engine/sharded.py).

    The speculative path lives on a 2-D ("doc", "chunk") matcher mesh
    (``launch.mesh.make_matcher_mesh``); legacy 1-D "data" meshes degrade to
    pure chunk sharding (doc axis absent -> replicated rows).

    Inputs (chunk-major):
      chunks [C, B, Lmax]  P(chunk, doc, None)  class ids per chunk slice
      lookahead [C, B]     P(chunk, doc)        boundary class before a chunk
      exact [C, B]         P(chunk, doc)        chunk matched exactly from
                                                its row-block's entry states
      entry [B, K]         P(doc, None)         per-document entry states
                                                (pattern starts, or a stream
                                                cursor's states)
    Output [Dc, B, K] finals: P(chunk, doc, None) — each doc shard folds only
    its own row block after the "chunk"-axis all_gather; doc shards never
    communicate, so every chunk device of a mesh row holds the same [B/Dd, K]
    answer.  The copies are returned behind an explicit leading chunk-axis
    dim (callers read ``out[0]``) so the out spec mentions *every* mesh axis:
    under jit, shard_map with ``check_vma=False`` turns an out spec that
    omits an axis into a psum over it when the operands were assembled inside
    the jit — 4x-scaled garbage, not a copy (jax 0.4 GSPMD lowering).
    """
    c_ax, d_ax = _matcher_axes(mesh)
    return ((P(c_ax, d_ax, None), P(c_ax, d_ax), P(c_ax, d_ax),
             P(d_ax, None)), P(c_ax, d_ax, None))


def _matcher_axes(mesh) -> tuple:
    if "chunk" in mesh.axis_names:
        return "chunk", ("doc" if "doc" in mesh.axis_names else None)
    return ("data" if "data" in mesh.axis_names else None), None


def matcher_lane_specs(mesh) -> tuple[tuple[P, P, P, P, P], P]:
    """in/out specs for the lane-plan (``ENTRY_LANES``) merge-stage body —
    the streaming device merge on the ("doc", "chunk") mesh
    (engine/sharded.py ``body_lanes``).

    Inputs extend ``matcher_chunk_specs`` for candidate-keyed cursors:
      chunks [C, B, Lmax]    P(chunk, doc, None)  as for exact plans
      lookahead [C, B]       P(chunk, doc)
      exact [C, B]           P(chunk, doc)
      cursor lanes [B, K, S] P(doc, None, None)   each stream's Eq. 11 lane
                                                  map — rides its doc shard,
                                                  never crosses "chunk"
      boundary class [B]     P(doc)               keys both the segment's
                                                  chunk-0 candidates and the
                                                  on-device composition
    Output [Dc, B, K, S] composed lanes: P(chunk, doc, None, None) — the
    same every-axis-mentioned shape discipline as ``matcher_chunk_specs``
    (callers read ``out[0]``); the cursor merge runs after the "chunk"-axis
    all_gather, per doc shard, so doc shards still never communicate.
    """
    c_ax, d_ax = _matcher_axes(mesh)
    return ((P(c_ax, d_ax, None), P(c_ax, d_ax), P(c_ax, d_ax),
             P(d_ax, None, None), P(d_ax)), P(c_ax, d_ax, None, None))


_DOC_AXES = ("pod", "data", "doc", "chunk")


def doc_batch_spec(mesh, batch: int) -> P:
    """Document-batch spec [B, ...]: shard the doc axis over the mesh's
    data-parallel axes when they divide it, replicate otherwise.

    On production meshes the dp axes are (pod, data); on a matcher mesh the
    batched *sequential* path treats every device as a row worker, so the doc
    axis spreads over ("doc", "chunk") jointly — rows are independent and
    nothing is exchanged, unlike the speculative chunk split."""
    axes = tuple(a for a in _DOC_AXES if a in mesh.axis_names)
    import math
    dp_size = math.prod(mesh.shape[a] for a in axes) if axes else 1
    if axes and dp_size > 1 and batch % dp_size == 0:
        return P(axes)
    return P()


def named(tree_specs: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
