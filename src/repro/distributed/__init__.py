"""Distribution layer: sharding rules, hierarchical collectives, PP, FT."""

from . import collectives, compression, fault_tolerance, pipeline, sharding

__all__ = ["collectives", "compression", "fault_tolerance", "pipeline",
           "sharding"]
