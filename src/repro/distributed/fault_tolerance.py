"""Fault tolerance & elasticity: restart policy, elastic re-mesh, stragglers.

Production posture for 1000+ nodes (DESIGN.md §5):

  * **Checkpoint/restart** — training/checkpoint.py persists sharded state;
    ``RestartManager`` wraps the step loop, catches worker failures, restores
    the latest complete checkpoint and resumes (tested with injected faults).
  * **Elastic re-mesh** — on node loss the job can restart on a smaller mesh:
    ``reshard_tree`` re-device_puts a restored host-side checkpoint under the
    new mesh's shardings (specs are recomputed from the same rules, so any
    (data, model) factorization works).
  * **Straggler mitigation** — the paper's own mechanism (Eq. 1/5): per-host
    throughput is profiled (core/profiling.py) and the weighted partitioner
    sizes host input shards; persistently slow hosts get proportionally less
    data instead of gating every step.  ``StragglerPolicy`` tracks EWMA step
    times and triggers re-profiling + re-partitioning past a threshold.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np

import jax

from ..core.partition import capacity_weights, weighted_partition

__all__ = ["RestartManager", "reshard_tree", "StragglerPolicy"]


class RestartManager:
    """Retry-with-restore wrapper around a training step loop."""

    def __init__(self, save_fn: Callable[[Any, int], None],
                 restore_fn: Callable[[], tuple[Any, int]],
                 max_restarts: int = 3):
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.max_restarts = max_restarts
        self.restarts = 0
        self.failures: list[tuple[int, str]] = []

    def run(self, state, start_step: int, n_steps: int,
            step_fn: Callable[[Any, int], Any],
            checkpoint_every: int = 50):
        step = start_step
        while step < n_steps:
            try:
                state = step_fn(state, step)
                step += 1
                if step % checkpoint_every == 0:
                    self.save_fn(state, step)
            except Exception as exc:  # noqa: BLE001 — any worker fault
                self.failures.append((step, repr(exc)))
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                state, step = self.restore_fn()
        return state, step


def reshard_tree(host_tree: Any, shardings: Any) -> Any:
    """Place a host-side (numpy) checkpoint tree under new shardings.

    Works across mesh shape changes: device_put with a NamedSharding reshards
    regardless of how the state was sharded when saved — this is the elastic
    scaling path (e.g. 512 -> 256 devices after losing a pod).
    """
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), host_tree, shardings)


@dataclasses.dataclass
class StragglerPolicy:
    """EWMA step-time tracking -> re-profile + re-partition trigger."""

    n_workers: int
    threshold: float = 1.3     # worker slower than 1.3x fleet median
    alpha: float = 0.2
    ewma: Optional[np.ndarray] = None

    def update(self, per_worker_times: np.ndarray) -> bool:
        t = np.asarray(per_worker_times, dtype=np.float64)
        self.ewma = t if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * t
        return bool((self.ewma / np.median(self.ewma)).max() > self.threshold)

    def capacities(self) -> np.ndarray:
        """Observed per-worker capacities (1 / EWMA time) — the Eq. 1 inputs.

        Feed straight into ``Matcher.rebalance``: the streaming scheduler
        does exactly that when ``update`` trips, so a degraded device's
        decayed timing becomes a proportionally smaller chunk of every
        bucket (paper Eq. 5) without re-running offline calibration.
        """
        if self.ewma is None:
            raise ValueError("no step times observed yet")
        return 1.0 / np.maximum(self.ewma, 1e-9)

    def rebalanced_shards(self, n_items: int, m: int = 1):
        """New weighted partition from observed speeds (paper Eqs. 1/5)."""
        return weighted_partition(n_items, capacity_weights(self.capacities()),
                                  m)
