"""DFA corpus filtering — the paper's technique as a data-pipeline stage.

Quality/PII filtering of LM training corpora is regex scanning at TB scale:
exactly the "single long-running membership test" workload the paper targets.
``CorpusFilter`` compiles the block-list patterns to search DFAs; documents
are scanned either

  * **batched** (default, ``filter``/``scan_batch``): a whole document batch
    advances against *all* patterns in one fused device call per shape bucket
    via the packed-DFA ``BatchMatcher`` — lanes are chunks x candidates x
    patterns, and only one [B, K] decision array returns to the host; or
  * **per-document** (``document_ok``): each pattern's ``SpecDFAEngine`` runs
    in turn with an early exit on the first hit (remaining patterns are not
    scanned; ``FilterStats.patterns_scanned`` records how many were); or
  * **streaming** (``scan_stream``): documents arriving as interleaved byte
    chunks — a corpus being downloaded, log tails — are filtered *as the
    bytes land*: every open document rides a resumable cursor
    (``streaming.StreamMatcher``) and chunks from many documents coalesce
    into shared micro-batched device ticks.  Decisions are bit-identical to
    ``scan_batch`` on the assembled documents, and fully-matched documents
    stop being scanned at all (absorbed early exit).

At fleet scale the byte stream is split across hosts with the paper's
weighted partitioning (loader.py) and per-host scans use these engines.

A document is dropped when any pattern's search DFA reaches an accepting
(absorbing) state anywhere in the document.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import numpy as np

from ..core import (BlockedMatcher, Matcher, PatternSet, SpecDFAEngine,
                    compile_regex, make_search_dfa, pack_dfas)

__all__ = ["CorpusFilter", "FilterStats"]


@dataclasses.dataclass
class FilterStats:
    scanned: int = 0
    dropped: int = 0
    bytes_scanned: int = 0
    work_parallel: int = 0
    work_sequential: int = 0
    patterns_scanned: int = 0  # pattern engines actually run (early exit!)
    early_exits: int = 0       # per-doc path: scan stopped before the last
                               # pattern; batch path: docs retired by the
                               # absorbing-state early exit
    batch_calls: int = 0       # fused device dispatches used by the batch path
    time_steps: int = 0        # lane-parallel matching steps (batch path)

    @property
    def model_speedup(self) -> float:
        """Scalar-work speedup proxy (meaningful for the per-document path)."""
        return self.work_sequential / max(self.work_parallel, 1)

    @property
    def lane_speedup(self) -> float:
        """Lane-parallel model: symbols scanned per wall-clock matching step."""
        return self.work_sequential / max(self.time_steps, 1)


class CorpusFilter:
    """Block-list regex filter backed by the speculative DFA engine.

    ``num_chunks``/``mode``/``partition``/``lookahead_r`` configure the
    per-document engines; ``batch_tile``/``max_buckets``/``backend``/
    ``capacities``/``mesh``/``mesh_shape``/``devices`` configure the packed
    batch matcher facade (see ``core.engine.Matcher`` — ``backend="sharded"``
    with measured ``capacities`` runs the capacity-balanced mesh executor;
    ``mesh_shape=(doc, chunk)`` or ``"auto"`` spreads large batches over a
    2-D doc x chunk mesh).  Keep/drop decisions are [B] bool and
    bit-identical across all backends, mesh shapes and scan paths
    (``scan_batch`` / ``filter`` / ``scan_stream``).

    Large block lists ride the pattern-set scale tier: ``k_blk`` splits the
    K patterns into independently-determinized blocks behind a
    ``core.engine.BlockedMatcher`` (same [B, K] decisions, bounded
    per-block determinization) and ``prefilter`` gates whole blocks per
    batch by required-literal fingerprints — documents that cannot contain
    any of a block's literals never dispatch that block.  Both paths stay
    bit-identical on decisions; only the gated work is skipped.
    """

    def __init__(self, patterns: Iterable[str], *, num_chunks: int = 8,
                 mode: str = "lookahead", partition: str = "balanced",
                 lookahead_r: int = 1, batch_tile: int = 64,
                 max_buckets: int = 2, backend: str = "local",
                 capacities=None, mesh=None, mesh_shape=None,
                 devices=None, k_blk: int | None = None,
                 prefilter: bool = True):
        patterns = list(patterns)
        matcher_kwargs = dict(num_chunks=num_chunks, batch_tile=batch_tile,
                              max_buckets=max_buckets, backend=backend,
                              capacities=capacities, mesh=mesh,
                              mesh_shape=mesh_shape, devices=devices)
        self.pattern_set: PatternSet | None = None
        if k_blk is not None and patterns:
            # PatternSet(search=True) compiles the identical search DFAs the
            # unblocked path builds below; reuse them for the per-doc engines
            self.pattern_set = PatternSet(patterns, k_blk=k_blk, search=True)
            self.dfas = list(self.pattern_set.dfas)
            self.batch = BlockedMatcher(self.pattern_set,
                                        prefilter=prefilter,
                                        **matcher_kwargs)
        else:
            self.dfas = [make_search_dfa(compile_regex(".*(" + pat + ")"))
                         for pat in patterns]
            # zero patterns = filter nothing, keep everything (no matcher)
            self.batch = (Matcher(pack_dfas(self.dfas), **matcher_kwargs)
                          if self.dfas else None)
        self.engines = [
            SpecDFAEngine(dfa, num_chunks=num_chunks, mode=mode,
                          partition=partition, lookahead_r=lookahead_r)
            for dfa in self.dfas]
        self.stats = FilterStats()

    # -- per-document path (early exit across patterns) ---------------------

    def document_ok(self, doc: bytes) -> bool:
        self.stats.scanned += 1
        self.stats.bytes_scanned += len(doc)
        data = np.frombuffer(doc, np.uint8)
        hit = False
        for j, eng in enumerate(self.engines):
            res = eng.membership(data)
            self.stats.patterns_scanned += 1
            self.stats.work_parallel += res.work_parallel
            self.stats.work_sequential += res.work_sequential
            if res.accepted:
                hit = True
                if j < len(self.engines) - 1:
                    self.stats.early_exits += 1
                break
        if hit:
            self.stats.dropped += 1
        return not hit

    # -- batched path (all patterns at once, no per-doc sync) ---------------

    def scan_batch(self, docs: list[bytes]) -> np.ndarray:
        """[B] keep-mask for a document batch; one fused call per bucket.

        All K patterns are matched simultaneously (no early exit — the packed
        lanes cost the same whether or not an earlier pattern hit), so
        ``patterns_scanned`` grows by B * K.
        """
        if not docs:
            return np.zeros(0, dtype=bool)
        if self.batch is None:  # no patterns: keep everything
            self.stats.scanned += len(docs)
            self.stats.bytes_scanned += int(sum(len(d) for d in docs))
            return np.ones(len(docs), dtype=bool)
        res = self.batch.membership_batch(docs)
        hit = res.accepted.any(axis=1)
        self.stats.scanned += len(docs)
        self.stats.bytes_scanned += int(sum(len(d) for d in docs))
        self.stats.dropped += int(hit.sum())
        self.stats.patterns_scanned += len(docs) * self.batch.n_patterns
        self.stats.work_parallel += int(res.work_parallel.sum())
        self.stats.work_sequential += int(res.work_sequential.sum())
        self.stats.time_steps += int(res.time_steps.sum())
        self.stats.batch_calls += res.bucket_calls
        self.stats.early_exits += res.early_exits  # absorbing-state retires
        return ~hit

    def filter(self, docs: Iterable[bytes], *,
               batch_size: int = 64) -> Iterator[bytes]:
        """Stream kept documents, scanning in batches of ``batch_size``."""
        pending: list[bytes] = []
        for doc in docs:
            pending.append(doc)
            if len(pending) >= batch_size:
                for d, ok in zip(pending, self.scan_batch(pending)):
                    if ok:
                        yield d
                pending = []
        if pending:
            for d, ok in zip(pending, self.scan_batch(pending)):
                if ok:
                    yield d

    # -- streaming path (documents arrive as interleaved chunks) -------------

    def scan_stream(self, events, *, max_batch: int = 64,
                    max_delay: int = 8):
        """Filter documents that arrive as interleaved byte chunks.

        ``events`` yields ``(key, chunk)`` pairs: ``chunk`` is the next bytes
        of document ``key`` (documents interleave freely — concurrent
        downloads), and ``chunk=None`` marks the document complete.  Yields
        ``(key, keep)`` as each document completes; documents still open when
        ``events`` is exhausted are finalized in arrival order.

        Matching is resumable and micro-batched: chunks only *admit* work,
        and the tick policy (``max_batch`` pending documents, or a chunk
        waiting ``max_delay`` admission events) decides when one fused device
        round advances every pending document at once.  A document whose
        patterns have all absorbed (e.g. a block-list hit) stops being
        scanned entirely; its remaining bytes are only counted.
        """
        from ..streaming import (BlockedStreamMatcher, StreamMatcher,
                                 TickPolicy)

        if self.batch is None:  # no patterns: keep everything
            open_counts: dict = {}
            for key, chunk in events:
                if chunk is None:
                    self._stream_account(open_counts.pop(key, 0))
                    yield key, True
                else:
                    open_counts[key] = open_counts.get(key, 0) + len(chunk)
            for key, n in open_counts.items():
                self._stream_account(n)
                yield key, True
            return

        policy = TickPolicy(max_batch=max_batch, max_delay=max_delay)
        if self.pattern_set is not None:
            # blocked filter: one child StreamMatcher per block behind a
            # single session API, sharing the batch matcher's lowerings
            sm = BlockedStreamMatcher(self.batch, policy=policy)
        else:
            sm = StreamMatcher(self.batch, policy=policy)
        open_sessions: dict = {}
        # device ticks fire while events are consumed, so fold the scheduler
        # stats in even when the consumer abandons the generator early
        seen_skips = seen_calls = 0

        def sync_stats():
            nonlocal seen_skips, seen_calls
            self.stats.early_exits += sm.stats.absorbed_skips - seen_skips
            self.stats.batch_calls += sm.stats.bucket_calls - seen_calls
            seen_skips = sm.stats.absorbed_skips
            seen_calls = sm.stats.bucket_calls

        try:
            for key, chunk in events:
                if chunk is None:
                    sess = open_sessions.pop(key, None) or sm.open()
                    yield key, self._stream_close(sm, sess)
                else:
                    sess = open_sessions.get(key)
                    if sess is None:
                        sess = open_sessions[key] = sm.open()
                    sess.feed(chunk)
            for key, sess in open_sessions.items():
                yield key, self._stream_close(sm, sess)
        finally:
            sync_stats()

    def _stream_account(self, n_bytes: int) -> None:
        self.stats.scanned += 1
        self.stats.bytes_scanned += n_bytes

    def _stream_close(self, sm, sess) -> bool:
        res = sm.close(sess)
        hit = bool(res.accepted.any())
        self.stats.scanned += 1
        self.stats.bytes_scanned += res.byte_count
        self.stats.dropped += int(hit)
        self.stats.patterns_scanned += self.batch.n_patterns
        self.stats.work_parallel += res.byte_count * self.batch.n_patterns
        self.stats.work_sequential += res.byte_count * self.batch.n_patterns
        return not hit
