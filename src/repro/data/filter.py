"""DFA corpus filtering — the paper's technique as a data-pipeline stage.

Quality/PII filtering of LM training corpora is regex scanning at TB scale:
exactly the "single long-running membership test" workload the paper targets.
``CorpusFilter`` compiles the block-list patterns to search DFAs and runs the
speculative chunked matcher over each document; at fleet scale the byte
stream is split across hosts with the paper's weighted partitioning
(loader.py) and per-host scans use the SpecDFAEngine.

A document is dropped when any pattern's search DFA reaches an accepting
(absorbing) state anywhere in the document.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import numpy as np

from ..core import SpecDFAEngine, compile_regex, make_search_dfa

__all__ = ["CorpusFilter", "FilterStats"]


@dataclasses.dataclass
class FilterStats:
    scanned: int = 0
    dropped: int = 0
    bytes_scanned: int = 0
    work_parallel: int = 0
    work_sequential: int = 0

    @property
    def model_speedup(self) -> float:
        return self.work_sequential / max(self.work_parallel, 1)


class CorpusFilter:
    """Block-list regex filter backed by the speculative DFA engine."""

    def __init__(self, patterns: Iterable[str], *, num_chunks: int = 8,
                 mode: str = "lookahead", partition: str = "balanced",
                 lookahead_r: int = 1):
        self.engines = []
        for pat in patterns:
            dfa = make_search_dfa(compile_regex(".*(" + pat + ")"))
            self.engines.append(
                SpecDFAEngine(dfa, num_chunks=num_chunks, mode=mode,
                              partition=partition, lookahead_r=lookahead_r))
        self.stats = FilterStats()

    def document_ok(self, doc: bytes) -> bool:
        self.stats.scanned += 1
        self.stats.bytes_scanned += len(doc)
        hit = False
        for eng in self.engines:
            res = eng.membership(np.frombuffer(doc, np.uint8))
            self.stats.work_parallel += res.work_parallel
            self.stats.work_sequential += res.work_sequential
            if res.accepted:
                hit = True
                break
        if hit:
            self.stats.dropped += 1
        return not hit

    def filter(self, docs: Iterable[bytes]) -> Iterator[bytes]:
        for doc in docs:
            if self.document_ok(doc):
                yield doc
