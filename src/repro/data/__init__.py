"""Data pipeline: tokenizer, synthetic corpus, DFA filter, packed loader."""

from .corpus import (CorpusConfig, generate_bytes, generate_documents,
                     load_pattern_fixtures)
from .filter import CorpusFilter, FilterStats
from .loader import LoaderConfig, PackedBatcher, data_stream, host_shard
from .tokenizer import ByteTokenizer

__all__ = ["CorpusConfig", "generate_bytes", "generate_documents",
           "load_pattern_fixtures",
           "CorpusFilter", "FilterStats", "LoaderConfig", "PackedBatcher",
           "data_stream", "host_shard", "ByteTokenizer"]
