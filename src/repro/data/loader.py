"""Sharded, packed batch loader with capacity-weighted host partitioning.

The paper's load-balancing scheme (Eqs. 1, 5–7) applied to data ingestion:
host shards of the corpus byte stream are sized by profiled per-host
throughput weights, so heterogeneous fleets (mixed TPU generations, noisy
cloud VMs — the paper's EC2 scenario) finish their scan+tokenize work
simultaneously.  Re-partitioning on updated weights is the straggler
mitigation hook (distributed/fault_tolerance.StragglerPolicy).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence

import numpy as np

from ..core.partition import weighted_partition
from .filter import CorpusFilter
from .tokenizer import ByteTokenizer

__all__ = ["LoaderConfig", "host_shard", "PackedBatcher", "data_stream"]


@dataclasses.dataclass
class LoaderConfig:
    batch_size: int = 8
    seq_len: int = 256
    seed: int = 0


def host_shard(n_bytes: int, weights: Sequence[float], host_id: int,
               m: int = 1) -> tuple[int, int]:
    """[start, end) byte range for this host under capacity weights."""
    part = weighted_partition(n_bytes, np.asarray(weights, np.float64), m)
    return int(part.start[host_id]), int(part.end[host_id])


class PackedBatcher:
    """Pack variable-length documents into dense [B, T+1] token blocks."""

    def __init__(self, cfg: LoaderConfig, tokenizer: Optional[ByteTokenizer] = None):
        self.cfg = cfg
        self.tok = tokenizer or ByteTokenizer()
        self._buf: list[int] = []

    def add_document(self, doc: bytes) -> None:
        self._buf.extend(self.tok.encode(doc).tolist())

    def ready(self) -> bool:
        need = self.cfg.batch_size * (self.cfg.seq_len + 1)
        return len(self._buf) >= need

    def next_batch(self) -> dict:
        b, t = self.cfg.batch_size, self.cfg.seq_len
        need = b * (t + 1)
        chunk = np.asarray(self._buf[:need], np.int32).reshape(b, t + 1)
        del self._buf[:need]
        return {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}


def data_stream(docs, cfg: LoaderConfig,
                corpus_filter: Optional[CorpusFilter] = None) -> Iterator[dict]:
    """documents -> (optional DFA filter) -> packed batches."""
    batcher = PackedBatcher(cfg)
    source = corpus_filter.filter(docs) if corpus_filter else iter(docs)
    for doc in source:
        batcher.add_document(doc)
        while batcher.ready():
            yield batcher.next_batch()
