"""Synthetic corpus generation for end-to-end training and filter benchmarks.

Generates documents from a mixture of character distributions (english-ish
words, code-ish tokens, protein-ish residue strings, numeric noise) with
pattern "contaminants" planted at a controlled rate so the DFA filter has
real positives to find — mirroring the paper's PCRE/PROSITE evaluation data.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterator, Optional

import numpy as np

__all__ = ["CorpusConfig", "generate_documents", "generate_bytes",
           "load_pattern_fixtures"]

_FIXTURES = (pathlib.Path(__file__).resolve().parents[3]
             / "tests" / "fixtures" / "pattern_corpus.json")

_WORDS = (b"the quick brown fox jumps over lazy dog state machine parallel "
          b"speculative chunk merge lookahead automaton pattern match input "
          b"cloud vector gather table processor speedup").split()
_CODE = (b"for while if else return int float def class import lambda "
         b"static void template typename").split()
_RESIDUES = b"ACDEFGHIKLMNPQRSTVWY"


@dataclasses.dataclass
class CorpusConfig:
    n_documents: int = 64
    doc_len: int = 2048
    contaminant: bytes = b"SECRET-123"   # planted pattern for filter tests
    contaminant_rate: float = 0.1        # fraction of docs containing it
    seed: int = 0


def _one_doc(rng: np.random.Generator, cfg: CorpusConfig) -> bytes:
    kind = rng.integers(0, 3)
    out = bytearray()
    while len(out) < cfg.doc_len:
        if kind == 0:
            out += rng.choice(_WORDS) + b" "
        elif kind == 1:
            out += rng.choice(_CODE) + b"_" + str(rng.integers(100)).encode() + b" "
        else:
            out += bytes(rng.choice(list(_RESIDUES),
                                    size=int(rng.integers(5, 40)))) + b"\n"
    doc = bytes(out[: cfg.doc_len])
    if rng.random() < cfg.contaminant_rate:
        pos = int(rng.integers(0, max(1, cfg.doc_len - len(cfg.contaminant))))
        doc = doc[:pos] + cfg.contaminant + doc[pos + len(cfg.contaminant):]
    return doc


def generate_documents(cfg: CorpusConfig) -> Iterator[bytes]:
    rng = np.random.default_rng(cfg.seed)
    for _ in range(cfg.n_documents):
        yield _one_doc(rng, cfg)


def generate_bytes(total: int, seed: int = 0) -> bytes:
    cfg = CorpusConfig(n_documents=(total // 2048) + 1, seed=seed)
    return b"".join(generate_documents(cfg))[:total]


def load_pattern_fixtures(path: Optional[str] = None) -> list[dict]:
    """Load the checked-in pattern corpus fixtures.

    Each entry is ``{"name", "kind" ("pcre"|"prosite"), "source" (the raw
    PCRE regex or PROSITE motif), "pattern" (the translated regex actually
    compiled — also valid Python ``re`` syntax, the conformance oracle),
    "positive": [str, ...], "negative": [str, ...]}`` with every example
    pre-verified against ``re.search`` at generation time.  Shared by the
    conformance suite and the ``pattern_scale`` benchmark so both sweep the
    same corpus the paper's PCRE/PROSITE evaluation stands in for.
    """
    p = pathlib.Path(path) if path is not None else _FIXTURES
    with open(p) as f:
        data = json.load(f)
    entries = data["entries"]
    if not entries:
        raise ValueError(f"no fixture entries in {p}")
    return entries
