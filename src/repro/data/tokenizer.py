"""Byte-level tokenizer with a small reserved-special block.

Production LM stacks pair a learned subword vocab with the model's embedding
table; for this framework the data path is byte-level (ids 0..255) plus
specials, which keeps the DFA corpus filter (data/filter.py) and the
grammar-constrained decoder (serving/constrained.py) operating on the same
alphabet the paper's automata use.  Models with larger vocabs simply embed
the byte ids; nothing in the pipeline assumes vocab == 256 + specials.
"""

from __future__ import annotations

import numpy as np

__all__ = ["ByteTokenizer"]


class ByteTokenizer:
    PAD = 256
    BOS = 257
    EOS = 258
    N_SPECIAL = 3

    @property
    def vocab_size(self) -> int:
        return 256 + self.N_SPECIAL

    def encode(self, text: str | bytes, *, bos: bool = True,
               eos: bool = True) -> np.ndarray:
        raw = text.encode("utf-8") if isinstance(text, str) else bytes(text)
        ids = list(raw)
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return np.asarray(ids, dtype=np.int32)

    def decode(self, ids: np.ndarray) -> bytes:
        return bytes(int(i) for i in np.asarray(ids).reshape(-1)
                     if 0 <= int(i) < 256)
