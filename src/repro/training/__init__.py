"""Training substrate: optimizer, train step factory, checkpointing."""

from .checkpoint import (CheckpointManager, latest_step, restore_checkpoint,
                         save_checkpoint, save_checkpoint_async)
from .optimizer import AdamWConfig, adamw_init, adamw_update, cosine_lr
from .train_loop import (TrainOptions, init_train_state,
                         init_train_state_sharded, make_train_step)

__all__ = ["CheckpointManager", "latest_step", "restore_checkpoint",
           "save_checkpoint", "save_checkpoint_async", "AdamWConfig",
           "adamw_init", "adamw_update", "cosine_lr", "TrainOptions",
           "init_train_state", "make_train_step"]
