"""Training step factory: microbatched grad accumulation under pjit.

``make_train_step(cfg, mesh, ...)`` returns a jit-compiled
``train_step(state, batch) -> (state, metrics)`` with:

  * gradient accumulation over ``num_microbatches`` via ``lax.scan`` —
    bounds live activation memory to one microbatch (the dominant memory
    lever for train_4k cells; see EXPERIMENTS.md §Perf),
  * params/optimizer fully sharded by distributed/sharding rules,
  * optional int8 cross-pod gradient compression with error feedback,
  * loss = token CE (+ MoE aux), fp32 accumulation.

The same factory serves the dry-run (lower/compile on ShapeDtypeStructs) and
real training (examples/train_tiny_lm.py), so the compiled artifact analyzed
in §Roofline is exactly the production step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed import compression as comp
from ..distributed import sharding as shr
from ..models import api
from ..models.transformer import lm_loss
from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainOptions", "make_train_step", "init_train_state"]


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    num_microbatches: int = 1
    aux_loss_weight: float = 0.01
    grad_compression: str = "none"   # none | int8
    optimizer: AdamWConfig = AdamWConfig()


def init_train_state(cfg: ModelConfig, key, mesh=None,
                     opts: TrainOptions = TrainOptions()) -> dict:
    params = api.init(cfg, key)
    state = {"params": params, "opt": adamw_init(params)}
    if opts.grad_compression == "int8" and mesh is not None \
            and "pod" in getattr(mesh, "axis_names", ()):
        state["err"] = comp.init_error_state(params)
    return state


def state_shardings(state: dict, mesh):
    specs = {
        "params": shr.param_specs(state["params"], mesh),
        "opt": {"m": shr.param_specs(state["opt"]["m"], mesh),
                "v": shr.param_specs(state["opt"]["v"], mesh),
                "step": jax.sharding.PartitionSpec()},
    }
    if "err" in state:
        specs["err"] = shr.param_specs(state["err"], mesh)
    return shr.named(specs, mesh)


def init_train_state_sharded(cfg: ModelConfig, key, mesh,
                             opts: TrainOptions = TrainOptions()) -> dict:
    """Initialize directly into the sharded layout (no host round-trip).

    jit with out_shardings materializes each param shard on its device —
    this is how a 42B-param state comes up on a real pod without ever
    existing unsharded anywhere.
    """
    def make():
        return init_train_state(cfg, key, mesh, opts)

    shapes = jax.eval_shape(make)
    sh = state_shardings(shapes, mesh)
    return jax.jit(make, out_shardings=sh)()


def make_train_step(cfg: ModelConfig, mesh=None,
                    opts: TrainOptions = TrainOptions()):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        logits, aux = api.train_logits(params, cfg, mb, mesh=mesh)
        labels = mb["labels"]
        return lm_loss(logits, labels) + opts.aux_loss_weight * aux

    def train_step(state, batch):
        params = state["params"]
        nm = opts.num_microbatches

        if nm > 1:
            def split(x):
                return x.reshape((nm, x.shape[0] // nm) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                gsum, lsum = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_step, (zeros, jnp.float32(0)), micro)
            grads = jax.tree.map(lambda g: g / nm, gsum)
            loss = lsum / nm
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        new_state = dict(state)
        if "err" in state and mesh is not None:
            grads, new_err = comp.compressed_pod_mean(grads, state["err"], mesh)
            new_state["err"] = new_err

        new_params, new_opt, metrics = adamw_update(
            opts.optimizer, params, grads, state["opt"])
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def jit_train_step(cfg: ModelConfig, mesh, state, batch_specs_tree,
                   opts: TrainOptions = TrainOptions()):
    """pjit-wrapped step with explicit in/out shardings (dry-run entry)."""
    step = make_train_step(cfg, mesh, opts)
    st_sh = state_shardings(state, mesh)
    b_sh = shr.named(batch_specs_tree, mesh)
    return jax.jit(step, in_shardings=(st_sh, b_sh),
                   out_shardings=(st_sh, None), donate_argnums=(0,))
