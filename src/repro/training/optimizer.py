"""AdamW with cosine schedule, global-norm clipping, and fully-sharded states.

Hand-rolled (no optax in this environment): state = {m, v, step} pytrees
mirroring the params, sharded with the same PartitionSpec rules (ZeRO-style).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, opt: dict):
    """One AdamW step; returns (new_params, new_opt, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt["step"] + 1
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         opt["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         opt["v"], grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
