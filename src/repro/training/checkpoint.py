"""Sharded checkpointing: async save, atomic publish, elastic restore.

Layout per step:  <dir>/step_<N>/
    manifest.msgpack   — tree structure, dtypes, shapes, step, wall-time
    arrays.npz         — one entry per leaf (path-joined key)

Writes go to ``step_<N>.tmp`` and are atomically renamed — a crashed writer
never publishes a partial checkpoint, so restore always finds the latest
*complete* step (the RestartManager contract).  Saving runs on a background
thread (async checkpointing off the training critical path); ``wait()``
joins before the next save to bound staleness to one interval.

On multi-host deployments each host would write its addressable shards;
this single-process build writes full arrays but restores through
``distributed.fault_tolerance.reshard_tree`` so the restore path already
supports arbitrary mesh changes (elastic scaling).
"""

from __future__ import annotations

import os
import threading
import time
from typing import Any, Optional

import msgpack
import numpy as np

import jax

__all__ = ["save_checkpoint", "save_checkpoint_async", "restore_checkpoint",
           "latest_step", "CheckpointManager"]


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(tree_like: Any, flat: dict[str, np.ndarray]) -> Any:
    paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)
    leaves = []
    for path, _ in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, state: Any, step: int) -> str:
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step, "time": time.time(),
                "keys": sorted(flat.keys())}
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):  # idempotent re-save
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class _AsyncSaver:
    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def submit(self, directory: str, state: Any, step: int):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            self.last_path = save_checkpoint(directory, host_state, step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


_SAVER = _AsyncSaver()


def save_checkpoint_async(directory: str, state: Any, step: int) -> None:
    _SAVER.submit(directory, state, step)


def _step_of(name: str) -> Optional[int]:
    """Step number of a *published* checkpoint dir name, else None.

    ``step_<N>.tmp`` (a crashed or in-flight writer) and any stray
    non-numeric ``step_*`` entry are never a restore candidate.
    """
    if not name.startswith("step_") or name.endswith(".tmp"):
        return None
    try:
        return int(name.split("_", 1)[1])
    except ValueError:
        return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [s for d in os.listdir(directory)
             if (s := _step_of(d)) is not None]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore the latest (or given) step; optionally reshard onto a mesh."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(like, flat)
    if shardings is not None:
        from ..distributed.fault_tolerance import reshard_tree
        tree = reshard_tree(tree, shardings)
    return tree, step


class CheckpointManager:
    """Keep-last-K policy + async saves + restart-manager adapters."""

    def __init__(self, directory: str, keep: int = 3, use_async: bool = True):
        self.directory = directory
        self.keep = keep
        self.use_async = use_async
        os.makedirs(directory, exist_ok=True)

    def save(self, state: Any, step: int) -> None:
        if self.use_async:
            save_checkpoint_async(self.directory, state, step)
        else:
            save_checkpoint(self.directory, state, step)
        self._gc()

    def wait(self):
        _SAVER.wait()

    def restore(self, like: Any, shardings: Any = None) -> tuple[Any, int]:
        self.wait()
        return restore_checkpoint(self.directory, like, shardings=shardings)

    def _gc(self):
        steps = sorted(s for d in os.listdir(self.directory)
                       if (s := _step_of(d)) is not None)
        for s in steps[: -self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
