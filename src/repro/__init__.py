"""repro: speculative parallel DFA membership testing as a multi-pod JAX framework."""

__version__ = "1.0.0"
