"""Unified model API: one entry point per phase, dispatched on cfg.family.

  init(cfg, key)                      -> params
  train_logits(params, cfg, batch)    -> (logits, aux_loss)
  prefill(params, cfg, batch)         -> (logits, cache)
  decode(params, cfg, batch)          -> (logits, new_cache/state)
  make_inputs(cfg, shape, seed)       -> concrete batch (smoke tests)
  input_specs(cfg, shape)             -> ShapeDtypeStruct batch (dry-run)

Batch layouts per family are documented in input_specs.  The modality
frontends ([audio] seamless, [vlm] internvl) are stubs per the assignment:
batches carry precomputed frame/patch embeddings.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from . import encdec as ED
from . import recurrent as RG
from . import transformer as TF
from . import xlstm as XL
from .layers import Compute
from .transformer import lm_loss

__all__ = ["init", "train_logits", "prefill", "decode", "make_inputs",
           "input_specs", "lm_loss"]


def init(cfg: ModelConfig, key) -> dict:
    if cfg.family in ("dense", "moe", "vlm"):
        return TF.init_lm(cfg, key)
    if cfg.family == "hybrid":
        return RG.init_hybrid(cfg, key)
    if cfg.family == "ssm":
        return XL.init_xlstm(cfg, key)
    if cfg.family == "encdec":
        return ED.init_encdec(cfg, key)
    raise ValueError(cfg.family)


def train_logits(params, cfg: ModelConfig, batch: dict, *,
                 mesh: Optional[jax.sharding.Mesh] = None):
    if cfg.family in ("dense", "moe"):
        logits, _, aux = TF.forward(params, cfg, batch["tokens"], mesh=mesh)
        return logits, aux
    if cfg.family == "vlm":
        logits, _, aux = TF.forward(params, cfg, batch["tokens"],
                                    prefix_embeds=batch["patches"], mesh=mesh)
        return logits[:, batch["patches"].shape[1]:], aux  # text positions only
    if cfg.family == "hybrid":
        logits, _, aux = RG.forward_hybrid(params, cfg, batch["tokens"], mesh=mesh)
        return logits, aux
    if cfg.family == "ssm":
        logits, _, aux = XL.forward_xlstm(params, cfg, batch["tokens"], mesh=mesh)
        return logits, aux
    if cfg.family == "encdec":
        logits, _, aux = ED.forward_encdec(params, cfg, batch["frames"],
                                           batch["tokens"], mesh=mesh)
        return logits, aux
    raise ValueError(cfg.family)


def prefill(params, cfg: ModelConfig, batch: dict, *, mesh=None,
            last_only: bool = True):
    """Prompt ingestion.  ``last_only`` (production default, §Perf iteration
    3) emits logits for the final position only — materializing [B, T, V]
    prompt logits is pure waste (a 537 GB tensor for recurrentgemma's 256K
    vocab at 32K context) since decoding continues from the last position."""
    if cfg.family in ("dense", "moe"):
        t = batch["tokens"].shape[1]
        cache = TF.init_cache(cfg, batch["tokens"].shape[0], t)
        logits, cache, _ = TF.forward(params, cfg, batch["tokens"], cache=cache,
                                      mesh=mesh, last_only=last_only)
        return logits, cache
    if cfg.family == "vlm":
        b = batch["tokens"].shape[0]
        t = batch["tokens"].shape[1] + batch["patches"].shape[1]
        cache = TF.init_cache(cfg, b, t)
        logits, cache, _ = TF.forward(params, cfg, batch["tokens"],
                                      prefix_embeds=batch["patches"],
                                      cache=cache, mesh=mesh,
                                      last_only=last_only)
        return logits, cache
    if cfg.family == "hybrid":
        logits, _, _ = RG.forward_hybrid(params, cfg, batch["tokens"],
                                         mesh=mesh, last_only=last_only)
        return logits, None
    if cfg.family == "ssm":
        logits, _, _ = XL.forward_xlstm(params, cfg, batch["tokens"],
                                        mesh=mesh, last_only=last_only)
        return logits, None
    if cfg.family == "encdec":
        b, t = batch["tokens"].shape
        cache = ED.init_encdec_cache(cfg, b, t)
        logits, cache, _ = ED.forward_encdec(params, cfg, batch["frames"],
                                             batch["tokens"], cache=cache,
                                             mesh=mesh, last_only=last_only)
        return logits, cache
    raise ValueError(cfg.family)


def decode(params, cfg: ModelConfig, batch: dict, *, mesh=None):
    if cfg.family in ("dense", "moe", "vlm"):
        return TF.decode_step(params, cfg, batch["cache"], batch["tokens"],
                              batch["pos"], mesh=mesh)
    if cfg.family == "hybrid":
        return RG.decode_step_hybrid(params, cfg, batch["state"],
                                     batch["tokens"], batch["pos"], mesh=mesh)
    if cfg.family == "ssm":
        return XL.decode_step_xlstm(params, cfg, batch["state"],
                                    batch["tokens"], batch["pos"], mesh=mesh)
    if cfg.family == "encdec":
        return ED.decode_step_encdec(params, cfg, batch["cache"],
                                     batch["memory"], batch["tokens"],
                                     batch["pos"], mesh=mesh)
    raise ValueError(cfg.family)


# --------------------------------------------------------------------------
# Inputs: concrete (smoke) and symbolic (dry-run)
# --------------------------------------------------------------------------

def _token_split(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """How a shape's seq_len budget maps to this family's streams."""
    t, b = shape.seq_len, shape.global_batch
    if cfg.family == "encdec":
        return {"enc": t // 2, "dec": t // 2, "batch": b}
    if cfg.family == "vlm":
        npatch = cfg.n_patches or 256
        return {"patches": npatch, "text": t - npatch, "batch": b}
    return {"text": t, "batch": b}


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sd = jax.ShapeDtypeStruct
    sp = _token_split(cfg, shape)
    b = sp["batch"]
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            out = {"frames": sd((b, sp["enc"], cfg.d_model), Compute),
                   "tokens": sd((b, sp["dec"]), i32)}
            if shape.kind == "train":
                out["labels"] = sd((b, sp["dec"]), i32)
            return out
        out = {"tokens": sd((b, sp["text"]), i32)}
        if cfg.family == "vlm":
            out["patches"] = sd((b, sp["patches"], cfg.d_model), Compute)
        if shape.kind == "train":
            out["labels"] = sd((b, sp["text"]), i32)
        return out

    # decode shapes: one new token against a seq_len-deep cache/state
    t_cache = shape.seq_len
    out = {"tokens": sd((b, 1), i32), "pos": sd((), i32)}
    if cfg.family in ("dense", "moe", "vlm"):
        cshape = (cfg.n_layers, b, t_cache, cfg.n_kv_heads, cfg.hd)
        out["cache"] = {"k": sd(cshape, Compute), "v": sd(cshape, Compute)}
    elif cfg.family == "encdec":
        cshape = (cfg.n_layers, b, t_cache, cfg.n_kv_heads, cfg.hd)
        out["cache"] = {"k": sd(cshape, Compute), "v": sd(cshape, Compute)}
        out["memory"] = sd((b, cfg.enc_frames_decode, cfg.d_model), Compute)
    elif cfg.family == "hybrid":
        out["state"] = jax.eval_shape(lambda: RG.init_hybrid_state(cfg, b))
    elif cfg.family == "ssm":
        out["state"] = jax.eval_shape(lambda: XL.init_xlstm_state(cfg, b))
    return out


def make_inputs(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0) -> dict:
    """Concrete random batch matching input_specs (for smoke tests)."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)

    def concrete(path_leaf):
        if isinstance(path_leaf, jax.ShapeDtypeStruct):
            if jnp.issubdtype(path_leaf.dtype, jnp.integer):
                hi = max(cfg.vocab_size - 1, 1)
                return jnp.asarray(rng.integers(0, hi, size=path_leaf.shape),
                                   path_leaf.dtype)
            return jnp.asarray(rng.normal(0, 0.02, size=path_leaf.shape)
                               .astype(np.float32), path_leaf.dtype)
        return path_leaf

    batch = jax.tree.map(concrete, specs)
    if "pos" in batch:
        # decode smoke tests write at a mid-cache position
        batch["pos"] = jnp.asarray(min(7, shape.seq_len - 2), jnp.int32)
    return batch
