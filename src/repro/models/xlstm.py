"""xLSTM (sLSTM + mLSTM) stack — the [ssm] architecture of the assignment.

mLSTM: matrix-memory cell with outer-product updates.  Training/prefill runs
the **chunkwise-parallel** form (intra-chunk quadratic with decay mask on the
MXU; inter-chunk recurrent state (C, n) carried by a ``lax.scan``) — the same
split the paper applies to DFA chunks: parallel within, compose across.
Decode is the O(1) recurrent step on (C, n).

sLSTM: strictly sequential scalar-memory cell (lax.scan over time).

Numerics note (DESIGN.md deviations): the published exponential input gate is
used with a clamp (|logit| <= 8) instead of the paper's running-max
stabilizer; forget gates are sigmoid.  Stable in bf16/fp32 and shape-faithful;
the stabilizer is orthogonal to the systems content.

Block pattern: 7 mLSTM : 1 sLSTM (config.block_pattern), d_ff = 0 — the cells
contain their own projections, there is no separate FFN.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L

__all__ = ["init_xlstm", "forward_xlstm", "init_xlstm_state", "decode_step_xlstm"]

CHUNK = 256
GATE_CLAMP = 8.0


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def init_mlstm_block(key, d_model: int, n_heads: int):
    ks = jax.random.split(key, 7)
    hd = d_model // n_heads
    s = d_model ** -0.5
    return {
        "ln": L.init_rmsnorm(d_model),
        "wq": L.truncated_normal(ks[0], (d_model, n_heads, hd), s),
        "wk": L.truncated_normal(ks[1], (d_model, n_heads, hd), s),
        "wv": L.truncated_normal(ks[2], (d_model, n_heads, hd), s),
        "wi": L.truncated_normal(ks[3], (d_model, n_heads), s),
        "wf": L.truncated_normal(ks[4], (d_model, n_heads), s),
        "wog": L.truncated_normal(ks[5], (d_model, d_model), s),
        "wo": L.truncated_normal(ks[6], (d_model, d_model), s),
    }


def _mlstm_gates(p, xn):
    i_log = jnp.clip(jnp.einsum("btd,dn->btn", xn, p["wi"].astype(L.Compute))
                     .astype(jnp.float32), -GATE_CLAMP, GATE_CLAMP)
    f = jax.nn.sigmoid(jnp.einsum("btd,dn->btn", xn, p["wf"].astype(L.Compute))
                       .astype(jnp.float32))
    return i_log, f


def mlstm_block(p, x, *, n_heads: int, eps: float, state=None):
    """x [B,T,D].  state = {"C": [B,N,h,h], "n": [B,N,h]} for decode."""
    b, t, d = x.shape
    hd = d // n_heads
    xn = L.rms_norm(p["ln"], x, eps)
    q = jnp.einsum("btd,dnh->btnh", xn, p["wq"].astype(L.Compute)) * hd ** -0.5
    k = jnp.einsum("btd,dnh->btnh", xn, p["wk"].astype(L.Compute))
    v = jnp.einsum("btd,dnh->btnh", xn, p["wv"].astype(L.Compute))
    i_log, f = _mlstm_gates(p, xn)

    if state is not None:  # single-step decode
        i = jnp.exp(i_log[:, 0])                                   # [B,N]
        f0 = f[:, 0]
        c_new = f0[..., None, None] * state["C"] + \
            i[..., None, None] * jnp.einsum("bnh,bng->bnhg",
                                            k[:, 0].astype(jnp.float32),
                                            v[:, 0].astype(jnp.float32))
        n_new = f0[..., None] * state["n"] + i[..., None] * k[:, 0].astype(jnp.float32)
        num = jnp.einsum("bnh,bnhg->bng", q[:, 0].astype(jnp.float32), c_new)
        den = jnp.abs(jnp.einsum("bnh,bnh->bn", q[:, 0].astype(jnp.float32), n_new))
        h = (num / jnp.maximum(den, 1.0)[..., None])[:, None]      # [B,1,N,h]
        new_state = {"C": c_new, "n": n_new}
    else:  # chunkwise-parallel training/prefill
        ck = min(CHUNK, t)
        assert t % ck == 0, (t, CHUNK)
        nc = t // ck
        def resh(a):
            return a.reshape(b, nc, ck, *a.shape[2:]).swapaxes(0, 1)
        qc, kc, vc = resh(q), resh(k), resh(v)
        ic, fc = resh(i_log), resh(f)

        def chunk_step(carry, xs):
            # §Perf iteration 4: bf16 tiles, fp32 gates/state/accumulation.
            # The [B,K,K,N] decay/score tiles dominated the xlstm prefill
            # memory term (937 s census) in fp32; bf16 halves them while the
            # recurrent state (C, n) and the gate log-space math stay fp32.
            C, n = carry                       # [B,N,h,h], [B,N,h] fp32
            qj, kj, vj, ij, fj = xs
            lf = jnp.log(jnp.maximum(fj, 1e-9))          # [B,K,N] fp32 (tiny)
            cum = jnp.cumsum(lf, axis=1)                  # inclusive
            total = cum[:, -1:]
            # intra-chunk decay: D[t,s] = exp(cum_t - cum_s + i_s), s <= t
            dmat = cum[:, :, None] - cum[:, None, :] + ij[:, None, :]
            mask = jnp.tril(jnp.ones((ck, ck), bool))
            dmat = jnp.where(mask[None, :, :, None], dmat, -1e30)
            dexp = jnp.exp(jnp.minimum(dmat, GATE_CLAMP))
            # fp32 product, single rounding into the stored bf16 tile
            scores = (jnp.einsum("btnh,bsnh->btsn", qj, kj,
                                 preferred_element_type=jnp.float32)
                      * dexp).astype(L.Compute)           # [B,t,s,N] bf16
            intra = jnp.einsum("btsn,bsnh->btnh", scores, vj,
                               preferred_element_type=jnp.float32)
            # inter-chunk: state decayed to position t
            qdec = jnp.exp(cum)[..., None] * qj.astype(jnp.float32)
            inter = jnp.einsum("btnh,bnhg->btng", qdec, C)
            inter_n = jnp.einsum("btnh,bnh->btn", qdec, n)
            num = intra + inter
            # normalizer: q . n_t = sum_s decay_s * (q . k_s)  (= scores summed)
            den = jnp.abs(scores.astype(jnp.float32).sum(axis=2) + inter_n)
            h = num / jnp.maximum(den, 1.0)[..., None]
            # state update: C' = F C + sum_s exp(total - cum_s + i_s) k v^T
            w = jnp.exp(total - cum + ij).astype(L.Compute)   # [B,K,N]
            kv = jnp.einsum("bsn,bsnh,bsng->bnhg", w, kj, vj,
                            preferred_element_type=jnp.float32)
            ksum = jnp.einsum("bsn,bsnh->bnh", w, kj,
                              preferred_element_type=jnp.float32)
            ftot = jnp.exp(total[:, 0])[..., None]
            C = ftot[..., None] * C + kv
            n = ftot * n + ksum
            return (C, n), h

        c0 = jnp.zeros((b, n_heads, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, n_heads, hd), jnp.float32)
        (_, _), hs = jax.lax.scan(chunk_step, (c0, n0), (qc, kc, vc, ic, fc))
        h = hs.swapaxes(0, 1).reshape(b, t, n_heads, hd)
        new_state = None

    og = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xn, p["wog"].astype(L.Compute)))
    y = jnp.einsum("bte,ed->btd", h.reshape(b, -1, d).astype(L.Compute) * og,
                   p["wo"].astype(L.Compute))
    return x + y, new_state


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def init_slstm_block(key, d_model: int):
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    return {
        "ln": L.init_rmsnorm(d_model),
        "wz": L.truncated_normal(ks[0], (d_model, d_model), s),
        "wi": L.truncated_normal(ks[1], (d_model, d_model), s),
        "wf": L.truncated_normal(ks[2], (d_model, d_model), s),
        "wo_gate": L.truncated_normal(ks[3], (d_model, d_model), s),
        "wo": L.truncated_normal(ks[4], (d_model, d_model), s),
    }


def slstm_block(p, x, *, eps: float, state=None):
    """Sequential scalar-memory cell.  state = {"c": [B,D], "n": [B,D]}."""
    xn = L.rms_norm(p["ln"], x, eps)
    z = jnp.tanh(jnp.einsum("btd,de->bte", xn, p["wz"].astype(L.Compute))
                 .astype(jnp.float32))
    i = jnp.exp(jnp.clip(jnp.einsum("btd,de->bte", xn, p["wi"].astype(L.Compute))
                         .astype(jnp.float32), -GATE_CLAMP, GATE_CLAMP))
    f = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xn, p["wf"].astype(L.Compute))
                       .astype(jnp.float32))
    o = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xn, p["wo_gate"].astype(L.Compute)))

    if state is not None:
        c = f[:, 0] * state["c"] + i[:, 0] * z[:, 0]
        n = f[:, 0] * state["n"] + i[:, 0]
        h = (c / jnp.maximum(n, 1.0))[:, None]
        new_state = {"c": c, "n": n}
    else:
        def step(carry, xs):
            c, n = carry
            zt, it, ft = xs
            c = ft * c + it * zt
            n = ft * n + it
            return (c, n), c / jnp.maximum(n, 1.0)
        b, t, d = x.shape
        c0 = jnp.zeros((b, d), jnp.float32)
        (_, _), hs = jax.lax.scan(step, (c0, c0),
                                  (z.swapaxes(0, 1), i.swapaxes(0, 1), f.swapaxes(0, 1)))
        h = hs.swapaxes(0, 1)
        new_state = None
    y = jnp.einsum("bte,ed->btd", h.astype(L.Compute) * o, p["wo"].astype(L.Compute))
    return x + y, new_state


# --------------------------------------------------------------------------
# Stack assembly (pattern groups, like recurrent.py)
# --------------------------------------------------------------------------

def _pattern_layout(cfg: ModelConfig):
    pat = cfg.block_pattern or ("mlstm",) * 7 + ("slstm",)
    n_groups = cfg.n_layers // len(pat)
    return n_groups, pat, pat[: cfg.n_layers - n_groups * len(pat)]


def init_group(cfg: ModelConfig, key, pattern):
    ks = jax.random.split(key, len(pattern))
    out = {}
    for i, (kind, k) in enumerate(zip(pattern, ks)):
        out[f"b{i}_{kind}"] = (init_mlstm_block(k, cfg.d_model, cfg.n_heads)
                               if kind == "mlstm" else init_slstm_block(k, cfg.d_model))
    return out


def init_xlstm(cfg: ModelConfig, key) -> dict:
    n_groups, pat, tail = _pattern_layout(cfg)
    ks = jax.random.split(key, 4)
    params = {
        "embed": L.init_embedding(ks[1], cfg.padded_vocab, cfg.d_model),
        "groups": jax.vmap(functools.partial(init_group, cfg, pattern=pat))(
            jax.random.split(ks[0], n_groups)),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if tail:
        params["tail"] = init_group(cfg, ks[2], tail)
    if not cfg.tie_embeddings:
        params["head"] = L.init_dense(ks[3], cfg.d_model, cfg.padded_vocab)
    return params


def _run_pattern(cfg, x, gp, pattern, states=None, decode=False):
    new_states = {}
    for i, kind in enumerate(pattern):
        key = f"b{i}_{kind}"
        st = states[key] if states is not None else None
        if kind == "mlstm":
            x, ns = mlstm_block(gp[key], x, n_heads=cfg.n_heads,
                                eps=cfg.norm_eps, state=st)
        else:
            x, ns = slstm_block(gp[key], x, eps=cfg.norm_eps, state=st)
        if decode:
            new_states[key] = ns
    return x, new_states


def forward_xlstm(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
                  mesh=None, last_only: bool = False):
    n_groups, pat, tail = _pattern_layout(cfg)
    x = L.embed(params["embed"], tokens)

    def body(x, gp):
        x, _ = _run_pattern(cfg, x, gp, pat)
        return x, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat_policy != "none" else body
    x, _ = jax.lax.scan(body, x, params["groups"])
    if tail:
        x, _ = _run_pattern(cfg, x, params["tail"], tail)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = (L.unembed(params["embed"], x) if cfg.tie_embeddings
              else L.dense(params["head"], x))
    return logits, None, jnp.float32(0)


def _group_state(cfg: ModelConfig, batch: int, pattern):
    hd = cfg.d_model // cfg.n_heads
    st = {}
    for i, kind in enumerate(pattern):
        if kind == "mlstm":
            st[f"b{i}_{kind}"] = {
                "C": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
                "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
            }
        else:
            st[f"b{i}_{kind}"] = {
                "c": jnp.zeros((batch, cfg.d_model), jnp.float32),
                "n": jnp.zeros((batch, cfg.d_model), jnp.float32),
            }
    return st


def init_xlstm_state(cfg: ModelConfig, batch: int) -> dict:
    n_groups, pat, tail = _pattern_layout(cfg)
    state = {"groups": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape),
        _group_state(cfg, batch, pat))}
    if tail:
        state["tail"] = _group_state(cfg, batch, tail)
    return state


def decode_step_xlstm(params: dict, cfg: ModelConfig, state: dict,
                      tokens: jnp.ndarray, pos, *, mesh=None):
    n_groups, pat, tail = _pattern_layout(cfg)
    x = L.embed(params["embed"], tokens)

    def body(x, xs):
        gp, st = xs
        x, ns = _run_pattern(cfg, x, gp, pat, states=st, decode=True)
        return x, ns

    x, new_groups = jax.lax.scan(body, x, (params["groups"], state["groups"]))
    new_state = {"groups": new_groups}
    if tail:
        x, new_state["tail"] = _run_pattern(cfg, x, params["tail"], tail,
                                            states=state["tail"], decode=True)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = (L.unembed(params["embed"], x) if cfg.tie_embeddings
              else L.dense(params["head"], x))
    return logits, new_state
