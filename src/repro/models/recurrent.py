"""Hybrid RG-LRU + local-attention model (RecurrentGemma / Griffin family).

Block pattern (config.block_pattern, default ("rglru","rglru","attn")) is
scanned in groups; remainder layers are unrolled.  The RG-LRU training pass
uses ``jax.lax.associative_scan`` over the linear recurrence
``h_t = a_t * h_{t-1} + b_t`` — the same compose-maps algebra as the paper's
L-vector merge (DESIGN.md §3.3): elements (a, b) compose as
``(a2*a1, a2*b1 + b2)``.

Decode state: RG-LRU hidden [B, d_rnn] + causal-conv tail [B, 3, d_rnn] per
recurrent layer; ring-buffer KV cache of ``attn_window`` slots per attention
layer, so long_500k decode memory is O(window), not O(T) — this is what makes
the arch eligible for the 524K shape.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from .attention_core import direct_attention

__all__ = ["init_hybrid", "forward_hybrid", "init_hybrid_state", "decode_step_hybrid"]

CONV_W = 4  # causal temporal-conv width (Griffin)


# --------------------------------------------------------------------------
# RG-LRU
# --------------------------------------------------------------------------

def init_rglru_block(key, d_model: int, d_rnn: int, d_ff: int):
    ks = jax.random.split(key, 7)
    return {
        "ln2": L.init_rmsnorm(d_model),
        "mlp": L.init_mlp(ks[6], d_model, d_ff),
        "ln": L.init_rmsnorm(d_model),
        "wx": L.truncated_normal(ks[0], (d_model, d_rnn), d_model ** -0.5),
        "wgate": L.truncated_normal(ks[1], (d_model, d_rnn), d_model ** -0.5),
        "conv_w": L.truncated_normal(ks[2], (CONV_W, d_rnn), CONV_W ** -0.5),
        "conv_b": jnp.zeros((d_rnn,), jnp.float32),
        "w_r": L.truncated_normal(ks[3], (d_rnn, d_rnn), d_rnn ** -0.5),
        "w_i": L.truncated_normal(ks[4], (d_rnn, d_rnn), d_rnn ** -0.5),
        "lam": jnp.linspace(0.9, 0.999, d_rnn).astype(jnp.float32),  # a ~ U
        "wo": L.truncated_normal(ks[5], (d_rnn, d_model), d_rnn ** -0.5),
    }


def _rglru_coeffs(p, u):
    """Per-step gate coefficients: h_t = a_t * h_{t-1} + b_t."""
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", u, p["w_r"].astype(L.Compute))
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("btd,de->bte", u, p["w_i"].astype(L.Compute))
                       .astype(jnp.float32))
    log_a = -8.0 * jax.nn.softplus(p["lam"]) * r          # [B,T,d_rnn] fp32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * u.astype(jnp.float32)
    return a, b


def _chunked_linear_scan(a, b, *, chunk: int = 2048):
    """h_t = a_t * h_{t-1} + b_t via chunked prefix scan (§Perf iteration 2).

    A full-length ``associative_scan`` over T = 32K materializes ~log2(T)
    levels of [B, T, d_rnn] fp32 intermediates (134 GB/device temp in the
    baseline dry-run).  Chunking bounds the parallel-scan working set to the
    chunk while the cross-chunk carry stays sequential — the same
    parallel-within / compose-across split the paper applies to DFA chunks.
    """
    bsz, t, d = a.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    if nc == 1:
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        return h

    a_c = a.reshape(bsz, nc, chunk, d).swapaxes(0, 1)
    b_c = b.reshape(bsz, nc, chunk, d).swapaxes(0, 1)

    def step(h_in, xs):
        ac, bc = xs
        acoef, bcoef = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_out = acoef * h_in[:, None] + bcoef
        return h_out[:, -1], h_out

    h0 = jnp.zeros((bsz, d), a.dtype)
    _, hs = jax.lax.scan(step, h0, (a_c, b_c))
    return hs.swapaxes(0, 1).reshape(bsz, t, d)


def _causal_conv(p, u, tail=None):
    """Depthwise causal conv width CONV_W; tail [B, CONV_W-1, d] for decode."""
    pad = jnp.zeros((u.shape[0], CONV_W - 1, u.shape[2]), u.dtype) if tail is None \
        else tail.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)
    out = sum(ext[:, i : i + u.shape[1]] * p["conv_w"][i].astype(u.dtype)
              for i in range(CONV_W))
    return out + p["conv_b"].astype(u.dtype), ext[:, -(CONV_W - 1):]


def rglru_block(p, x, *, eps: float, state=None):
    """x [B,T,D] -> (y, new_state).  state = {"h": [B,d], "conv": [B,3,d]}."""
    xn = L.rms_norm(p["ln"], x, eps)
    u = jnp.einsum("btd,de->bte", xn, p["wx"].astype(L.Compute))
    gate = jnp.einsum("btd,de->bte", xn, p["wgate"].astype(L.Compute))
    u, conv_tail = _causal_conv(p, u, None if state is None else state["conv"])
    a, b = _rglru_coeffs(p, u)
    if state is None:
        h = _chunked_linear_scan(a, b, chunk=2048)
        new_state = None
    else:
        h = a[:, 0] * state["h"] + b[:, 0]                # single step (T==1)
        new_state = {"h": h, "conv": conv_tail}
        h = h[:, None]
    y = jnp.einsum("bte,ed->btd", (h.astype(L.Compute) * jax.nn.gelu(gate)),
                   p["wo"].astype(L.Compute))
    x = x + y
    x = x + L.swiglu_mlp(p["mlp"], L.rms_norm(p["ln2"], x, eps))
    return x, new_state


# --------------------------------------------------------------------------
# Local attention with ring-buffer cache
# --------------------------------------------------------------------------

def init_attn_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.hd),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def attn_block(p, x, cfg: ModelConfig, *, positions):
    h, _ = L.attention(p["attn"], L.rms_norm(p["ln"], x, cfg.norm_eps),
                       positions=positions, rope_theta=cfg.rope_theta,
                       window=cfg.attn_window)
    x = x + h
    return x + L.swiglu_mlp(p["mlp"], L.rms_norm(p["ln2"], x, cfg.norm_eps))


def init_ring_cache(cfg: ModelConfig, batch: int):
    w = cfg.attn_window
    return {
        "k": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.hd), L.Compute),
        "v": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.hd), L.Compute),
    }


def attn_block_decode(p, x, cfg: ModelConfig, ring: dict, pos):
    """Single-token local attention against the ring buffer."""
    w = cfg.attn_window
    xn = L.rms_norm(p["ln"], x, cfg.norm_eps)
    ap = p["attn"]
    q = jnp.einsum("btd,dnh->btnh", xn, ap["wq"].astype(L.Compute))
    k = jnp.einsum("btd,dkh->btkh", xn, ap["wk"].astype(L.Compute))
    v = jnp.einsum("btd,dkh->btkh", xn, ap["wv"].astype(L.Compute))
    q = L.rope(q, pos + jnp.zeros((1, 1), jnp.int32), cfg.rope_theta)
    k = L.rope(k, pos + jnp.zeros((1, 1), jnp.int32), cfg.rope_theta)
    slot = pos % w
    rk = jax.lax.dynamic_update_slice_in_dim(ring["k"], k, slot, axis=1)
    rv = jax.lax.dynamic_update_slice_in_dim(ring["v"], v, slot, axis=1)
    # absolute position held by each slot (within the last w writes)
    idx = jnp.arange(w)
    slot_pos = pos - (pos - idx) % w
    b, t = x.shape[0], 1
    n_kv = k.shape[2]
    qg = q.reshape(b, t, n_kv, -1, q.shape[-1])
    # keys were stored post-rope at their absolute positions; mask invalids
    logits = jnp.einsum("btkgh,bskh->bkgts", qg, rk).astype(jnp.float32)
    logits *= q.shape[-1] ** -0.5
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    logits = jnp.where(ok[None, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(L.Compute)
    ctx = jnp.einsum("bkgts,bskh->btkgh", probs, rv).reshape(b, t, -1, q.shape[-1])
    h = jnp.einsum("btnh,nhd->btd", ctx.reshape(b, t, cfg.n_heads, -1),
                   ap["wo"].astype(L.Compute))
    x = x + h
    x = x + L.swiglu_mlp(p["mlp"], L.rms_norm(p["ln2"], x, cfg.norm_eps))
    return x, {"k": rk, "v": rv}


# --------------------------------------------------------------------------
# Model assembly
# --------------------------------------------------------------------------

def _pattern_layout(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    pat = cfg.block_pattern or ("rglru", "rglru", "attn")
    n_groups = cfg.n_layers // len(pat)
    tail = cfg.n_layers - n_groups * len(pat)
    return n_groups, pat[:tail]


def init_group(cfg: ModelConfig, key, pattern):
    ks = jax.random.split(key, len(pattern))
    d_rnn = cfg.rglru_dim or cfg.d_model
    out = {}
    for i, (kind, k) in enumerate(zip(pattern, ks)):
        out[f"b{i}_{kind}"] = (init_rglru_block(k, cfg.d_model, d_rnn, cfg.d_ff)
                               if kind == "rglru" else init_attn_block(k, cfg))
    return out


def init_hybrid(cfg: ModelConfig, key) -> dict:
    pat = cfg.block_pattern or ("rglru", "rglru", "attn")
    n_groups, tail = _pattern_layout(cfg)
    ks = jax.random.split(key, 4)
    gkeys = jax.random.split(ks[0], n_groups)
    params = {
        "embed": L.init_embedding(ks[1], cfg.padded_vocab, cfg.d_model),
        "groups": jax.vmap(functools.partial(init_group, cfg, pattern=pat))(gkeys),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if tail:
        params["tail"] = init_group(cfg, ks[2], tail)
    if not cfg.tie_embeddings:
        params["head"] = L.init_dense(ks[3], cfg.d_model, cfg.padded_vocab)
    return params


def forward_hybrid(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
                   mesh=None, last_only: bool = False):
    """Training / prefill forward. Returns (logits, None, aux=0)."""
    pat = cfg.block_pattern or ("rglru", "rglru", "attn")
    _, tail = _pattern_layout(cfg)
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(x.shape[1])[None, :]

    def run_pattern(x, gp, pattern):
        for i, kind in enumerate(pattern):
            p = gp[f"b{i}_{kind}"]
            if kind == "rglru":
                x, _ = rglru_block(p, x, eps=cfg.norm_eps)
            else:
                x = attn_block(p, x, cfg, positions=positions)
        return x

    def body(x, gp):
        return run_pattern(x, gp, pat), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat_policy != "none" else body
    x, _ = jax.lax.scan(body, x, params["groups"])
    if tail:
        x = run_pattern(x, params["tail"], tail)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    head = (L.unembed(params["embed"], x) if cfg.tie_embeddings
            else L.dense(params["head"], x))
    return head, None, jnp.float32(0)


def _group_state(cfg: ModelConfig, batch: int, pattern) -> dict:
    d_rnn = cfg.rglru_dim or cfg.d_model
    st = {}
    for i, kind in enumerate(pattern):
        if kind == "rglru":
            st[f"b{i}_{kind}"] = {
                "h": jnp.zeros((batch, d_rnn), jnp.float32),
                "conv": jnp.zeros((batch, CONV_W - 1, d_rnn), L.Compute),
            }
        else:
            st[f"b{i}_{kind}"] = init_ring_cache(cfg, batch)
    return st


def init_hybrid_state(cfg: ModelConfig, batch: int) -> dict:
    pat = cfg.block_pattern or ("rglru", "rglru", "attn")
    n_groups, tail = _pattern_layout(cfg)
    state = {"groups": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape),
        _group_state(cfg, batch, pat))}
    if tail:
        state["tail"] = _group_state(cfg, batch, tail)
    return state


def decode_step_hybrid(params: dict, cfg: ModelConfig, state: dict,
                       tokens: jnp.ndarray, pos, *, mesh=None):
    """One-token decode: O(window + d_rnn) state, O(1) in sequence length."""
    pat = cfg.block_pattern or ("rglru", "rglru", "attn")
    _, tail = _pattern_layout(cfg)
    x = L.embed(params["embed"], tokens)

    def run_pattern(x, gp, st, pattern):
        new = {}
        for i, kind in enumerate(pattern):
            key = f"b{i}_{kind}"
            if kind == "rglru":
                x, new[key] = rglru_block(gp[key], x, eps=cfg.norm_eps, state=st[key])
            else:
                x, new[key] = attn_block_decode(gp[key], x, cfg, st[key], pos)
        return x, new

    def body(x, xs):
        gp, st = xs
        x, new = run_pattern(x, gp, st, pat)
        return x, new

    x, new_groups = jax.lax.scan(body, x, (params["groups"], state["groups"]))
    new_state = {"groups": new_groups}
    if tail:
        x, new_state["tail"] = run_pattern(x, params["tail"], state["tail"], tail)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = (L.unembed(params["embed"], x) if cfg.tie_embeddings
              else L.dense(params["head"], x))
    return logits, new_state
