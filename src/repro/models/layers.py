"""Functional NN layers: GQA attention (+RoPE, windows, KV cache), SwiGLU,
RMSNorm, embeddings.

Everything is pure-functional over param pytrees (nested dicts of jnp arrays).
Matmuls are einsums with legible axis names; sharding is applied from the
outside via path-based PartitionSpec rules (distributed/sharding.py), so these
layers contain no mesh-specific code.  Compute dtype is bf16 with fp32 params
(cast at use) and fp32 softmax/norm accumulation.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "init_dense", "dense", "init_rmsnorm", "rms_norm", "init_embedding",
    "embed", "unembed", "rope", "init_attention", "attention",
    "init_kv_cache_layer", "init_mlp", "swiglu_mlp", "truncated_normal",
]

Compute = jnp.bfloat16


def truncated_normal(key, shape, scale: float, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * scale


def init_dense(key, d_in: int, d_out: int, *, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": truncated_normal(key, (d_in, d_out), scale)}


def dense(p, x):
    return jnp.einsum("...i,io->...o", x, p["w"].astype(Compute))


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(Compute)


def init_embedding(key, vocab: int, d: int):
    return {"table": truncated_normal(key, (vocab, d), 1.0)}


def embed(p, tokens):
    return p["table"].astype(Compute)[tokens]


def unembed(p, x):
    return jnp.einsum("...d,vd->...v", x, p["table"].astype(Compute))


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding. x [..., T, H, D]; positions [..., T]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angle = positions[..., :, None, None].astype(jnp.float32) * freq  # [..., T, 1, half]
    sin, cos = jnp.sin(angle), jnp.cos(angle)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA; full-causal, local-window, or cross)
# --------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int):
    ks = jax.random.split(key, 4)
    return {
        "wq": truncated_normal(ks[0], (d_model, n_heads, head_dim), d_model ** -0.5),
        "wk": truncated_normal(ks[1], (d_model, n_kv, head_dim), d_model ** -0.5),
        "wv": truncated_normal(ks[2], (d_model, n_kv, head_dim), d_model ** -0.5),
        "wo": truncated_normal(ks[3], (n_heads, head_dim, d_model),
                               (n_heads * head_dim) ** -0.5),
    }


def init_kv_cache_layer(batch: int, n_kv: int, max_len: int, head_dim: int):
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), Compute),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), Compute),
    }


def attention(p, x, *, positions, rope_theta: float, window: int = 0,
              cache: Optional[dict] = None, cache_index=None,
              memory: Optional[jnp.ndarray] = None, causal: bool = True,
              q_block: int = 512, kv_block: int = 1024):
    """GQA attention.

    x [B, T, D].  Modes:
      * self-attention over x (causal or bidirectional),
      * cross-attention to ``memory`` [B, S, D] (causal=False, no rope),
      * incremental decode when ``cache``/``cache_index`` are given: x is the
        new token block, K/V are written at cache_index.
    Long queries run the blockwise flash path; short (decode) queries run the
    direct path.  Returns (out [B, T, D], new_cache).
    """
    from .attention_core import direct_attention, flash_attention

    # Fused Pallas attention for inference prefill (no autodiff through it):
    # "auto" enables it on TPU; "1" forces it (interpret mode off-TPU, used by
    # tests).  Training keeps the XLA path (differentiable).
    pallas_mode = os.environ.get("REPRO_PALLAS_ATTN", "auto")

    b, t, d = x.shape
    q = jnp.einsum("btd,dnh->btnh", x, p["wq"].astype(Compute))
    src = memory if memory is not None else x
    k = jnp.einsum("bsd,dkh->bskh", src, p["wk"].astype(Compute))
    v = jnp.einsum("bsd,dkh->bskh", src, p["wv"].astype(Compute))

    if memory is None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)

    new_cache = None
    kv_valid = None
    q_offset = 0
    if cache is not None:
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_index, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_index, axis=1)
        new_cache = {"k": k, "v": v}
        kv_valid = cache_index + t
        q_offset = cache_index

    n_heads = q.shape[2]
    n_kv = k.shape[2]
    group = n_heads // n_kv
    qg = q.reshape(b, t, n_kv, group, q.shape[-1])
    is_causal = causal and memory is None
    use_pallas = (cache is not None and t > 16 and t == k.shape[1]
                  and (pallas_mode == "1"
                       or (pallas_mode == "auto"
                           and jax.default_backend() == "tpu")))
    if use_pallas:
        # prefill: full prompt, kv_valid == t -> kernel mask is exact
        from ..kernels import ops as kops
        hd = q.shape[-1]
        qf = qg.transpose(0, 2, 3, 1, 4).reshape(b * n_kv * group, t, hd)
        kf = jnp.repeat(k.transpose(0, 2, 1, 3), group, axis=1)             .reshape(b * n_kv * group, t, hd)
        vf = jnp.repeat(v.transpose(0, 2, 1, 3), group, axis=1)             .reshape(b * n_kv * group, t, hd)
        ctx = kops.flash_attn(qf, kf, vf, causal=is_causal, window=window,
                              interpret=pallas_mode == "1")
        ctx = ctx.reshape(b, n_kv, group, t, hd).transpose(0, 3, 1, 2, 4)
    elif t > 16:
        ctx = flash_attention(qg, k, v, q_offset=q_offset, causal=is_causal,
                              window=window, kv_valid=kv_valid,
                              q_block=q_block, kv_block=kv_block)
    else:
        ctx = direct_attention(qg, k, v, q_offset=q_offset, causal=is_causal,
                               window=window, kv_valid=kv_valid)
    ctx = ctx.reshape(b, t, n_heads, -1)
    out = jnp.einsum("btnh,nhd->btd", ctx, p["wo"].astype(Compute))
    return out, new_cache


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return {
        "wi_gate": truncated_normal(ks[0], (d_model, d_ff), d_model ** -0.5),
        "wi_up": truncated_normal(ks[1], (d_model, d_ff), d_model ** -0.5),
        "wo": truncated_normal(ks[2], (d_ff, d_model), d_ff ** -0.5),
    }


def swiglu_mlp(p, x):
    gate = jnp.einsum("btd,df->btf", x, p["wi_gate"].astype(Compute))
    up = jnp.einsum("btd,df->btf", x, p["wi_up"].astype(Compute))
    return jnp.einsum("btf,fd->btd", jax.nn.silu(gate) * up, p["wo"].astype(Compute))
