"""Mixture-of-Experts FFN with top-k token-choice routing.

Two execution paths with identical semantics:

  * ``local``    — one-hot dispatch einsum on this device's tokens; used for
                   CPU smoke tests and single-device runs.
  * ``sharded``  — ``shard_map`` expert parallelism: tokens are locally
                   dispatched into per-expert capacity buffers, exchanged with
                   ``all_to_all`` over the ``model`` mesh axis (experts live
                   there), FFN'd, and returned.  This is the production EP
                   path; the all-to-all pair is the collective the roofline
                   attributes to MoE layers.

Capacity: per-group capacity C = ceil(tokens * top_k * capacity_factor / E);
overflowing tokens are dropped (their residual stream passes through), the
standard GShard/Switch behaviour.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import Compute, truncated_normal

__all__ = ["init_moe", "moe_mlp"]


def init_moe(key, d_model: int, d_ff: int, n_experts: int):
    ks = jax.random.split(key, 4)
    return {
        "router": truncated_normal(ks[0], (d_model, n_experts), d_model ** -0.5),
        "wi_gate": truncated_normal(ks[1], (n_experts, d_model, d_ff), d_model ** -0.5),
        "wi_up": truncated_normal(ks[2], (n_experts, d_model, d_ff), d_model ** -0.5),
        "wo": truncated_normal(ks[3], (n_experts, d_ff, d_model), d_ff ** -0.5),
    }


def _route(p, x_flat, top_k: int):
    """Router: probs -> top-k (gates renormalized, Mixtral-style)."""
    logits = jnp.einsum("nd,de->ne", x_flat, p["router"].astype(Compute))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)              # [n, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch eq. 4): E * sum_e f_e * p_e
    e = probs.shape[-1]
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(
        jnp.ones_like(ids.reshape(-1), jnp.float32)) / ids.size
    aux = e * jnp.sum(me * ce)
    return gates.astype(Compute), ids, aux


def _dispatch_tensors(ids, gates, n_experts: int, capacity: int):
    """Position-in-expert assignment -> dispatch/combine one-hots.

    ids [n, k] int32, gates [n, k].  Returns
      dispatch [n, E, C] bool-ish Compute, combine [n, E, C] Compute.
    """
    n, k = ids.shape
    flat_ids = ids.reshape(-1)                            # [n*k], token-major
    onehot = jax.nn.one_hot(flat_ids, n_experts, dtype=jnp.int32)  # [n*k, E]
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot   # rank within expert
    pos = (pos_in_expert * onehot).sum(-1)                # [n*k]
    keep = pos < capacity
    disp = (jax.nn.one_hot(flat_ids, n_experts, dtype=Compute)[:, :, None]
            * jax.nn.one_hot(pos, capacity, dtype=Compute)[:, None, :]
            * keep[:, None, None].astype(Compute))        # [n*k, E, C]
    disp = disp.reshape(n, k, n_experts, capacity)
    combine = disp * gates[..., None, None]
    return disp.sum(1), combine.sum(1)                    # [n, E, C]


def _expert_ffn(p, h):
    """h [E, C, d] -> [E, C, d] SwiGLU per expert (E-major grouped GEMM)."""
    gate = jnp.einsum("ecd,edf->ecf", h, p["wi_gate"].astype(Compute))
    up = jnp.einsum("ecd,edf->ecf", h, p["wi_up"].astype(Compute))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, p["wo"].astype(Compute))


def moe_mlp(p, x, *, top_k: int, capacity_factor: float = 1.25,
            mesh: Optional[jax.sharding.Mesh] = None,
            expert_axis: str = "model",
            batch_axes: tuple[str, ...] = ("pod", "data")):
    """x [B, T, D] -> ([B, T, D], aux_loss)."""
    if mesh is None or expert_axis not in mesh.axis_names:
        return _moe_local(p, x, top_k, capacity_factor)
    return _moe_sharded(p, x, top_k, capacity_factor, mesh, expert_axis, batch_axes)


def _moe_local(p, x, top_k, capacity_factor):
    b, t, d = x.shape
    e = p["router"].shape[1]
    x_flat = x.reshape(-1, d)
    n = x_flat.shape[0]
    capacity = max(top_k, int(math.ceil(n * top_k * capacity_factor / e)))
    gates, ids, aux = _route(p, x_flat, top_k)
    disp, combine = _dispatch_tensors(ids, gates, e, capacity)
    buf = jnp.einsum("nd,nec->ecd", x_flat, disp)          # [E, C, d]
    h = _expert_ffn(p, buf)
    out = jnp.einsum("ecd,nec->nd", h, combine)
    return out.reshape(b, t, d), aux


def _moe_sharded(p, x, top_k, capacity_factor, mesh, expert_axis, batch_axes):
    """shard_map EP: local dispatch + all_to_all over the expert axis.

    Inside the region each device holds a [b_loc, t_loc, d] block (sequence
    additionally split over the expert/model axis so routing work is spread),
    builds [E, C_loc, d] send buffers, and exchanges them so each device runs
    its resident experts on tokens from every peer.
    """
    from ..jax_compat import shard_map

    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    ep = mesh.shape[expert_axis]
    e = p["router"].shape[1]
    assert e % ep == 0, (e, ep)

    dp_size = math.prod(mesh.shape[a] for a in axes) if axes else 1
    batch_spec = axes if (axes and x.shape[0] % dp_size == 0 and dp_size > 1) \
        else None
    # split the sequence over the expert axis too when it divides (spreads
    # routing work); decode steps (t == 1) keep the sequence whole.
    seq_spec = expert_axis if x.shape[1] % ep == 0 else None

    in_specs = (
        {  # params: experts sharded over the expert axis, router replicated
            "router": P(),
            "wi_gate": P(expert_axis), "wi_up": P(expert_axis), "wo": P(expert_axis),
        },
        P(batch_spec, seq_spec, None),
    )
    out_specs = (P(batch_spec, seq_spec, None), P())

    def body(p_loc, x_loc):
        b_loc, t_loc, d = x_loc.shape
        x_flat = x_loc.reshape(-1, d)
        n = x_flat.shape[0]
        capacity = max(top_k, int(math.ceil(n * top_k * capacity_factor / e)))
        gates, ids, aux = _route(p_loc, x_flat, top_k)
        disp, combine = _dispatch_tensors(ids, gates, e, capacity)
        send = jnp.einsum("nd,nec->ecd", x_flat, disp)     # [E, C, d]
        # exchange: split expert dim, concat capacity dim across the axis
        recv = jax.lax.all_to_all(send, expert_axis, split_axis=0, concat_axis=1,
                                  tiled=True)              # [E/ep, ep*C, d]
        h = _expert_ffn(p_loc, recv)
        back = jax.lax.all_to_all(h, expert_axis, split_axis=1, concat_axis=0,
                                  tiled=True)               # [E, C, d]
        out = jnp.einsum("ecd,nec->nd", back, combine)
        aux = jax.lax.pmean(aux, expert_axis)
        for a in axes:
            aux = jax.lax.pmean(aux, a)
        return out.reshape(b_loc, t_loc, d), aux

    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    return fn(p, x)
