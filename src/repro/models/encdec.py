"""Encoder-decoder backbone (seamless-m4t-medium assignment entry).

Per the assignment, the audio/multimodal frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings [B, T_enc, D] directly to the encoder.
The decoder is a standard causal stack with cross-attention to the encoder
memory.  Shape convention for LM shapes (DESIGN.md): for train/prefill the
seq_len budget is split evenly between encoder frames and decoder tokens; for
decode shapes the decoder KV cache has seq_len slots and the encoder memory
length is config.enc_frames_decode.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L

__all__ = ["init_encdec", "forward_encdec", "encode", "init_encdec_cache",
           "decode_step_encdec"]


def init_enc_layer(cfg: ModelConfig, key):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.hd),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def init_dec_layer(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "self": L.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.hd),
        "ln_x": L.init_rmsnorm(cfg.d_model),
        "cross": L.init_attention(ks[1], cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.hd),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff),
    }


def init_encdec(cfg: ModelConfig, key) -> dict:
    n_enc = cfg.n_enc_layers or cfg.n_layers
    ks = jax.random.split(key, 4)
    return {
        "embed": L.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
        "enc": jax.vmap(functools.partial(init_enc_layer, cfg))(
            jax.random.split(ks[1], n_enc)),
        "dec": jax.vmap(functools.partial(init_dec_layer, cfg))(
            jax.random.split(ks[2], cfg.n_layers)),
        "enc_norm": L.init_rmsnorm(cfg.d_model),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "head": L.init_dense(ks[3], cfg.d_model, cfg.padded_vocab),
    }


def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames [B, T_enc, D] (stubbed frontend output) -> memory [B, T_enc, D]."""
    x = frames.astype(L.Compute)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, p):
        h, _ = L.attention(p["attn"], L.rms_norm(p["ln1"], x, cfg.norm_eps),
                           positions=positions, rope_theta=cfg.rope_theta,
                           causal=False)
        x = x + h
        x = x + L.swiglu_mlp(p["mlp"], L.rms_norm(p["ln2"], x, cfg.norm_eps))
        return x, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat_policy != "none" else body
    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.rms_norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(cfg, p, x, memory, *, positions, cache=None, cache_index=None):
    h, new_cache = L.attention(p["self"], L.rms_norm(p["ln1"], x, cfg.norm_eps),
                               positions=positions, rope_theta=cfg.rope_theta,
                               cache=cache, cache_index=cache_index)
    x = x + h
    h, _ = L.attention(p["cross"], L.rms_norm(p["ln_x"], x, cfg.norm_eps),
                       positions=positions, rope_theta=cfg.rope_theta,
                       memory=memory, causal=False)
    x = x + h
    x = x + L.swiglu_mlp(p["mlp"], L.rms_norm(p["ln2"], x, cfg.norm_eps))
    return x, new_cache


def forward_encdec(params: dict, cfg: ModelConfig, frames: jnp.ndarray,
                   dec_tokens: jnp.ndarray, *, cache: Optional[dict] = None,
                   mesh=None, last_only: bool = False):
    """Teacher-forced training / prefill.  Returns (logits, cache', aux)."""
    memory = encode(params, cfg, frames)
    x = L.embed(params["embed"], dec_tokens)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, xs):
        p, c = xs
        x, nc = _dec_layer(cfg, p, x, memory, positions=positions, cache=c,
                           cache_index=0 if c is not None else None)
        return x, nc

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat_policy != "none" else body
    x, new_cache = jax.lax.scan(body, x, (params["dec"], cache))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    return L.dense(params["head"], x), new_cache, jnp.float32(0)


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, L.Compute), "v": jnp.zeros(shape, L.Compute)}


def decode_step_encdec(params: dict, cfg: ModelConfig, cache: dict,
                       memory: jnp.ndarray, tokens: jnp.ndarray, pos, *,
                       mesh=None):
    """One-token decode against self cache + precomputed encoder memory."""
    x = L.embed(params["embed"], tokens)
    positions = pos + jnp.arange(x.shape[1])[None, :]

    def body(x, xs):
        p, ck, cv = xs
        x, nc = _dec_layer(cfg, p, x, memory, positions=positions,
                           cache={"k": ck, "v": cv}, cache_index=pos)
        return x, nc

    x, new_cache = jax.lax.scan(body, x, (params["dec"], cache["k"], cache["v"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return L.dense(params["head"], x), new_cache
