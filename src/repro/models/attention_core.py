"""Blockwise (flash-style) attention in pure XLA.

Full [T, S] score materialization at 32K+ context is a memory-roofline
disaster (8 kv-heads x 4 groups x 32768^2 fp32 = 137 GB/layer), so prefill and
training attention run blockwise with online-softmax carries — the
FlashAttention recurrence expressed in XLA.

Schedule (§Perf iteration 1): a single ``lax.scan`` over the **static list of
valid (q-block, kv-block) pairs**.  Causal masking skips the upper triangle
and a sliding window keeps only the band, so dead tiles are never computed —
for causal train_4k that halves tile flops+bytes vs the rectangular double
scan; for window-2048 prefill_32k it cuts them ~20x.  The running (m, l, acc)
state lives in carry buffers indexed by q-block (dynamic-update-slice, aliased
in place by XLA), keeping the HLO one compact loop body.

Tiles are computed in fp32 for the softmax max/sum but stored/multiplied in
bf16 (§Perf iteration 2) — exactness of the max is preserved, p*V matches the
Pallas-kernel convention.

Decode (short query) takes the direct path: scores are [.., t, S] with t<=16,
which is megabytes, and loop overhead would dominate.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["flash_attention", "direct_attention", "valid_block_pairs"]

NEG = -1e30


def _block_mask(q_pos, k_pos, *, causal: bool, window: int, kv_valid):
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    if kv_valid is not None:
        ok &= k_pos[None, :] < kv_valid
    return ok


def direct_attention(q, k, v, *, q_offset=0, causal=True, window: int = 0,
                     kv_valid=None):
    """q [b,t,n_kv,g,h]; k,v [b,s,n_kv,h] -> [b,t,n_kv,g,h]."""
    b, t, n_kv, g, h = q.shape
    s = k.shape[1]
    logits = jnp.einsum("btkgh,bskh->bkgts", q, k).astype(jnp.float32) * h ** -0.5
    q_pos = jnp.arange(t) + q_offset
    k_pos = jnp.arange(s)
    ok = _block_mask(q_pos, k_pos, causal=causal, window=window, kv_valid=kv_valid)
    logits = jnp.where(ok[None, None, None], logits, NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgts,bskh->btkgh", probs, v)


def valid_block_pairs(nq: int, ns: int, q_block: int, kv_block: int,
                      q_offset_static: int, *, causal: bool,
                      window: int) -> np.ndarray:
    """Static (i, j) block pairs that can contain unmasked entries."""
    pairs = []
    for i in range(nq):
        q_lo = i * q_block + q_offset_static
        q_hi = q_lo + q_block - 1
        for j in range(ns):
            k_lo = j * kv_block
            k_hi = k_lo + kv_block - 1
            if causal and k_lo > q_hi:
                continue  # entirely in the future
            if window > 0 and k_hi <= q_lo - window:
                continue  # entirely out of the lookback band
            pairs.append((i, j))
    return np.asarray(pairs, np.int32).reshape(-1, 2)


def flash_attention(q, k, v, *, q_offset=0, causal=True, window: int = 0,
                    kv_valid=None, q_block: int = 512, kv_block: int = 1024,
                    q_offset_static: int = 0):
    """Blockwise attention with static causal/window block pruning.

    Schedule: python-unrolled loop over q blocks; each q block runs one
    ``lax.scan`` over only its *statically valid* kv prefix/band (causal
    triangle / sliding-window band).  Static slices keep GSPMD sharding
    propagation trivial (iteration 1b — the dynamic-indexed pair-scan variant
    made XLA re-gather q/k/v per step on sharded meshes; see §Perf).

    ``q_offset`` may be traced (decode); static pruning uses
    ``q_offset_static`` (0 in training/prefill) — in-tile masking stays exact.
    """
    b, t, n_kv, g, h = q.shape
    s = k.shape[1]
    q_block = min(q_block, t)
    kv_block = min(kv_block, s)
    assert t % q_block == 0 and s % kv_block == 0, (t, s, q_block, kv_block)
    nq = t // q_block
    scale = h ** -0.5

    outs = []
    for i in range(nq):
        q_lo_s = i * q_block + q_offset_static
        q_hi_s = q_lo_s + q_block - 1
        # static kv block range for this q block
        j_hi = (min(q_hi_s, s - 1) // kv_block) if causal else (s - 1) // kv_block
        j_lo = max(0, (q_lo_s - window + 1) // kv_block) if window > 0 else 0
        j_hi = max(j_hi, j_lo)
        nsj = j_hi - j_lo + 1

        qi = jax.lax.slice_in_dim(q, i * q_block, (i + 1) * q_block, axis=1)
        kpre = jax.lax.slice_in_dim(k, j_lo * kv_block,
                                    (j_hi + 1) * kv_block, axis=1)
        vpre = jax.lax.slice_in_dim(v, j_lo * kv_block,
                                    (j_hi + 1) * kv_block, axis=1)
        kb = kpre.reshape(b, nsj, kv_block, n_kv, h).swapaxes(0, 1)
        vb = vpre.reshape(b, nsj, kv_block, n_kv, h).swapaxes(0, 1)
        q_pos = jnp.arange(q_block) + i * q_block + q_offset

        def kv_step(carry, xs, qi=qi, q_pos=q_pos, j_lo=j_lo):
            m, l, acc = carry
            kj, vj, jj = xs
            k_pos = jnp.arange(kv_block) + (j_lo + jj) * kv_block
            logit = jnp.einsum("bqkgh,bskh->bkgqs", qi, kj).astype(jnp.float32)
            logit *= scale
            ok = _block_mask(q_pos, k_pos, causal=causal, window=window,
                             kv_valid=kv_valid)
            logit = jnp.where(ok[None, None, None], logit, NEG)
            m_new = jnp.maximum(m, logit.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logit - m_new[..., None]).astype(qi.dtype)  # bf16 tile
            l_new = l * alpha + p.astype(jnp.float32).sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p, vj)
            acc_new = acc * alpha[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, n_kv, g, q_block), NEG, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, q_block, h), q.dtype)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kb, vb, jnp.arange(nsj)))
        out_i = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        outs.append(out_i.transpose(0, 3, 1, 2, 4))  # [b,qb,k,g,h]
    return jnp.concatenate(outs, axis=1) if nq > 1 else outs[0]
