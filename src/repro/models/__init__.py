"""Model zoo: dense/MoE transformers, RG-LRU hybrid, xLSTM, enc-dec, VLM."""

from . import api, attention_core, encdec, layers, moe, recurrent, transformer, xlstm
from .api import (decode, init, input_specs, lm_loss, make_inputs, prefill,
                  train_logits)

__all__ = ["api", "attention_core", "encdec", "layers", "moe", "recurrent",
           "transformer", "xlstm", "init", "train_logits", "prefill", "decode",
           "make_inputs", "input_specs", "lm_loss"]
