"""Decoder-only LM: dense, MoE, and vision-prefixed (VLM) variants.

Layer stack is a ``lax.scan`` over stacked per-layer params (compile-time
O(1) in depth) with configurable rematerialization.  The same ``forward``
serves training (no cache) and prefill (zero cache passed in, filled and
returned); ``decode_step`` consumes one token block against the cache.

MoE layers call models.moe which picks local one-hot dispatch or the
shard_map all-to-all EP path depending on the mesh.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from .moe import init_moe, moe_mlp

__all__ = ["init_lm", "forward", "init_cache", "decode_step", "lm_loss"]


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    pol = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[policy]
    return jax.checkpoint(fn, policy=pol)


def init_layer(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.hd),
        "ln2": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.n_experts:
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init_lm(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    stacked = jax.vmap(functools.partial(init_layer, cfg))(layer_keys)
    params = {
        "embed": L.init_embedding(ks[1], cfg.padded_vocab, cfg.d_model),
        "layers": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.init_dense(ks[2], cfg.d_model, cfg.padded_vocab)
    return params


def _layer_body(cfg: ModelConfig, mesh, x, p, *, positions, cache=None,
                cache_index=None):
    h, new_cache = L.attention(
        p["attn"], L.rms_norm(p["ln1"], x, cfg.norm_eps), positions=positions,
        rope_theta=cfg.rope_theta, window=cfg.attn_window, cache=cache,
        cache_index=cache_index)
    x = x + h
    hn = L.rms_norm(p["ln2"], x, cfg.norm_eps)
    if cfg.n_experts:
        h, aux = moe_mlp(p["moe"], hn, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor, mesh=mesh)
    else:
        h, aux = L.swiglu_mlp(p["mlp"], hn), jnp.float32(0)
    return x + h, new_cache, aux


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
            prefix_embeds: Optional[jnp.ndarray] = None,
            cache: Optional[dict] = None,
            mesh: Optional[jax.sharding.Mesh] = None,
            last_only: bool = False):
    """tokens [B, T] -> logits [B, T(+Np), V_pad].

    prefix_embeds [B, Np, D] (VLM patch embeddings) are prepended.
    If ``cache`` is given (zero-initialized, [L, B, S, K, H] leaves) this is a
    prefill: the filled cache is returned alongside the logits.
    Returns (logits, new_cache_or_None, aux_loss).
    """
    x = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    t = x.shape[1]
    positions = jnp.arange(t)[None, :]

    def body(x, xs):
        p, c = xs
        x, new_c, aux = _layer_body(cfg, mesh, x, p, positions=positions,
                                    cache=c, cache_index=0 if c is not None else None)
        return x, (new_c, aux)

    body = _remat(body, cfg.remat_policy)
    if cache is not None:
        x, (new_cache, auxs) = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        x, (new_cache, auxs) = jax.lax.scan(
            body, x, (params["layers"], None))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.dense(params["head"], x)
    return logits, new_cache, jnp.sum(auxs)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, L.Compute), "v": jnp.zeros(shape, L.Compute)}


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jnp.ndarray, pos: jnp.ndarray, *,
                mesh: Optional[jax.sharding.Mesh] = None):
    """tokens [B, t] (t small) at position ``pos`` -> (logits, new_cache)."""
    x = L.embed(params["embed"], tokens)
    t = x.shape[1]
    positions = pos + jnp.arange(t)[None, :]

    def body(x, xs):
        p, ck, cv = xs
        x, new_c, _ = _layer_body(cfg, mesh, x, p, positions=positions,
                                  cache={"k": ck, "v": cv}, cache_index=pos)
        return x, new_c

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.dense(params["head"], x)
    return logits, new_cache


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray,
            mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token-mean cross entropy in fp32; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = labels >= 0 if mask is None else mask & (labels >= 0)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
