"""Stream sessions: the per-stream handle of the streaming match runtime.

A ``StreamSession`` is what ``StreamMatcher.open()`` returns — a resumable
cursor (``streaming.cursor.MatchCursor``) plus the session's slot in the
scheduler's admission queue.  Sessions are cheap (a few numpy scalars); a
serving tier holds one per live connection.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .cursor import MatchCursor

__all__ = ["StreamSession", "StreamResult"]


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Final outcome of a closed stream (mirrors one row of BatchResult)."""

    accepted: np.ndarray      # [K] bool per packed pattern
    final_states: np.ndarray  # [K] int32 packed states
    byte_count: int
    segments_fed: int         # feed() calls over the stream's lifetime

    def __bool__(self) -> bool:  # "did anything match?"
        return bool(self.accepted.any())


class StreamSession:
    """Handle for one open byte stream; all methods delegate to the owner.

    ``feed``/``close`` proxy ``StreamMatcher.feed``/``close`` so consumers
    can pass sessions around without the matcher.  ``states``/``accepted``
    read the cursor *as of the last tick* — call ``flush`` (or feed with
    ``flush=True``) first when the latest segment must be reflected.
    """

    __slots__ = ("sid", "owner", "cursor", "segments_fed", "closed",
                 "_pending", "_pending_since", "_pending_wall", "_evicted")

    def __init__(self, sid: int, owner, cursor: MatchCursor):
        self.sid = sid
        self.owner = owner
        self.cursor = cursor
        self.segments_fed = 0
        self.closed = False
        self._pending = bytearray()
        self._pending_since: int | None = None
        self._pending_wall: float | None = None  # max_delay_s admission stamp
        self._evicted = False  # counted once in SchedulerStats.evicted

    @property
    def pending_bytes(self) -> int:
        return len(self._pending)

    @property
    def byte_count(self) -> int:
        """Bytes absorbed into the cursor (excludes unflushed pending)."""
        return self.cursor.byte_count

    @property
    def states(self) -> np.ndarray:
        return self.cursor.states

    def accepted(self) -> np.ndarray:
        return self.cursor.accepted(self.owner.matcher.dev)

    def feed(self, data: bytes | np.ndarray, *, flush: bool = False) -> None:
        self.owner.feed(self, data, flush=flush)

    def close(self) -> StreamResult:
        return self.owner.close(self)
