"""Per-stream sequencing state: frontier tracking + entry-key resolution.

One ``Sequencer`` per open out-of-order stream.  It owns the stream's
exact ``MatchCursor`` (the composed prefix up to the frontier), the
``ReorderBuffer`` of parked future segments, the duplicate-verification
window, and the composed whole-stream Rabin fingerprint.

The entry-key chain is what makes "match first" possible: a buffered
segment can be matched speculatively (``Matcher.advance_cursors`` from the
Eq. 11 candidates of its entry key) as soon as its boundary key is known,
which happens through either

  * a producer ``prev_tail`` hint — the <= r bytes preceding the segment,
    carried by the transport (``advance_key(-1, prev_tail)``), or
  * its predecessor: once segment ``n-1`` is buffered with a known entry
    key, ``n``'s key is ``advance_key(entry(n-1), tail(n-1))`` — one pass
    in ascending ``seq_no`` order propagates whole chains.

When both sources exist they must agree (``OooIntegrityError`` otherwise —
the hint claims bytes that contradict what actually precedes the segment).
Segments whose key never resolves before they reach the frontier simply
fall back to the exact path there: sound, merely less speculative.
"""

from __future__ import annotations

from ..cursor import MatchCursor
from .buffer import (BufferedSegment, OooIntegrityError, OooPolicy,
                     ReorderBuffer)
from .fingerprint import compose_fingerprints

__all__ = ["Sequencer"]


class Sequencer:
    """Sequencing state of one out-of-order stream."""

    __slots__ = ("sid", "cursor", "next_seq", "buf", "folded_fp",
                 "stream_fp", "segments_fed", "closed")

    def __init__(self, sid: int, cursor: MatchCursor, policy: OooPolicy):
        self.sid = sid
        self.cursor = cursor
        self.next_seq = 0
        self.buf = ReorderBuffer(policy)
        # seq -> (fingerprint, n_bytes) of already-folded segments, kept for
        # policy.dedup_window seqs behind the frontier so late duplicate
        # deliveries verify instead of erroring
        self.folded_fp: dict[int, tuple[int, int]] = {}
        self.stream_fp = 0     # Rabin fp of all folded bytes, in order
        self.segments_fed = 0  # distinct (non-duplicate) arrivals accepted
        self.closed = False

    # -- duplicate delivery --------------------------------------------------

    def is_duplicate(self, seq: int, fp: int, n_bytes: int) -> bool:
        """True when ``seq`` was already delivered (drop the copy).

        Verifies content against the recorded ``(fingerprint, n_bytes)``
        pair — a mismatch means the transport delivered *different* bytes
        under one sequence number (``OooIntegrityError``).  Folded seqs
        older than the dedup window are assumed duplicates unverified.
        """
        if seq < self.next_seq:
            rec = self.folded_fp.get(seq)
            if rec is not None and rec != (fp, n_bytes):
                raise OooIntegrityError(
                    f"stream {self.sid} seq {seq}: duplicate delivery with "
                    f"different content (fp {fp}/{n_bytes}B vs recorded "
                    f"{rec[0]}/{rec[1]}B)")
            return True
        seg = self.buf.get(seq)
        if seg is not None:
            if (seg.fp, seg.n_bytes) != (fp, n_bytes):
                raise OooIntegrityError(
                    f"stream {self.sid} seq {seq}: duplicate delivery with "
                    f"different content (fp {fp}/{n_bytes}B vs buffered "
                    f"{seg.fp}/{seg.n_bytes}B)")
            return True
        return False

    # -- entry-key chains ----------------------------------------------------

    def resolve_keys(self, dev) -> list[BufferedSegment]:
        """Propagate entry keys through the buffer; returns segments that
        are now speculatively matchable (key known, payload unmatched).

        One ascending pass suffices: a segment's key comes from its hint or
        from its immediate predecessor's ``out key``
        (``advance_key(entry, tail)`` — computable from the buffered tail
        even for matched segments whose payload is gone).  The frontier
        segment's key is the cursor's ``last_class`` when the cursor has
        absorbed enough history for a boundary key.
        """
        matchable = []
        for seq in sorted(self.buf.segments):
            seg = self.buf.segments[seq]
            if seg.entry_key < 0:
                derived = -1
                if seq == self.next_seq:
                    derived = int(self.cursor.last_class) \
                        if self.cursor.last_class >= 0 else -1
                else:
                    pred = self.buf.get(seq - 1)
                    if pred is not None and pred.entry_key >= 0:
                        derived = dev.advance_key(pred.entry_key, pred.tail)
                if seg.hint_key >= 0:
                    if derived >= 0 and derived != seg.hint_key:
                        raise OooIntegrityError(
                            f"stream {self.sid} seq {seq}: prev_tail hint "
                            f"keys the segment on boundary {seg.hint_key}, "
                            f"but the preceding bytes key it on {derived}")
                    seg.entry_key = seg.hint_key if derived < 0 else derived
                elif derived >= 0:
                    seg.entry_key = derived
            # the frontier segment is never matched speculatively: it folds
            # through the cheaper exact path (advance_segments) in the same
            # flush — its resolved key above only seeds successors' chains
            if (seg.entry_key >= 0 and seq != self.next_seq
                    and not seg.matched and seg.data is not None
                    and seg.n_bytes):
                matchable.append(seg)
        return matchable

    # -- fold bookkeeping ----------------------------------------------------

    def record_folded(self, seg: BufferedSegment) -> None:
        """Account one segment folded into the cursor (in sequence order)."""
        self.stream_fp = compose_fingerprints(self.stream_fp, seg.fp,
                                              seg.n_bytes)
        window = self.buf.policy.dedup_window
        if window > 0:
            self.folded_fp[seg.seq] = (seg.fp, seg.n_bytes)
            floor = self.next_seq - window
            if len(self.folded_fp) > window:
                for old in [s for s in self.folded_fp if s < floor]:
                    del self.folded_fp[old]
