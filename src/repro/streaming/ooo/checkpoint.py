"""Failover for the out-of-order tier: snapshot/restore mid-reorder.

An ``OooStreamMatcher``'s recoverable state is strictly larger than the
in-order runtime's: besides each stream's exact cursor it holds the parked
future — buffered segments (raw payloads not yet matched, ``[K, S]``
transition maps already matched), the entry-key chain, and the duplicate
verification window.  All of it is plain host data with fixed-shape array
encodings, so a snapshot is one flat tree of numpy leaves riding the same
atomic-publish checkpoint layer as the in-order sessions
(``training/checkpoint.py``: write to ``step_<N>.tmp``, rename into place).

Ragged structure flattens CSR-style: per-stream buffered segments
concatenate into ``bs_*`` arrays with ``bs_off`` [B+1] offsets, raw
payloads into one uint8 blob with ``bs_data_off`` [M+1] offsets, and the
dedup windows into ``dd_*`` with ``dd_off`` [B+1].

The same two compatibility guards as ``streaming/checkpoint.py`` apply,
plus one: the packed-table signature must match (cursor/lane state ids are
meaningless against another pattern set), restore on a mesh-sharded
matcher routes through replicated reshard placement (mesh-shape agnostic),
and additionally ``spec_r``/``lane_width`` must match — buffered maps are
keyed in the boundary-key space of the resolved lookahead depth, so a
snapshot taken at r=2 cannot seed an r=1 matcher.
"""

from __future__ import annotations

import numpy as np

from ...training.checkpoint import restore_checkpoint, save_checkpoint
from ..checkpoint import table_signature
from ..cursor import MatchCursor
from .buffer import BufferedSegment
from .sequencer import Sequencer

__all__ = ["OOO_TREE_KEYS", "ooo_tree", "save_ooo_tree", "load_ooo_tree",
           "restore_streams"]

OOO_TREE_KEYS = (
    "sig", "spec_r", "lane_width", "next_sid",
    # per stream [B]
    "sid", "states", "absorbed", "byte_count", "last_class", "next_seq",
    "segments_fed", "stream_fp",
    # buffered segments, CSR over streams ([B+1] offsets into [M])
    "bs_off", "bs_seq", "bs_n", "bs_fp", "bs_entry", "bs_hint", "bs_tail",
    "bs_tail_len", "bs_matched", "bs_lanes", "bs_has_data", "bs_data",
    "bs_data_off",
    # dedup windows, CSR over streams
    "dd_off", "dd_seq", "dd_fp", "dd_n",
)


def ooo_tree(ooo) -> dict:
    """Pack an ``OooStreamMatcher``'s open streams into the snapshot tree."""
    dev = ooo.matcher.dev
    k = ooo.matcher.packed.n_patterns
    s = dev.i_max
    seqs = [ooo._streams[sid] for sid in sorted(ooo._streams)]
    b = len(seqs)
    states = np.zeros((b, k), np.int32)
    absorbed = np.zeros((b, k), bool)
    byte_count = np.zeros(b, np.int64)
    last_class = np.zeros(b, np.int32)
    next_seq = np.zeros(b, np.int64)
    segments_fed = np.zeros(b, np.int64)
    stream_fp = np.zeros(b, np.int64)  # Rabin fps < 2^61 fit int64 exactly
    sid = np.zeros(b, np.int64)
    segs: list[BufferedSegment] = []
    bs_off = np.zeros(b + 1, np.int64)
    dd: list[tuple[int, int, int]] = []
    dd_off = np.zeros(b + 1, np.int64)
    for i, sq in enumerate(seqs):
        sid[i] = sq.sid
        states[i] = sq.cursor.states
        absorbed[i] = sq.cursor.absorbed
        byte_count[i] = sq.cursor.byte_count
        last_class[i] = sq.cursor.last_class
        next_seq[i] = sq.next_seq
        segments_fed[i] = sq.segments_fed
        stream_fp[i] = sq.stream_fp
        segs.extend(sq.buf.segments[q] for q in sorted(sq.buf.segments))
        bs_off[i + 1] = len(segs)
        dd.extend((q, fp, n) for q, (fp, n) in sorted(sq.folded_fp.items()))
        dd_off[i + 1] = len(dd)
    m = len(segs)
    bs_tail = np.zeros((m, 2), np.uint8)
    bs_lanes = np.zeros((m, k, s), np.int32)
    blobs: list[bytes] = []
    bs_data_off = np.zeros(m + 1, np.int64)
    for j, seg in enumerate(segs):
        bs_tail[j, :len(seg.tail)] = np.frombuffer(seg.tail, np.uint8)
        if seg.lanes is not None:
            bs_lanes[j] = seg.lanes
        blobs.append(seg.data or b"")
        bs_data_off[j + 1] = bs_data_off[j] + len(blobs[-1])
    return {
        "sig": np.frombuffer(
            table_signature(ooo.matcher.packed).encode(), np.uint8).copy(),
        "spec_r": np.int64(dev.spec_r),
        "lane_width": np.int64(s),
        "next_sid": np.int64(ooo._next_sid),
        "sid": sid, "states": states, "absorbed": absorbed,
        "byte_count": byte_count, "last_class": last_class,
        "next_seq": next_seq, "segments_fed": segments_fed,
        "stream_fp": stream_fp,
        "bs_off": bs_off,
        "bs_seq": np.array([g.seq for g in segs], np.int64),
        "bs_n": np.array([g.n_bytes for g in segs], np.int64),
        "bs_fp": np.array([g.fp for g in segs], np.int64),
        "bs_entry": np.array([g.entry_key for g in segs], np.int32),
        "bs_hint": np.array([g.hint_key for g in segs], np.int32),
        "bs_tail": bs_tail,
        "bs_tail_len": np.array([len(g.tail) for g in segs], np.int64),
        "bs_matched": np.array([g.matched for g in segs], bool),
        "bs_lanes": bs_lanes,
        "bs_has_data": np.array([g.data is not None for g in segs], bool),
        "bs_data": np.frombuffer(b"".join(blobs), np.uint8).copy(),
        "bs_data_off": bs_data_off,
        "dd_off": dd_off,
        "dd_seq": np.array([q for q, _, _ in dd], np.int64),
        "dd_fp": np.array([fp for _, fp, _ in dd], np.int64),
        "dd_n": np.array([n for _, _, n in dd], np.int64),
    }


def save_ooo_tree(directory: str, tree: dict, step: int) -> str:
    """Atomic publish through the shared checkpoint layer."""
    return save_checkpoint(directory, tree, step)


def load_ooo_tree(directory: str, ooo, *, step=None) -> tuple[dict, int]:
    """Load and verify the latest complete snapshot for ``ooo.matcher``."""
    like = {key: np.zeros(0) for key in OOO_TREE_KEYS}
    shardings = None
    if ooo.matcher.backend == "sharded":
        from jax.sharding import NamedSharding, PartitionSpec

        repl = NamedSharding(ooo.matcher.executor.mesh, PartitionSpec())
        shardings = {key: repl for key in OOO_TREE_KEYS}
    tree, step = restore_checkpoint(directory, like, step=step,
                                    shardings=shardings)
    tree = {key: np.asarray(val) for key, val in tree.items()}
    want = table_signature(ooo.matcher.packed)
    got = bytes(tree["sig"].astype(np.uint8)).decode()
    if got != want:
        raise ValueError(
            "snapshot was taken against a different packed pattern set "
            f"(signature {got[:12]}.. != {want[:12]}..); buffered maps are "
            "only meaningful relative to the table they were matched with")
    dev = ooo.matcher.dev
    if int(tree["spec_r"]) != dev.spec_r or \
            int(tree["lane_width"]) != dev.i_max:
        raise ValueError(
            f"snapshot keyed at lookahead r={int(tree['spec_r'])} with lane "
            f"width S={int(tree['lane_width'])}, but the target matcher "
            f"resolved r={dev.spec_r}, S={dev.i_max}; buffered transition "
            "maps cannot be re-keyed across boundary-key spaces")
    return tree, step


def restore_streams(ooo, tree: dict) -> list:
    """Rebuild sequencers from a loaded tree into ``ooo``; returns the
    re-opened ``OooStream`` handles in snapshot (sid) order."""
    from .matcher import OooStream  # cycle: matcher imports this module

    k = ooo.matcher.packed.n_patterns
    handles = []
    for i in range(len(tree["sid"])):
        sid = int(tree["sid"][i])
        if sid in ooo._streams:
            raise ValueError(f"stream id {sid} is already open; restore "
                             "into a fresh OooStreamMatcher")
        cursor = MatchCursor(
            lane_states=np.ascontiguousarray(
                tree["states"][i, :, None], np.int32),
            entry_class=-1,
            absorbed=np.asarray(tree["absorbed"][i], bool).copy(),
            byte_count=int(tree["byte_count"][i]),
            last_class=int(tree["last_class"][i]))
        sq = Sequencer(sid, cursor, ooo.policy)
        sq.next_seq = int(tree["next_seq"][i])
        sq.segments_fed = int(tree["segments_fed"][i])
        sq.stream_fp = int(tree["stream_fp"][i])
        for j in range(int(tree["bs_off"][i]), int(tree["bs_off"][i + 1])):
            lo, hi = int(tree["bs_data_off"][j]), int(tree["bs_data_off"][j + 1])
            seg = BufferedSegment(
                seq=int(tree["bs_seq"][j]),
                n_bytes=int(tree["bs_n"][j]),
                fp=int(tree["bs_fp"][j]),
                tail=bytes(tree["bs_tail"][j, :int(tree["bs_tail_len"][j])]
                           .astype(np.uint8)),
                data=(bytes(tree["bs_data"][lo:hi].astype(np.uint8))
                      if bool(tree["bs_has_data"][j]) else None),
                entry_key=int(tree["bs_entry"][j]),
                hint_key=int(tree["bs_hint"][j]),
                lanes=(np.ascontiguousarray(tree["bs_lanes"][j], np.int32)
                       if bool(tree["bs_matched"][j]) else None))
            sq.buf.admit(seg, stream_id=sid, bypass_caps=True)
        for j in range(int(tree["dd_off"][i]), int(tree["dd_off"][i + 1])):
            sq.folded_fp[int(tree["dd_seq"][j])] = (
                int(tree["dd_fp"][j]), int(tree["dd_n"][j]))
        ooo._streams[sid] = sq
        handles.append(OooStream(sid, ooo))
    ooo._next_sid = max(ooo._next_sid, int(tree["next_sid"]))
    assert tree["states"].shape[1:] == (k,) or len(tree["sid"]) == 0
    return handles
