"""Rabin fingerprints over byte segments (after arXiv:1512.09228).

A segment's fingerprint is its byte string read as a base-256 polynomial
modulo the Mersenne prime 2^61 - 1:

    fp(b_0 .. b_{n-1}) = (sum_i b_i * 256^(n-1-i)) mod (2^61 - 1)

computed via CPython's bignum (``int.from_bytes`` + one ``%``), so hashing
is C-speed rather than a per-byte Python loop.  The payoff is the algebra:
fingerprints *compose* exactly like the transition maps they tag —

    fp(a || b) = (fp(a) * 256^len(b) + fp(b)) mod p

— so the out-of-order tier can (a) key every buffered segment map by
``(seq_no, fp, n_bytes)`` and drop duplicate deliveries from at-least-once
transports without re-matching or double-composing, and (b) maintain a
whole-stream fingerprint incrementally as gaps close, giving a cheap
equality witness that the bytes sequenced out of order are the bytes an
in-order reader would have seen (``OooStream.stream_fingerprint``).

Like any polynomial fingerprint, ``fp`` alone does not see leading zero
bytes (``fp(b"\\x00a") == fp(b"a")``); every comparison here therefore
pairs the fingerprint with the byte count, which restores uniqueness of
the pair up to hash collisions (~2^-61 per comparison, non-adversarial).
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np

__all__ = ["FP_MOD", "segment_fingerprint", "compose_fingerprints",
           "FingerprintWindow"]

FP_MOD = (1 << 61) - 1  # Mersenne prime modulus


def segment_fingerprint(data: bytes | np.ndarray) -> int:
    """Rabin fingerprint of one segment (0 for the empty segment)."""
    if not isinstance(data, (bytes, bytearray, memoryview)):
        data = np.asarray(data, np.uint8).tobytes()
    return int.from_bytes(data, "big") % FP_MOD


def compose_fingerprints(fp_a: int, fp_b: int, len_b: int) -> int:
    """Fingerprint of the concatenation a || b from the parts.

    ``len_b`` is b's byte count (the shift amount); composition is
    associative with identity ``(0, 0)``, mirroring Eq. 9 map composition.
    """
    return (fp_a * pow(256, int(len_b), FP_MOD) + fp_b) % FP_MOD


class FingerprintWindow:
    """Bounded LRU map of ``(fingerprint, n_bytes, boundary_key)`` -> value.

    The cross-stream dedup window: many real feeds replay the *same content*
    on different streams (fan-out topics, mirrored shards, at-least-once
    transports re-partitioning), and a segment's candidate-keyed ``[K, S]``
    transition map depends only on its bytes and its entry boundary key —
    not on which stream carried it.  ``OooStreamMatcher`` therefore caches
    matched maps here (``OooPolicy.cross_stream_dedup_window`` entries) and
    reuses them across streams instead of re-matching, a *compute* dedup:
    every stream still folds its own copy of the bytes, so decisions stay
    bit-identical — only the device work disappears.

    The window pairs the fingerprint with the byte count (leading-zero
    blindness, see module docstring) and the boundary key (the map is keyed
    on its Eq. 11 entry).  It is deliberately **ephemeral**: checkpoints
    persist per-stream state only, and a restored matcher simply refills
    the window as traffic flows.
    """

    __slots__ = ("capacity", "_entries", "hits", "misses")

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, fp: int, n_bytes: int, key: int):
        """The cached value, or None; a hit refreshes LRU recency."""
        k = (int(fp), int(n_bytes), int(key))
        val = self._entries.get(k)
        if val is None:
            self.misses += 1
            return None
        self._entries.move_to_end(k)
        self.hits += 1
        return val

    def put(self, fp: int, n_bytes: int, key: int, value) -> None:
        k = (int(fp), int(n_bytes), int(key))
        self._entries[k] = value
        self._entries.move_to_end(k)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
