"""Rabin fingerprints over byte segments (after arXiv:1512.09228).

A segment's fingerprint is its byte string read as a base-256 polynomial
modulo the Mersenne prime 2^61 - 1:

    fp(b_0 .. b_{n-1}) = (sum_i b_i * 256^(n-1-i)) mod (2^61 - 1)

computed via CPython's bignum (``int.from_bytes`` + one ``%``), so hashing
is C-speed rather than a per-byte Python loop.  The payoff is the algebra:
fingerprints *compose* exactly like the transition maps they tag —

    fp(a || b) = (fp(a) * 256^len(b) + fp(b)) mod p

— so the out-of-order tier can (a) key every buffered segment map by
``(seq_no, fp, n_bytes)`` and drop duplicate deliveries from at-least-once
transports without re-matching or double-composing, and (b) maintain a
whole-stream fingerprint incrementally as gaps close, giving a cheap
equality witness that the bytes sequenced out of order are the bytes an
in-order reader would have seen (``OooStream.stream_fingerprint``).

Like any polynomial fingerprint, ``fp`` alone does not see leading zero
bytes (``fp(b"\\x00a") == fp(b"a")``); every comparison here therefore
pairs the fingerprint with the byte count, which restores uniqueness of
the pair up to hash collisions (~2^-61 per comparison, non-adversarial).
"""

from __future__ import annotations

import numpy as np

__all__ = ["FP_MOD", "segment_fingerprint", "compose_fingerprints"]

FP_MOD = (1 << 61) - 1  # Mersenne prime modulus


def segment_fingerprint(data: bytes | np.ndarray) -> int:
    """Rabin fingerprint of one segment (0 for the empty segment)."""
    if not isinstance(data, (bytes, bytearray, memoryview)):
        data = np.asarray(data, np.uint8).tobytes()
    return int.from_bytes(data, "big") % FP_MOD


def compose_fingerprints(fp_a: int, fp_b: int, len_b: int) -> int:
    """Fingerprint of the concatenation a || b from the parts.

    ``len_b`` is b's byte count (the shift amount); composition is
    associative with identity ``(0, 0)``, mirroring Eq. 9 map composition.
    """
    return (fp_a * pow(256, int(len_b), FP_MOD) + fp_b) % FP_MOD
