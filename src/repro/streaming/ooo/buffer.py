"""Per-stream reorder buffer: bounded parking for out-of-sequence segments.

A stream's segments carry ``(seq_no, bytes)`` and may arrive in any order,
more than once.  Segments ahead of the stream's frontier (the next
unfolded ``seq_no``) park here as ``BufferedSegment`` records; the matcher
replaces each record's raw payload with its candidate-keyed ``[K, S]``
transition map as soon as the segment's entry key is known (match first),
and the sequencer drains contiguous runs into the exact cursor when the
gap closes (sequence later).

Memory is bounded two ways, both per stream (``OooPolicy``):

  * ``max_buffered_segments`` caps parked records — matched maps are
    fixed-size ``[K, S]`` int32, so this bounds map memory;
  * ``max_buffered_bytes`` caps *raw payload* bytes held (payloads are
    dropped the moment a segment is matched, so a fast matcher keeps this
    near zero even under heavy reordering).

Hitting either cap raises ``ReorderBufferFull`` — the backpressure signal
to the admission path: the transport should redeliver after the frontier
advances.  Frontier segments (``seq_no == next_seq``) bypass the caps;
they strictly drain the buffer at the next flush, so refusing them could
deadlock a full buffer.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["OooPolicy", "BufferedSegment", "ReorderBuffer",
           "ReorderBufferFull", "OooIntegrityError", "SequenceGapError"]


class ReorderBufferFull(RuntimeError):
    """A stream's reorder buffer is at capacity (backpressure, not failure).

    The segment was **not** admitted; nothing was mutated.  Deliver the
    stream's missing frontier segments (``OooStream.next_seq``) or flush,
    then redeliver.
    """

    def __init__(self, msg: str, *, stream_id: int, seq_no: int):
        super().__init__(msg)
        self.stream_id = stream_id
        self.seq_no = seq_no


class OooIntegrityError(ValueError):
    """Conflicting deliveries: same ``seq_no``, different content — or a
    ``prev_tail`` hint that contradicts the bytes that actually precede the
    segment.  Retrying cannot help; the transport is corrupting data."""


class SequenceGapError(RuntimeError):
    """A stream was closed while sequence numbers are still missing."""


@dataclasses.dataclass(frozen=True)
class OooPolicy:
    """Bounds and batching knobs of the out-of-order ingestion tier.

    max_buffered_segments : per-stream cap on parked segments (bounds the
                            ``[K, S]`` map memory of matched segments).
    max_buffered_bytes    : per-stream cap on *unmatched* raw payload bytes.
    dedup_window          : folded ``seq_no``s (behind the frontier) whose
                            ``(fingerprint, n_bytes)`` are retained so late
                            duplicate deliveries verify instead of erroring;
                            older late arrivals are dropped unverified.
    match_batch           : arrivals with a known entry key accumulated
                            before an automatic flush batches them into one
                            ``Matcher.advance_cursors`` dispatch (1 =
                            match every arrival eagerly).
    cross_stream_dedup_window : entries of the *global* (fingerprint,
                            n_bytes, boundary key) -> matched-map LRU shared
                            across streams (``fingerprint
                            .FingerprintWindow``): identical content fed on
                            different streams reuses the already-matched
                            ``[K, S]`` map instead of re-dispatching —
                            compute dedup, never drop dedup, so every
                            stream's decisions stay bit-identical.  0
                            (default) disables; the window is ephemeral
                            across checkpoints.
    """

    max_buffered_segments: int = 1024
    max_buffered_bytes: int = 1 << 22
    dedup_window: int = 256
    match_batch: int = 32
    cross_stream_dedup_window: int = 0

    def __post_init__(self):
        if self.max_buffered_segments < 1:
            raise ValueError("max_buffered_segments must be >= 1")
        if self.max_buffered_bytes < 1:
            raise ValueError("max_buffered_bytes must be >= 1")
        if self.dedup_window < 0:
            raise ValueError("dedup_window must be >= 0")
        if self.match_batch < 1:
            raise ValueError("match_batch must be >= 1")
        if self.cross_stream_dedup_window < 0:
            raise ValueError("cross_stream_dedup_window must be >= 0")


@dataclasses.dataclass
class BufferedSegment:
    """One parked segment of one stream.

    ``data`` holds the raw payload only while the segment is unmatched;
    matching replaces it with ``lanes`` (the segment's restricted transition
    map) and releases the bytes.  ``tail`` keeps the last <= 2 raw bytes —
    enough to chain boundary keys through ``DeviceTables.advance_key`` for
    any supported lookahead depth r — so successors can resolve their entry
    keys (and the fold can maintain ``last_class``) without the payload.
    ``entry_key`` is the boundary key the map is keyed on (-1 while
    unknown); ``hint_key`` is the producer-supplied ``prev_tail`` derivation
    used both to match before the predecessor lands and to cross-check the
    chain (mismatch = ``OooIntegrityError``).
    """

    seq: int
    n_bytes: int
    fp: int
    tail: bytes
    data: bytes | None
    entry_key: int = -1
    hint_key: int = -1
    lanes: np.ndarray | None = None    # [K, S] int32 once matched

    @property
    def matched(self) -> bool:
        return self.lanes is not None


class ReorderBuffer:
    """seq_no-keyed parking lot of one stream, capacity-enforced."""

    def __init__(self, policy: OooPolicy):
        self.policy = policy
        self.segments: dict[int, BufferedSegment] = {}
        self.payload_bytes = 0  # raw (unmatched) payload held

    def __len__(self) -> int:
        return len(self.segments)

    def get(self, seq: int) -> BufferedSegment | None:
        return self.segments.get(seq)

    def admit(self, seg: BufferedSegment, *, stream_id: int,
              bypass_caps: bool = False) -> None:
        """Park one segment; raises ``ReorderBufferFull`` (nothing mutated)
        when a cap would be exceeded and ``bypass_caps`` is False (frontier
        segments bypass — they strictly drain the buffer)."""
        pol = self.policy
        held = len(seg.data) if seg.data is not None else 0
        if not bypass_caps:
            if len(self.segments) + 1 > pol.max_buffered_segments:
                raise ReorderBufferFull(
                    f"stream {stream_id}: reorder buffer at "
                    f"{len(self.segments)} segments "
                    f"(max_buffered_segments={pol.max_buffered_segments}); "
                    f"deliver the frontier or flush, then redeliver seq "
                    f"{seg.seq}", stream_id=stream_id, seq_no=seg.seq)
            if self.payload_bytes + held > pol.max_buffered_bytes:
                raise ReorderBufferFull(
                    f"stream {stream_id}: reorder buffer holds "
                    f"{self.payload_bytes} unmatched payload bytes "
                    f"(max_buffered_bytes={pol.max_buffered_bytes}); "
                    f"deliver the frontier or flush, then redeliver seq "
                    f"{seg.seq}", stream_id=stream_id, seq_no=seg.seq)
        self.segments[seg.seq] = seg
        self.payload_bytes += held

    def release_payload(self, seg: BufferedSegment) -> None:
        """Drop a segment's raw payload (it has been matched into lanes)."""
        if seg.data is not None:
            self.payload_bytes -= len(seg.data)
            seg.data = None

    def pop(self, seq: int) -> BufferedSegment:
        seg = self.segments.pop(seq)
        if seg.data is not None:
            self.payload_bytes -= len(seg.data)
        return seg
