"""Out-of-order ingestion front-end: match first, sequence later.

``OooStreamMatcher`` accepts segments tagged ``(stream, seq_no)`` in any
arrival order, from any number of producers, with at-least-once delivery —
and produces results bit-identical to feeding every stream in order:

  * **match first** — an out-of-sequence segment whose boundary key is
    known (producer ``prev_tail`` hint, or chained from a buffered
    predecessor) is matched *immediately* as an independent candidate-keyed
    ``[K, S]`` transition map, batched across streams through the fused
    ``Matcher.advance_cursors`` path; its raw payload is dropped on the
    spot (the map is a complete composable summary — SFA, arXiv:1405.0562);
  * **sequence later** — the moment a stream's sequence gap closes, the
    contiguous run of buffered maps folds into the exact cursor in ONE
    log-depth device call (``Matcher.compose_lane_maps``, a
    ``lax.associative_scan`` over the run — not one compose per segment);
    in-order arrivals never park and ride the plain exact path
    (``advance_segments``), so zero reordering costs zero overhead;
  * **duplicates dedup** — every delivery is keyed by its Rabin
    fingerprint; a re-delivered ``seq_no`` with identical content drops, a
    conflicting one raises (``OooIntegrityError``).  Nothing is ever
    double-composed.  With ``OooPolicy.cross_stream_dedup_window`` > 0, the
    same content arriving on *different* streams (fan-out topics, mirrored
    shards) is also deduped — as a compute dedup: the already-matched map
    is reused (``fingerprint.FingerprintWindow``), every stream still folds
    its own copy, decisions stay bit-identical;
  * **memory is bounded** — per-stream ``OooPolicy`` caps with
    ``ReorderBufferFull`` backpressure to the admission path.

No composition ever happens on the host: ``streaming.cursor.merge_calls``
stays untouched by feed/flush/close, exactly like the in-order scheduler
tick.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ...core.engine.facade import Matcher
from ..cursor import open_cursor
from ..session import StreamResult
from .buffer import (BufferedSegment, OooIntegrityError, OooPolicy,
                     ReorderBufferFull, SequenceGapError)
from .fingerprint import FingerprintWindow, segment_fingerprint
from .sequencer import Sequencer

__all__ = ["OooStreamMatcher", "OooStream", "OooStats"]

# raw tail bytes retained per segment: enough to chain boundary keys for
# any supported lookahead depth (DeviceTables.advance_key reads <= 2 bytes)
_TAIL_BYTES = 2


@dataclasses.dataclass
class OooStats:
    arrivals: int = 0           # feed() deliveries (incl. duplicates)
    duplicates: int = 0         # deliveries dropped by fingerprint dedup
    cross_stream_hits: int = 0  # maps reused from the cross-stream window
    ooo_arrivals: int = 0       # non-duplicate deliveries ahead of frontier
    bytes_fed: int = 0
    spec_matched: int = 0       # segments matched ahead of sequencing
    match_rounds: int = 0       # advance_cursors dispatch rounds
    exact_segments: int = 0     # frontier segments folded via the exact path
    exact_rounds: int = 0       # advance_segments dispatch rounds
    gap_closes: int = 0         # contiguous buffered runs folded
    scan_folds: int = 0         # compose_lane_maps dispatches (batched runs)
    scan_fold_segments: int = 0 # buffered maps folded through the scan
    absorbed_skips: int = 0     # segments never matched (cursor absorbed)
    flushes: int = 0
    bucket_calls: int = 0       # fused match dispatches (both paths)
    rows_dispatched: int = 0    # tile-padded device rows (occupancy denom)
    peak_buffered_segments: int = 0  # max parked in any one stream's buffer
    peak_buffered_bytes: int = 0     # max unmatched payload bytes, one stream

    @property
    def occupancy(self) -> float:
        """Real matched segments per padded device row."""
        return ((self.spec_matched + self.exact_segments)
                / max(self.rows_dispatched, 1))

    @property
    def scan_batch(self) -> float:
        """Mean buffered maps folded per associative-scan dispatch."""
        return self.scan_fold_segments / max(self.scan_folds, 1)


class OooStream:
    """Per-stream handle: carries the stream id, delegates to the owner."""

    __slots__ = ("sid", "owner")

    def __init__(self, sid: int, owner: "OooStreamMatcher"):
        self.sid = sid
        self.owner = owner

    def feed(self, seq_no: int, data, *, prev_tail: bytes | None = None,
             flush: bool = False) -> None:
        self.owner.feed(self, seq_no, data, prev_tail=prev_tail, flush=flush)

    def close(self) -> StreamResult:
        return self.owner.close(self)

    @property
    def _sq(self) -> Sequencer:
        return self.owner._streams[self.sid]

    @property
    def next_seq(self) -> int:
        """The frontier: lowest sequence number not yet folded."""
        return self._sq.next_seq

    @property
    def buffered_segments(self) -> int:
        return len(self._sq.buf)

    @property
    def buffered_bytes(self) -> int:
        """Unmatched raw payload bytes currently parked."""
        return self._sq.buf.payload_bytes

    @property
    def byte_count(self) -> int:
        """Bytes folded into the exact cursor so far."""
        return self._sq.cursor.byte_count

    @property
    def stream_fingerprint(self) -> int:
        """Composed Rabin fingerprint of all folded bytes, in order."""
        return self._sq.stream_fp

    def early_accepts(self) -> np.ndarray:
        """[K] patterns already *decided to accept*, sequencing incomplete.

        Pattern ``k`` is decided when its states are accepting AND absorbing
        either on the exact cursor, or on **every candidate lane of some
        buffered matched map** — the suffix run guarantees the match no
        matter which bytes eventually fill the gap.  This is the match-first
        payoff for intrusion detection: alert on a segment from the future.
        """
        return self.owner._early_accepts(self._sq)


class OooStreamMatcher:
    """Out-of-order streaming facade over a ``Matcher``.

    ``source`` is anything ``Matcher`` accepts, or a pre-built ``Matcher``
    (shared compiled buckets).  ``policy`` is an ``OooPolicy``; remaining
    keyword arguments construct the matcher (``num_chunks`` defaults to 1,
    as in ``StreamMatcher`` — the stream/row axis is the parallelism).

    Drives the engine directly (``advance_cursors`` for speculative
    matching, ``advance_segments`` for the in-order frontier,
    ``compose_lane_maps`` for bulk gap closes) rather than through
    ``MicroBatchScheduler`` — sequencing, not tick latency, is the control
    problem here.  The scheduler's candidate-keyed twin is
    ``StreamMatcher(lane_ticks=True)`` + ``open_at``/``close_map``.
    """

    def __init__(self, source, *, policy: OooPolicy | None = None,
                 **matcher_kwargs):
        if isinstance(source, Matcher):
            if matcher_kwargs:
                raise ValueError("matcher kwargs conflict with a pre-built "
                                 f"Matcher: {sorted(matcher_kwargs)}")
            self.matcher = source
        else:
            matcher_kwargs.setdefault("num_chunks", 1)
            self.matcher = Matcher(source, **matcher_kwargs)
        self.policy = policy or OooPolicy()
        self.stats = OooStats()
        # cross-stream compute dedup: identical (fp, n_bytes, boundary key)
        # content on *different* streams reuses the matched [K, S] map
        # instead of re-dispatching; ephemeral (never checkpointed)
        self._xwindow = (FingerprintWindow(
            self.policy.cross_stream_dedup_window)
            if self.policy.cross_stream_dedup_window else None)
        self._streams: dict[int, Sequencer] = {}
        self._next_sid = 0
        self._since_flush = 0   # accepted arrivals since the last flush
        self._snapshot_step = 0

    # -- lifecycle -----------------------------------------------------------

    def open(self) -> OooStream:
        """Open a stream; its segments number 0, 1, 2, ... in stream order
        but may arrive in any order."""
        sid = self._next_sid
        self._next_sid += 1
        self._streams[sid] = Sequencer(sid, open_cursor(self.matcher.dev),
                                       self.policy)
        return OooStream(sid, self)

    def feed(self, stream: OooStream, seq_no: int, data, *,
             prev_tail: bytes | None = None, flush: bool = False) -> None:
        """Deliver one segment of one stream, in whatever order it arrived.

        ``prev_tail`` optionally carries the <= 2 raw bytes immediately
        preceding the segment in stream order (producers shipping from a
        contiguous source have them for free): it lets the segment be
        matched speculatively *before* any of its predecessors land.
        Without it the entry key resolves by chaining from buffered
        predecessors, or the segment waits for the frontier (exact path).

        Raises ``ReorderBufferFull`` (backpressure; nothing mutated — the
        transport redelivers later) and ``OooIntegrityError`` (conflicting
        duplicate content, or a ``prev_tail`` contradicting the actual
        predecessor bytes).
        """
        sq = self._sequencer(stream)
        seq = int(seq_no)
        if seq < 0:
            raise ValueError(f"seq_no must be >= 0, got {seq}")
        buf = (bytes(data) if isinstance(data, (bytes, bytearray))
               else np.asarray(data, np.uint8).tobytes())
        self.stats.arrivals += 1
        self.stats.bytes_fed += len(buf)
        fp = segment_fingerprint(buf)
        if sq.is_duplicate(seq, fp, len(buf)):
            self.stats.duplicates += 1
            if flush:
                self.flush()
            return
        if seq != sq.next_seq:
            self.stats.ooo_arrivals += 1
        hint = -1
        if prev_tail is not None:
            if seq == 0 and len(prev_tail):
                raise ValueError("segment 0 has no preceding bytes; "
                                 "prev_tail must be empty")
            hint = self.matcher.dev.advance_key(-1, prev_tail)
        absorbed = bool(sq.cursor.absorbed.all())
        seg = BufferedSegment(
            seq=seq, n_bytes=len(buf), fp=fp, tail=buf[-_TAIL_BYTES:],
            # absorbed streams skip matching entirely: only the tail (for
            # boundary-key chaining) and byte accounting survive
            data=(buf if buf and not absorbed else None),
            hint_key=hint)
        try:
            sq.buf.admit(seg, stream_id=sq.sid,
                         bypass_caps=(seq == sq.next_seq))
        except ReorderBufferFull:
            # a flush may close gaps and drain the buffer; one retry, then
            # the backpressure propagates to the transport
            self.flush()
            sq.buf.admit(seg, stream_id=sq.sid,
                         bypass_caps=(seq == sq.next_seq))
        sq.segments_fed += 1
        self._since_flush += 1
        self.stats.peak_buffered_segments = max(
            self.stats.peak_buffered_segments, len(sq.buf))
        self.stats.peak_buffered_bytes = max(
            self.stats.peak_buffered_bytes, sq.buf.payload_bytes)
        if flush or self._since_flush >= self.policy.match_batch:
            self.flush()

    def close(self, stream: OooStream) -> StreamResult:
        """Flush, require a gapless sequence, and return the final decision
        — bit-identical to in-order feeding of the same segments."""
        sq = self._sequencer(stream)
        self.flush()
        if len(sq.buf):
            parked = sorted(sq.buf.segments)
            raise SequenceGapError(
                f"stream {sq.sid} closed with sequence gaps: seq "
                f"{sq.next_seq} never arrived ({len(parked)} segment(s) "
                f"parked beyond it: {parked[:8]}{'...' if len(parked) > 8 else ''})")
        sq.closed = True
        self._streams.pop(sq.sid, None)
        states = sq.cursor.states
        return StreamResult(
            accepted=self.matcher.packed.accepting[states].copy(),
            final_states=states.copy(),
            byte_count=sq.cursor.byte_count,
            segments_fed=sq.segments_fed)

    # -- failover ------------------------------------------------------------

    def snapshot(self, directory: str, *, step: int | None = None) -> str:
        """Persist every open stream — exact cursors AND the parked future
        (buffered payloads, matched maps, key chains, dedup windows) — as
        one atomically-published checkpoint step."""
        from .checkpoint import ooo_tree, save_ooo_tree

        if step is None:
            step = self._snapshot_step
        self._snapshot_step = step + 1
        return save_ooo_tree(directory, ooo_tree(self), step)

    def restore(self, directory: str, *, step: int | None = None) -> list:
        """Re-open the streams of the latest complete snapshot; returns the
        ``OooStream`` handles in snapshot order.  Mesh-shape agnostic: a
        snapshot taken on any backend restores on any other with the same
        packed tables and resolved lookahead depth."""
        from .checkpoint import load_ooo_tree, restore_streams

        tree, got_step = load_ooo_tree(directory, self, step=step)
        self._snapshot_step = max(self._snapshot_step, got_step + 1)
        return restore_streams(self, tree)

    # -- the flush loop ------------------------------------------------------

    def flush(self) -> None:
        """Run speculative matching + gap closing to quiescence.

        Each iteration batches across every open stream: one
        ``advance_cursors`` round matches all newly-keyed buffered segments,
        one ``advance_segments`` round advances all in-order frontiers, and
        one ``compose_lane_maps`` round folds all closed gaps (one
        associative-scan dispatch for the whole batch of contiguous runs).
        Iterates because each round can unlock the next — a fold advances a
        frontier, which keys a chain, which matches more segments.
        """
        self.stats.flushes += 1
        self._since_flush = 0
        dev = self.matcher.dev
        while True:
            progress = False
            # round 1: speculative matching of newly keyed segments
            batch: list[tuple[Sequencer, BufferedSegment]] = []
            for sq in self._streams.values():
                for seg in sq.resolve_keys(dev):
                    batch.append((sq, seg))
            if batch:
                self._match_batch(batch)
                progress = True
            # round 2: classify each stream's frontier
            skip_runs, exact_runs, fold_runs = [], [], []
            for sq in self._streams.values():
                kind, run = self._frontier_run(sq)
                if kind == "skip":
                    skip_runs.append((sq, run))
                elif kind == "exact":
                    exact_runs.append((sq, run))
                elif kind == "fold":
                    fold_runs.append((sq, run))
            for sq, run in skip_runs:
                self._commit_skip(sq, run)
            if exact_runs:
                self._exact_round(exact_runs)
            if fold_runs:
                self._fold_round(fold_runs)
            progress |= bool(skip_runs or exact_runs or fold_runs)
            if not progress:
                return

    def _frontier_run(self, sq: Sequencer):
        """Classify the maximal homogeneous run starting at the frontier.

        ``skip``  — cursor fully absorbed: every contiguous parked segment
                    folds with pure host accounting (no device work);
        ``fold``  — matched maps (and empties): one scan-compose row;
        ``exact`` — unmatched payloads (and empties): concatenate and ride
                    ``advance_segments``, exactly like in-order streaming.
        """
        buf = sq.buf
        first = buf.get(sq.next_seq)
        if first is None:
            return None, []
        run: list[BufferedSegment] = []
        s = sq.next_seq
        if bool(sq.cursor.absorbed.all()):
            while (seg := buf.get(s)) is not None:
                run.append(seg)
                s += 1
            return "skip", run
        if first.matched or first.n_bytes == 0:
            while ((seg := buf.get(s)) is not None
                   and (seg.matched or seg.n_bytes == 0)):
                run.append(seg)
                s += 1
            return "fold", run
        while ((seg := buf.get(s)) is not None and not seg.matched
               and (seg.data is not None or seg.n_bytes == 0)):
            run.append(seg)
            s += 1
        return "exact", run

    def _match_batch(self, batch) -> None:
        """Match keyed buffered segments independently, one fused round.

        Each row enters at the Eq. 11 candidates of its entry key (an
        identity lane map), so the result lanes ARE the segment's restricted
        transition map; the raw payload is released on the spot.  With a
        cross-stream dedup window, content already matched under the same
        (fingerprint, n_bytes, boundary key) — on *any* stream — reuses the
        cached map and skips the dispatch entirely (the maps are read-only
        from here on, so sharing one array across streams is safe).  The
        dedup also collapses duplicates *within* the round, so fan-out
        topics feeding N mirrored streams dispatch each segment once, not
        N times.
        """
        followers: dict = {}
        if self._xwindow is not None:
            misses = []
            for sq, seg in batch:
                lanes = self._xwindow.get(seg.fp, seg.n_bytes, seg.entry_key)
                if lanes is not None:
                    seg.lanes = lanes
                    sq.buf.release_payload(seg)
                    self.stats.cross_stream_hits += 1
                    continue
                fkey = (seg.fp, seg.n_bytes, seg.entry_key)
                if fkey in followers:
                    # same content, same round: ride the leader's dispatch
                    followers[fkey].append((sq, seg))
                    self.stats.cross_stream_hits += 1
                else:
                    followers[fkey] = []
                    misses.append((sq, seg))
            batch = misses
            if not batch:
                return
        cands = self.matcher.dev.tables.candidates
        segs = [seg.data for _, seg in batch]
        lanes = np.ascontiguousarray(
            cands[[seg.entry_key for _, seg in batch]], np.int32)
        keys = np.array([seg.entry_key for _, seg in batch], np.int32)
        res = self.matcher.advance_cursors(segs, lanes, keys)
        for i, (sq, seg) in enumerate(batch):
            seg.lanes = np.asarray(res.lane_states[i], np.int32)
            sq.buf.release_payload(seg)
            if self._xwindow is not None:
                self._xwindow.put(seg.fp, seg.n_bytes, seg.entry_key,
                                  seg.lanes)
                for sq2, seg2 in followers[(seg.fp, seg.n_bytes,
                                            seg.entry_key)]:
                    seg2.lanes = seg.lanes
                    sq2.buf.release_payload(seg2)
        self.stats.spec_matched += len(batch)
        self.stats.match_rounds += 1
        self.stats.bucket_calls += res.bucket_calls
        self.stats.rows_dispatched += res.padded_rows

    def _exact_round(self, runs) -> None:
        """Advance in-order frontiers: one ``advance_segments`` dispatch."""
        payloads = [b"".join(seg.data or b"" for seg in run)
                    for _, run in runs]
        live = [(sq, run, pay) for (sq, run), pay in zip(runs, payloads)
                if pay]
        if live:
            entry = np.stack([sq.cursor.states for sq, _, _ in live])
            res = self.matcher.advance_segments([p for _, _, p in live],
                                                entry.astype(np.int32))
            self.stats.exact_rounds += 1
            self.stats.bucket_calls += res.bucket_calls
            self.stats.rows_dispatched += res.padded_rows
            for i, (sq, run, pay) in enumerate(live):
                last = self.matcher.dev.advance_key(sq.cursor.last_class, pay)
                sq.cursor = sq.cursor.advanced(res.final_states[i], len(pay),
                                               last, self.matcher.dev,
                                               absorbed=res.absorbed[i])
        for sq, run in runs:
            self._retire_run(sq, run)
            self.stats.exact_segments += len(run)

    def _fold_round(self, runs) -> None:
        """Close gaps: fold every stream's contiguous matched run in ONE
        ``compose_lane_maps`` dispatch (log-depth associative scan)."""
        dev = self.matcher.dev
        k = self.matcher.packed.n_patterns
        s = dev.i_max
        rows = []  # (sq, run, maps) — runs with at least one non-empty map
        for sq, run in runs:
            maps = [seg for seg in run if seg.n_bytes > 0]
            # the entry-key chain from the exact cursor is authoritative:
            # a spec-matched map whose key contradicts it means a corrupt
            # prev_tail hint slipped past resolve-time checking
            last = sq.cursor.last_class
            for seg in maps:
                if seg.entry_key != last:
                    raise OooIntegrityError(
                        f"stream {sq.sid} seq {seg.seq}: map keyed on "
                        f"boundary {seg.entry_key}, but the preceding bytes "
                        f"key it on {last}")
                last = dev.advance_key(last, seg.tail)
            if maps:
                rows.append((sq, run, maps))
            else:
                self._retire_run(sq, run)  # all-empty run: pure accounting
        if not rows:
            return
        n = 1 + max(len(maps) for _, _, maps in rows)
        b = len(rows)
        lane_maps = np.zeros((b, n, k, s), np.int32)
        keys = np.full((b, n), dev.pad_key, np.int32)
        for i, (sq, _, maps) in enumerate(rows):
            # element 0 seeds the scan with the exact cursor broadcast to
            # lane width (its key is never read); pads on the right are
            # identities, so ragged runs share one compiled scan
            lane_maps[i, 0] = sq.cursor.states[:, None]
            for j, seg in enumerate(maps):
                lane_maps[i, 1 + j] = seg.lanes
                keys[i, 1 + j] = seg.entry_key
        out = self.matcher.compose_lane_maps(lane_maps, keys)
        for i, (sq, run, maps) in enumerate(rows):
            n_bytes = sum(seg.n_bytes for seg in run)
            last = sq.cursor.last_class
            for seg in run:
                last = dev.advance_key(last, seg.tail) if seg.n_bytes else last
            # composed lanes agree across the lane axis (the seed was exact):
            # collapse via lane 0
            sq.cursor = sq.cursor.advanced(out[i, :, 0], n_bytes, last, dev)
            self._retire_run(sq, run)
            self.stats.scan_fold_segments += len(maps)
        self.stats.scan_folds += 1
        self.stats.gap_closes += len(rows)

    def _commit_skip(self, sq: Sequencer, run) -> None:
        """Fold a fully-absorbed stream's run: byte/key accounting only."""
        dev = self.matcher.dev
        last = sq.cursor.last_class
        n_bytes = 0
        for seg in run:
            last = dev.advance_key(last, seg.tail) if seg.n_bytes else last
            n_bytes += seg.n_bytes
        if n_bytes:
            sq.cursor = sq.cursor.skipped(n_bytes, last)
        self._retire_run(sq, run)
        self.stats.absorbed_skips += len(run)

    def _retire_run(self, sq: Sequencer, run) -> None:
        """Pop a folded run from the buffer and advance the frontier."""
        for seg in run:
            sq.buf.pop(seg.seq)
            sq.next_seq = seg.seq + 1
            sq.record_folded(seg)

    # -- introspection -------------------------------------------------------

    def _sequencer(self, stream: OooStream) -> Sequencer:
        if stream.owner is not self:
            raise ValueError("stream belongs to a different OooStreamMatcher")
        sq = self._streams.get(stream.sid)
        if sq is None or sq.closed:
            raise ValueError("stream is closed")
        return sq

    def _early_accepts(self, sq: Sequencer) -> np.ndarray:
        packed = self.matcher.packed
        absorbing = self.matcher.dev.absorbing
        states = sq.cursor.states
        decided = packed.accepting[states] & absorbing[states]
        for seg in sq.buf.segments.values():
            if seg.matched:
                decided |= (packed.accepting[seg.lanes].all(axis=1)
                            & absorbing[seg.lanes].all(axis=1))
        return decided

    @property
    def open_streams(self) -> int:
        return len(self._streams)

    @property
    def n_patterns(self) -> int:
        return self.matcher.n_patterns
