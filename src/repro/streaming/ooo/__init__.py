"""Out-of-order ingestion tier: match first, sequence later.

Segments tagged ``(stream, seq_no)`` arrive in any order (multi-producer
shippers, retrying transports, cloud object notifications) and are matched
*immediately* as independent candidate-keyed ``[K, S]`` transition maps;
sequencing happens later, when gaps close, by folding contiguous runs of
buffered maps into the exact cursor through one log-depth
``lax.associative_scan`` dispatch.  The result is bit-identical to feeding
the stream in order — Eq. 9 composition is associative, so arrival order
is a scheduling detail, not a semantic one.

Layers (bottom up):

  * ``fingerprint`` — composable Rabin fingerprints: duplicate-delivery
    dedup and a whole-stream equality witness;
  * ``buffer``      — bounded per-stream reorder buffer (``OooPolicy`` caps,
    ``ReorderBufferFull`` backpressure);
  * ``sequencer``   — frontier tracking + entry-key chain resolution;
  * ``matcher``     — the ``OooStreamMatcher`` front-end driving the engine
    (``advance_cursors`` / ``advance_segments`` / ``compose_lane_maps``);
  * ``checkpoint``  — snapshot/restore of cursors *and* the parked future.
"""

from .buffer import (BufferedSegment, OooIntegrityError, OooPolicy,
                     ReorderBuffer, ReorderBufferFull, SequenceGapError)
from .fingerprint import (FP_MOD, compose_fingerprints, segment_fingerprint)
from .matcher import OooStats, OooStream, OooStreamMatcher
from .sequencer import Sequencer

__all__ = [
    "OooStreamMatcher", "OooStream", "OooStats", "OooPolicy",
    "ReorderBuffer", "ReorderBufferFull", "BufferedSegment", "Sequencer",
    "OooIntegrityError", "SequenceGapError",
    "FP_MOD", "segment_fingerprint", "compose_fingerprints",
]
