"""Streaming match runtime: resumable cursors + micro-batched scheduling.

The batched runtime (``core.engine.Matcher``) answers "does this whole
document match?"; this package answers the serving-tier question "keep
matching these million byte streams as their bytes arrive".  Three layers:

    cursor.py     ``MatchCursor`` / ``segment_result`` / ``merge`` — the pure
                  Eq. 8 composition that makes matching resumable: per-stream
                  speculative lane states, absorbed flags and byte counts
                  carried across segment boundaries, bit-identical to
                  one-shot matching under any segmentation.  ``merge`` is
                  the host reference of the *device merge*
                  (``Matcher.advance_cursors`` composes [B, K, S] cursor
                  lane batches on device, one jitted call per bucket;
                  ``kernels.ref.cursor_merge_ref`` is the shared numpy
                  definition and ``merge_calls`` the tick-path regression
                  counter).
    scheduler.py  ``MicroBatchScheduler`` + ``TickPolicy`` — an admission
                  queue that coalesces pending segments from many unrelated
                  streams into the sticky pow2 shape buckets and dispatches
                  one fused, fully on-device round per tick via
                  ``Matcher.advance_segments`` (local / pallas / sharded);
                  fully-absorbed sessions are evicted from admission
                  (``SchedulerStats.evicted``).
    session.py    ``StreamSession`` / ``StreamResult`` — the per-stream
                  handle a serving tier holds per live connection.
    checkpoint.py session snapshot/restore on ``training/checkpoint.py``'s
                  atomic-publish format: because a cursor's [K, S] lane
                  state is a complete composable summary (Eq. 8), a stream
                  frozen here resumes anywhere — including on a matcher
                  with a *different* ``mesh_shape`` — bit-identically
                  (``StreamMatcher.snapshot`` / ``restore``).
    faults.py     ``FaultPlan`` — deterministic fault injection (killed
                  ticks, delayed devices, corrupted capacities) driving the
                  scheduler's retry-with-restore + rebalance paths in tests
                  and ``tools/faultbench.py``.

``StreamMatcher`` below is the public facade:

    sm = StreamMatcher([r"SECRET-[0-9]+", r"key=[a-z]{8}"],
                       policy=TickPolicy(max_batch=256, max_delay=8))
    s = sm.open()
    s.feed(chunk)            # admits; scheduler decides when to dispatch
    ...
    res = s.close()          # flushes; [K] accept flags + final states

Consumers: ``data.filter.CorpusFilter.scan_stream`` (filter a corpus as it
downloads), ``serving.constrained.GrammarConstraint.open_decode``
(incremental grammar prefill/decode over cursors), and the ``--stream`` path
of ``launch.serve``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.engine.facade import Matcher
from .checkpoint import (load_sessions_tree, pattern_set_signature,
                         save_sessions_tree, sessions_tree, table_signature,
                         unpack_cursor)
from .cursor import (ENTRY_EXACT, MatchCursor, SegmentResult, counting_merges,
                     merge, merge_calls, open_cursor, open_lane_cursor,
                     reset_merge_calls, segment_result)
from .faults import FaultPlan, InjectedFault
from .ooo import (OooIntegrityError, OooPolicy, OooStats, OooStream,
                  OooStreamMatcher, ReorderBufferFull, SequenceGapError,
                  segment_fingerprint)
from .scheduler import (MicroBatchScheduler, RetryPolicy, SchedulerStats,
                        TickPolicy)
from .session import StreamResult, StreamSession

__all__ = ["StreamMatcher", "StreamSession", "StreamResult", "TickPolicy",
           "RetryPolicy", "SchedulerStats", "MicroBatchScheduler",
           "MatchCursor", "SegmentResult", "ENTRY_EXACT", "open_cursor",
           "open_lane_cursor", "segment_result", "merge", "merge_calls",
           "reset_merge_calls", "counting_merges", "FaultPlan",
           "InjectedFault", "table_signature", "pattern_set_signature",
           "sessions_tree",
           "save_sessions_tree", "load_sessions_tree", "unpack_cursor",
           "BlockedStreamMatcher", "BlockedStreamSession",
           "OooStreamMatcher", "OooStream", "OooStats", "OooPolicy",
           "ReorderBufferFull", "SequenceGapError", "OooIntegrityError",
           "segment_fingerprint"]


class StreamMatcher:
    """Resumable, continuously micro-batched matching over byte streams.

    ``source`` is anything ``core.engine.Matcher`` accepts (a DFA, a
    ``PackedDFA``, a sequence of DFAs) — or an existing ``Matcher``, whose
    compiled buckets, backend and capacity layout are then shared with
    whole-document matching.

    **Bit-identity guarantee**: a closed stream's [K] ``accepted`` /
    ``final_states`` equal ``Matcher.membership_batch`` on the stream's
    concatenated bytes, regardless of how the bytes were split across
    ``feed`` calls — on every backend ("local" / "pallas" / "sharded") and
    mesh shape, including 2-D doc x chunk meshes where each tick's segment
    rows shard over "doc" (large tick batches scale past one host).

    ``policy`` sets the tick policy (default: eager flush; see
    ``TickPolicy`` — ``max_batch`` pending streams, ``max_delay`` feed
    events, or a ``max_delay_s`` wall-clock deadline).  Remaining keyword
    arguments (``backend=``, ``capacities=``, ``mesh_shape=``,
    ``calibrate=``, ``num_chunks=``, ...) construct the underlying
    ``Matcher``.  When the matcher is built here, ``num_chunks`` defaults
    to 1 (batched sequential scan): with many concurrent streams the *row*
    axis already saturates the device, and per-segment chunk speculation
    would add C x S redundant lanes per stream — ``benchmarks --only
    stream_throughput`` measures the difference.  Pass ``num_chunks>1`` (or
    a pre-built ``Matcher``) for few heavy streams, where in-segment
    speculation is the only parallelism.
    """

    def __init__(self, source, *, policy: TickPolicy | None = None,
                 clock=None, retry: RetryPolicy | None = None,
                 straggler=None, fault_plan: FaultPlan | None = None,
                 lane_ticks: bool = False, **matcher_kwargs):
        if isinstance(source, Matcher):
            if matcher_kwargs:
                raise ValueError("matcher kwargs conflict with a pre-built "
                                 f"Matcher: {sorted(matcher_kwargs)}")
            self.matcher = source
        else:
            matcher_kwargs.setdefault("num_chunks", 1)
            self.matcher = Matcher(source, **matcher_kwargs)
        # clock (default time.monotonic) feeds the max_delay_s deadline;
        # simulated event loops and tests inject their own.  retry /
        # straggler / fault_plan configure the scheduler's fault-tolerance
        # layer (see scheduler.py docstring).
        sched_kwargs = dict(retry=retry, straggler=straggler,
                            fault_plan=fault_plan, lane_ticks=lane_ticks)
        if clock is not None:
            sched_kwargs["clock"] = clock
        self.scheduler = MicroBatchScheduler(self.matcher, policy,
                                             **sched_kwargs)
        self._next_sid = 0
        self._sessions: dict[int, StreamSession] = {}
        self._snapshot_step = 0
        # snapshot identity override: a BlockedStreamMatcher stamps the
        # full-set pattern_set_signature here so each per-block snapshot
        # refuses restore when *any* sibling block (or the prefilter)
        # changed, not merely this block's own table
        self.snapshot_signature: str | None = None

    # -- session lifecycle ---------------------------------------------------

    def open(self) -> StreamSession:
        """Open a stream at byte position 0 (exact cursor at the starts)."""
        sid = self._next_sid
        self._next_sid += 1
        session = StreamSession(sid, self, open_cursor(self.matcher.dev))
        self._sessions[sid] = session
        return session

    def open_at(self, entry_class: int) -> StreamSession:
        """Open a candidate-keyed stream *mid-flight*: its bytes start at an
        unknown position whose preceding boundary key is ``entry_class``.

        Requires ``lane_ticks=True``.  The session's cursor stays a [K, S]
        restricted transition map across ticks (``Matcher.advance_cursors``
        advances it without collapsing), so ``close_map`` can hand back a
        ``SegmentResult`` composable onto whatever prefix eventually lands —
        the scheduler half of the out-of-order tier (``streaming.ooo`` owns
        sequencing).
        """
        if not self.scheduler.lane_ticks:
            raise ValueError("open_at requires StreamMatcher(..., "
                             "lane_ticks=True)")
        sid = self._next_sid
        self._next_sid += 1
        session = StreamSession(sid, self,
                                open_lane_cursor(self.matcher.dev,
                                                 entry_class))
        self._sessions[sid] = session
        return session

    def close_map(self, session: StreamSession) -> SegmentResult:
        """Close a candidate-keyed session; returns its accumulated
        restricted transition map (everything fed, as one composable
        ``SegmentResult`` keyed on the session's ``entry_class``)."""
        if session.closed:
            raise ValueError("stream session is already closed")
        if session.owner is not self:
            raise ValueError("session belongs to a different StreamMatcher")
        if session.cursor.exact:
            raise ValueError("session is exact (opened at byte 0); use "
                             "close() for its final decision")
        if session.pending_bytes:
            self.scheduler.tick()
        session.closed = True
        self._sessions.pop(session.sid, None)
        cur = session.cursor
        return SegmentResult(lane_states=cur.lane_states.copy(),
                             entry_class=cur.entry_class,
                             n_bytes=cur.byte_count,
                             last_class=cur.last_class)

    def feed(self, session: StreamSession, data: bytes | np.ndarray, *,
             flush: bool = False) -> None:
        """Admit the stream's next segment; dispatch is up to the policy
        (``flush=True`` forces a tick after admission)."""
        if session.closed:
            raise ValueError("stream session is closed")
        if session.owner is not self:
            raise ValueError("session belongs to a different StreamMatcher")
        buf = (bytes(data) if isinstance(data, (bytes, bytearray))
               else np.asarray(data, np.uint8).tobytes())
        session.segments_fed += 1
        # empty segments route through too: they are a no-op for this stream
        # but still a feed event, so queued streams' max_delay / max_delay_s
        # deadlines advance (the scheduler never parks a zero-byte segment)
        self.scheduler.enqueue(session, buf)
        if flush:
            self.scheduler.tick()

    def flush(self) -> int:
        """Force one tick over everything pending; returns streams advanced."""
        return self.scheduler.tick()

    def close(self, session: StreamSession) -> StreamResult:
        """Flush the stream's pending bytes and return its final decision."""
        if session.closed:
            raise ValueError("stream session is already closed")
        if session.owner is not self:
            raise ValueError("session belongs to a different StreamMatcher")
        if session.pending_bytes:
            # one tick drains the whole queue, so closing one stream still
            # coalesces every other pending stream into the same device round
            self.scheduler.tick()
        session.closed = True
        self._sessions.pop(session.sid, None)
        states = session.cursor.states
        return StreamResult(
            accepted=self.matcher.packed.accepting[states].copy(),
            final_states=states.copy(),
            byte_count=session.cursor.byte_count,
            segments_fed=session.segments_fed)

    # -- hot pattern swap ----------------------------------------------------

    def _reset_open_cursors(self) -> None:
        """Re-open every live session's cursor at the new pattern starts.

        The post-swap carry for *changed* tables: old packed state ids are
        meaningless under the new table, so swapped patterns see only bytes
        fed after the swap.  ``byte_count`` keeps counting (it is a stream
        property, not a pattern one); ``segments_fed`` persists on the
        session; eviction state resets so admission re-evaluates under the
        new tables (``MicroBatchScheduler.reopen``).
        """
        for sess in self._sessions.values():
            fresh = open_cursor(self.matcher.dev)
            sess.cursor = dataclasses.replace(
                fresh, byte_count=sess.cursor.byte_count)
            self.scheduler.reopen(sess)

    def swap_patterns(self, source) -> bool:
        """Hot-swap the pattern set at a tick boundary; True iff changed.

        Semantics:

        * **Identical tables** (same ``packed_signature``): a guaranteed
          no-op — returns False and in-flight cursors carry over
          bit-identically (nothing is touched).
        * **Changed tables**: pending bytes first flush through the *old*
          tables (the tick boundary), then the underlying
          ``Matcher.swap_patterns`` rebuilds device tables and every open
          exact session re-opens at the new starts
          (``_reset_open_cursors``).
        * **Candidate-keyed sessions** (``open_at``): refused while any is
          open — a [K, S] restricted map cannot be re-keyed onto different
          tables; close them (``close_map``) first.

        Block-granular carry — unchanged blocks keeping their cursors
        mid-stream while siblings swap — lives in
        ``BlockedStreamMatcher.swap_patterns``.
        """
        lanes = [s for s in self._sessions.values() if not s.cursor.exact]
        if lanes:
            raise ValueError(
                f"{len(lanes)} candidate-keyed session(s) are open; their "
                "[K, S] maps cannot be re-keyed onto new tables — close_map "
                "them before swap_patterns")
        if self.scheduler.pending_streams:
            self.scheduler.tick()
        if not self.matcher.swap_patterns(source):
            return False
        self._reset_open_cursors()
        return True

    # -- failover ------------------------------------------------------------

    def snapshot(self, directory: str, *, step: int | None = None) -> str:
        """Atomically publish every open session's state to ``directory``.

        The snapshot covers cursor lane states, absorbed flags, byte counts,
        boundary classes *and* unflushed pending bytes — the complete
        per-stream state (the Eq. 8 composition makes the cursor a full
        summary of everything already matched).  Writes go through
        ``training/checkpoint.py``'s atomic publish (``step_<N>.tmp`` then
        rename), so a writer killed mid-snapshot leaves only a ``.tmp``
        directory that restore ignores.  Returns the published path.
        """
        sessions = sorted((s for s in self._sessions.values() if not s.closed),
                          key=lambda s: s.sid)
        tree = sessions_tree(sessions, self.matcher.packed, self._next_sid,
                             signature=self.snapshot_signature)
        if step is None:
            step = self._snapshot_step
        self._snapshot_step = step + 1
        return save_sessions_tree(directory, tree, step)

    def restore(self, directory: str, *,
                step: int | None = None) -> list[StreamSession]:
        """Rebuild sessions from the latest (or ``step``-th) snapshot.

        The restoring matcher may run any backend or ``mesh_shape`` — a
        stream frozen on a 2x4 ("doc", "chunk") mesh resumes on 1x1 or 8x1
        bit-identically; on a sharded target the tree is re-placed through
        ``distributed.fault_tolerance.reshard_tree``.  Restored sessions
        with pending bytes are re-admitted to the scheduler (no feed event
        is counted — their bytes were accounted when originally fed).
        Refuses a snapshot taken against a different packed pattern set, or
        one whose session ids collide with sessions already open here.
        """
        tree, step = load_sessions_tree(
            directory, self.matcher, step=step,
            expect_signature=self.snapshot_signature)
        sids = [int(s) for s in tree["sid"]]
        clash = [sid for sid in sids if sid in self._sessions]
        if clash:
            raise ValueError(
                f"snapshot session ids {clash[:5]} are already open on this "
                "StreamMatcher; restore into a fresh matcher (or close the "
                "colliding sessions first)")
        off = tree["pending_off"]
        restored = []
        for i, sid in enumerate(sids):
            sess = StreamSession(sid, self, unpack_cursor(tree, i))
            sess.segments_fed = int(tree["segments_fed"][i])
            sess._evicted = bool(tree["evicted"][i])
            sess._pending = bytearray(
                tree["pending"][int(off[i]):int(off[i + 1])].tobytes())
            self._sessions[sid] = sess
            self.scheduler.readmit(sess)
            restored.append(sess)
        self._next_sid = max(self._next_sid, int(tree["next_sid"]))
        self._snapshot_step = max(self._snapshot_step, step + 1)
        return restored

    # -- introspection -------------------------------------------------------

    @property
    def stats(self) -> SchedulerStats:
        return self.scheduler.stats

    @property
    def n_patterns(self) -> int:
        return self.matcher.n_patterns


# imported last: blocked.py builds on StreamMatcher above
from .blocked import BlockedStreamMatcher, BlockedStreamSession  # noqa: E402
