"""Resumable match cursors: Eq. 8 composition across segment boundaries.

The paper's merge (Eq. 8) is associative: a chunk's contribution to the
final state is a *function* from entry states to exit states, keyed by the
reverse-lookahead class of the byte just before the chunk, and functions
compose.  That is exactly the property Simultaneous Finite Automata
(Sin'ya et al., arXiv:1405.0562) exploit — and it means a membership test
never has to see the whole input at once.

Two representations of a stream prefix live here:

  * a **collapsed (exact) cursor** — ``entry_class == ENTRY_EXACT`` with one
    lane per pattern holding the exact packed state after the prefix.  This
    is what ``StreamMatcher`` sessions carry: streams are fed from their true
    beginning, so the exact state is always known and the device's
    segment-entry path (``Matcher.advance_segments``) continues it directly.
  * a **speculative lane cursor** — ``lane_states [K, S]`` holding the exit
    state of the prefix under each Eq. 11 candidate entry state of
    ``entry_class`` (the SFA-style restricted transition map).  This is what
    an *independently matched* segment produces (``segment_result``): it can
    be computed before the preceding bytes are known and composed later.

``merge`` is the pure Eq. 8 composition of a cursor with a segment's map.
It is exact by the paper's argument: the cursor's state ``q`` was produced
by reading a byte of class ``c = seg.entry_class``, so ``q`` has an incoming
``c``-transition and is a candidate of ``I_c`` — unless ``q`` is the
pattern's sink, which is absorbing and stays the sink.  Feeding a document
through any segmentation is therefore bit-identical to one-shot matching
(property-tested in tests/test_streaming.py).
"""

from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

from ..core.engine.plan import DeviceTables
from ..kernels.ref import cursor_merge_ref

__all__ = ["ENTRY_EXACT", "MatchCursor", "SegmentResult", "open_cursor",
           "open_lane_cursor", "segment_result", "merge", "merge_calls",
           "reset_merge_calls", "counting_merges"]

ENTRY_EXACT = -1  # lane axis is exact (one true lane), not candidate-keyed

# Host merges performed since import — the scheduler's tick path must leave
# this untouched (composition happens on device: ``Matcher.advance_segments``
# fuses the entry seed, ``Matcher.advance_cursors`` the lane composition).
# ``benchmarks --only stream_throughput --smoke`` fails on a regression.
_MERGE_CALLS = 0


def merge_calls() -> int:
    """Host-side ``merge`` invocations so far (regression counter)."""
    return _MERGE_CALLS


def reset_merge_calls() -> int:
    """Zero the counter; returns the value it had.

    Tests must not couple through import-lifetime state: an autouse fixture
    (tests/conftest.py) resets the counter before every test, so a test that
    asserts ``merge_calls() == 0`` measures only its own tick path, not
    whichever test imported the module first.
    """
    global _MERGE_CALLS
    prev = _MERGE_CALLS
    _MERGE_CALLS = 0
    return prev


@contextlib.contextmanager
def counting_merges():
    """Scoped view of the counter: yields a callable returning the number of
    host merges performed since entering the context.

        with counting_merges() as merged:
            ... tick path ...
        assert merged() == 0
    """
    start = _MERGE_CALLS
    yield lambda: _MERGE_CALLS - start


@dataclasses.dataclass(frozen=True)
class SegmentResult:
    """One segment's restricted transition map, matched independently.

    ``lane_states[k, j]`` is pattern ``k``'s exit state when the segment is
    entered in ``candidates[entry_class, k, j]`` (or in its start state for
    ``entry_class == ENTRY_EXACT``, where the lane axis has width 1).
    """

    lane_states: np.ndarray  # [K, S] int32 exit states per entry lane
    entry_class: int         # joint class keying the lane axis, or ENTRY_EXACT
    n_bytes: int
    last_class: int          # boundary key after the segment (r-byte suffix
                             # window, ``DeviceTables.advance_key``);
                             # ENTRY_EXACT when the segment is empty


@dataclasses.dataclass(frozen=True)
class MatchCursor:
    """Resumable per-stream matching state (pure host data, pattern-packed).

    ``absorbed[k]`` means every lane of pattern ``k`` sits in an absorbing
    state: no further byte can move it, so a scheduler may skip matching the
    stream's remaining segments entirely (stream-level early exit).
    ``byte_count`` and ``last_class`` persist across segment boundaries;
    ``last_class`` keys the candidate row of the next independent segment.
    """

    lane_states: np.ndarray  # [K, S] int32 (S == 1 for exact cursors)
    entry_class: int         # ENTRY_EXACT or the joint class keying the lanes
    absorbed: np.ndarray     # [K] bool
    byte_count: int
    last_class: int          # ENTRY_EXACT before any byte was absorbed

    @property
    def exact(self) -> bool:
        return self.entry_class == ENTRY_EXACT

    @property
    def states(self) -> np.ndarray:
        """Exact [K] packed states (collapsed cursors only)."""
        if not self.exact:
            raise ValueError("cursor is candidate-keyed; merge it onto an "
                             "exact prefix before reading states")
        return self.lane_states[:, 0]

    def accepted(self, tables: DeviceTables) -> np.ndarray:
        """[K] accept flags of the exact current states."""
        return tables.packed.accepting[self.states]

    def advanced(self, final_states: np.ndarray, n_bytes: int,
                 last_class: int, tables: DeviceTables,
                 absorbed: np.ndarray | None = None) -> "MatchCursor":
        """Collapsed successor from a device segment result (the scheduler's
        fast path: ``Matcher.advance_segments`` already composed on device).

        ``absorbed`` takes the batch result's precomputed [K] flags
        (``SegmentBatchResult.absorbed`` rows) so a tick performs zero
        per-stream table lookups; omitted, they are derived here.
        """
        if not self.exact:
            raise ValueError("device continuation requires an exact cursor")
        if n_bytes == 0:
            return self
        st = np.asarray(final_states, np.int32).reshape(-1, 1)
        if absorbed is None:
            absorbed = tables.absorbing[st].all(axis=1)
        return MatchCursor(lane_states=st, entry_class=ENTRY_EXACT,
                           absorbed=np.asarray(absorbed, bool).reshape(-1),
                           byte_count=self.byte_count + int(n_bytes),
                           last_class=int(last_class))

    def advanced_lanes(self, lane_states: np.ndarray, n_bytes: int,
                       last_class: int,
                       absorbed: np.ndarray) -> "MatchCursor":
        """Candidate-keyed successor from a device cursor result — the
        lane-tick scheduler path (``Matcher.advance_cursors`` rows).

        The cursor stays keyed on its original ``entry_class`` across ticks
        (its restricted transition map just grew by one segment), so it
        remains composable onto whatever prefix eventually lands.
        """
        if self.exact:
            raise ValueError("exact cursors continue via advanced(); "
                             "advanced_lanes extends candidate-keyed maps")
        if n_bytes == 0:
            return self
        return MatchCursor(lane_states=np.asarray(lane_states, np.int32),
                           entry_class=self.entry_class,
                           absorbed=np.asarray(absorbed, bool).reshape(-1),
                           byte_count=self.byte_count + int(n_bytes),
                           last_class=int(last_class))

    def skipped(self, n_bytes: int, last_class: int) -> "MatchCursor":
        """Account bytes the scheduler never matched (fully absorbed)."""
        return dataclasses.replace(self, byte_count=self.byte_count + int(n_bytes),
                                   last_class=int(last_class))


def open_cursor(tables: DeviceTables) -> MatchCursor:
    """Fresh exact cursor at the packed pattern starts (zero bytes read)."""
    starts = tables.packed.starts.astype(np.int32).reshape(-1, 1)
    return MatchCursor(lane_states=starts.copy(), entry_class=ENTRY_EXACT,
                       absorbed=tables.absorbing[starts].all(axis=1),
                       byte_count=0, last_class=ENTRY_EXACT)


def open_lane_cursor(tables: DeviceTables, entry_class: int) -> MatchCursor:
    """Identity candidate-keyed cursor: zero bytes read, keyed on
    ``entry_class``.

    Its lane map is the identity on the Eq. 11 candidate row itself — lane
    ``(k, j)`` holds ``candidates[entry_class, k, j]`` — so composing it
    under any prefix ending in ``entry_class`` is a no-op.  This is how a
    stream opens *mid-flight* (an out-of-order segment run, a lane-tick
    scheduler session): match first, compose onto the exact prefix later.
    """
    cls = int(entry_class)
    if not 0 <= cls < tables.n_keys:
        raise ValueError(f"entry_class must be a boundary key in "
                         f"[0, {tables.n_keys}), got {cls}")
    lanes = tables.tables.candidates[cls].astype(np.int32).copy()
    return MatchCursor(lane_states=lanes, entry_class=cls,
                       absorbed=tables.absorbing[lanes].all(axis=1),
                       byte_count=0, last_class=cls)


def segment_result(tables: DeviceTables, data: bytes | np.ndarray,
                   entry_class: int = ENTRY_EXACT) -> SegmentResult:
    """Match one segment independently of whatever precedes it.

    For ``entry_class == ENTRY_EXACT`` the segment is matched from the
    pattern starts (only composable onto a zero-byte cursor); otherwise it is
    matched speculatively from every Eq. 11 candidate of ``entry_class`` —
    computable before the preceding bytes are known, exactly like a
    speculative chunk of the in-document pipeline.
    """
    packed = tables.packed
    arr = (np.frombuffer(data, np.uint8)
           if isinstance(data, (bytes, bytearray))
           else np.asarray(data, np.uint8))
    cls = packed.classes_of(arr)
    if entry_class == ENTRY_EXACT:
        states = packed.starts.astype(np.int32).reshape(-1, 1).copy()
    else:
        states = tables.tables.candidates[entry_class].astype(np.int32).copy()
    for c in cls:
        states = packed.table[states, int(c)]
    return SegmentResult(lane_states=states.astype(np.int32),
                         entry_class=int(entry_class), n_bytes=int(arr.size),
                         last_class=(tables.advance_key(entry_class, arr)
                                     if arr.size else ENTRY_EXACT))


def merge(cursor: MatchCursor, seg: SegmentResult, *,
          tables: DeviceTables) -> MatchCursor:
    """Pure Eq. 8 composition: extend ``cursor`` by one matched segment.

    For every cursor lane state ``q``: look up ``q``'s lane in the segment's
    candidate row (``cand_index[seg.entry_class, q]``), take the segment's
    exit state there; a missing ``q`` is the pattern's absorbing sink; and a
    ``pad``-free empty segment passes the cursor through unchanged.  The
    composition itself is ``kernels.ref.cursor_merge_ref`` at batch size 1 —
    the numpy host reference of the device merge
    (``Matcher.advance_cursors`` runs the same composition batched on
    device; the scheduler's tick path never calls this function, see
    ``merge_calls``).
    """
    global _MERGE_CALLS
    _MERGE_CALLS += 1
    if seg.n_bytes == 0:
        return cursor
    if seg.entry_class == ENTRY_EXACT:
        if cursor.byte_count != 0:
            raise ValueError("an exact-entry segment only composes onto a "
                             "zero-byte cursor; match it with entry_class = "
                             "the cursor's last_class instead")
        lane_states = np.broadcast_to(
            seg.lane_states[:, :1], cursor.lane_states.shape).copy()
    else:
        if seg.entry_class != cursor.last_class:
            raise ValueError(
                f"segment keyed on class {seg.entry_class} cannot extend a "
                f"cursor whose last byte classified to {cursor.last_class}")
        lane_states = cursor_merge_ref(
            cursor.lane_states[None], seg.lane_states[None],
            np.array([seg.entry_class], np.int32),
            tables.tables.cand_index, tables.packed.sinks,
            pad_cls=tables.pad_key)[0]
    return MatchCursor(lane_states=lane_states,
                       entry_class=cursor.entry_class,
                       absorbed=tables.absorbing[lane_states].all(axis=1),
                       byte_count=cursor.byte_count + seg.n_bytes,
                       last_class=seg.last_class)
