"""Deterministic fault injection for the streaming runtime.

A production streaming tier fails in three distinct ways, and a recovery
layer is only trustworthy when every one of them is exercised on demand:

  * a **tick dispatch raises** — device loss, OOM, a preempted host.  The
    ``"pre"`` phase models the fused call failing before any result landed;
    the ``"post"`` phase models the nastier case where the failure surfaces
    *after* cursors were already updated, so recovery must restore them from
    their pre-tick snapshots or segments get double-composed;
  * a **device degrades** — it still answers, slower.  ``delay_s`` adds
    per-device seconds to the observed tick timings that feed the
    ``StragglerPolicy`` EWMA (``MicroBatchScheduler._feed_straggler``);
  * a **capacity measurement is corrupted** — ``capacity_skew`` multiplies
    the observed per-device times, standing in for a host whose profiled
    capacity no longer reflects reality.

``FaultPlan`` schedules all three by tick index, so every recovery path of
the scheduler (retry-with-restore, requeue-on-giveup, EWMA-triggered
rebalance) runs deterministically in tests and CI (``tools/faultbench.py``).
The scheduler consumes the plan through exactly two hooks — ``maybe_fail``
around the dispatch and ``device_times`` on the observed timings — so a plan
can be attached to any ``MicroBatchScheduler`` without touching its logic.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

__all__ = ["InjectedFault", "FaultPlan"]


class InjectedFault(RuntimeError):
    """A scheduled dispatch failure (stands in for device loss / OOM)."""


@dataclasses.dataclass
class FaultPlan:
    """Tick-indexed fault schedule consumed by ``MicroBatchScheduler``.

    ``kill[t] = n`` fails the first ``n`` dispatch attempts of tick ``t``
    before the fused call runs; ``kill_post[t] = n`` fails them *after* the
    cursors were updated (the double-compose hazard).  ``delay_s[t]`` is a
    per-device [D] array of extra seconds and ``capacity_skew[t]`` a [D]
    multiplier (> 1 = slower), both folded into the timings the straggler
    EWMA sees.  ``injected`` counts faults actually raised.
    """

    kill: Mapping[int, int] = dataclasses.field(default_factory=dict)
    kill_post: Mapping[int, int] = dataclasses.field(default_factory=dict)
    delay_s: Mapping[int, Sequence[float]] = dataclasses.field(
        default_factory=dict)
    capacity_skew: Mapping[int, Sequence[float]] = dataclasses.field(
        default_factory=dict)
    injected: int = 0

    def maybe_fail(self, tick: int, attempt: int, phase: str) -> None:
        """Raise ``InjectedFault`` if the schedule kills this attempt.

        ``phase`` is ``"pre"`` (before the fused dispatch) or ``"post"``
        (after cursors were committed — recovery must roll them back).
        """
        if phase not in ("pre", "post"):
            raise ValueError(f"unknown fault phase {phase!r}")
        plan = self.kill if phase == "pre" else self.kill_post
        if attempt < int(plan.get(tick, 0)):
            self.injected += 1
            raise InjectedFault(
                f"injected {phase}-dispatch fault (tick {tick}, "
                f"attempt {attempt})")

    def device_times(self, tick: int, base: np.ndarray) -> np.ndarray:
        """Per-device observed times for one tick: base + delays, skewed."""
        t = np.asarray(base, np.float64).copy()
        delay = self.delay_s.get(tick)
        if delay is not None:
            t = t + np.asarray(delay, np.float64)
        skew = self.capacity_skew.get(tick)
        if skew is not None:
            t = t * np.asarray(skew, np.float64)
        return t
