"""K-blocked streaming: per-block ``StreamMatcher``s behind one session.

The batched side of the pattern-set scale tier fans documents over
``core.engine.BlockedMatcher``'s per-block matchers; this module is the
streaming side.  A ``BlockedStreamMatcher`` keeps one child ``StreamMatcher``
per block — sharing the blocked matcher's compiled buckets and one
``TickPolicy`` — and a ``BlockedStreamSession`` holds the aligned per-block
child sessions, so ``open`` / ``feed`` / ``flush`` / ``close`` look exactly
like the single-table runtime while each block's cursors stay local to its
own table (packed state ids are block-local; ``close`` re-offsets finals by
the set's ``state_bases`` into the global [K] result).

Hot swaps are where blocking earns its keep mid-stream: ``swap_patterns``
leaves unchanged blocks' children — compiled lowerings *and* live cursors —
completely untouched (their streams keep their full byte history,
bit-identically), while changed blocks re-open their sessions' cursors at
the new starts (the ``StreamMatcher.swap_patterns`` carry rules, applied per
block).

Snapshots write one tree per block (``block_<b>/``) with the full-set
``pattern_set_signature`` stamped over every tree, so a restore is refused
when *any* part of the set changed — a swapped sibling block or a different
prefilter table, not merely the restored block's own content.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Sequence, Union

import numpy as np

from ..core.engine.blocked import BlockedMatcher
from ..core.patterns import PatternSet
from . import StreamMatcher
from .checkpoint import pattern_set_signature
from .cursor import open_cursor
from .scheduler import SchedulerStats, TickPolicy
from .session import StreamResult, StreamSession

__all__ = ["BlockedStreamMatcher", "BlockedStreamSession"]


class BlockedStreamSession:
    """Handle over one logical stream's aligned per-block child sessions."""

    __slots__ = ("sid", "owner", "parts", "closed", "segments_fed")

    def __init__(self, sid: int, owner, parts: list[StreamSession]):
        self.sid = sid
        self.owner = owner
        self.parts = parts
        self.closed = False
        self.segments_fed = 0

    @property
    def pending_bytes(self) -> int:
        return max(p.pending_bytes for p in self.parts)

    @property
    def byte_count(self) -> int:
        """Bytes absorbed into the cursors (excludes unflushed pending)."""
        return max(p.byte_count for p in self.parts)

    def feed(self, data: bytes | np.ndarray, *, flush: bool = False) -> None:
        self.owner.feed(self, data, flush=flush)

    def close(self) -> StreamResult:
        return self.owner.close(self)


class BlockedStreamMatcher:
    """Streaming front end over a multi-block pattern set.

    ``source`` is a ``BlockedMatcher`` (sharing its compiled buckets — the
    ``CorpusFilter.scan_stream`` path), a ``PatternSet``, or anything
    ``PatternSet`` accepts (then ``k_blk`` / ``search`` / ``prefilter`` and
    the remaining ``Matcher`` kwargs apply).  The same bit-identity contract
    as ``StreamMatcher`` holds per block: a closed stream's [K] verdict
    equals ``BlockedMatcher.membership_batch`` on the concatenated bytes.

    The streaming path runs every block on every fed byte — the prefilter
    gate needs whole documents and so applies to batch scans, not to
    incremental feeds (a stream's bytes are not known until close).
    """

    def __init__(self, source: Union[BlockedMatcher, PatternSet, Sequence,
                                     dict], *,
                 policy: Optional[TickPolicy] = None,
                 k_blk: Optional[int] = None, search: bool = True,
                 prefilter: bool = True, **matcher_kwargs):
        if isinstance(source, BlockedMatcher):
            if matcher_kwargs or k_blk is not None:
                raise ValueError("matcher kwargs conflict with a pre-built "
                                 "BlockedMatcher")
            self.blocked = source
        else:
            self.blocked = BlockedMatcher(source, k_blk=k_blk, search=search,
                                          prefilter=prefilter,
                                          **matcher_kwargs)
        self._policy = policy
        self._sms: list[StreamMatcher] = [
            StreamMatcher(m, policy=policy) for m in self.blocked.matchers]
        self._stamp_signature()
        self._sessions: dict[int, BlockedStreamSession] = {}
        self._next_sid = 0
        self._snapshot_step = 0

    def _stamp_signature(self) -> None:
        sig = pattern_set_signature(self.blocked.pattern_set,
                                    self.blocked.prefilter)
        for sm in self._sms:
            sm.snapshot_signature = sig

    # -- shape ---------------------------------------------------------------

    @property
    def pattern_set(self) -> PatternSet:
        return self.blocked.pattern_set

    @property
    def n_patterns(self) -> int:
        return self.blocked.n_patterns

    @property
    def n_blocks(self) -> int:
        return self.blocked.n_blocks

    # -- session lifecycle ---------------------------------------------------

    def open(self) -> BlockedStreamSession:
        """Open one logical stream: aligned child sessions on every block."""
        parts = [sm.open() for sm in self._sms]
        sid = self._next_sid
        self._next_sid += 1
        sess = BlockedStreamSession(sid, self, parts)
        self._sessions[sid] = sess
        return sess

    def feed(self, session: BlockedStreamSession, data: bytes | np.ndarray,
             *, flush: bool = False) -> None:
        """Admit the stream's next segment to every block's child."""
        if session.closed:
            raise ValueError("stream session is closed")
        if session.owner is not self:
            raise ValueError("session belongs to a different matcher")
        session.segments_fed += 1
        for sm, part in zip(self._sms, session.parts):
            sm.feed(part, data)
        if flush:
            self.flush()

    def flush(self) -> int:
        """Tick every block; returns the max streams advanced in any block."""
        return max((sm.flush() for sm in self._sms), default=0)

    def close(self, session: BlockedStreamSession) -> StreamResult:
        """Flush and fan every block's [k_blk] verdict into one [K] result."""
        if session.closed:
            raise ValueError("stream session is already closed")
        if session.owner is not self:
            raise ValueError("session belongs to a different matcher")
        ps = self.pattern_set
        results = [sm.close(part)
                   for sm, part in zip(self._sms, session.parts)]
        session.closed = True
        self._sessions.pop(session.sid, None)
        accepted = np.concatenate([r.accepted for r in results])
        finals = np.concatenate(
            [r.final_states + int(ps.state_bases[bi])
             for bi, r in enumerate(results)]).astype(np.int32)
        return StreamResult(accepted=accepted, final_states=finals,
                            byte_count=max(r.byte_count for r in results),
                            segments_fed=session.segments_fed)

    # -- hot pattern swap ----------------------------------------------------

    def swap_patterns(self, source, *, k_blk: Optional[int] = None,
                      search: Optional[bool] = None) -> dict:
        """Swap the set at a tick boundary; unchanged blocks carry cursors.

        Pending bytes flush through the old tables first.  Then
        ``BlockedMatcher.swap_patterns`` rebuilds only changed blocks, and
        per block:

        * **unchanged** — the child ``StreamMatcher`` (compiled lowerings
          *and* every live cursor) is untouched: its streams keep their
          full byte history bit-identically across the swap;
        * **changed in place** — the child's open cursors re-open at the
          new starts (``StreamMatcher`` carry rules: swapped patterns see
          only post-swap bytes, byte counts persist, eviction resets);
        * **added** — a fresh child with sessions aligned to every open
          stream;
        * **dropped** — trailing children discarded with their cursors.

        Returns the ``BlockedMatcher`` report dict.
        """
        if any(sm.scheduler.pending_streams for sm in self._sms):
            self.flush()
        info = self.blocked.swap_patterns(source, k_blk=k_blk, search=search)
        for bi in info["rebuilt"]:
            if bi < len(self._sms):
                self._sms[bi]._reset_open_cursors()
            else:
                self._sms.append(self._adopt_block(bi))
        if info["dropped"]:
            del self._sms[len(self.blocked.matchers):]
        for sess in self._sessions.values():
            del sess.parts[len(self.blocked.matchers):]
        self._stamp_signature()
        return info

    def _adopt_block(self, bi: int) -> StreamMatcher:
        """Child for a block added by a swap: every open stream gets an
        aligned session whose cursor starts at the new block's starts (the
        block has seen none of the stream's earlier bytes — same rule as a
        changed block) with the stream's byte count carried."""
        sm = StreamMatcher(self.blocked.matchers[bi], policy=self._policy)
        sm._next_sid = self._next_sid
        for sid in sorted(self._sessions):
            sess = self._sessions[sid]
            part = StreamSession(sid, sm, dataclasses.replace(
                open_cursor(sm.matcher.dev),
                byte_count=sess.parts[0].cursor.byte_count))
            part.segments_fed = sess.parts[0].segments_fed
            sm._sessions[sid] = part
            sess.parts.append(part)
        return sm

    # -- failover ------------------------------------------------------------

    def snapshot(self, directory: str, *, step: Optional[int] = None) -> str:
        """Publish one tree per block under ``directory/block_<b>/``.

        Every tree carries the full-set ``pattern_set_signature`` (blocking
        layout + every block's tables + prefilter literals), so restore
        refuses the whole snapshot when any part of the set changed.
        """
        if step is None:
            step = self._snapshot_step
        self._snapshot_step = step + 1
        for bi, sm in enumerate(self._sms):
            sm.snapshot(os.path.join(directory, f"block_{bi:03d}"), step=step)
        return directory

    def restore(self, directory: str, *, step: Optional[int] = None
                ) -> list[BlockedStreamSession]:
        """Rebuild logical sessions from a per-block snapshot.

        Each block's tree re-verifies the full-set signature; a stream must
        restore on every block (a snapshot with mismatched session sets
        across blocks is refused as corrupt).
        """
        per_block = [sm.restore(os.path.join(directory, f"block_{bi:03d}"),
                                step=step)
                     for bi, sm in enumerate(self._sms)]
        by_sid: dict[int, list[Optional[StreamSession]]] = {}
        for bi, parts in enumerate(per_block):
            for p in parts:
                by_sid.setdefault(p.sid, [None] * self.n_blocks)[bi] = p
        restored = []
        for sid in sorted(by_sid):
            parts = by_sid[sid]
            if any(p is None for p in parts):
                missing = [bi for bi, p in enumerate(parts) if p is None]
                raise ValueError(
                    f"snapshot is inconsistent: stream {sid} is missing from "
                    f"block(s) {missing}")
            sess = BlockedStreamSession(sid, self, parts)  # type: ignore[arg-type]
            sess.segments_fed = parts[0].segments_fed
            self._sessions[sid] = sess
            restored.append(sess)
        self._next_sid = max(self._next_sid,
                             max(by_sid, default=-1) + 1)
        self._snapshot_step = max(self._snapshot_step,
                                  (step if step is not None
                                   else self._snapshot_step))
        return restored

    # -- introspection -------------------------------------------------------

    @property
    def stats(self) -> SchedulerStats:
        """Summed scheduler stats across all blocks' children."""
        agg = SchedulerStats()
        for sm in self._sms:
            st = sm.stats
            for f in dataclasses.fields(SchedulerStats):
                setattr(agg, f.name,
                        getattr(agg, f.name) + getattr(st, f.name))
        return agg

    @property
    def block_stats(self) -> list[SchedulerStats]:
        return [sm.stats for sm in self._sms]

    def perf_report(self) -> dict:
        return self.blocked.perf_report()
