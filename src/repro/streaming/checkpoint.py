"""Streaming failover: session snapshot/restore on the atomic checkpoint
format.

The SFA formulation (arXiv:1405.0562) guarantees a cursor's ``[K, S]`` lane
state is a *complete, composable* summary of every byte the stream has seen
— so the entire per-stream state of the runtime is one small fixed tree:
cursor lane states, absorbed flags, byte counts, boundary classes, plus any
unflushed pending bytes sitting in the admission queue.  This module packs
that tree, and snapshots ride ``training/checkpoint.py``'s atomic-publish
layout (writes go to ``step_<N>.tmp`` and are renamed into place), so a
crashed writer never publishes a partial snapshot and restore always finds
the latest *complete* step.

Restore places the tree through ``distributed.fault_tolerance.reshard_tree``
when the target matcher is mesh-sharded — ``jax.device_put`` under the *new*
mesh's shardings re-places the state regardless of the mesh shape the
snapshot was taken on — so a stream frozen on a 2x4 ("doc", "chunk") mesh
resumes on 1x1 or 8x1 with bit-identical results (the Eq. 8 composition does
not care where it runs; tests/test_fault_tolerance.py sweeps the shapes).

A snapshot is refused on restore unless its packed-table signature matches
the target matcher's: resuming a cursor against a different pattern set
would silently decode garbage states.
"""

from __future__ import annotations

import hashlib

import numpy as np

from ..core.automata import PackedDFA, packed_signature
from ..training.checkpoint import restore_checkpoint, save_checkpoint
from .cursor import MatchCursor

__all__ = ["table_signature", "pattern_set_signature", "sessions_tree",
           "save_sessions_tree", "load_sessions_tree", "unpack_cursor"]

# One leaf per field; the tree structure is the restore contract (the
# ``like`` argument of restore_checkpoint only needs matching keys).
TREE_KEYS = ("sig", "next_sid", "sid", "lane", "lane_width", "entry_class",
             "absorbed", "byte_count", "last_class", "segments_fed",
             "evicted", "pending", "pending_off")


def table_signature(packed: PackedDFA) -> str:
    """Content hash of the packed table a snapshot was taken against.

    Delegates to ``core.automata.packed_signature`` — which also folds in
    sinks and per-pattern offsets — so checkpoint identity, block-level
    lowering reuse and hot-swap no-op detection all agree on what "the same
    pattern set" means.  Covers only *one* packed table; a blocked pattern
    set snapshots per block with the full-set ``pattern_set_signature``
    stamped over each block's tree.
    """
    return packed_signature(packed)


def pattern_set_signature(pattern_set, prefilter=None) -> str:
    """Content hash of a full K-blocked pattern set (+ prefilter tables).

    ``table_signature`` covers exactly one packed table, which is the fix
    this function exists for: a blocked streaming runtime snapshots one
    tree per block, and each block's tree must refuse restore when *any*
    part of the set changed — a hot-swapped sibling block, a different
    blocking layout, or a changed required-literal table would all silently
    re-gate or re-interpret restored traffic.  ``prefilter`` is the
    ``core.prefilter.Prefilter`` in force, or None when gating is off.
    """
    h = hashlib.sha1()
    h.update(f"k_blk={pattern_set.k_blk};".encode())
    for sig in pattern_set.block_signatures:
        h.update(sig.encode())
    h.update(b"|pf:")
    if prefilter is not None:
        h.update(prefilter.signature().encode())
    return h.hexdigest()


def sessions_tree(sessions, packed: PackedDFA, next_sid: int, *,
                  signature: str | None = None) -> dict:
    """Pack open sessions into the fixed checkpoint tree (pure host numpy).

    Cursor lane axes may differ (exact cursors carry S=1, candidate-keyed
    ones S=i_max); lanes pad to the widest and ``lane_width`` records each
    cursor's real width.  Pending bytes concatenate with [B+1] offsets.
    ``signature`` overrides the embedded identity (a blocked runtime stamps
    the full-set ``pattern_set_signature`` instead of this one block's).
    """
    b = len(sessions)
    k = packed.n_patterns
    s = max((sess.cursor.lane_states.shape[1] for sess in sessions),
            default=1)
    lane = np.zeros((b, k, s), np.int32)
    lane_width = np.zeros(b, np.int64)
    entry_class = np.zeros(b, np.int32)
    absorbed = np.zeros((b, k), bool)
    byte_count = np.zeros(b, np.int64)
    last_class = np.zeros(b, np.int32)
    segments_fed = np.zeros(b, np.int64)
    evicted = np.zeros(b, bool)
    sid = np.zeros(b, np.int64)
    pend: list[bytes] = []
    for i, sess in enumerate(sessions):
        cur = sess.cursor
        w = cur.lane_states.shape[1]
        lane[i, :, :w] = cur.lane_states
        lane_width[i] = w
        entry_class[i] = cur.entry_class
        absorbed[i] = cur.absorbed
        byte_count[i] = cur.byte_count
        last_class[i] = cur.last_class
        segments_fed[i] = sess.segments_fed
        evicted[i] = sess._evicted
        sid[i] = sess.sid
        pend.append(bytes(sess._pending))
    off = np.zeros(b + 1, np.int64)
    if b:
        off[1:] = np.cumsum([len(p) for p in pend])
    pending = np.frombuffer(b"".join(pend), np.uint8).copy()
    sig = signature if signature is not None else table_signature(packed)
    return {
        "sig": np.frombuffer(sig.encode(), np.uint8).copy(),
        "next_sid": np.int64(next_sid),
        "sid": sid, "lane": lane, "lane_width": lane_width,
        "entry_class": entry_class, "absorbed": absorbed,
        "byte_count": byte_count, "last_class": last_class,
        "segments_fed": segments_fed, "evicted": evicted,
        "pending": pending, "pending_off": off,
    }


def save_sessions_tree(directory: str, tree: dict, step: int) -> str:
    """Atomic publish through the shared checkpoint layer."""
    return save_checkpoint(directory, tree, step)


def load_sessions_tree(directory: str, matcher, *, step=None,
                       expect_signature: str | None = None
                       ) -> tuple[dict, int]:
    """Load (and verify) the latest complete snapshot for ``matcher``.

    On a mesh-sharded matcher the restored tree is placed through
    ``reshard_tree`` under the *target* mesh before coming back to host
    numpy — the elastic path that makes a snapshot mesh-shape agnostic
    (``restore_checkpoint(shardings=...)`` routes through it).
    """
    like = {key: np.zeros(0) for key in TREE_KEYS}
    shardings = None
    if matcher.backend == "sharded":
        from jax.sharding import NamedSharding, PartitionSpec

        # replicated placement: cursor trees are small host-side state, and
        # replication is valid on every mesh shape (doc-sharding would pin
        # the session count to the doc extent)
        repl = NamedSharding(matcher.executor.mesh, PartitionSpec())
        shardings = {key: repl for key in TREE_KEYS}
    tree, step = restore_checkpoint(directory, like, step=step,
                                    shardings=shardings)
    tree = {key: np.asarray(val) for key, val in tree.items()}
    want = (expect_signature if expect_signature is not None
            else table_signature(matcher.packed))
    got = bytes(tree["sig"].astype(np.uint8)).decode()
    if got != want:
        raise ValueError(
            "snapshot was taken against a different packed pattern set "
            f"(signature {got[:12]}.. != {want[:12]}..); cursor states are "
            "only meaningful relative to the table they were matched with")
    return tree, step


def unpack_cursor(tree: dict, i: int) -> MatchCursor:
    """Rebuild row ``i``'s ``MatchCursor`` from a loaded snapshot tree."""
    w = int(tree["lane_width"][i])
    return MatchCursor(
        lane_states=np.ascontiguousarray(tree["lane"][i, :, :w], np.int32),
        entry_class=int(tree["entry_class"][i]),
        absorbed=np.asarray(tree["absorbed"][i], bool).copy(),
        byte_count=int(tree["byte_count"][i]),
        last_class=int(tree["last_class"][i]))
