"""Micro-batching scheduler: many independent streams, one device tick.

Unbounded byte streams (log tails, token-by-token decodes, chunked uploads)
arrive asynchronously and in tiny pieces — the worst case for a runtime
whose efficiency comes from fused, batched device calls.  The scheduler
closes that gap:

  * an **admission queue** collects pending segments per ``StreamSession``;
    multiple ``feed`` calls to the same stream between ticks *coalesce* into
    one segment (one scan instead of many);
  * a **tick** drains the queue: every pending stream contributes its
    coalesced segment and its cursor's entry states, and one
    ``Matcher.advance_segments`` call advances them all — segments share the
    planner's sticky pow2 shape buckets and ``batch_tile`` device tiles with
    whole-document matching, on any backend (local / pallas / sharded);
  * streams whose cursor is **fully absorbed** are *evicted from admission*:
    their bytes are accounted at ``enqueue`` time and they never enter the
    queue again, so a long-lived serving tier pays nothing — not even queue
    traversal — for decided streams (absorbing states self-loop on every
    class, so skipping is exact; ``SchedulerStats.evicted`` counts sessions
    dropped this way, once each);
  * a **tick is fully on-device**: one ``Matcher.advance_segments`` call
    composes every pending stream's cursor with its coalesced segment (the
    entry seed *is* the Eq. 8 composition), and cursors update from the
    batch result's precomputed arrays — zero per-stream host merges or
    table lookups (``streaming.cursor.merge_calls`` is the regression
    counter; the candidate-keyed batch variant is
    ``Matcher.advance_cursors``);
  * **tick policies** bound latency: eager flush (the default), or a tick
    fires when ``max_batch`` streams have pending data, the oldest pending
    segment has waited ``max_delay`` feed events, or it has waited
    ``max_delay_s`` wall-clock seconds — whichever comes first.  ``flush()``
    forces one.  Deadlines are evaluated at admission time (the scheduler
    owns no timer thread); an async serving loop enforces ``max_delay_s``
    between arrivals by calling ``flush()`` from its own timer.

``SchedulerStats.occupancy`` is real segments per padded device row — the
measure of how well micro-batching fills the fused calls (benchmarks
``--only stream_throughput`` tracks it against the one-shot baseline).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.engine.facade import Matcher

__all__ = ["TickPolicy", "SchedulerStats", "MicroBatchScheduler"]


@dataclasses.dataclass(frozen=True)
class TickPolicy:
    """When the scheduler dispatches the admission queue.

    max_batch   : dispatch as soon as this many streams have pending
                  segments.
    max_delay   : max number of subsequent ``feed`` events a pending segment
                  may wait before a forced dispatch; 0 disables the
                  event-count deadline.
    max_delay_s : max wall-clock seconds the oldest pending segment may wait
                  before a forced dispatch; ``None`` disables the wall-clock
                  deadline.  Checked when segments are admitted (the
                  scheduler owns no timer — an async loop calls ``flush()``
                  on its own timer to bound latency between arrivals).

    With ``max_delay == 0`` and ``max_delay_s is None`` (the default) the
    policy is *eager*: every feed dispatches immediately.  Otherwise a tick
    fires on whichever deadline — batch, event-count or wall-clock — trips
    first.
    """

    max_batch: int = 64
    max_delay: int = 0
    max_delay_s: float | None = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_delay < 0:
            raise ValueError("max_delay must be >= 0")
        if self.max_delay_s is not None and self.max_delay_s < 0:
            raise ValueError("max_delay_s must be >= 0")

    @property
    def eager(self) -> bool:
        return self.max_delay == 0 and self.max_delay_s is None


@dataclasses.dataclass
class SchedulerStats:
    ticks: int = 0            # device dispatch rounds
    feeds: int = 0            # feed() calls admitted
    segments: int = 0         # coalesced segments actually matched
    absorbed_skips: int = 0   # segments skipped: cursor fully absorbed
    evicted: int = 0          # sessions dropped from admission (absorbed)
    bytes_fed: int = 0
    bytes_matched: int = 0    # excludes absorbed skips
    bucket_calls: int = 0     # fused device dispatches across all ticks
    rows_dispatched: int = 0  # tile-padded device rows (occupancy denom)
    early_exits: int = 0      # segments retired by the absorbing early exit

    @property
    def occupancy(self) -> float:
        """Real segments per padded device row (1.0 = perfectly full tiles)."""
        return self.segments / max(self.rows_dispatched, 1)

    @property
    def coalescing(self) -> float:
        """feed() calls folded into each matched segment (>= 1.0)."""
        return self.feeds / max(self.segments + self.absorbed_skips, 1)


class MicroBatchScheduler:
    """Admission queue + tick dispatch over a ``Matcher`` facade.

    ``clock`` (default ``time.monotonic``) timestamps pending segments for
    the ``max_delay_s`` wall-clock deadline; tests and simulated event loops
    may inject their own.
    """

    def __init__(self, matcher: Matcher, policy: TickPolicy | None = None,
                 *, clock=time.monotonic):
        self.matcher = matcher
        self.policy = policy or TickPolicy()
        self._clock = clock
        # sid -> session; dict preserves admission order, and re-feeding an
        # already-queued session keeps its (oldest) position — so the first
        # entry always carries the oldest pending_since for the latency test
        self._queue: dict[int, object] = {}
        self._feed_seq = 0
        self.stats = SchedulerStats()

    @property
    def pending_streams(self) -> int:
        return len(self._queue)

    def enqueue(self, session, data: bytes) -> None:
        """Admit one segment; may trigger a tick per the policy.

        Fully-absorbed sessions are **evicted** instead of admitted: no byte
        can move any of their lanes (absorbing states self-loop on every
        class), so their segments are accounted into the cursor's byte count
        right here and the session never occupies a queue slot — ``close()``
        stays bit-identical, the serving tier just stops paying for decided
        streams.
        """
        self._feed_seq += 1
        self.stats.feeds += 1
        self.stats.bytes_fed += len(data)
        if bool(session.cursor.absorbed.all()):
            buf = bytes(session._pending) + data
            session._pending = bytearray()
            session._pending_since = None
            session._pending_wall = None
            self._queue.pop(session.sid, None)
            if buf:
                last_class = int(self.matcher.packed.byte_to_class[buf[-1]])
                session.cursor = session.cursor.skipped(len(buf), last_class)
                self.stats.absorbed_skips += 1
            if not session._evicted:
                session._evicted = True
                self.stats.evicted += 1
            # the feed still counts as an event for everyone else's deadline:
            # a queued live stream may now have waited max_delay feed events
            # (or max_delay_s seconds), so the policy check must still run
            if self._should_tick():
                self.tick()
            return
        session._pending += data
        if session._pending_since is None:
            session._pending_since = self._feed_seq
            session._pending_wall = self._clock()
        self._queue[session.sid] = session
        if self._should_tick():
            self.tick()

    def _should_tick(self) -> bool:
        if not self._queue:
            return False
        if self.policy.eager:
            return True
        if len(self._queue) >= self.policy.max_batch:
            return True
        oldest = next(iter(self._queue.values()))
        if self.policy.max_delay > 0 and \
                self._feed_seq - oldest._pending_since >= self.policy.max_delay:
            return True
        return (self.policy.max_delay_s is not None
                and self._clock() - oldest._pending_wall
                >= self.policy.max_delay_s)

    def tick(self) -> int:
        """Drain the queue in one coalesced device round; returns the number
        of streams advanced (matched or skipped).

        The round is fully on-device: segment matching *and* the Eq. 8
        cursor composition happen inside ``advance_segments``'s fused bucket
        calls (the entry seed is the composition), and every cursor updates
        from the batch result's arrays — no per-stream host merges
        (``streaming.cursor.merge`` stays untouched; ``merge_calls`` proves
        it) and no per-stream table lookups (absorbed flags come from
        ``SegmentBatchResult.absorbed`` rows).
        """
        if not self._queue:
            return 0
        sessions = list(self._queue.values())
        self._queue.clear()
        live, segs, entries = [], [], []
        for s in sessions:
            data = bytes(s._pending)
            s._pending = bytearray()
            s._pending_since = None
            s._pending_wall = None
            if not data:
                continue
            last_class = int(self.matcher.packed.byte_to_class[data[-1]])
            if bool(s.cursor.absorbed.all()):
                # enqueue-time eviction keeps absorbed sessions out of the
                # queue, so this only catches sessions absorbed *by the
                # current drain order*; skipping the scan is bit-identical
                s.cursor = s.cursor.skipped(len(data), last_class)
                self.stats.absorbed_skips += 1
                continue
            live.append((s, len(data), last_class))
            segs.append(data)
            entries.append(s.cursor.states)
        if live:
            res = self.matcher.advance_segments(
                segs, np.stack(entries).astype(np.int32))
            for i, (s, n, last_class) in enumerate(live):
                s.cursor = s.cursor.advanced(res.final_states[i], n,
                                             last_class, self.matcher.dev,
                                             absorbed=res.absorbed[i])
            self.stats.segments += len(live)
            self.stats.bytes_matched += int(res.lengths.sum())
            self.stats.bucket_calls += res.bucket_calls
            self.stats.rows_dispatched += res.padded_rows
            self.stats.early_exits += res.early_exits
        self.stats.ticks += 1
        return len(sessions)
