"""Micro-batching scheduler: many independent streams, one device tick.

Unbounded byte streams (log tails, token-by-token decodes, chunked uploads)
arrive asynchronously and in tiny pieces — the worst case for a runtime
whose efficiency comes from fused, batched device calls.  The scheduler
closes that gap:

  * an **admission queue** collects pending segments per ``StreamSession``;
    multiple ``feed`` calls to the same stream between ticks *coalesce* into
    one segment (one scan instead of many);
  * a **tick** drains the queue: every pending stream contributes its
    coalesced segment and its cursor's entry states, and one
    ``Matcher.advance_segments`` call advances them all — segments share the
    planner's sticky pow2 shape buckets and ``batch_tile`` device tiles with
    whole-document matching, on any backend (local / pallas / sharded);
  * streams whose cursor is **fully absorbed** are *evicted from admission*:
    their bytes are accounted at ``enqueue`` time and they never enter the
    queue again, so a long-lived serving tier pays nothing — not even queue
    traversal — for decided streams (absorbing states self-loop on every
    class, so skipping is exact; ``SchedulerStats.evicted`` counts sessions
    dropped this way, once each);
  * a **tick is fully on-device**: one ``Matcher.advance_segments`` call
    composes every pending stream's cursor with its coalesced segment (the
    entry seed *is* the Eq. 8 composition), and cursors update from the
    batch result's precomputed arrays — zero per-stream host merges or
    table lookups (``streaming.cursor.merge_calls`` is the regression
    counter; the candidate-keyed batch variant is
    ``Matcher.advance_cursors``);
  * **tick policies** bound latency: eager flush (the default), or a tick
    fires when ``max_batch`` streams have pending data, the oldest pending
    segment has waited ``max_delay`` feed events, or it has waited
    ``max_delay_s`` wall-clock seconds — whichever comes first.  ``flush()``
    forces one.  Deadlines are evaluated at admission time (the scheduler
    owns no timer thread); an async serving loop enforces ``max_delay_s``
    between arrivals by calling ``flush()`` from its own timer.

Around the tick sits the **fault-tolerance layer** (see
docs/architecture.md, "Failover"):

  * a **dispatch that raises** (device loss, OOM, an injected fault) is
    retried under a bounded backoff through
    ``distributed.fault_tolerance.RestartManager``: affected cursors are
    restored from their pre-tick snapshots (``MatchCursor`` is frozen, so
    the held references *are* the snapshot), and the identical segments are
    re-dispatched — possibly onto a rebalanced layout.  When retries are
    exhausted, every segment goes back into admission (``_requeue``) before
    the failure propagates: no byte lost, none double-composed;
  * **degraded capacity rebalancing**: per-tick device timings feed a
    ``StragglerPolicy`` EWMA; when a device's decayed time drifts past the
    threshold, the matcher re-derives its capacity-weighted chunk layouts
    (``Matcher.rebalance``) strictly *between* ticks — the in-flight tick
    always completes on the layout it started with;
  * a ``FaultPlan`` (``streaming.faults``) injects kills, delays and
    capacity corruption by tick index, so all of the above runs
    deterministically in tests and ``tools/faultbench.py``.

``SchedulerStats.occupancy`` is real segments per padded device row — the
measure of how well micro-batching fills the fused calls (benchmarks
``--only stream_throughput`` tracks it against the one-shot baseline).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.engine.facade import Matcher
from ..distributed.fault_tolerance import RestartManager, StragglerPolicy
from .faults import FaultPlan

__all__ = ["TickPolicy", "RetryPolicy", "SchedulerStats",
           "MicroBatchScheduler"]


@dataclasses.dataclass(frozen=True)
class TickPolicy:
    """When the scheduler dispatches the admission queue.

    max_batch   : dispatch as soon as this many streams have pending
                  segments.
    max_delay   : max number of subsequent ``feed`` events a pending segment
                  may wait before a forced dispatch; 0 disables the
                  event-count deadline.
    max_delay_s : max wall-clock seconds the oldest pending segment may wait
                  before a forced dispatch; ``None`` disables the wall-clock
                  deadline.  Checked when segments are admitted (the
                  scheduler owns no timer — an async loop calls ``flush()``
                  on its own timer to bound latency between arrivals).

    With ``max_delay == 0`` and ``max_delay_s is None`` (the default) the
    policy is *eager*: every feed dispatches immediately.  Otherwise a tick
    fires on whichever deadline — batch, event-count or wall-clock — trips
    first.
    """

    max_batch: int = 64
    max_delay: int = 0
    max_delay_s: float | None = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_delay < 0:
            raise ValueError("max_delay must be >= 0")
        if self.max_delay_s is not None and self.max_delay_s < 0:
            raise ValueError("max_delay_s must be >= 0")

    @property
    def eager(self) -> bool:
        return self.max_delay == 0 and self.max_delay_s is None


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry of a failed tick dispatch (device loss, OOM).

    max_retries    : dispatch attempts allowed *after* the first failure
                     (0 = fail fast: first raise propagates, segments
                     requeued).
    backoff_s      : sleep before the first retry; each further retry
                     multiplies by ``backoff_factor``, capped at
                     ``max_backoff_s``.  0 disables sleeping (tests, and
                     schedulers whose caller owns pacing).
    """

    max_retries: int = 2
    backoff_s: float = 0.0
    backoff_factor: float = 2.0
    max_backoff_s: float = 1.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff seconds must be >= 0")
        if self.backoff_factor < 1.0:
            raise ValueError("backoff_factor must be >= 1")

    def delay(self, retry_index: int) -> float:
        """Sleep before retry ``retry_index`` (0-based), bounded."""
        return min(self.backoff_s * self.backoff_factor ** retry_index,
                   self.max_backoff_s)


@dataclasses.dataclass
class SchedulerStats:
    ticks: int = 0            # device dispatch rounds
    feeds: int = 0            # feed() calls admitted
    empty_feeds: int = 0      # zero-byte feeds (no-ops that advance deadlines)
    segments: int = 0         # coalesced segments actually matched
    absorbed_skips: int = 0   # segments skipped: cursor fully absorbed
    evicted: int = 0          # sessions dropped from admission (absorbed)
    bytes_fed: int = 0
    bytes_matched: int = 0    # excludes absorbed skips
    bucket_calls: int = 0     # fused device dispatches across all ticks
    rows_dispatched: int = 0  # tile-padded device rows (occupancy denom)
    early_exits: int = 0      # segments retired by the absorbing early exit
    dispatch_failures: int = 0  # dispatch attempts that raised (any cause)
    retries: int = 0          # re-dispatches after a failed attempt
    failed_ticks: int = 0     # ticks abandoned after max_retries (requeued)
    requeued_segments: int = 0  # segments returned to admission on giveup
    rebalances: int = 0       # capacity re-layouts applied between ticks

    @property
    def occupancy(self) -> float:
        """Real segments per padded device row (1.0 = perfectly full tiles)."""
        return self.segments / max(self.rows_dispatched, 1)

    @property
    def coalescing(self) -> float:
        """feed() calls folded into each matched segment (>= 1.0)."""
        return self.feeds / max(self.segments + self.absorbed_skips, 1)


class MicroBatchScheduler:
    """Admission queue + tick dispatch over a ``Matcher`` facade.

    ``clock`` (default ``time.monotonic``) timestamps pending segments for
    the ``max_delay_s`` wall-clock deadline; tests and simulated event loops
    may inject their own.  ``retry`` bounds the retry-with-restore loop
    around a failed dispatch; ``straggler`` (a
    ``distributed.fault_tolerance.StragglerPolicy``) turns per-tick device
    timings into between-tick capacity rebalances on a sharded matcher;
    ``fault_plan`` (``streaming.faults.FaultPlan``) injects deterministic
    failures, delays and capacity corruption; ``sleep`` is the backoff
    sleeper (injectable for tests).
    """

    def __init__(self, matcher: Matcher, policy: TickPolicy | None = None,
                 *, clock=time.monotonic, retry: RetryPolicy | None = None,
                 straggler: StragglerPolicy | None = None,
                 fault_plan: FaultPlan | None = None, sleep=time.sleep,
                 lane_ticks: bool = False):
        self.matcher = matcher
        self.policy = policy or TickPolicy()
        # lane_ticks=True admits candidate-keyed sessions (opened mid-flight
        # via StreamMatcher.open_at): their cursors stay [K, S] lane maps
        # across ticks — advanced through Matcher.advance_cursors instead of
        # collapsing to exact states every tick — so a session's accumulated
        # map remains composable onto whatever prefix eventually lands (the
        # out-of-order tier's "match first, sequence later")
        self.lane_ticks = bool(lane_ticks)
        self.retry = retry or RetryPolicy()
        self.straggler = straggler
        self.fault_plan = fault_plan
        self._clock = clock
        self._sleep = sleep
        # sid -> session; dict preserves admission order, and re-feeding an
        # already-queued session keeps its (oldest) position — so the first
        # entry always carries the oldest pending_since for the latency test
        self._queue: dict[int, object] = {}
        self._feed_seq = 0
        self.stats = SchedulerStats()
        self.failures: list[tuple[int, str]] = []  # (tick index, repr(exc))

    @property
    def pending_streams(self) -> int:
        return len(self._queue)

    def enqueue(self, session, data: bytes) -> None:
        """Admit one segment; may trigger a tick per the policy.

        Fully-absorbed sessions are **evicted** instead of admitted: no byte
        can move any of their lanes (absorbing states self-loop on every
        class), so their segments are accounted into the cursor's byte count
        right here and the session never occupies a queue slot — ``close()``
        stays bit-identical, the serving tier just stops paying for decided
        streams.
        """
        self._feed_seq += 1
        self.stats.feeds += 1
        self.stats.bytes_fed += len(data)
        if not data and not session._pending:
            # empty segment: a no-op for this stream — it must not occupy a
            # queue slot (a pending-since stamp with zero bytes would trip
            # max_delay forever and inflate max_batch) — but it is still a
            # feed event, so every queued stream's max_delay / max_delay_s
            # deadline check must run
            self.stats.empty_feeds += 1
            if self._should_tick():
                self.tick()
            return
        if bool(session.cursor.absorbed.all()):
            buf = bytes(session._pending) + data
            session._pending = bytearray()
            session._pending_since = None
            session._pending_wall = None
            self._queue.pop(session.sid, None)
            if buf:
                last_class = self.matcher.dev.advance_key(
                    session.cursor.last_class, buf)
                session.cursor = session.cursor.skipped(len(buf), last_class)
                self.stats.absorbed_skips += 1
            if not session._evicted:
                session._evicted = True
                self.stats.evicted += 1
            # the feed still counts as an event for everyone else's deadline:
            # a queued live stream may now have waited max_delay feed events
            # (or max_delay_s seconds), so the policy check must still run
            if self._should_tick():
                self.tick()
            return
        session._pending += data
        if session._pending_since is None:
            session._pending_since = self._feed_seq
            session._pending_wall = self._clock()
        self._queue[session.sid] = session
        if self._should_tick():
            self.tick()

    def _should_tick(self) -> bool:
        if not self._queue:
            return False
        if self.policy.eager:
            return True
        if len(self._queue) >= self.policy.max_batch:
            return True
        oldest = next(iter(self._queue.values()))
        if self.policy.max_delay > 0 and \
                self._feed_seq - oldest._pending_since >= self.policy.max_delay:
            return True
        return (self.policy.max_delay_s is not None
                and self._clock() - oldest._pending_wall
                >= self.policy.max_delay_s)

    def reopen(self, session) -> None:
        """Clear a session's eviction state after a hot pattern swap.

        ``StreamMatcher.swap_patterns`` re-opens cursors at the *new*
        pattern starts, so a session evicted as fully absorbed under the old
        tables is live again — admission must re-evaluate it.  If it
        re-absorbs under the new tables it is evicted (and counted in
        ``stats.evicted``) anew; the eager-eviction invariant above is per
        table generation, not per stream lifetime.
        """
        session._evicted = False

    def readmit(self, session) -> None:
        """Re-admit a restored session's unflushed pending bytes.

        The snapshot/restore path (``StreamMatcher.restore``) rebuilds
        sessions whose pending segments were frozen mid-flight; re-admission
        counts no feed event — the bytes were accounted when originally fed
        — and triggers no tick (the caller decides when to flush).
        """
        if not session._pending:
            return
        if session._pending_since is None:
            session._pending_since = self._feed_seq
            session._pending_wall = self._clock()
        self._queue[session.sid] = session

    def tick(self) -> int:
        """Drain the queue in one coalesced device round; returns the number
        of streams advanced (matched or skipped).

        The round is fully on-device: segment matching *and* the Eq. 8
        cursor composition happen inside ``advance_segments``'s fused bucket
        calls (the entry seed is the composition), and every cursor updates
        from the batch result's arrays — no per-stream host merges
        (``streaming.cursor.merge`` stays untouched; ``merge_calls`` proves
        it) and no per-stream table lookups (absorbed flags come from
        ``SegmentBatchResult.absorbed`` rows).

        A dispatch that raises is retried with cursors restored from their
        pre-tick snapshots (``_dispatch_tick``); when retries are exhausted
        the segments return to admission and the failure propagates — the
        queue never loses a byte.
        """
        if not self._queue:
            return 0
        # failed ticks don't increment stats.ticks, but their dispatch round
        # still consumed a tick index — keep indices unique so a FaultPlan
        # schedule never re-fires on the requeued round
        tick_idx = self.stats.ticks + self.stats.failed_ticks
        sessions = list(self._queue.values())
        self._queue.clear()
        live, segs, entries = [], [], []
        lanes, lane_segs, lane_entries, lane_keys = [], [], [], []
        for s in sessions:
            data = bytes(s._pending)
            s._pending = bytearray()
            s._pending_since = None
            s._pending_wall = None
            if not data:
                continue
            last_class = self.matcher.dev.advance_key(s.cursor.last_class, data)
            if bool(s.cursor.absorbed.all()):
                # enqueue-time eviction keeps absorbed sessions out of the
                # queue, so this only catches sessions absorbed *by the
                # current drain order*; skipping the scan is bit-identical
                s.cursor = s.cursor.skipped(len(data), last_class)
                self.stats.absorbed_skips += 1
                continue
            if s.cursor.exact:
                live.append((s, len(data), last_class))
                segs.append(data)
                entries.append(s.cursor.states)
            else:
                if not self.lane_ticks:
                    raise ValueError(
                        "candidate-keyed session admitted without "
                        "lane_ticks=True (open mid-flight streams via "
                        "StreamMatcher(..., lane_ticks=True).open_at)")
                lanes.append((s, len(data), last_class))
                lane_segs.append(data)
                lane_entries.append(s.cursor.lane_states)
                lane_keys.append(s.cursor.last_class)
        if live or lanes:
            res, lres = self._dispatch_tick(tick_idx, live, segs, entries,
                                            lanes, lane_segs, lane_entries,
                                            lane_keys)
            self.stats.segments += len(live) + len(lanes)
            for r in (res, lres):
                if r is None:
                    continue
                self.stats.bytes_matched += int(r.lengths.sum())
                self.stats.bucket_calls += r.bucket_calls
                self.stats.rows_dispatched += r.padded_rows
                self.stats.early_exits += r.early_exits
        self.stats.ticks += 1
        return len(sessions)

    # -- fault-tolerant dispatch ---------------------------------------------

    def _dispatch_tick(self, tick_idx: int, live, segs, entries,
                       lanes=(), lane_segs=(), lane_entries=(),
                       lane_keys=()):
        """One fused dispatch round under retry-with-restore semantics.

        The pre-tick cursors are the snapshot — ``MatchCursor`` is frozen,
        so holding the references is a complete, immutable copy.  The fused
        calls *and* the cursor commits run as one ``RestartManager`` step
        (exact sessions through ``advance_segments``, candidate-keyed
        lane-tick sessions through ``advance_cursors``): a raise anywhere
        (device loss inside a fused call, or a post-commit fault) restores
        every affected cursor from its snapshot via the manager's
        ``restore_fn``, applies the bounded backoff, lets the straggler
        monitor rebalance the layout, and re-dispatches the identical
        segments — so a retried segment is composed exactly once.  When
        ``RetryPolicy.max_retries`` is exhausted the segments are requeued
        into admission (no byte lost) and the failure propagates, cursors
        restored.
        """
        lanes = list(lanes)
        all_live = list(live) + lanes
        snapshots = [s.cursor for (s, _, _) in all_live]
        entry = np.stack(entries).astype(np.int32) if live else None
        lentry = (np.stack(lane_entries).astype(np.int32) if lanes else None)
        lkeys = np.asarray(lane_keys, np.int32) if lanes else None
        state = {"attempt": 0}
        box: dict[str, object] = {}

        def step_fn(st, _step):
            attempt = state["attempt"]
            state["attempt"] += 1
            if self.fault_plan is not None:
                self.fault_plan.maybe_fail(tick_idx, attempt, "pre")
            t0 = self._clock()
            res = lres = None
            if live:
                res = self.matcher.advance_segments(segs, entry)
            if lanes:
                lres = self.matcher.advance_cursors(lane_segs, lentry, lkeys)
            wall = self._clock() - t0
            if live:
                for i, (s, n, last_class) in enumerate(live):
                    s.cursor = s.cursor.advanced(res.final_states[i], n,
                                                 last_class, self.matcher.dev,
                                                 absorbed=res.absorbed[i])
            for i, (s, n, last_class) in enumerate(lanes):
                s.cursor = s.cursor.advanced_lanes(lres.lane_states[i], n,
                                                   last_class,
                                                   lres.absorbed[i])
            if self.fault_plan is not None:
                # post-commit fault: cursors are already updated — recovery
                # MUST roll them back or the retry double-composes
                self.fault_plan.maybe_fail(tick_idx, attempt, "post")
            box["res"], box["lres"], box["wall"] = res, lres, wall
            return st

        def restore_fn():
            for (s, _, _), cur in zip(all_live, snapshots):
                s.cursor = cur
            retry_idx = state["attempt"] - 1  # per-dispatch backoff index
            self.stats.retries += 1
            # a failed attempt is itself a degradation signal: feed the
            # straggler EWMA so the retry can land on a rebalanced layout
            self._feed_straggler(tick_idx, None)
            delay = self.retry.delay(retry_idx)
            if delay > 0:
                self._sleep(delay)
            return None, 0

        mgr = RestartManager(lambda _state, _step: None, restore_fn,
                             max_restarts=self.retry.max_retries)
        try:
            mgr.run(None, 0, 1, step_fn)
        except Exception:
            # retries exhausted: cursors back to their snapshots, segments
            # back into admission ahead of anything fed later — the caller
            # sees the failure, the queue sees no loss
            for (s, _, _), cur in zip(all_live, snapshots):
                s.cursor = cur
            self._requeue(all_live, list(segs) + list(lane_segs))
            self.stats.failed_ticks += 1
            raise
        finally:
            self.stats.dispatch_failures += len(mgr.failures)
            self.failures.extend((tick_idx, msg) for _, msg in mgr.failures)
        self._feed_straggler(tick_idx, float(box["wall"]))
        return box["res"], box["lres"]

    def _requeue(self, live, segs) -> None:
        """Return a failed tick's segments to the head of admission."""
        requeued: dict[int, object] = {}
        for (s, _, _), data in zip(live, segs):
            # anything fed between the failed dispatch and this requeue sits
            # in s._pending already — the failed segment goes back in front
            s._pending = bytearray(data) + s._pending
            if s._pending_since is None:
                s._pending_since = self._feed_seq
                s._pending_wall = self._clock()
            requeued[s.sid] = s
            self.stats.requeued_segments += 1
        requeued.update(self._queue)
        self._queue = requeued

    def _feed_straggler(self, tick_idx: int, wall: float | None) -> None:
        """Feed per-device timings into the EWMA; rebalance on a trip.

        Runs strictly *between* dispatches (after a tick completes, or
        between retry attempts) — an in-flight fused call always finishes on
        the layout it started with.  Without a fault plan the single wall
        measurement spreads uniformly (real per-host telemetry would slot in
        here); a ``FaultPlan`` overlays its scheduled delays and capacity
        corruption, which is how degraded-capacity recovery is exercised
        deterministically.
        """
        if self.straggler is None:
            return
        m = self.matcher
        if m.backend != "sharded" or m.n_devices < 2:
            return  # single-device layouts are uniform: nothing to rebalance
        n = m.n_devices
        base = np.full(n, max(wall if wall is not None else 1e-3, 1e-9) / n)
        times = (self.fault_plan.device_times(tick_idx, base)
                 if self.fault_plan is not None else base)
        if self.straggler.update(times):
            m.rebalance(self.straggler.capacities())
            self.stats.rebalances += 1
