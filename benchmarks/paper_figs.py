"""Benchmarks reproducing each paper table/figure.

One function per figure; all emit CSV rows ``name,us_per_call,derived``.
Wall-clock numbers are single-host CPU (this container); the paper's *model*
quantities (work-based speedup, gamma, I_max reduction) are hardware-
independent and are the reproduction targets.  See EXPERIMENTS.md
§Paper-validation for the comparison against the paper's claims.

Bench-path rule: *throughput* benchmarks (batch_throughput,
capacity_balance, stream_throughput) go through the ``Matcher`` /
``StreamMatcher`` facades only — the lane-program runtime cannot silently
fork from what they measure.  Figure benchmarks for the paper's
single-document algorithms use ``SpecDFAEngine`` and the
``engine.baselines`` primitives (``sequential_state`` /
``match_chunks_lanes``) — those *are* their subject.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import (SpecDFAEngine, compile_pattern_suite, i_max_r,
                        random_dfa, sequential_state, weighted_partition)
from repro.core.engine import match_chunks_lanes

from .common import (dfa_zoo, emit, meta_note, random_input, suite_cached,
                     time_us)

N_INPUT = 200_000


# --------------------------------------------------------------------------
# Fig. 10 / Fig. 15: speedup vs |Q|, with and without I_max optimization
# --------------------------------------------------------------------------

def bench_speedup_vs_states(p: int = 40) -> None:
    for name, dfa in dfa_zoo():
        data = random_input(dfa, N_INPUT)
        eng_look = SpecDFAEngine(dfa, num_chunks=p, mode="lookahead")
        eng_basic = SpecDFAEngine(dfa, num_chunks=p, mode="basic")
        res_l = eng_look.membership(data)
        res_b = eng_basic.membership(data)
        assert res_l.final_state == res_b.final_state
        us = time_us(lambda: eng_look.membership(data))
        q = dfa.n_states
        predicted = 1 + (p - 1) / max(q, 1)           # Eq. 15 (basic)
        emit(f"fig10/lookahead/{name}/P{p}", us, res_l.model_speedup)
        if dfa.n_classes ** 2 * q <= 2_000_000:       # runtime r=2 tables
            eng_r2 = SpecDFAEngine(dfa, num_chunks=p, mode="lookahead",
                                   lookahead_r=2)
            res_r2 = eng_r2.membership(data)
            assert res_r2.final_state == res_l.final_state
            emit(f"fig10/lookahead_r2/{name}/P{p}", 0.0, res_r2.model_speedup)
        emit(f"fig15/basic/{name}/P{p}", 0.0, res_b.model_speedup)
        emit(f"fig15/predicted/{name}/P{p}", 0.0, predicted)
        # Fig 10(b)/(d): Imax optimization gain over matching all |Q|
        emit(f"fig10b/imax_gain/{name}", 0.0,
             res_l.model_speedup / max(res_b.model_speedup, 1e-9))


# --------------------------------------------------------------------------
# Fig. 11: Holub–Stekr [19] baseline (speed-down when |Q| > |P|)
# --------------------------------------------------------------------------

def bench_holub_stekr(p: int = 40) -> None:
    for name, dfa in dfa_zoo():
        data = random_input(dfa, N_INPUT // 4)
        eng = SpecDFAEngine(dfa, num_chunks=p, mode="holub")
        res = eng.membership(data)
        # paper plots speed-downs as negative values
        s = res.model_speedup
        emit(f"fig11/holub/{name}/P{p}", 0.0, s if s >= 1 else -1.0 / s)


# --------------------------------------------------------------------------
# Fig. 12: ScanProsite-style backtracking baseline vs our matcher
# --------------------------------------------------------------------------

def _backtrack_search(pattern_ast, data: bytes) -> int:
    """Perl-style backtracking matcher (the ScanProsite stand-in).

    findall = ScanProsite's find-every-signature mode: forces a full scan,
    matching our engine's whole-input membership semantics (search would
    early-exit on the first hit and measure nothing).
    """
    import re as _re  # python re IS a backtracking engine, like Perl's
    return len(_re.findall(pattern_ast, data))


def bench_scanprosite() -> None:
    from repro.core.regex import prosite_to_regex
    from repro.core import PROSITE_PATTERNS
    rng = np.random.default_rng(0)
    residues = np.frombuffer(b"ACDEFGHIKLMNPQRSTVWY", np.uint8)
    data = rng.choice(residues, size=N_INPUT).tobytes()
    for name, pat in list(PROSITE_PATTERNS.items())[:6]:
        regex = prosite_to_regex(pat)
        us_bt = time_us(lambda: _backtrack_search(regex.encode(), data),
                        repeats=3)
        dfa = suite_cached("prosite")[name]
        eng = SpecDFAEngine(dfa, num_chunks=8, mode="lookahead")
        arr = np.frombuffer(data, np.uint8)
        us_spec = time_us(lambda: eng.membership(arr))
        emit(f"fig12/backtrack/{name}", us_bt, 0.0)
        emit(f"fig12/speculative/{name}", us_spec, us_bt / max(us_spec, 1e-9))


# --------------------------------------------------------------------------
# Fig. 13: vectorized matching (lanes) vs scalar sequential
# --------------------------------------------------------------------------

def bench_vectorization() -> None:
    rng = np.random.default_rng(0)
    dfa = random_dfa(128, 16, rng=rng)
    table = jnp.asarray(dfa.table)
    n = 131_072
    classes = jnp.asarray(rng.integers(0, 16, size=n, dtype=np.int32))
    us_scalar = time_us(
        lambda: sequential_state(table, classes, jnp.int32(0)).block_until_ready())
    lanes = 8  # the AVX2 lane count of Listing 2
    chunks = classes.reshape(lanes, n // lanes)
    init = jnp.zeros((lanes, 1), jnp.int32)
    import jax
    matcher = jax.jit(match_chunks_lanes)
    us_vec = time_us(
        lambda: matcher(table, chunks, init).block_until_ready())
    # throughput ratio per symbol: scalar does n symbols, vector n/lanes steps
    emit("fig13/scalar_us", us_scalar, n / max(us_scalar, 1e-9))
    emit("fig13/vector8_us", us_vec, n / max(us_vec, 1e-9))
    emit("fig13/vector_speedup", 0.0, us_scalar / max(us_vec, 1e-9))


# --------------------------------------------------------------------------
# Fig. 16 / Table 4: I_max,r reduction rates
# --------------------------------------------------------------------------

def bench_imax_reduction() -> None:
    for suite_name in ("pcre", "prosite"):
        suite = suite_cached(suite_name)
        ratios = {r: [] for r in (1, 2, 3, 4)}
        for name, dfa in suite.items():
            qeff = max(dfa.n_states - (1 if dfa.sink >= 0 else 0), 1)
            vals = i_max_r(dfa, 4)
            for r, v in enumerate(vals, start=1):
                ratios[r].append(v / qeff)
        for r in (1, 2, 3, 4):
            emit(f"table4/{suite_name}/r{r}", 0.0, float(np.mean(ratios[r])))


# --------------------------------------------------------------------------
# Fig. 17: I_max,r computation overhead (paper enum vs our dedup BFS)
# --------------------------------------------------------------------------

def bench_lookahead_overhead() -> None:
    """Fig 17: Algorithm 4 is O(|Sigma|^r·|Q|); the dedup BFS cost follows the
    number of inclusion-maximal image sets instead.

    Finding (recorded in EXPERIMENTS.md): on *structured* pattern DFAs the
    image lattice collapses and dedup wins asymptotically in r; on *random*
    DFAs images stay incomparable and Algorithm 4's enumeration is faster —
    the structure the paper exploits (Sec. 4.2) is also what makes the
    improved analysis cheap."""
    # structured: the two largest PROSITE membership DFAs
    suite = suite_cached("prosite")
    for name in ("PS00018_EF_HAND_1", "PS00135_TRYPSIN_SER"):
        dfa = suite[name]
        q, ncls = dfa.n_states, dfa.n_classes
        for r in (2, 3, 4):
            us_dedup = time_us(lambda: i_max_r(dfa, r, method="dedup"),
                               repeats=2)
            emit(f"fig17/structured_dedup/{name}/r{r}", us_dedup, 0.0)
            if ncls ** r * q <= 3_000_000:
                us_enum = time_us(lambda: i_max_r(dfa, r, method="enum"),
                                  repeats=2)
                emit(f"fig17/structured_enum/{name}/r{r}", us_enum,
                     us_enum / max(us_dedup, 1e-9))
    # random worst case: enum wins (dedup prune finds nothing to prune)
    rng = np.random.default_rng(3)
    dfa = random_dfa(64, 8, rng=rng)
    for r in (2, 3):
        us_dedup = time_us(lambda: i_max_r(dfa, r, method="dedup"), repeats=2)
        us_enum = time_us(lambda: i_max_r(dfa, r, method="enum"), repeats=2)
        emit(f"fig17/random_dedup/q64/r{r}", us_dedup, 0.0)
        emit(f"fig17/random_enum/q64/r{r}", us_enum,
             us_enum / max(us_dedup, 1e-9))


# --------------------------------------------------------------------------
# Fig. 18/19: input-size scaling
# --------------------------------------------------------------------------

def bench_input_scaling() -> None:
    rng = np.random.default_rng(4)
    dfa = random_dfa(128, 16, rng=rng)
    eng = SpecDFAEngine(dfa, num_chunks=40, mode="lookahead")
    for n in (100_000, 1_000_000, 10_000_000):
        data = rng.integers(0, 256, size=n, dtype=np.uint8)
        res = eng.membership(data)
        us = time_us(lambda: eng.membership(data), repeats=2)
        emit(f"fig18/n{n}", us, res.model_speedup)  # speedup ~ const in n
        emit(f"fig18/throughput_msym_s/n{n}", 0.0, n / max(us, 1e-9))


# --------------------------------------------------------------------------
# Table 3: load balancing on inhomogeneous workers
# --------------------------------------------------------------------------

def bench_load_balance() -> None:
    rng = np.random.default_rng(5)
    n = 1_000_000
    m = 8
    for fast, slow in ((0, 5), (2, 3), (5, 0)):
        speeds = np.array([1.41] * fast + [1.0] * slow)  # paper's 1.41 ratio
        w = speeds / speeds.mean()
        part = weighted_partition(n, w, m)
        work = part.work()
        times = work / speeds
        cv_weighted = float(times.std() / times.mean())
        # uniform baseline
        from repro.core import uniform_partition
        pu = uniform_partition(n, len(speeds), m)
        tu = pu.work() / speeds
        cv_uniform = float(tu.std() / tu.mean())
        emit(f"table3/weighted/f{fast}s{slow}", 0.0, cv_weighted)
        emit(f"table3/uniform/f{fast}s{slow}", 0.0, cv_uniform)


# --------------------------------------------------------------------------
# Sec. 5.2: merge strategy cost model (sequential vs tree vs 2-tier)
# --------------------------------------------------------------------------

def bench_merge_strategies() -> None:
    # the paper's measured latencies: 2.68us intra-node, 362us inter-node
    intra, inter = 2.68, 362.0
    for p, cores in ((288, 15), (512, 256)):
        nodes = max(p // cores, 1)
        seq = p * inter / nodes + p * intra  # master pulls every L-vector
        import math
        tree_steps = math.ceil(math.log2(p))
        tree = tree_steps * inter            # >=1 inter-node hop per level
        two_tier = intra * math.ceil(math.log2(max(cores, 2))) + inter
        emit(f"sec52/sequential/P{p}", seq, 0.0)
        emit(f"sec52/tree/P{p}", tree, 0.0)
        emit(f"sec52/two_tier/P{p}", two_tier, tree / max(two_tier, 1e-9))
    # measured on-device composition cost (leaf fold)
    from repro.kernels import ref
    import jax
    rng = np.random.default_rng(6)
    maps = jnp.asarray(rng.integers(0, 512, size=(256, 512), dtype=np.int32))
    fold = jax.jit(ref.lvec_compose_ref)
    us = time_us(lambda: fold(maps).block_until_ready())
    emit("sec52/local_fold_256x512", us, 0.0)


# --------------------------------------------------------------------------
# Capacity-balanced vs uniform chunk layouts (paper §load-balancing, at the
# device-mesh level: the plan/executor runtime's sharded backend)
# --------------------------------------------------------------------------

def bench_capacity_balance(d: int = 8, n_docs: int = 32,
                           doc_len: int = 4096) -> None:
    """Per-device chunk work, utilization skew, and docs/sec for uniform vs
    capacity-weighted partitions on a deliberately skewed capacity profile.

    Plan level (D = 8 simulated devices, paper's 1.41 EC2 speed ratio): the
    planner's ``ChunkLayout`` assigns real symbols per device; utilization is
    ``work_d / capacity_d`` and the derived columns are its CV and max/mean
    skew — capacity weighting must cut both versus uniform chunks.
    Documents fill the bucket width (the paper's single-long-stream setting,
    Table 3): ragged tails turn trailing chunks into free padding and would
    measure raggedness, not the balancing mechanism.  Wall clock: the
    mesh-sharded executor end to end on however many local devices exist
    (1 in this container; the layouts still differ).

    Doc axis (PR 10): on a 2-D (doc x chunk) mesh the same Eq. 7 applies to
    the *document count* of a tile — ``MeshLayout.tile_rows`` packs real
    documents raggedly into the fixed physical row-blocks.  The skewed rows
    here put the slow mesh rows first (the hard case: uniform positional
    front-fill loads them before any fast row sees a document) on a
    partially-filled tile (full row-blocks cannot shed documents), and the
    per-row wall-clock-proxy skew ``(work_r / cap_r).max() / mean`` must
    drop toward 1.0 under the ragged placement.
    """
    from repro.core import (ChunkLayout, Matcher, compile_regex,
                            make_search_dfa, profile_workers,
                            synthetic_capacities)
    from repro.core.engine import layout_device_work, next_pow2
    from repro.core.patterns import PCRE_PATTERNS
    from repro.launch.mesh import make_matcher_mesh

    rng = np.random.default_rng(11)
    caps = synthetic_capacities(d)          # 1.41x fast half (Table 3 ratio)
    weights = profile_workers(caps)         # Eq. 1
    c = 2 * d                               # two chunks per device
    width = c * next_pow2(-(-doc_len // c))
    sizes = np.full(n_docs, width, np.int64)

    skews = {}
    for name, layout in (
            ("uniform", ChunkLayout.uniform(width, c, d)),
            ("weighted", ChunkLayout.weighted(width, c, d, weights))):
        work = layout_device_work(layout, sizes).astype(np.float64)
        util = work / caps
        skews[name] = float(util.max() / util.mean())
        for i, v in enumerate(work):
            emit(f"capacity_balance/{name}/work_dev{i}", 0.0, float(v))
        emit(f"capacity_balance/{name}/util_cv", 0.0,
             float(util.std() / util.mean()))
        emit(f"capacity_balance/{name}/util_skew", 0.0, skews[name])
    emit("capacity_balance/skew_reduction", 0.0,
         skews["uniform"] / max(skews["weighted"], 1e-9))

    # end-to-end docs/sec through the sharded executor on the local mesh
    # (1-D chunk layout; mesh_shape="auto" would also split the doc axis)
    from repro.launch.mesh import matcher_mesh_extents
    mesh = make_matcher_mesh()
    d_loc = int(np.prod(matcher_mesh_extents(mesh)))
    docs = [rng.integers(0, 256, size=int(n), dtype=np.uint8) for n in sizes]
    pats = list(PCRE_PATTERNS.values())[:4]
    dfas = [make_search_dfa(compile_regex(".*(" + p + ")")) for p in pats]
    for name, cap_arg in (("uniform", None),
                          ("weighted", synthetic_capacities(d_loc))):
        m = Matcher(dfas, num_chunks=c, backend="sharded", mesh=mesh,
                    batch_tile=n_docs, capacities=cap_arg)
        m.membership_batch(docs)  # compile + warm buckets
        us = time_us(lambda: m.membership_batch(docs), repeats=2)
        emit(f"capacity_balance/sharded_{name}/D{d_loc}/docs_per_s",
             us / n_docs, n_docs / (us / 1e6))

    # doc-axis raggedness (plan level, like the chunk rows above): slow mesh
    # rows FIRST so uniform front-fill is maximally wrong, and a partial
    # tile (m < tile) so placement has slack to move
    from repro.core import capacity_weights
    from repro.core.engine import MeshLayout
    dd, dc = 4, 2
    doc_caps = np.repeat([1.0, 2.0], (dd * dc) // 2)    # slow rows first
    caps2 = doc_caps.reshape(dd, dc)
    row_caps = caps2.sum(axis=1)
    mesh_rows = tuple(ChunkLayout.weighted(width, 2 * dc, dc,
                                           capacity_weights(caps2[r]))
                      for r in range(dd))
    tile, m = 16, 10
    lens = np.full(m, doc_len, np.int64)
    doc_skews = {}
    for name, layout in (
            ("uniform", MeshLayout(width, mesh_rows)),
            ("ragged", MeshLayout(width, mesh_rows, row_weights=tuple(
                capacity_weights(row_caps))))):
        rowpos = layout.tile_rows(m, tile)
        full = np.zeros(tile, np.int64)
        full[rowpos] = lens
        work = layout.device_work(full).astype(np.float64)
        rwork = work.reshape(dd, dc).sum(axis=1) / row_caps
        doc_skews[name] = float(rwork.max() / rwork.mean())
        emit(f"capacity_balance/doc_axis/{name}/row_skew", 0.0,
             doc_skews[name])
        emit(f"capacity_balance/doc_axis/{name}/docs_on_slow_rows", 0.0,
             float((rowpos < (dd // 2) * (tile // dd)).sum()))
    emit("capacity_balance/doc_axis/skew_reduction", 0.0,
         doc_skews["uniform"] / max(doc_skews["ragged"], 1e-9))


# --------------------------------------------------------------------------
# Batched multi-pattern pipeline: docs/sec and bytes/sec, batch and K scaling
# --------------------------------------------------------------------------

def bench_batch_throughput(n_docs: int = 64, doc_len: int = 512) -> None:
    """Throughput of the fused batch pipeline vs per-document dispatch.

    batch=1 pays one (1-row-tile, best-case) device call per document;
    batch=n_docs amortizes dispatch + transfer across the bucket.  K=8 packs
    eight block-list patterns into one table so one sweep answers all of
    them.  doc_len=512 is the corpus-filtering regime where dispatch
    overhead, not matching compute, bounds per-document scanning.
    """
    # the facade is the bench path: the lane-program runtime cannot fork
    # from what this measures (BatchMatcher is only a deprecation shim)
    from repro.core import Matcher, compile_regex, make_search_dfa
    from repro.core.patterns import PCRE_PATTERNS

    rng = np.random.default_rng(7)
    # ragged corpus around doc_len (stays inside <= 2 pow2 buckets)
    sizes = rng.integers(doc_len // 2 + 1, doc_len + 1, size=n_docs)
    docs = [rng.integers(0, 256, size=int(n), dtype=np.uint8) for n in sizes]
    total_bytes = int(sizes.sum())

    pats = list(PCRE_PATTERNS.values())[:8]
    dfas = [make_search_dfa(compile_regex(".*(" + p + ")")) for p in pats]

    us_bn_by_k = {}
    for k in (1, 8):
        bm = Matcher(dfas[:k], num_chunks=8, batch_tile=n_docs)
        bm.membership_batch(docs)  # compile + warm buckets
        # best-case per-document baseline: a 1-row tile (no row padding)
        bm1 = Matcher(dfas[:k], num_chunks=8, batch_tile=1)
        bm1.membership_batch(docs[:1])

        us_b1 = time_us(
            lambda: [bm1.membership_batch([d]) for d in docs], repeats=2)
        us_bn = time_us(lambda: bm.membership_batch(docs), repeats=2)
        us_bn_by_k[k] = us_bn

        d_s_b1 = n_docs / (us_b1 / 1e6)
        d_s_bn = n_docs / (us_bn / 1e6)
        emit(f"batch_throughput/b1/K{k}/docs_per_s", us_b1 / n_docs, d_s_b1)
        emit(f"batch_throughput/b{n_docs}/K{k}/docs_per_s", us_bn / n_docs,
             d_s_bn)
        emit(f"batch_throughput/b1/K{k}/bytes_per_s", 0.0,
             total_bytes / (us_b1 / 1e6))
        emit(f"batch_throughput/b{n_docs}/K{k}/bytes_per_s", 0.0,
             total_bytes / (us_bn / 1e6))
        emit(f"batch_throughput/b{n_docs}_vs_b1/K{k}", 0.0, d_s_bn / d_s_b1)
    # pattern amortization: packed K=8 sweep vs running the K=1 sweep 8 times
    emit("batch_throughput/pattern_amortization/K8", us_bn_by_k[8],
         8.0 * us_bn_by_k[1] / max(us_bn_by_k[8], 1e-9))
    meta_note("batch_throughput/K8", bm.perf_report())


# --------------------------------------------------------------------------
# Streaming runtime: resumable cursors + micro-batched scheduler (PR 3)
# --------------------------------------------------------------------------

def bench_stream_throughput(doc_len: int = 2048, seg_len: int = 256,
                            stream_counts: tuple[int, ...] = (64, 256),
                            smoke: bool = False) -> None:
    """Throughput of the streaming runtime vs the one-shot batch pipeline.

    N concurrent streams each deliver a ``doc_len``-byte document in
    ``seg_len`` segments, round-robin (the chunked-upload arrival order).
    Baseline: ``Matcher.membership_batch`` (num_chunks=8, the
    batch_throughput configuration) over the same documents, whole.

    The streaming matcher is the ``StreamMatcher`` default — ``num_chunks=1``
    (batched sequential scan): with hundreds of concurrent streams the row
    axis is the parallelism, and per-segment chunk speculation would add
    C x S redundant lanes per stream.  Two tick policies bound the
    latency/throughput tradeoff:

      * ``eager``     — every arrival round dispatches (minimum latency);
      * ``coalesce4`` — a stream's segments may wait 4 rounds and merge into
        one scan (the scheduler's micro-batching lever).

    Derived columns per (streams, policy): segments/sec, bytes/sec, the
    bytes/sec ratio to the one-shot baseline (acceptance: >= 0.5x at 256
    streams), per-tick batch occupancy (real segments per padded device
    row; >= 0.5 target), and ``host_ms_per_tick`` — wall milliseconds per
    scheduler tick, the metric the on-device merge keeps flat as stream
    counts grow (the pre-refactor per-stream host composition scaled it
    linearly in N).

    **Host-merge regression guard**: the tick path must perform *zero*
    per-stream host merges (``streaming.cursor.merge_calls``) — the run
    aborts with a nonzero exit if any sneak back in (``--smoke`` CI job).
    ``smoke=True`` shrinks sizes for CI.
    """
    from repro.core import Matcher, compile_regex, make_search_dfa
    from repro.core.patterns import PCRE_PATTERNS
    from repro.streaming import StreamMatcher, TickPolicy
    from repro.streaming.cursor import merge_calls

    if smoke:
        doc_len, seg_len, stream_counts = 512, 128, (32,)
    rng = np.random.default_rng(13)
    pats = list(PCRE_PATTERNS.values())[:4]
    dfas = [make_search_dfa(compile_regex(".*(" + p + ")")) for p in pats]
    merges_before = merge_calls()

    for n_streams in stream_counts:
        docs = [rng.integers(0, 256, size=doc_len, dtype=np.uint8).tobytes()
                for _ in range(n_streams)]
        total_bytes = n_streams * doc_len
        n_rounds = doc_len // seg_len

        m = Matcher(dfas, num_chunks=8, batch_tile=64)
        m.membership_batch(docs)  # compile + warm buckets
        us_batch = time_us(lambda: m.membership_batch(docs), repeats=2)
        bs_batch = total_bytes / (us_batch / 1e6)
        want = m.membership_batch(docs)

        seg_matcher = Matcher(dfas, num_chunks=1, batch_tile=64)
        for policy_name, rounds_per_tick in (("eager", 1), ("coalesce4", 4)):
            sm = StreamMatcher(
                seg_matcher,
                policy=TickPolicy(max_batch=(n_streams if rounds_per_tick == 1
                                             else n_streams + 1),
                                  max_delay=rounds_per_tick * n_streams))

            def run_streams():
                sessions = [sm.open() for _ in range(n_streams)]
                for r in range(n_rounds):
                    lo = r * seg_len
                    for s, d in zip(sessions, docs):
                        s.feed(d[lo:lo + seg_len])
                return [s.close() for s in sessions]

            # correctness guard: streamed decisions == one-shot decisions
            got = run_streams()
            assert all(
                np.array_equal(got[i].final_states, want.final_states[i])
                for i in range(n_streams))

            repeats, warmup = 2, 1
            ticks_before = sm.stats.ticks
            us_stream = time_us(run_streams, repeats=repeats, warmup=warmup)
            # ticks accumulate over every timed+warmup run of the closure
            ticks = max((sm.stats.ticks - ticks_before) // (repeats + warmup),
                        1)
            segs = n_streams * n_rounds
            bs_stream = total_bytes / (us_stream / 1e6)
            tag = f"stream_throughput/S{n_streams}/{policy_name}"
            emit(f"{tag}/segments_per_s", us_stream / segs,
                 segs / (us_stream / 1e6))
            emit(f"{tag}/bytes_per_s", 0.0, bs_stream)
            emit(f"{tag}/occupancy", 0.0, sm.stats.occupancy)
            emit(f"{tag}/vs_batch", 0.0, bs_stream / max(bs_batch, 1e-9))
            # wall ms per scheduler tick over the timed repeats (the timed
            # run re-opens its own streams; ticks delta tracks only those)
            emit(f"{tag}/host_ms_per_tick", 0.0, us_stream / 1e3 / ticks)
        meta_note(f"stream_throughput/S{n_streams}",
                  seg_matcher.perf_report())

    host_merges = merge_calls() - merges_before
    emit("stream_throughput/host_merges_on_tick_path", 0.0,
         float(host_merges))
    if host_merges:
        raise SystemExit(
            f"host-merge regression: the streaming tick path performed "
            f"{host_merges} per-stream host merges (must be 0 — composition "
            "belongs on device; see streaming.cursor.merge_calls)")


# --------------------------------------------------------------------------
# out-of-order ingestion: match-first throughput vs in-order delivery
# --------------------------------------------------------------------------

def bench_ooo_throughput(doc_len: int = 2048, seg_len: int = 256,
                         n_streams: int = 64,
                         shuffle_fracs: tuple[float, ...] = (0.0, 0.25, 1.0),
                         smoke: bool = False) -> None:
    """Throughput of the out-of-order tier across arrival-shuffle fractions.

    N streams each deliver a ``doc_len``-byte document in ``seg_len``
    segments, round-robin.  Per stream, a ``frac`` fraction of its segments
    is displaced to the end of its arrival sequence (shuffled) — ``0.0`` is
    pure in-order delivery (must ride the exact path: zero parking, zero
    scan folds), ``1.0`` a fully shuffled transport.  Every delivery carries
    its ``prev_tail`` boundary hint (producers shipping from a contiguous
    source have those bytes for free), so displaced segments are matched
    speculatively on arrival and each closing gap folds through one
    associative-scan dispatch.

    Derived columns per (streams, frac): segments/sec, bytes/sec,
    ``vs_inorder`` (bytes/sec ratio to the frac=0.0 run — the price of the
    reorder machinery), batch occupancy (real matched segments per padded
    device row), ``scan_batch`` (mean buffered maps folded per scan
    dispatch) and ``buffer_peak`` (max segments parked in any one stream's
    reorder buffer — the memory-bound witness).

    **Host-merge regression guard**: like the in-order tick path, feed /
    flush / close must perform *zero* host-side compositions
    (``streaming.cursor.merge_calls``); the run aborts otherwise.
    ``smoke=True`` shrinks sizes for CI.

    compose_scan microbench (PR 10): the gap-close bulk fold
    (``Matcher.compose_lane_maps``) in isolation over a runs x run-length
    sweep of real segment maps, jnp associative scan (local backend) vs the
    Pallas scan-compose kernel (pallas backend).  Both lowerings must be
    bit-identical (asserted in place) and the pallas lowering must actually
    be the kernel (``perf_report()["compose_lowering"]``).
    """
    from repro.core import Matcher, compile_regex, make_search_dfa
    from repro.core.patterns import PCRE_PATTERNS
    from repro.streaming import OooPolicy, OooStreamMatcher
    from repro.streaming.cursor import merge_calls

    if smoke:
        doc_len, seg_len, n_streams = 512, 128, 16
    rng = np.random.default_rng(29)
    pats = list(PCRE_PATTERNS.values())[:4]
    dfas = [make_search_dfa(compile_regex(".*(" + p + ")")) for p in pats]
    docs = [rng.integers(0, 256, size=doc_len, dtype=np.uint8).tobytes()
            for _ in range(n_streams)]
    n_segs = doc_len // seg_len
    total_bytes = n_streams * doc_len
    m = Matcher(dfas, num_chunks=1, batch_tile=64)
    want = m.membership_batch(docs)
    merges_before = merge_calls()

    bs_inorder = None
    for frac in shuffle_fracs:
        # fixed arrival plan per stream: the last round(frac * n_segs)
        # positions hold displaced segments, shuffled among themselves
        prng = np.random.default_rng(41)
        arrivals = []
        for _ in range(n_streams):
            k = int(round(frac * n_segs))
            displaced = (sorted(prng.choice(n_segs, size=k, replace=False)
                                .tolist()) if k else [])
            kept = [i for i in range(n_segs) if i not in set(displaced)]
            prng.shuffle(displaced)
            arrivals.append(kept + list(displaced))
        ooo = OooStreamMatcher(m, policy=OooPolicy(match_batch=n_streams))

        def run_streams():
            streams = [ooo.open() for _ in range(n_streams)]
            for r in range(n_segs):
                for s, d, order in zip(streams, docs, arrivals):
                    i = order[r]
                    s.feed(i, d[i * seg_len:(i + 1) * seg_len],
                           prev_tail=d[max(0, i * seg_len - 2):i * seg_len])
                ooo.flush()
            return [s.close() for s in streams]

        # correctness guard: permuted arrival == one-shot batch decisions
        got = run_streams()
        assert all(np.array_equal(got[i].final_states, want.final_states[i])
                   for i in range(n_streams))
        if frac == 0.0:
            assert ooo.stats.scan_folds == 0 and ooo.stats.spec_matched == 0, \
                "in-order delivery must ride the exact path untouched"

        us = time_us(run_streams, repeats=2, warmup=1)
        segs = n_streams * n_segs
        bs = total_bytes / (us / 1e6)
        if bs_inorder is None:
            bs_inorder = bs
        st = ooo.stats
        tag = f"ooo_throughput/S{n_streams}/shuffle{frac:g}"
        emit(f"{tag}/segments_per_s", us / segs, segs / (us / 1e6))
        emit(f"{tag}/bytes_per_s", 0.0, bs)
        emit(f"{tag}/vs_inorder", 0.0, bs / max(bs_inorder, 1e-9))
        emit(f"{tag}/occupancy", 0.0, st.occupancy)
        emit(f"{tag}/scan_batch", 0.0, st.scan_batch)
        emit(f"{tag}/buffer_peak", 0.0, float(st.peak_buffered_segments))

    host_merges = merge_calls() - merges_before
    emit(f"ooo_throughput/S{n_streams}/host_merges", 0.0, float(host_merges))
    if host_merges:
        raise SystemExit(
            f"host-merge regression: the out-of-order data path performed "
            f"{host_merges} host-side merges (must be 0 — composition "
            "belongs on device; see streaming.cursor.merge_calls)")

    # compose_scan microbench: the bulk fold in isolation — jnp associative
    # scan vs both Pallas kernels (grid-carry and in-kernel Blelloch tree)
    sweep = ((8, 4), (8, 16)) if smoke else ((32, 4), (32, 16), (8, 64))
    outs, rates = {}, {}
    variants = (("jnp", "local", None), ("kernel_carry", "pallas", "carry"),
                ("kernel_tree", "pallas", "tree"))
    for label, backend, mode in variants:
        mc = Matcher(dfas, num_chunks=1, batch_tile=64, backend=backend)
        if mode is not None:
            mc.executor.compose_mode = mode
        cands = np.asarray(mc.dev.tables.candidates, np.int32)
        for b, n in sweep:
            prng = np.random.default_rng(53)
            segs, keys = [], []
            for _ in range(b):
                # 2 prefix bytes so the run's first entry key is valid for
                # any lookahead depth r <= 2
                d = prng.integers(0, 256, size=2 + n * seg_len,
                                  dtype=np.uint8).tobytes()
                key, kseq = mc.dev.advance_key(-1, d[:2]), []
                for i in range(n):
                    p = d[2 + i * seg_len:2 + (i + 1) * seg_len]
                    segs.append(p)
                    kseq.append(key)
                    key = mc.dev.advance_key(key, p)
                keys.append(kseq)
            keys = np.asarray(keys, np.int32)
            flat = keys.reshape(-1)
            # identity lanes at each entry key -> result lanes ARE the
            # segments' restricted maps (the _match_batch construction)
            res = mc.advance_cursors(
                segs, np.ascontiguousarray(cands[flat], np.int32), flat)
            maps = np.asarray(res.lane_states, np.int32)
            maps = maps.reshape(b, n, *maps.shape[1:])
            # bit-identity below is asserted on real candidate lanes only:
            # pad lanes of composed maps hold evaluation-order-dependent
            # passthrough (sequential carry vs tree reduction) and are
            # never addressable through cand_index
            cidx = np.asarray(mc.dev.tables.cand_index)
            k0 = keys[:, 0]
            s = cands.shape[-1]
            feas = (np.take_along_axis(
                cidx[k0], cands[k0].reshape(b, -1), axis=1)
                .reshape(b, *cands.shape[1:]) == np.arange(s))
            outs[(label, b, n)] = np.where(feas, np.asarray(
                mc.compose_lane_maps(maps, keys)), -1)   # warm + compile
            us = time_us(lambda: np.asarray(
                mc.compose_lane_maps(maps, keys)), repeats=2)
            rates[(label, b, n)] = (b * n) / (us / 1e6)
            emit(f"ooo_throughput/compose_scan/{label}/R{b}xN{n}"
                 f"/segments_per_s", us / (b * n), rates[(label, b, n)])
        rep = mc.perf_report()
        meta_note(f"ooo_throughput/compose_scan/{label}", rep)
        want = "compose-scan" if mode is None else f"compose-kernel-{mode}"
        assert rep["compose_lowering"] == want, \
            f"{label}: unexpected compose lowering {rep['compose_lowering']}"
    for label, _, _ in variants[1:]:
        for b, n in sweep:
            assert np.array_equal(outs[("jnp", b, n)],
                                  outs[(label, b, n)]), \
                f"compose lowerings disagree: {label} at R{b}xN{n}"
            emit(f"ooo_throughput/compose_scan/{label}_vs_jnp/R{b}xN{n}",
                 0.0, rates[(label, b, n)] / max(rates[("jnp", b, n)], 1e-9))


# --------------------------------------------------------------------------
# pattern-set scale tier: K sweep through K-blocked plans +/- prefilter
# --------------------------------------------------------------------------

def bench_pattern_scale(k_sweep: tuple[int, ...] = (16, 128, 512, 2048),
                        k_blk: int = 32, n_docs: int = 32,
                        doc_len: int = 512, smoke: bool = False) -> None:
    """Throughput vs pattern count K through the pattern-set scale tier.

    Every K in the sweep builds a ``BlockedMatcher`` (blocks of ``k_blk``,
    independently-determinized packs) over literal-bearing patterns and
    scans the same document batch twice — required-literal prefilter on and
    off.  A quarter of the documents plant some pattern's literal, so the
    gate has real survivors; the rest dispatch zero blocks when gating is
    on.  Emitted per (K, gate): ``bytes_per_s`` (the ``tools/bench_compare``
    regression gate rides these rows) and ``skipped_blocks`` /
    ``gated_docs`` (the gate's work-avoidance witness, from
    ``perf_report()["prefilter_skipped_blocks"]``).

    Correctness guard: the gated and ungated [B, K] verdicts must be
    identical — the prefilter may only skip guaranteed non-matches — and at
    least one document must match (the gate is not vacuous).
    ``smoke=True`` shrinks the sweep for CI.
    """
    from repro.core import BlockedMatcher

    if smoke:
        k_sweep, n_docs, doc_len = (16, 64), 16, 256
    rng = np.random.default_rng(17)
    k_max = max(k_sweep)
    pats = [f"P{i:04x}e" for i in range(k_max)]
    docs = []
    for d in range(n_docs):
        body = rng.integers(ord("f"), ord("z") + 1, size=doc_len,
                            dtype=np.uint8).tobytes()
        if d % 4 == 0:  # plant a first-block literal mid-document, so the
            # gate's skip witness is exactly n_blocks - 1 at every K
            lit = pats[int(rng.integers(0, min(k_blk, k_max)))].encode()
            body = body[:doc_len // 2] + lit + body[doc_len // 2 + len(lit):]
        docs.append(body)
    total_bytes = n_docs * doc_len

    for k in k_sweep:
        runs = {}
        for gate in (True, False):
            bm = BlockedMatcher(pats[:k], k_blk=k_blk, prefilter=gate,
                                num_chunks=4, lookahead_r=1, batch_tile=32)
            res = bm.membership_batch(docs)  # warm + correctness capture
            us = time_us(lambda: bm.membership_batch(docs), repeats=2,
                         warmup=0)
            runs[gate] = res.accepted
            tag = (f"pattern_scale/K{k}/"
                   + ("prefilter" if gate else "noprefilter"))
            emit(f"{tag}/bytes_per_s", us, total_bytes / (us / 1e6))
            if gate:
                rep = bm.perf_report()
                per_scan = rep["prefilter_skipped_blocks"] / 3  # 3 scans
                emit(f"pattern_scale/K{k}/skipped_blocks", 0.0, per_scan)
                emit(f"pattern_scale/K{k}/gated_docs", 0.0,
                     rep["prefilter_gated_docs"] / 3)
        assert (runs[True] == runs[False]).all(), \
            "prefilter changed a verdict — the gate must be sound"
        assert runs[True].any(), "planted literals must produce matches"
