"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
``derived`` is the figure's model quantity (speedup, gamma, reduction rate,
CV, ...); wall-clock is single-host CPU and serves as a relative measure.

Run:  PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the 10M-symbol scaling points")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: tiny inputs, regression guards still "
                         "enforced (benchmarks that accept smoke=)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as a BENCH_*.json "
                         "artifact (schema: benchmarks/common.write_json)")
    args = ap.parse_args()

    from . import paper_figs as pf

    benches = [
        ("speedup_vs_states", pf.bench_speedup_vs_states),   # Fig 10 + 15
        ("holub_stekr", pf.bench_holub_stekr),               # Fig 11
        ("scanprosite", pf.bench_scanprosite),               # Fig 12
        ("vectorization", pf.bench_vectorization),           # Fig 13
        ("imax_reduction", pf.bench_imax_reduction),         # Fig 16 / Table 4
        ("lookahead_overhead", pf.bench_lookahead_overhead), # Fig 17
        ("input_scaling", pf.bench_input_scaling),           # Fig 18/19
        ("load_balance", pf.bench_load_balance),             # Table 3
        ("merge_strategies", pf.bench_merge_strategies),     # Sec 5.2
        ("batch_throughput", pf.bench_batch_throughput),     # batched pipeline
        ("capacity_balance", pf.bench_capacity_balance),     # sharded runtime
        ("stream_throughput", pf.bench_stream_throughput),   # streaming runtime
        ("ooo_throughput", pf.bench_ooo_throughput),         # out-of-order tier
        ("pattern_scale", pf.bench_pattern_scale),           # pattern-set scale tier
    ]
    if args.only:
        names = set(args.only.split(","))
        benches = [(n, f) for n, f in benches if n in names]

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in benches:
        sys.stderr.write(f"[bench] {name}\n")
        if args.quick and name == "input_scaling":
            continue
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        fn(**kwargs)
    total = time.time() - t0
    sys.stderr.write(f"[bench] total {total:.1f}s\n")
    if args.json:
        from . import common
        common.write_json(args.json, meta={
            "argv": sys.argv[1:], "total_s": round(total, 2),
            "benchmarks": [n for n, _ in benches]})
        sys.stderr.write(f"[bench] wrote {args.json}\n")


if __name__ == "__main__":
    main()
