"""Shared benchmark helpers: timing, CSV rows, benchmark DFA zoo."""

from __future__ import annotations

import time
from typing import Callable

import numpy as np

ROWS: list[tuple[str, float, float]] = []
META: dict = {}


def emit(name: str, us_per_call: float, derived: float) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived:.6g}")


def meta_note(key: str, value) -> None:
    """Attach structured provenance to the next ``write_json`` artifact.

    Benchmarks use this for ``Matcher.perf_report()`` snapshots — the
    lowering chosen per compiled plan (fused kernel vs jnp stages), the
    in-kernel early-exit skip counts and the lane width after r=2 shrinking
    — so a BENCH artifact explains *why* a number moved, not just that it
    did.  Values must be JSON-serializable.
    """
    META[key] = value


def write_json(path: str, meta: dict | None = None) -> None:
    """Persist the rows emitted so far as a ``BENCH_*.json`` artifact.

    The schema is the CSV contract plus provenance — enough for CI to
    archive per-commit artifacts and diff them against the committed
    baseline (``benchmarks/baselines/``); wall-clock fields are relative
    measures, ``derived`` columns are the model quantities worth tracking.
    """
    import json
    import platform
    import sys

    payload = {
        "schema": 1,
        "meta": {
            "python": sys.version.split()[0],
            "platform": platform.platform(),
            **({"perf": META} if META else {}),
            **(meta or {}),
        },
        "rows": [{"name": n, "us_per_call": u, "derived": d}
                 for n, u, d in ROWS],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def time_us(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


import functools


@functools.lru_cache(maxsize=None)
def suite_cached(kind: str):
    """Membership-semantics suites (paper's |Q| regime; see EXPERIMENTS.md)."""
    from repro.core import compile_pattern_suite
    return compile_pattern_suite(kind, search=False)


@functools.lru_cache(maxsize=None)
def _zoo_cached(max_states: int, seed: int):
    from repro.core import random_dfa
    rng = np.random.default_rng(seed)
    zoo = []
    for name, dfa in list(suite_cached("pcre").items())[:6]:
        zoo.append((f"pcre:{name}", dfa))
    for name, dfa in list(suite_cached("prosite").items())[:6]:
        zoo.append((f"prosite:{name}", dfa))
    # random DFAs extend |Q| to the paper's PROSITE range (up to 1288)
    for q in (16, 64, 128, 256, max_states, 1288):
        zoo.append((f"random:q{q}", random_dfa(q, 16, rng=rng)))
    return zoo


def dfa_zoo(max_states: int = 512, seed: int = 0):
    """(name, DFA) pairs spanning |Q| like the paper's suites."""
    return list(_zoo_cached(max_states, seed))


def random_input(dfa, n: int, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size=n, dtype=np.uint8)
