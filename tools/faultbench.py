"""Fault-injection harness for the streaming runtime.

Every recovery path of the fault-tolerance layer runs here, deterministically,
against a bit-identity oracle (``Matcher.membership_batch`` on each stream's
concatenated bytes):

  * ``kill_retry``        — a ``FaultPlan`` kills dispatch attempts before
    *and after* the cursor commit; the scheduler's retry-with-restore loop
    (``RestartManager``) must converge with zero lost and zero
    double-composed segments (byte counts are exact to the input).
  * ``giveup_requeue``    — retries exhausted: the failure propagates, the
    segments return to admission, and a later flush completes bit-identically.
  * ``degraded_capacity`` — scheduled per-device delays + corrupted capacity
    measurements drive the ``StragglerPolicy`` EWMA past threshold; the
    matcher rebalances its chunk layouts between ticks and decisions stay
    bit-identical.
  * ``snapshot_restore``  — streams are checkpointed mid-run (with pending
    unflushed bytes), the "host" dies, and a fresh ``StreamMatcher`` on a
    *different* mesh shape restores and finishes: 2x4 -> 1x1 and 2x4 -> 8x1,
    with a crashed-writer ``step_*.tmp`` directory left in the checkpoint
    dir to prove restore ignores it.
  * ``ooo_reorder``       — the out-of-order tier under a hostile transport:
    arbitrary arrival permutations, at-least-once duplicate deliveries, and
    one late straggler segment per stream withheld until the very end; the
    ``OooStreamMatcher`` must close every stream bit-identical to the
    in-order oracle with zero host-side merges.

Run (exits non-zero if any scenario fails its bit-identity check):

  PYTHONPATH=src python tools/faultbench.py --smoke
  PYTHONPATH=src python tools/faultbench.py --json BENCH_faultbench.json

CI runs ``--smoke`` on every push (.github/workflows/ci.yml, bench-smoke).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

# the sharded scenarios need a multi-device mesh; the flag must be set
# before jax first initializes (same contract as tests/conftest.py)
_FLAG = "xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
if _FLAG not in _flags:
    os.environ["XLA_FLAGS"] = f"--{_FLAG}=8 {_flags}".strip()

import numpy as np  # noqa: E402

PATTERNS = (".*(ab|ba){2}", ".*[0-9]{3}", ".*x+y")
ALPHABET = np.frombuffer(b"abxy0189", np.uint8)


def _dfas():
    from repro.core import compile_regex, make_search_dfa
    return [make_search_dfa(compile_regex(p)) for p in PATTERNS]


def _docs(rng, n_streams: int, n_bytes: int) -> list[bytes]:
    return [bytes(rng.choice(ALPHABET, size=n_bytes).astype(np.uint8))
            for _ in range(n_streams)]


def _segments(doc: bytes, seg_len: int) -> list[bytes]:
    return [doc[i:i + seg_len] for i in range(0, len(doc), seg_len)]


def _baseline(dfas, docs) -> np.ndarray:
    """Uninterrupted [B, K] final states — the bit-identity oracle."""
    from repro.core import Matcher
    return Matcher(dfas, num_chunks=1).membership_batch(docs).final_states


def _drive(sm, docs: list[bytes], seg_len: int, *, on_round=None,
           swallow=()) -> list:
    """Feed every doc round-robin in fixed segments, flushing per round."""
    sessions = [sm.open() for _ in docs]
    segs = [_segments(d, seg_len) for d in docs]
    rounds = max(len(s) for s in segs)
    for r in range(rounds):
        for sess, ss in zip(sessions, segs):
            if r < len(ss):
                try:
                    sess.feed(ss[r])
                except swallow:
                    pass  # scheduler requeued; a later flush retries
        try:
            sm.flush()
        except swallow:
            pass
        if on_round is not None:
            on_round(r, sessions)
    while True:
        try:
            sm.flush()
            break
        except swallow:
            continue
    return sessions


def _verify(name: str, sessions, docs, oracle: np.ndarray, sm,
            extra: dict | None = None) -> dict:
    """Close every stream and check bit-identity + exact byte accounting."""
    finals = np.stack([s.close().final_states for s in sessions])
    bytes_ok = all(s.byte_count == len(d) for s, d in zip(sessions, docs))
    identical = bool((finals == oracle).all())
    out = {"scenario": name, "ok": identical and bytes_ok,
           "bit_identical": identical, "bytes_exact": bytes_ok,
           "ticks": sm.stats.ticks, "retries": sm.stats.retries,
           "dispatch_failures": sm.stats.dispatch_failures,
           "failed_ticks": sm.stats.failed_ticks,
           "requeued_segments": sm.stats.requeued_segments,
           "rebalances": sm.stats.rebalances}
    out.update(extra or {})
    return out


def scenario_kill_retry(dfas, docs, oracle, seg_len: int) -> dict:
    """Killed dispatches (pre *and* post cursor-commit) under bounded retry."""
    from repro.streaming import FaultPlan, RetryPolicy, StreamMatcher
    # tick t: kill[t] pre-dispatch attempts, kill_post[t] post-commit ones —
    # post-commit is the double-compose hazard (cursors must roll back)
    plan = FaultPlan(kill={0: 1, 2: 2}, kill_post={1: 1, 3: 1})
    sm = StreamMatcher(dfas, retry=RetryPolicy(max_retries=3),
                       fault_plan=plan)
    sessions = _drive(sm, docs, seg_len)
    res = _verify("kill_retry", sessions, docs, oracle, sm,
                  {"injected": plan.injected})
    res["ok"] = res["ok"] and plan.injected == 5 and res["retries"] >= 5
    return res


def scenario_giveup_requeue(dfas, docs, oracle, seg_len: int) -> dict:
    """Retries exhausted: failure propagates, segments requeue, run finishes."""
    from repro.streaming import (FaultPlan, InjectedFault, RetryPolicy,
                                 StreamMatcher)
    plan = FaultPlan(kill={1: 5})  # more kills than retries -> give up once
    sm = StreamMatcher(dfas, retry=RetryPolicy(max_retries=1),
                       fault_plan=plan)
    sessions = _drive(sm, docs, seg_len, swallow=(InjectedFault,))
    res = _verify("giveup_requeue", sessions, docs, oracle, sm,
                  {"injected": plan.injected})
    res["ok"] = (res["ok"] and res["failed_ticks"] >= 1
                 and res["requeued_segments"] >= 1)
    return res


def scenario_degraded_capacity(dfas, docs, oracle, seg_len: int,
                               mesh_shape=(2, 4)) -> dict:
    """Scheduled device delays + corrupted capacities -> EWMA rebalance."""
    import jax
    from repro.distributed.fault_tolerance import StragglerPolicy
    from repro.launch.mesh import make_matcher_mesh
    from repro.streaming import FaultPlan, StreamMatcher

    n_dev = mesh_shape[0] * mesh_shape[1]
    if len(jax.devices()) < n_dev:
        return {"scenario": "degraded_capacity", "ok": True,
                "skipped": f"needs {n_dev} devices"}
    # device 0 degrades from tick 1 on: +5ms latency and a 4x-slow corrupted
    # capacity measurement, every tick
    delay = np.zeros(n_dev)
    delay[0] = 5e-3
    skew = np.ones(n_dev)
    skew[0] = 4.0
    plan = FaultPlan(delay_s={t: delay for t in range(1, 64)},
                     capacity_skew={t: skew for t in range(1, 64)})
    sm = StreamMatcher(dfas, backend="sharded",
                       mesh=make_matcher_mesh(shape=mesh_shape),
                       num_chunks=8,
                       straggler=StragglerPolicy(n_workers=n_dev),
                       fault_plan=plan)
    sessions = _drive(sm, docs, seg_len)
    res = _verify("degraded_capacity", sessions, docs, oracle, sm)
    res["ok"] = res["ok"] and res["rebalances"] >= 1
    return res


def scenario_snapshot_restore(dfas, docs, oracle, seg_len: int,
                              src_shape=(2, 4), dst_shape=(1, 1)) -> dict:
    """Kill-and-restore across mesh shapes, pending bytes in flight."""
    import jax
    from repro.launch.mesh import make_matcher_mesh
    from repro.streaming import StreamMatcher, TickPolicy

    name = (f"snapshot_restore_{src_shape[0]}x{src_shape[1]}_to_"
            f"{dst_shape[0]}x{dst_shape[1]}")
    need = max(src_shape[0] * src_shape[1], dst_shape[0] * dst_shape[1])
    if len(jax.devices()) < need:
        return {"scenario": name, "ok": True,
                "skipped": f"needs {need} devices"}
    segs = [_segments(d, seg_len) for d in docs]
    half = max(len(s) for s in segs) // 2

    # explicit-flush policy on both sides: the mid-run segment below must
    # still be *pending* when the snapshot is taken (an eager policy would
    # dispatch it on feed and the snapshot would carry no in-flight bytes)
    lazy = TickPolicy(max_batch=1 << 30, max_delay=1 << 30)
    sm1 = StreamMatcher(dfas, backend="sharded",
                        mesh=make_matcher_mesh(shape=src_shape), num_chunks=8,
                        policy=lazy)
    sessions = [sm1.open() for _ in docs]
    for r in range(half):
        for sess, ss in zip(sessions, segs):
            if r < len(ss):
                sess.feed(ss[r])
        sm1.flush()
    # feed one more segment per stream *without* flushing: the snapshot must
    # carry unflushed pending bytes, not just cursor state
    for sess, ss in zip(sessions, segs):
        if half < len(ss):
            sess.feed(ss[half])

    with tempfile.TemporaryDirectory() as ckpt:
        sm1.snapshot(ckpt)
        # simulate a writer that died mid-snapshot: restore must ignore it
        os.makedirs(os.path.join(ckpt, "step_00000099.tmp"))
        del sm1, sessions  # the "host" is gone

        sm2 = StreamMatcher(dfas, backend="sharded",
                            mesh=make_matcher_mesh(shape=dst_shape),
                            num_chunks=8, policy=lazy)
        restored = {s.sid: s for s in sm2.restore(ckpt)}
        if not any(s.pending_bytes for s in restored.values()):
            raise AssertionError("snapshot carried no in-flight pending "
                                 "bytes; the scenario is under-testing")
    sessions = [restored[i] for i in range(len(docs))]
    for r in range(half + 1, max(len(s) for s in segs)):
        for sess, ss in zip(sessions, segs):
            if r < len(ss):
                sess.feed(ss[r])
        sm2.flush()
    sm2.flush()
    return _verify(name, sessions, docs, oracle, sm2)


def scenario_ooo_reorder(dfas, docs, oracle, seg_len: int,
                         backend: str = "local") -> dict:
    """Reordered, duplicated and late-delivered segments through the
    out-of-order tier: arbitrary arrival permutation + at-least-once
    duplicates + one straggler segment per stream held back until the very
    end must still close bit-identical to the in-order oracle, with zero
    host-side merges.  On ``backend="pallas"`` the scenario additionally
    requires every gap-close to ride the Pallas compose kernel (the
    ``compose-kernel-*`` lowering in ``perf_report``), not the jnp scan —
    a silent fallback is a failure, not a slowdown."""
    from repro.streaming import OooPolicy, OooStreamMatcher, merge_calls

    rng = np.random.default_rng(1234)
    ooo = OooStreamMatcher(dfas, policy=OooPolicy(match_batch=8),
                           backend=backend)
    segs = [_segments(d, seg_len) for d in docs]
    streams = [ooo.open() for _ in docs]
    base = merge_calls()
    late: list[tuple] = []
    for s, d, ss in zip(streams, docs, segs):
        order = rng.permutation(len(ss))
        hold = int(order[0])  # late delivery: withheld until every other
        for i in order[1:]:   # stream's segments have long since arrived
            i = int(i)
            tail = d[max(0, i * seg_len - 2):i * seg_len]
            s.feed(i, ss[i], prev_tail=tail)
            if rng.random() < 0.25:
                s.feed(i, ss[i], prev_tail=tail)  # duplicate delivery
        late.append((s, hold, ss[hold],
                     d[max(0, hold * seg_len - 2):hold * seg_len]))
    ooo.flush()
    for s, hold, seg, tail in late:
        s.feed(hold, seg, prev_tail=tail)
    finals = np.stack([s.close().final_states for s in streams])
    st = ooo.stats
    rep = ooo.matcher.perf_report()
    ok = (bool((finals == oracle).all()) and merge_calls() == base
          and st.duplicates > 0 and st.ooo_arrivals > 0)
    if backend == "pallas":
        # gap-closes must have happened AND ridden the compose kernel
        ok = (ok and ooo.matcher.compose_calls > 0
              and str(rep["compose_lowering"]).startswith("compose-kernel"))
    name = "ooo_reorder" if backend == "local" else f"ooo_reorder_{backend}"
    return {"scenario": name,
            "ok": ok,
            "bit_identical": bool((finals == oracle).all()),
            "host_merges": merge_calls() - base,
            "compose_calls": ooo.matcher.compose_calls,
            "compose_lowering": rep["compose_lowering"],
            "arrivals": st.arrivals, "duplicates": st.duplicates,
            "ooo_arrivals": st.ooo_arrivals, "spec_matched": st.spec_matched,
            "gap_closes": st.gap_closes, "scan_folds": st.scan_folds,
            "scan_batch": round(st.scan_batch, 2),
            "peak_buffered_segments": st.peak_buffered_segments}


def run_faultbench(*, n_streams: int = 8, n_bytes: int = 192,
                   seg_len: int = 48, seed: int = 0) -> list[dict]:
    """Run every scenario; returns one result dict per scenario."""
    rng = np.random.default_rng(seed)
    dfas = _dfas()
    docs = _docs(rng, n_streams, n_bytes)
    oracle = _baseline(dfas, docs)
    return [
        scenario_kill_retry(dfas, docs, oracle, seg_len),
        scenario_giveup_requeue(dfas, docs, oracle, seg_len),
        scenario_degraded_capacity(dfas, docs, oracle, seg_len),
        scenario_snapshot_restore(dfas, docs, oracle, seg_len,
                                  src_shape=(2, 4), dst_shape=(1, 1)),
        scenario_snapshot_restore(dfas, docs, oracle, seg_len,
                                  src_shape=(2, 4), dst_shape=(8, 1)),
        scenario_ooo_reorder(dfas, docs, oracle, seg_len),
        scenario_ooo_reorder(dfas, docs, oracle, seg_len, backend="pallas"),
    ]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: fewer/shorter streams, same scenarios")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results as a BENCH_*.json artifact")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    kwargs = (dict(n_streams=4, n_bytes=96, seg_len=48) if args.smoke
              else dict(n_streams=8, n_bytes=192, seg_len=48))
    t0 = time.time()
    results = run_faultbench(seed=args.seed, **kwargs)
    total = time.time() - t0

    print("scenario,ok,detail")
    for r in results:
        detail = ("skipped:" + r["skipped"] if "skipped" in r else
                  f"ticks={r.get('ticks', 0)} retries={r.get('retries', 0)} "
                  f"requeued={r.get('requeued_segments', 0)} "
                  f"rebalances={r.get('rebalances', 0)}")
        print(f"{r['scenario']},{r['ok']},{detail}")
    failed = [r["scenario"] for r in results if not r["ok"]]

    if args.json:
        payload = {"schema": 1,
                   "meta": {"argv": sys.argv[1:],
                            "total_s": round(total, 2), **kwargs},
                   "results": results}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        sys.stderr.write(f"[faultbench] wrote {args.json}\n")

    sys.stderr.write(f"[faultbench] total {total:.1f}s\n")
    if failed:
        sys.stderr.write(f"[faultbench] FAILED: {', '.join(failed)}\n")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
