"""Execute the ```python code blocks of markdown files, in order.

Usage:  PYTHONPATH=src python tools/run_doc_snippets.py README.md [more.md]

Each file's blocks run top-to-bottom in one shared namespace (so a later
snippet may use names a previous one defined), with asserts enabled — this
is what keeps documentation code from rotting: the CI docs job and
tests/test_doc_snippets.py both run it.  Only ```python fences execute;
```bash / ```text / plain fences are ignored.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def python_blocks(text: str) -> list[str]:
    return [m.group(1) for m in FENCE.finditer(text)]


def run_file(path: Path) -> int:
    blocks = python_blocks(path.read_text())
    ns: dict = {"__name__": f"doc_snippets:{path.name}"}
    for i, block in enumerate(blocks):
        print(f"[{path}] running python block {i + 1}/{len(blocks)} "
              f"({len(block.splitlines())} lines)")
        code = compile(block, f"{path}:block{i + 1}", "exec")
        exec(code, ns)  # noqa: S102 - executing our own documentation
    return len(blocks)


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    total = 0
    for arg in argv:
        total += run_file(Path(arg))
    print(f"OK: {total} snippet(s) from {len(argv)} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
