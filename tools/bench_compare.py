#!/usr/bin/env python
"""Throughput regression gate over ``BENCH_*.json`` artifacts.

Compares a freshly produced benchmark artifact (``benchmarks.run --json``)
against the committed baselines in ``benchmarks/baselines/``: every
throughput row (``derived`` column of names ending in ``segments_per_s`` or
``bytes_per_s`` — higher is better) must reach at least
``(1 - threshold)`` of the best value any baseline recorded for it.
Wall-clock rows other than throughput are provenance, not gates — they move
with host load; the throughput rows are what the raw-speed tier promises.

Usage:
    python tools/bench_compare.py BENCH_fresh.json [--baselines DIR]
        [--threshold 0.25] [--update]
    python tools/bench_compare.py --trend [--baselines DIR]

Exit codes: 0 = within budget, 1 = regression, 2 = usage/IO error.
``--update`` additionally copies the fresh artifact into the baselines
directory (under its own basename) after a passing comparison — how a PR
commits a new post-seed baseline.  ``--trend`` skips gating entirely and
prints each throughput row's trajectory across every committed baseline
(sorted by name), so a PR's perf claim is one table instead of archaeology.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys

THROUGHPUT_SUFFIXES = ("segments_per_s", "bytes_per_s")


def load_throughput_rows(path: str) -> dict[str, float]:
    with open(path) as fh:
        payload = json.load(fh)
    return {r["name"]: float(r["derived"]) for r in payload.get("rows", [])
            if r["name"].endswith(THROUGHPUT_SUFFIXES)}


def best_baselines(paths: list[str]) -> dict[str, tuple[float, str]]:
    """Per row name, the best (derived, source file) across all baselines."""
    best: dict[str, tuple[float, str]] = {}
    for p in paths:
        for name, derived in load_throughput_rows(p).items():
            if name not in best or derived > best[name][0]:
                best[name] = (derived, os.path.basename(p))
    return best


def print_trend(baselines_dir: str) -> int:
    """Per-row throughput across every committed baseline, oldest first.

    Baselines sort by filename (``BENCH_pr<N>`` orders naturally up to
    pr9 -> pr10 where lexicographic order breaks, so sort by the numeric
    suffix when every file carries one).  Rows a baseline predates print
    as ``-``; the final column is last/first growth.
    """
    paths = sorted(glob.glob(os.path.join(baselines_dir, "*.json")))

    def order(p):
        base = os.path.splitext(os.path.basename(p))[0]
        digits = "".join(ch for ch in base if ch.isdigit())
        return (int(digits) if digits else -1, base)

    paths.sort(key=order)
    if not paths:
        print(f"bench_compare: no baselines under {baselines_dir}",
              file=sys.stderr)
        return 2
    per_file = {os.path.basename(p): load_throughput_rows(p) for p in paths}
    names = sorted({n for rows in per_file.values() for n in rows})
    cols = list(per_file)
    print("row\t" + "\t".join(cols) + "\tgrowth")
    for name in names:
        vals = [per_file[c].get(name) for c in cols]
        present = [v for v in vals if v is not None]
        growth = (f"{present[-1] / present[0]:.2f}x"
                  if len(present) > 1 and present[0] > 0 else "-")
        cells = [f"{v:,.0f}" if v is not None else "-" for v in vals]
        print(name + "\t" + "\t".join(cells) + "\t" + growth)
    print(f"bench_compare: {len(names)} row(s) across {len(cols)} baseline(s)")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", nargs="?", default=None,
                    help="freshly produced BENCH_*.json")
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="directory of committed baseline artifacts")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="tolerated fractional drop vs the best baseline "
                         "(default 0.25 = fail below 75%% of baseline)")
    ap.add_argument("--update", action="store_true",
                    help="after a passing comparison, copy the fresh "
                         "artifact into the baselines directory")
    ap.add_argument("--trend", action="store_true",
                    help="print per-row throughput across all committed "
                         "baselines instead of gating a fresh artifact")
    args = ap.parse_args(argv)

    if args.trend:
        if args.fresh is not None or args.update:
            print("bench_compare: --trend takes no fresh artifact and no "
                  "--update", file=sys.stderr)
            return 2
        try:
            return print_trend(args.baselines)
        except (OSError, ValueError, KeyError) as e:
            print(f"bench_compare: unreadable baseline: {e}", file=sys.stderr)
            return 2
    if args.fresh is None:
        ap.error("fresh artifact required unless --trend")
    if not os.path.isfile(args.fresh):
        print(f"bench_compare: no such artifact: {args.fresh}",
              file=sys.stderr)
        return 2
    if not 0.0 <= args.threshold < 1.0:
        print("bench_compare: --threshold must be in [0, 1)", file=sys.stderr)
        return 2
    fresh_real = os.path.realpath(args.fresh)
    paths = [p for p in sorted(glob.glob(os.path.join(args.baselines,
                                                      "*.json")))
             if os.path.realpath(p) != fresh_real]
    if not paths:
        print(f"bench_compare: no baselines under {args.baselines}; "
              "nothing to gate (first run passes)")
        if args.update:
            return _update(args)
        return 0

    try:
        fresh = load_throughput_rows(args.fresh)
        best = best_baselines(paths)
    except (OSError, ValueError, KeyError) as e:
        print(f"bench_compare: unreadable artifact: {e}", file=sys.stderr)
        return 2

    regressions, compared = [], 0
    for name, (old, src) in sorted(best.items()):
        if name not in fresh:
            # a benchmark the fresh run did not execute (different --only
            # set) is not gated — CI runs a fixed set, so this only shows
            # up in local partial runs
            print(f"  skip  {name}  (not in fresh run)")
            continue
        new = fresh[name]
        floor = old * (1.0 - args.threshold)
        ratio = new / old if old > 0 else float("inf")
        verdict = "OK  " if new >= floor else "FAIL"
        print(f"  {verdict}  {name}  {new:,.0f} vs {old:,.0f} "
              f"({ratio:.2f}x, floor {floor:,.0f}, baseline {src})")
        compared += 1
        if new < floor:
            regressions.append((name, new, old, src))

    if not compared:
        print("bench_compare: no overlapping throughput rows; nothing gated")
    if regressions:
        print(f"bench_compare: {len(regressions)} throughput regression(s) "
              f"beyond {args.threshold:.0%}:", file=sys.stderr)
        for name, new, old, src in regressions:
            print(f"  {name}: {new:,.0f} < {old * (1 - args.threshold):,.0f} "
                  f"(baseline {old:,.0f} from {src})", file=sys.stderr)
        return 1
    print(f"bench_compare: {compared} throughput row(s) within "
          f"{args.threshold:.0%} of baseline")
    if args.update:
        return _update(args)
    return 0


def _update(args) -> int:
    dst = os.path.join(args.baselines, os.path.basename(args.fresh))
    if os.path.realpath(dst) != os.path.realpath(args.fresh):
        os.makedirs(args.baselines, exist_ok=True)
        shutil.copyfile(args.fresh, dst)
    print(f"bench_compare: baseline updated: {dst}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
