"""Pattern-set scale tier: K-blocked matching, prefilter gate, hot swap.

  PYTHONPATH=src python examples/hot_swap.py

A ``PatternSet`` splits K patterns into independently-determinized blocks;
``BlockedMatcher`` fans a document batch over the per-block matchers and the
required-literal prefilter skips every block whose literals cannot occur in
any document of the batch.  ``swap_patterns`` then hot-swaps part of the set:
only the changed blocks rebuild — unchanged blocks keep their compiled
lowerings, and (on the streaming side) their live cursors carry over
bit-identically mid-stream.
"""

import numpy as np

from repro.core import BlockedMatcher, PatternSet
from repro.streaming import BlockedStreamMatcher, TickPolicy


def main() -> None:
    # 256 block-list patterns, every one carrying a required literal
    patterns = {f"rule{i:02x}": f"BAD{i:02x}[0-9]+" for i in range(256)}
    ps = PatternSet(patterns, k_blk=32, search=True)
    bm = BlockedMatcher(ps, num_chunks=4, batch_tile=16)
    docs = [b"clean traffic, nothing to see",
            b"payload BAD07333 end",
            b"BADff9 tail hit"]
    res = bm.membership_batch(docs)
    rep = bm.perf_report()
    print(f"K={bm.n_patterns} patterns in {bm.n_blocks} blocks; "
          f"doc hits: {res.accepted.any(axis=1).tolist()}")
    print(f"prefilter skipped {rep['prefilter_skipped_blocks']} block "
          f"dispatches ({rep['prefilter_gated_docs']} gated doc-blocks)")

    # hot swap: one rule changes -> one block rebuilds, 7 are reused
    info = bm.swap_patterns(ps.with_patterns({"rule07": "SAFE[a-z]+"}))
    print(f"swap: reused blocks {info['reused']}, rebuilt {info['rebuilt']}")
    res2 = bm.membership_batch(docs)
    assert not res2.accepted[1].any()  # rule07 no longer fires
    assert res2.accepted[2].any()      # untouched rules still do

    # mid-stream swap: unchanged blocks keep their cursors bit-identically
    sm = BlockedStreamMatcher(bm, policy=TickPolicy(max_batch=2, max_delay=1))
    sess = sm.open()
    sess.feed(b"BADff")           # prefix lands before the swap...
    sm.flush()
    sm.swap_patterns(sm.pattern_set.with_patterns({"rule00": "OTHER"}))
    sess.feed(b"9 after swap")    # ...suffix after; block 7's cursor carried
    out = sess.close()
    hit = [sm.pattern_set.names[k] for k in np.flatnonzero(out.accepted)]
    print(f"mid-stream swap kept the match alive: {hit}")
    assert hit == ["ruleff"]


if __name__ == "__main__":
    main()
