"""Log-tail intrusion detection over out-of-order multi-host shippers.

  PYTHONPATH=src python examples/log_tail_ids.py

A fleet of hosts each tails its own log and ships fixed-size segments
tagged ``(host, seq_no)`` through an unreliable transport: segments arrive
interleaved across hosts, out of order within a host, and sometimes twice.
``OooStreamMatcher`` runs the intrusion-detection patterns over every
host's log as the segments land:

  * each arrival carries its ``prev_tail`` (the <= 2 log bytes preceding
    the segment — a tailer shipping from a contiguous file has them for
    free), so the segment is matched *immediately* as a candidate-keyed
    transition map, predecessors still missing;
  * ``early_accepts()`` raises the alarm the moment some already-buffered
    future segment guarantees a pattern hit — often long before the
    sequence gap closes;
  * when gaps do close, each contiguous run of buffered maps folds into
    the exact cursor in ONE associative-scan dispatch, and the closed
    stream's verdict is bit-identical to reading the log in order.
"""

import argparse

import numpy as np

from repro.core import Matcher, compile_regex, make_search_dfa
from repro.streaming import OooPolicy, OooStreamMatcher

SIGNATURES = {
    "backdoor-key": r".*SECRET-[0-9]+",
    "root-login":   r".*uid=0\(root\)",
    "scan-burst":   r".*(GET /admin ){2}",
}

CLEAN = (b"GET /index uid=12(app) ok\n", b"POST /api uid=40(web) ok\n",
         b"GET /static ok\n")
ATTACK = (b"auth SECRET-4411 accepted\n", b"su: uid=0(root) shell\n",
          b"GET /admin GET /admin probe\n")


def synth_logs(n_hosts: int, n_lines: int, attack_rate: float, seed: int):
    """Per-host log bytes; some hosts get attack lines spliced in."""
    rng = np.random.default_rng(seed)
    logs, truth = [], []
    for h in range(n_hosts):
        attacked = rng.random() < attack_rate
        lines = [CLEAN[int(rng.integers(len(CLEAN)))]
                 for _ in range(n_lines)]
        if attacked:
            lines[int(rng.integers(1, n_lines))] = \
                ATTACK[int(rng.integers(len(ATTACK)))]
        logs.append(b"".join(lines))
        truth.append(attacked)
    return logs, truth


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--hosts", type=int, default=12)
    ap.add_argument("--lines", type=int, default=24)
    ap.add_argument("--seg-len", type=int, default=64)
    ap.add_argument("--attack-rate", type=float, default=0.4)
    ap.add_argument("--dup-rate", type=float, default=0.15)
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()

    names = list(SIGNATURES)
    dfas = [make_search_dfa(compile_regex(p)) for p in SIGNATURES.values()]
    logs, truth = synth_logs(args.hosts, args.lines, args.attack_rate,
                             args.seed)

    ooo = OooStreamMatcher(dfas, policy=OooPolicy(match_batch=args.hosts))
    streams = [ooo.open() for _ in logs]

    # one shuffled delivery schedule across ALL hosts: (host, seq_no) pairs
    rng = np.random.default_rng(args.seed)
    sched = [(h, i) for h, log in enumerate(logs)
             for i in range(0, (len(log) + args.seg_len - 1) // args.seg_len)]
    rng.shuffle(sched)

    alerts: dict[int, list[str]] = {}
    for n, (h, i) in enumerate(sched):
        log, lo = logs[h], i * args.seg_len
        seg = log[lo:lo + args.seg_len]
        tail = log[max(0, lo - 2):lo]
        streams[h].feed(i, seg, prev_tail=tail)
        if rng.random() < args.dup_rate:          # at-least-once transport
            streams[h].feed(i, seg, prev_tail=tail)
        if n % args.hosts == 0:                   # periodic detection sweep
            ooo.flush()
            for hh, s in enumerate(streams):
                hit = s.early_accepts()
                for k in np.flatnonzero(hit):
                    alerts.setdefault(hh, []).append(names[k])

    flagged = {}
    for h, s in enumerate(streams):
        res = s.close()                           # exact, in-order verdict
        flagged[h] = [names[k] for k in np.flatnonzero(res.accepted)]

    # every close() is bit-identical to matching the assembled log whole
    whole = Matcher(dfas, num_chunks=1).membership_batch(logs)
    assert all((whole.accepted[h] == np.isin(names, flagged[h])).all()
               for h in range(len(logs)))
    assert [bool(flagged[h]) for h in range(len(logs))] == truth

    st = ooo.stats
    early = sum(1 for h in flagged if flagged[h] and alerts.get(h))
    print(f"{len(sched)} segments from {args.hosts} hosts, shuffled; "
          f"{st.duplicates} duplicate deliveries dropped, "
          f"{st.ooo_arrivals} arrivals ahead of their frontier")
    print(f"{st.spec_matched} segments matched before sequencing "
          f"({st.match_rounds} fused rounds); gaps closed via "
          f"{st.scan_folds} associative-scan dispatches "
          f"(mean {st.scan_batch:.1f} maps/scan)")
    print(f"hosts flagged: {sorted(h for h in flagged if flagged[h])} "
          f"(ground truth {sorted(h for h, t in enumerate(truth) if t)}); "
          f"{early} flagged by early_accepts before their gaps closed")
    for h in sorted(alerts):
        if flagged[h]:
            print(f"  host {h:2d}: early alert {sorted(set(alerts[h]))} -> "
                  f"closed with {flagged[h]}")


if __name__ == "__main__":
    main()
