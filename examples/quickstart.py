"""Quickstart: batched multi-pattern matching through the ``Matcher`` facade.

  PYTHONPATH=src python examples/quickstart.py

The facade packs K patterns into one transition table, buckets a ragged
corpus into at most ``max_buckets`` compiled shapes, and answers every
(document, pattern) pair in a few fused device calls — bit-identical to
sequential matching.  The legacy per-document ``SpecDFAEngine`` remains for
the paper's single-stream analysis (see ROADMAP §Batched matching).
"""

import numpy as np

from repro.core import Matcher, compile_regex, i_max_r, make_search_dfa
from repro.streaming import StreamMatcher

PATTERNS = [r"(GET|POST) /[a-z0-9/]+ HTTP", r"ERROR [0-9]{3}", r"key=[a-z]{8}"]


def main() -> None:
    # 1. compile each regex to a minimal, complete search DFA
    dfas = [make_search_dfa(compile_regex(".*(" + p + ")")) for p in PATTERNS]
    for p, dfa in zip(PATTERNS, dfas):
        print(f"{p!r}: |Q|={dfa.n_states} classes={dfa.n_classes} "
              f"I_max,r for r=1..3: {i_max_r(dfa, 3)}")

    # 2. one Matcher over all patterns; ragged corpus, one [B, K] answer
    m = Matcher(dfas, num_chunks=8, backend="local")   # or pallas / sharded
    rng = np.random.default_rng(0)
    corpus = [bytes(rng.choice(np.frombuffer(b"GET /apiP key=x 01", np.uint8),
                               size=int(n)))
              for n in rng.integers(20, 2000, size=64)]
    corpus[7] = corpus[7][:100] + b"GET /a/b/c HTTP" + corpus[7][100:]
    corpus[9] = b"boot ERROR 503 retry " * 30
    res = m.membership_batch(corpus)
    hits = res.accepted  # [B, K] bool
    print(f"\n{len(corpus)} docs x {m.n_patterns} patterns: "
          f"{int(hits.any(axis=1).sum())} docs hit, "
          f"{res.bucket_calls} fused device calls, "
          f"{m.trace_count} compiled shapes, "
          f"lane-parallel speedup {res.lane_speedup:.1f}x")

    # 3. the same answers from a byte *stream*: resumable cursors make any
    #    segmentation bit-identical to the one-shot batch above
    sm = StreamMatcher(m)                      # shares the compiled buckets
    s = sm.open()
    doc = corpus[9]
    for i in range(0, len(doc), 37):           # dribble it in 37-byte chunks
        s.feed(doc[i:i + 37])
    streamed = s.close()
    assert np.array_equal(streamed.accepted, hits[9])
    print(f"streamed doc 9 in 37-byte chunks -> same [K] decision "
          f"{streamed.accepted.tolist()} ({sm.stats.ticks} ticks)")


if __name__ == "__main__":
    main()
