"""Quickstart: the paper's speculative parallel DFA membership test.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import SpecDFAEngine, compile_regex, make_search_dfa, i_max_r


def main() -> None:
    # 1. compile a regex to a minimal, complete DFA (our Grail+ replacement)
    dfa = make_search_dfa(compile_regex(r".*(GET|POST) /[a-z0-9/]+ HTTP"))
    print(f"DFA: |Q|={dfa.n_states} classes={dfa.n_classes} sink={dfa.sink}")

    # 2. structural lookahead analysis (paper Sec. 4.2/4.3)
    print("I_max,r for r=1..4:", i_max_r(dfa, 4), "(Lemma 1: non-increasing)")

    # 3. speculative parallel membership test on a 1 MB input
    rng = np.random.default_rng(0)
    data = rng.choice(np.frombuffer(b"GET /apiP OSTHT x01", np.uint8),
                      size=1_000_000)
    data[500_000:500_016] = np.frombuffer(b"GET /a/b/c HTTP ", np.uint8)

    for mode in ("lookahead", "basic", "holub"):
        eng = SpecDFAEngine(dfa, num_chunks=40, mode=mode)
        res = eng.membership(data)
        print(f"{mode:9s}: accepted={res.accepted} "
              f"work-model speedup={res.model_speedup:5.2f}x "
              f"(gamma={eng.gamma:.3f}, I_max={eng.i_max})")

    # failure-freedom: speculative result always equals sequential
    seq = SpecDFAEngine(dfa).membership_sequential(data)
    assert seq.accepted == res.accepted
    print("sequential semantics preserved — speculation is failure-free")


if __name__ == "__main__":
    main()
