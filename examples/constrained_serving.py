"""Grammar-constrained decoding: DFA masks fused into the decode step.

  PYTHONPATH=src python examples/constrained_serving.py
"""

import numpy as np

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.core import compile_regex
from repro.models import api
from repro.serving import GrammarConstraint, ServeConfig, ServingEngine


def main() -> None:
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    params = api.init(cfg, jax.random.PRNGKey(0))

    # grammar: decimal numbers with optional fraction
    grammar = compile_regex(r"[0-9]{1,6}(\.[0-9]{1,4})?")
    con = GrammarConstraint(grammar, cfg.padded_vocab)

    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=12),
                        constraint=con)
    prompts = np.asarray([[ord("4"), ord("2")], [ord("7"), ord(".")]],
                         np.int32)
    out = eng.generate(prompts)
    for row in out:
        print("generated:", bytes(int(t) for t in row if t < 256).decode())

    # speculative-decoding draft verification = the paper's chunk membership
    n_ok, traj = con.verify_draft(grammar.start,
                                  np.frombuffer(b"123.45x9", np.uint8))
    print(f"draft 123.45x9 -> accepted prefix length {n_ok} (x kills it)")


if __name__ == "__main__":
    main()
