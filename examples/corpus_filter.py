"""Corpus filtering with the batched facade + the streaming scan path.

  PYTHONPATH=src python examples/corpus_filter.py

``CorpusFilter`` packs the block-list patterns into one table and scans a
document batch in a few fused device calls (``scan_batch`` / ``filter``).
``scan_stream`` goes further: documents arriving as interleaved byte chunks
— a corpus mid-download — are filtered *as the bytes land* on resumable
cursors, with chunks from many documents coalesced into shared micro-batched
ticks.
"""

import numpy as np

from repro.data import (CorpusConfig, CorpusFilter, LoaderConfig, data_stream,
                        generate_documents, host_shard)


def main() -> None:
    corpus = CorpusConfig(n_documents=200, contaminant=b"SECRET-123",
                          contaminant_rate=0.2, seed=7)
    filt = CorpusFilter([r"SECRET-[0-9]+", r"key=[A-Za-z0-9]{8}"],
                        num_chunks=8, partition="balanced")
    batches = list(data_stream(generate_documents(corpus),
                               LoaderConfig(batch_size=4, seq_len=512),
                               corpus_filter=filt))
    s = filt.stats
    print(f"scanned {s.scanned} docs ({s.bytes_scanned/1e6:.1f} MB), "
          f"dropped {s.dropped}, produced {len(batches)} packed batches")
    print(f"batched path: {s.batch_calls} fused device calls, "
          f"{filt.batch.trace_count} compiled shapes "
          f"({len(filt.dfas)} patterns packed into one "
          f"{filt.batch.packed.n_states}-state table)")

    # Streaming scan: the same corpus arriving as interleaved 64-byte chunks
    # (e.g. 8 concurrent downloads).  Decisions match scan_batch exactly;
    # fully-matched docs stop being scanned at all (absorbed early exit).
    stream_filt = CorpusFilter([r"SECRET-[0-9]+", r"key=[A-Za-z0-9]{8}"])
    docs = list(generate_documents(corpus))[:40]
    rng = np.random.default_rng(7)

    def downloads():
        cursors = {i: 0 for i in range(len(docs))}
        live = list(cursors)
        while live:
            i = live[int(rng.integers(len(live)))]
            if cursors[i] >= len(docs[i]):
                live.remove(i)
                yield i, None                  # download finished
            else:
                yield i, docs[i][cursors[i]:cursors[i] + 64]
                cursors[i] += 64

    kept = dict(stream_filt.scan_stream(downloads(), max_batch=8, max_delay=16))
    ss = stream_filt.stats
    print(f"streaming path: kept {sum(kept.values())}/{len(docs)} docs as "
          f"they downloaded; {ss.batch_calls} fused calls, "
          f"{ss.early_exits} chunk scans skipped after a block-list hit")

    # heterogeneous-fleet sharding (paper Eq. 1/5): profile-weighted ranges
    weights = [1.41, 1.0, 1.0, 0.8]  # e.g. mixed instance generations
    for host in range(4):
        lo, hi = host_shard(s.bytes_scanned, weights, host)
        print(f"host {host} (w={weights[host]}): bytes [{lo}, {hi})")


if __name__ == "__main__":
    main()
