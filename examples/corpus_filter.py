"""Corpus filtering with the speculative DFA engine (data-pipeline integration).

  PYTHONPATH=src python examples/corpus_filter.py
"""

from repro.data import (CorpusConfig, CorpusFilter, LoaderConfig, data_stream,
                        generate_documents, host_shard)


def main() -> None:
    corpus = CorpusConfig(n_documents=200, contaminant=b"SECRET-123",
                          contaminant_rate=0.2, seed=7)
    filt = CorpusFilter([r"SECRET-[0-9]+", r"key=[A-Za-z0-9]{8}"],
                        num_chunks=8, partition="balanced")
    batches = list(data_stream(generate_documents(corpus),
                               LoaderConfig(batch_size=4, seq_len=512),
                               corpus_filter=filt))
    s = filt.stats
    print(f"scanned {s.scanned} docs ({s.bytes_scanned/1e6:.1f} MB), "
          f"dropped {s.dropped}, produced {len(batches)} packed batches")
    print(f"lane-parallel model speedup {s.lane_speedup:.2f}x "
          f"(symbols scanned per matching step, all patterns at once)")
    print(f"batched path: {s.batch_calls} fused device calls, "
          f"{filt.batch.trace_count} compiled shapes "
          f"({len(filt.dfas)} patterns packed into one "
          f"{filt.batch.packed.n_states}-state table)")

    # Batched multi-pattern scanning, explicitly: one call for a whole doc
    # batch against ALL patterns — no per-document device sync.
    sample = [b"clean document " * 40, b"leak SECRET-42 here " * 30]
    keep = filt.scan_batch(sample)
    print(f"scan_batch keep-mask: {keep.tolist()}")

    # heterogeneous-fleet sharding (paper Eq. 1/5): profile-weighted ranges
    weights = [1.41, 1.0, 1.0, 0.8]  # e.g. mixed instance generations
    for host in range(4):
        lo, hi = host_shard(s.bytes_scanned, weights, host)
        print(f"host {host} (w={weights[host]}): bytes [{lo}, {hi})")


if __name__ == "__main__":
    main()
