"""End-to-end training of a small LM through the full production path
(filtered data pipeline -> sharded train step -> checkpoints -> restart).

  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""

import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "tinyllama-1.1b", "--smoke",
                "--steps", "200", "--batch", "8", "--seq", "256",
                "--microbatches", "2"] + sys.argv[1:]
    train_main()
