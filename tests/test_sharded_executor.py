"""Mesh-sharded executor: bit-identity, capacity weighting, runtime layers.

Runs in-process on the 8 simulated host devices that tests/conftest.py
forces (no subprocess needed).  Covers the tentpole guarantees:

  * ``backend="sharded"`` results are bit-identical to sequential matching
    for ragged multi-pattern corpora on every mesh shape — 1x1, 2x4, 4x2,
    8x1 (doc x chunk) — uniform and with capacity-weighted partitions drawn
    from ``profile_workers``, including profiles that skew *within* a mesh
    row (per-doc-row-block Eqs. 1–7);
  * the speculative path's only collective is an all_gather over the
    "chunk" axis — doc shards never communicate;
  * all three executor backends agree with each other;
  * the on-device byte->class classification matches the retired numpy
    reference (``kernels.ref.classify_pad_ref``);
  * the absorbing-state early exit retires documents (and stays exact);
  * the facade keeps the sticky-bucket retrace bound;
  * ``GrammarConstraint`` prompt prefill rides the facade unchanged.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Matcher, SpecDFAEngine, compile_regex, make_search_dfa,
                        pack_dfas, profile_workers, random_dfa,
                        synthetic_capacities)
from repro.core.engine import DeviceTables, LocalExecutor
from repro.kernels import ref as kref
from repro.launch.mesh import (factor_matcher_mesh, make_matcher_mesh,
                               matcher_mesh_extents)

PATTERNS = [".*(ab|ba){2}", ".*[0-9]{3}", ".*x+y"]
ALPHABET = b"abxy0189"
RAGGED = [0, 1, 3, 10, 31, 32, 33, 100, 255, 256, 513, 900, 1024]
MESH_SHAPES = [(1, 1), (2, 4), (4, 2), (8, 1)]


def _docs(rng, sizes):
    return [bytes(rng.choice(list(ALPHABET), size=int(n)).astype(np.uint8))
            for n in sizes]


def _assert_matches_sequential(matcher, docs, engines):
    res = matcher.membership_batch(docs)
    for i, d in enumerate(docs):
        for k, eng in enumerate(engines):
            want = eng.membership_sequential(d)
            off = int(matcher.packed.offsets[k])
            assert int(res.final_states[i, k]) - off == want.final_state, (i, k)
            assert bool(res.accepted[i, k]) == want.accepted
    return res


def _mesh_or_skip(shape):
    if isinstance(shape, int):
        shape = (1, shape)
    n = shape[0] * shape[1]
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} host devices (conftest forces 8)")
    return make_matcher_mesh(shape=shape)


def _skewed_caps(shape, seed=0):
    """Capacity profile that varies *within* each mesh row (so 2-D weighted
    layouts actually differ per row) — deterministic, strictly positive."""
    rng = np.random.default_rng(seed)
    return rng.uniform(0.6, 1.8, size=shape[0] * shape[1])


# --------------------------------------------------------------------------
# bit-identity on every mesh shape, uniform and capacity-weighted
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", MESH_SHAPES)
@pytest.mark.parametrize("weighted", [False, True])
def test_sharded_equals_sequential_ragged(shape, weighted):
    mesh = _mesh_or_skip(shape)
    devices = shape[0] * shape[1]
    rng = np.random.default_rng(20 + devices + shape[0])
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    caps = _skewed_caps(shape) if weighted else None
    # capacities flow through per-row Eq. 1 weights inside the facade
    m = Matcher(dfas, num_chunks=8, backend="sharded", mesh=mesh,
                capacities=caps, batch_tile=8)
    engines = [SpecDFAEngine(d, num_chunks=8) for d in dfas]
    docs = _docs(rng, RAGGED)
    res = _assert_matches_sequential(m, docs, engines)
    assert res.device_work is not None and res.device_work.shape == (devices,)
    # every speculative document's real symbols are assigned to some device
    spec = np.asarray(res.work_sequential) // len(PATTERNS) >= 4 * m.num_chunks
    assert int(res.device_work.sum()) == int(
        (np.asarray(res.work_sequential)[spec] // len(PATTERNS)).sum())


@pytest.mark.parametrize("shape", [(2, 4), (4, 2)])
def test_sharded_2d_weighted_rows_differ(shape):
    """Per-row capacity weighting: each mesh row's chunk boundaries track its
    own devices' weights (MeshLayout rows), and results stay exact."""
    from repro.core import MeshLayout
    mesh = _mesh_or_skip(shape)
    caps = _skewed_caps(shape, seed=3)
    m = Matcher([make_search_dfa(compile_regex(PATTERNS[0]))], num_chunks=8,
                backend="sharded", mesh=mesh, capacities=caps, batch_tile=8)
    layout = m.planner.layout_for(64)
    assert isinstance(layout, MeshLayout)
    assert layout.doc_shards == shape[0]
    caps2 = caps.reshape(shape)
    for r, row in enumerate(layout.rows):
        per_dev = np.zeros(shape[1])
        np.add.at(per_dev, row.device_of, row.sizes)
        # chunk symbols per device track that row's capacity ratios
        want = caps2[r] / caps2[r].sum() * row.width
        np.testing.assert_allclose(per_dev, want, atol=shape[1] * 8)
    # rows with different weight vectors produce different boundaries
    assert any(not np.array_equal(layout.rows[0].ends, row.ends)
               for row in layout.rows[1:])


def test_sharded_only_chunk_axis_gathers(monkeypatch):
    """The speculative path's only collective is the lane-state all_gather
    over "chunk" — doc shards must never communicate (acceptance criterion).
    """
    mesh = _mesh_or_skip((2, 4))
    gathered_axes = []
    orig = jax.lax.all_gather

    def spy(x, axis_name, **kw):
        gathered_axes.append(axis_name)
        return orig(x, axis_name, **kw)

    monkeypatch.setattr(jax.lax, "all_gather", spy)
    rng = np.random.default_rng(29)
    m = Matcher([make_search_dfa(compile_regex(p)) for p in PATTERNS],
                num_chunks=8, backend="sharded", mesh=mesh, batch_tile=8)
    docs = _docs(rng, [400, 700])
    res = m.membership_batch(docs)
    assert gathered_axes and set(gathered_axes) == {"chunk"}
    want = Matcher([make_search_dfa(compile_regex(p)) for p in PATTERNS],
                   num_chunks=8).membership_batch(docs)
    np.testing.assert_array_equal(res.final_states, want.final_states)


def test_matcher_mesh_factoring_and_extents():
    assert factor_matcher_mesh(8) == (2, 4)
    assert factor_matcher_mesh(16) == (4, 4)
    assert factor_matcher_mesh(6) == (2, 3)
    assert factor_matcher_mesh(7) == (1, 7)
    assert factor_matcher_mesh(1) == (1, 1)
    mesh = _mesh_or_skip((2, 4))
    assert matcher_mesh_extents(mesh) == (2, 4)
    assert matcher_mesh_extents(make_matcher_mesh(4)) == (1, 4)
    auto = make_matcher_mesh(shape="auto")
    assert matcher_mesh_extents(auto) == factor_matcher_mesh(
        len(jax.devices()))
    with pytest.raises(ValueError):
        make_matcher_mesh(devices=8, shape=(2, 3))  # 6 != 8
    legacy = jax.make_mesh((1, 1), ("data", "model"))
    assert matcher_mesh_extents(legacy) == (1, 1)


def test_matcher_mesh_shape_passthrough():
    """mesh_shape=/devices= build the mesh inside the facade; conflicting
    arguments are rejected."""
    _mesh_or_skip((2, 4))
    dfas = [make_search_dfa(compile_regex(PATTERNS[0]))]
    m = Matcher(dfas, num_chunks=8, backend="sharded", mesh_shape=(2, 4),
                batch_tile=8)
    assert (m.executor.doc_shards, m.executor.chunk_shards) == (2, 4)
    with pytest.raises(ValueError):
        Matcher(dfas, backend="sharded", mesh=make_matcher_mesh(1),
                mesh_shape=(1, 1))
    with pytest.raises(ValueError):
        Matcher(dfas, backend="local", mesh_shape=(1, 1))
    with pytest.raises(ValueError):  # batch_tile must split over doc shards
        Matcher(dfas, backend="sharded", mesh_shape=(2, 4), batch_tile=1)
    with pytest.raises(ValueError):  # one capacity per mesh device
        Matcher(dfas, backend="sharded", mesh_shape=(2, 4),
                capacities=[1.0, 2.0], batch_tile=8)


def test_sharded_weighted_partition_from_profile_workers():
    """The planner's weights must equal profile_workers of the capacities,
    and the resulting chunk sizes must track them."""
    mesh = _mesh_or_skip(8)
    caps = synthetic_capacities(8)
    m = Matcher([make_search_dfa(compile_regex(PATTERNS[0]))], num_chunks=16,
                backend="sharded", mesh=mesh, capacities=caps)
    # the planner holds one weight row per doc shard (a single row on 1-D)
    np.testing.assert_allclose(m.planner.weights[0], profile_workers(caps))
    layout = m.planner.layout_for(64)
    per_dev = np.zeros(8)
    np.add.at(per_dev, layout.device_of, layout.sizes)
    ratio = (per_dev[0] / per_dev[-1])
    assert ratio == pytest.approx(1.41, rel=0.1)


@pytest.mark.parametrize("shape", [(1, 8), (2, 4)])
def test_sharded_random_dfa_property(shape):
    mesh = _mesh_or_skip(shape)
    rng = np.random.default_rng(22)
    for trial in range(3):
        packed = pack_dfas([random_dfa(int(rng.integers(3, 20)),
                                       int(rng.integers(2, 8)), rng=rng)
                            for _ in range(int(rng.integers(1, 4)))])
        m = Matcher(packed, num_chunks=8, backend="sharded", mesh=mesh,
                    capacities=rng.uniform(0.5, 2.0, size=8), batch_tile=8)
        docs = [rng.integers(0, 256, size=int(n), dtype=np.uint8)
                for n in rng.integers(0, 500, size=10)]
        res = m.membership_batch(docs)
        for i, d in enumerate(docs):
            want = packed.run_all(d)
            np.testing.assert_array_equal(res.final_states[i], want, err_msg=str((trial, i)))


def test_all_backends_agree():
    rng = np.random.default_rng(23)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS[:2]]
    docs = _docs(rng, rng.integers(0, 600, size=16))
    mesh = _mesh_or_skip(min(8, len(jax.devices())))
    mesh2d = _mesh_or_skip((2, 4))
    results = []
    for kwargs in ({"backend": "local"}, {"backend": "pallas"},
                   {"backend": "sharded", "mesh": mesh},
                   {"backend": "sharded", "mesh": mesh,
                    "capacities": synthetic_capacities(
                        int(np.prod(matcher_mesh_extents(mesh))))},
                   {"backend": "sharded", "mesh": mesh2d},
                   {"backend": "sharded", "mesh": mesh2d,
                    "capacities": _skewed_caps((2, 4), seed=7)}):
        m = Matcher(dfas, num_chunks=8, batch_tile=8, **kwargs)
        results.append(m.membership_batch(docs))
    for r in results[1:]:
        np.testing.assert_array_equal(r.final_states, results[0].final_states)
        np.testing.assert_array_equal(r.accepted, results[0].accepted)


def test_sharded_retrace_bound_sticky_buckets():
    mesh = _mesh_or_skip(8)
    rng = np.random.default_rng(24)
    m = Matcher([make_search_dfa(compile_regex(p)) for p in PATTERNS],
                num_chunks=8, backend="sharded", mesh=mesh, max_buckets=2)
    corpus = _docs(rng, rng.integers(40, 3000, size=60))
    m.membership_batch(corpus[:30])
    m.membership_batch(corpus[30:])
    assert len(m._spec_keys) <= 2
    assert m.trace_count <= 2


# --------------------------------------------------------------------------
# on-device classification vs the retired numpy reference
# --------------------------------------------------------------------------

def test_on_device_classify_matches_numpy_ref():
    rng = np.random.default_rng(25)
    packed = pack_dfas([make_search_dfa(compile_regex(p)) for p in PATTERNS])
    tables = DeviceTables.build(packed)
    ex = LocalExecutor(tables, num_chunks=4)
    for trial in range(5):
        b, w = int(rng.integers(1, 6)), int(rng.integers(1, 200))
        buf = rng.integers(0, 256, size=(b, w), dtype=np.uint8)
        lens = rng.integers(0, w + 1, size=b).astype(np.int32)
        got = np.asarray(ex._classify(jnp.asarray(buf), jnp.asarray(lens)))
        want = kref.classify_pad_ref(packed.byte_to_class, buf, lens,
                                     tables.pad_cls)
        np.testing.assert_array_equal(got, want)
        # per-doc: the in-range prefix equals the plain host classify
        for r in range(b):
            np.testing.assert_array_equal(
                got[r, :lens[r]],
                kref.classify_ref(packed.byte_to_class, buf[r, :lens[r]]))


# --------------------------------------------------------------------------
# absorbing-state early exit
# --------------------------------------------------------------------------

def test_early_exit_retires_absorbed_docs_and_stays_exact():
    """Docs whose every lane absorbs early are counted and still exact.

    A speculative chunk's lanes all absorb only when the chunk *itself*
    drives every candidate into the absorbing accept — i.e. the pattern
    occurs inside every chunk — so the retiring corpus repeats the pattern
    densely; the clean doc never retires.
    """
    dfa = make_search_dfa(compile_regex(".*(hit)"))
    eng = SpecDFAEngine(dfa, num_chunks=4)
    docs = [b"hit " * 250, b"x" * 1000, b"hit " * 64]
    m = Matcher(dfa, num_chunks=4, early_exit_segments=8)
    res = m.membership_batch(docs)
    for i, d in enumerate(docs):
        want = eng.membership_sequential(d)
        assert int(res.final_states[i, 0]) == want.final_state
    assert res.early_exits == 2  # the two dense-hit docs retire early
    # disabling the early exit changes stats only, never decisions
    m1 = Matcher(dfa, num_chunks=4, early_exit_segments=1)
    res1 = m1.membership_batch(docs)
    np.testing.assert_array_equal(res1.final_states, res.final_states)
    assert res1.early_exits == 0


def test_early_exit_seq_path():
    """Short docs (batched sequential scan) also retire when absorbed."""
    dfa = make_search_dfa(compile_regex(".*(z)"))
    m = Matcher(dfa, num_chunks=8, early_exit_segments=8)
    docs = [b"z" + b"a" * 30, b"a" * 31]  # n < 4C -> seq path
    res = m.membership_batch(docs)
    assert bool(res.accepted[0, 0]) and not bool(res.accepted[1, 0])
    assert res.early_exits == 1


def test_early_exit_never_fires_without_absorption():
    from repro.core import DFA
    rng = np.random.default_rng(26)
    q, ncls = 6, 3
    # cyclic DFA: delta(s, c) = s + 1 + c (mod Q) — no self-loops anywhere,
    # so no state is absorbing and no document can ever retire early
    table = (np.arange(q)[:, None] + 1 + np.arange(ncls)[None, :]) % q
    dfa = DFA(table=table.astype(np.int32),
              accepting=np.array([True] + [False] * (q - 1)), start=0, sink=-1,
              byte_to_class=(np.arange(256) % ncls).astype(np.int32))
    tables = DeviceTables.build(pack_dfas([dfa]))
    assert not bool(np.asarray(tables.absorbing_j).any())
    m = Matcher(dfa, num_chunks=4, early_exit_segments=8)
    docs = [rng.integers(0, 256, size=256, dtype=np.uint8) for _ in range(4)]
    res = m.membership_batch(docs)
    assert res.early_exits == 0
    for i, d in enumerate(docs):
        assert int(res.final_states[i, 0]) == dfa.run(d)


# --------------------------------------------------------------------------
# consumers on the new layers
# --------------------------------------------------------------------------

def test_corpus_filter_sharded_backend():
    from repro.data.filter import CorpusFilter
    mesh = _mesh_or_skip(8)
    rng = np.random.default_rng(27)
    patterns = [r"SECRET-[0-9]+", r"key=[a-z]{4}"]
    base = CorpusFilter(patterns, num_chunks=8)
    # default mesh = all 8 forced host devices (make_matcher_mesh)
    shard = CorpusFilter(patterns, num_chunks=8, backend="sharded",
                         capacities=synthetic_capacities(
                             int(np.prod(matcher_mesh_extents(mesh)))))
    # mesh_shape pass-through: same answers on the 2-D doc x chunk mesh
    shard2d = CorpusFilter(patterns, num_chunks=8, backend="sharded",
                           mesh_shape=(2, 4), batch_tile=8,
                           capacities=_skewed_caps((2, 4), seed=9))
    docs = []
    for n in rng.integers(5, 500, size=20):
        d = bytearray(rng.choice(list(b"abc 01xyz"), size=int(n)).astype(np.uint8))
        if rng.random() < 0.5:
            d[2:2] = b"SECRET-7"
        docs.append(bytes(d))
    want = base.scan_batch(docs)
    np.testing.assert_array_equal(shard.scan_batch(docs), want)
    np.testing.assert_array_equal(shard2d.scan_batch(docs), want)
