"""Mesh-sharded executor: bit-identity, capacity weighting, runtime layers.

Runs in-process on the 8 simulated host devices that tests/conftest.py
forces (no subprocess needed).  Covers the tentpole guarantees:

  * ``backend="sharded"`` results are bit-identical to sequential matching
    for ragged multi-pattern corpora on 1 and 8 devices, uniform and with
    capacity-weighted partitions drawn from ``profile_workers``;
  * all three executor backends agree with each other;
  * the on-device byte->class classification matches the retired numpy
    reference (``kernels.ref.classify_pad_ref``);
  * the absorbing-state early exit retires documents (and stays exact);
  * the facade keeps the sticky-bucket retrace bound;
  * ``GrammarConstraint`` prompt prefill rides the facade unchanged.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Matcher, SpecDFAEngine, compile_regex, make_search_dfa,
                        pack_dfas, profile_workers, random_dfa,
                        synthetic_capacities)
from repro.core.engine import DeviceTables, LocalExecutor
from repro.kernels import ref as kref
from repro.launch.mesh import make_matcher_mesh

PATTERNS = [".*(ab|ba){2}", ".*[0-9]{3}", ".*x+y"]
ALPHABET = b"abxy0189"
RAGGED = [0, 1, 3, 10, 31, 32, 33, 100, 255, 256, 513, 900, 1024]


def _docs(rng, sizes):
    return [bytes(rng.choice(list(ALPHABET), size=int(n)).astype(np.uint8))
            for n in sizes]


def _assert_matches_sequential(matcher, docs, engines):
    res = matcher.membership_batch(docs)
    for i, d in enumerate(docs):
        for k, eng in enumerate(engines):
            want = eng.membership_sequential(d)
            off = int(matcher.packed.offsets[k])
            assert int(res.final_states[i, k]) - off == want.final_state, (i, k)
            assert bool(res.accepted[i, k]) == want.accepted
    return res


def _mesh_or_skip(d):
    if len(jax.devices()) < d:
        pytest.skip(f"needs {d} host devices (conftest forces 8)")
    return make_matcher_mesh(d)


# --------------------------------------------------------------------------
# bit-identity on 1 and 8 devices, uniform and capacity-weighted
# --------------------------------------------------------------------------

@pytest.mark.parametrize("devices", [1, 8])
@pytest.mark.parametrize("weighted", [False, True])
def test_sharded_equals_sequential_ragged(devices, weighted):
    mesh = _mesh_or_skip(devices)
    rng = np.random.default_rng(20 + devices)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    caps = synthetic_capacities(devices) if weighted else None
    # capacities flow through profile_workers (Eq. 1) inside the facade
    m = Matcher(dfas, num_chunks=8, backend="sharded", mesh=mesh,
                capacities=caps)
    engines = [SpecDFAEngine(d, num_chunks=8) for d in dfas]
    docs = _docs(rng, RAGGED)
    res = _assert_matches_sequential(m, docs, engines)
    assert res.device_work is not None and res.device_work.shape == (devices,)
    # every speculative document's real symbols are assigned to some device
    spec = np.asarray(res.work_sequential) // len(PATTERNS) >= 4 * m.num_chunks
    assert int(res.device_work.sum()) == int(
        (np.asarray(res.work_sequential)[spec] // len(PATTERNS)).sum())


def test_sharded_weighted_partition_from_profile_workers():
    """The planner's weights must equal profile_workers of the capacities,
    and the resulting chunk sizes must track them."""
    mesh = _mesh_or_skip(8)
    caps = synthetic_capacities(8)
    m = Matcher([make_search_dfa(compile_regex(PATTERNS[0]))], num_chunks=16,
                backend="sharded", mesh=mesh, capacities=caps)
    np.testing.assert_allclose(m.planner.weights, profile_workers(caps))
    layout = m.planner.layout_for(64)
    per_dev = np.zeros(8)
    np.add.at(per_dev, layout.device_of, layout.sizes)
    ratio = (per_dev[0] / per_dev[-1])
    assert ratio == pytest.approx(1.41, rel=0.1)


def test_sharded_random_dfa_property():
    mesh = _mesh_or_skip(8)
    rng = np.random.default_rng(22)
    for trial in range(3):
        packed = pack_dfas([random_dfa(int(rng.integers(3, 20)),
                                       int(rng.integers(2, 8)), rng=rng)
                            for _ in range(int(rng.integers(1, 4)))])
        m = Matcher(packed, num_chunks=8, backend="sharded", mesh=mesh,
                    capacities=rng.uniform(0.5, 2.0, size=8))
        docs = [rng.integers(0, 256, size=int(n), dtype=np.uint8)
                for n in rng.integers(0, 500, size=10)]
        res = m.membership_batch(docs)
        for i, d in enumerate(docs):
            want = packed.run_all(d)
            np.testing.assert_array_equal(res.final_states[i], want, err_msg=str((trial, i)))


def test_all_backends_agree():
    rng = np.random.default_rng(23)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS[:2]]
    docs = _docs(rng, rng.integers(0, 600, size=16))
    mesh = _mesh_or_skip(min(8, len(jax.devices())))
    results = []
    for kwargs in ({"backend": "local"}, {"backend": "pallas"},
                   {"backend": "sharded", "mesh": mesh},
                   {"backend": "sharded", "mesh": mesh,
                    "capacities": synthetic_capacities(int(mesh.shape["data"]))}):
        m = Matcher(dfas, num_chunks=8, batch_tile=8, **kwargs)
        results.append(m.membership_batch(docs))
    for r in results[1:]:
        np.testing.assert_array_equal(r.final_states, results[0].final_states)
        np.testing.assert_array_equal(r.accepted, results[0].accepted)


def test_sharded_retrace_bound_sticky_buckets():
    mesh = _mesh_or_skip(8)
    rng = np.random.default_rng(24)
    m = Matcher([make_search_dfa(compile_regex(p)) for p in PATTERNS],
                num_chunks=8, backend="sharded", mesh=mesh, max_buckets=2)
    corpus = _docs(rng, rng.integers(40, 3000, size=60))
    m.membership_batch(corpus[:30])
    m.membership_batch(corpus[30:])
    assert len(m._spec_keys) <= 2
    assert m.trace_count <= 2


# --------------------------------------------------------------------------
# on-device classification vs the retired numpy reference
# --------------------------------------------------------------------------

def test_on_device_classify_matches_numpy_ref():
    rng = np.random.default_rng(25)
    packed = pack_dfas([make_search_dfa(compile_regex(p)) for p in PATTERNS])
    tables = DeviceTables.build(packed)
    ex = LocalExecutor(tables, num_chunks=4)
    for trial in range(5):
        b, w = int(rng.integers(1, 6)), int(rng.integers(1, 200))
        buf = rng.integers(0, 256, size=(b, w), dtype=np.uint8)
        lens = rng.integers(0, w + 1, size=b).astype(np.int32)
        got = np.asarray(ex._classify(jnp.asarray(buf), jnp.asarray(lens)))
        want = kref.classify_pad_ref(packed.byte_to_class, buf, lens,
                                     tables.pad_cls)
        np.testing.assert_array_equal(got, want)
        # per-doc: the in-range prefix equals the plain host classify
        for r in range(b):
            np.testing.assert_array_equal(
                got[r, :lens[r]],
                kref.classify_ref(packed.byte_to_class, buf[r, :lens[r]]))


# --------------------------------------------------------------------------
# absorbing-state early exit
# --------------------------------------------------------------------------

def test_early_exit_retires_absorbed_docs_and_stays_exact():
    """Docs whose every lane absorbs early are counted and still exact.

    A speculative chunk's lanes all absorb only when the chunk *itself*
    drives every candidate into the absorbing accept — i.e. the pattern
    occurs inside every chunk — so the retiring corpus repeats the pattern
    densely; the clean doc never retires.
    """
    dfa = make_search_dfa(compile_regex(".*(hit)"))
    eng = SpecDFAEngine(dfa, num_chunks=4)
    docs = [b"hit " * 250, b"x" * 1000, b"hit " * 64]
    m = Matcher(dfa, num_chunks=4, early_exit_segments=8)
    res = m.membership_batch(docs)
    for i, d in enumerate(docs):
        want = eng.membership_sequential(d)
        assert int(res.final_states[i, 0]) == want.final_state
    assert res.early_exits == 2  # the two dense-hit docs retire early
    # disabling the early exit changes stats only, never decisions
    m1 = Matcher(dfa, num_chunks=4, early_exit_segments=1)
    res1 = m1.membership_batch(docs)
    np.testing.assert_array_equal(res1.final_states, res.final_states)
    assert res1.early_exits == 0


def test_early_exit_seq_path():
    """Short docs (batched sequential scan) also retire when absorbed."""
    dfa = make_search_dfa(compile_regex(".*(z)"))
    m = Matcher(dfa, num_chunks=8, early_exit_segments=8)
    docs = [b"z" + b"a" * 30, b"a" * 31]  # n < 4C -> seq path
    res = m.membership_batch(docs)
    assert bool(res.accepted[0, 0]) and not bool(res.accepted[1, 0])
    assert res.early_exits == 1


def test_early_exit_never_fires_without_absorption():
    from repro.core import DFA
    rng = np.random.default_rng(26)
    q, ncls = 6, 3
    # cyclic DFA: delta(s, c) = s + 1 + c (mod Q) — no self-loops anywhere,
    # so no state is absorbing and no document can ever retire early
    table = (np.arange(q)[:, None] + 1 + np.arange(ncls)[None, :]) % q
    dfa = DFA(table=table.astype(np.int32),
              accepting=np.array([True] + [False] * (q - 1)), start=0, sink=-1,
              byte_to_class=(np.arange(256) % ncls).astype(np.int32))
    tables = DeviceTables.build(pack_dfas([dfa]))
    assert not bool(np.asarray(tables.absorbing_j).any())
    m = Matcher(dfa, num_chunks=4, early_exit_segments=8)
    docs = [rng.integers(0, 256, size=256, dtype=np.uint8) for _ in range(4)]
    res = m.membership_batch(docs)
    assert res.early_exits == 0
    for i, d in enumerate(docs):
        assert int(res.final_states[i, 0]) == dfa.run(d)


# --------------------------------------------------------------------------
# consumers on the new layers
# --------------------------------------------------------------------------

def test_corpus_filter_sharded_backend():
    from repro.data.filter import CorpusFilter
    mesh = _mesh_or_skip(8)
    rng = np.random.default_rng(27)
    patterns = [r"SECRET-[0-9]+", r"key=[a-z]{4}"]
    base = CorpusFilter(patterns, num_chunks=8)
    # default mesh = all 8 forced host devices (make_matcher_mesh)
    shard = CorpusFilter(patterns, num_chunks=8, backend="sharded",
                         capacities=synthetic_capacities(int(mesh.shape["data"])))
    docs = []
    for n in rng.integers(5, 500, size=20):
        d = bytearray(rng.choice(list(b"abc 01xyz"), size=int(n)).astype(np.uint8))
        if rng.random() < 0.5:
            d[2:2] = b"SECRET-7"
        docs.append(bytes(d))
    np.testing.assert_array_equal(shard.scan_batch(docs), base.scan_batch(docs))
