"""Device cursor merge: ``Matcher.advance_cursors`` vs the host references.

The streaming tick's device merge — segments matched independently,
candidate-keyed on each stream's boundary class, composed with [B, K, S]
cursor lane states inside the same fused bucket call — must be bit-identical
to the pure host composition (``streaming.cursor.merge``, which is
``kernels.ref.cursor_merge_ref`` at batch size 1) across:

  * random segmentations of random documents,
  * every backend (local / pallas / sharded),
  * 1 and 8 devices, mesh shapes 1x1 / 2x4 / 8x1 (conftest forces 8 host
    devices),

and collapsing the composed lanes onto the exact prefix state must
reproduce whole-document matching.  A hypothesis property test drives the
same invariant when hypothesis is installed; the seeded sweep always runs.

Also here: the ``LanePlan`` lowering contract (one compiled program per
plan key) and the Pallas all-absorbed bucket early exit.
"""

import numpy as np
import pytest

import jax

from repro.core import Matcher, compile_regex, make_search_dfa
from repro.core.engine import ENTRY_STARTS, LanePlan
from repro.kernels import ref as kref
from repro.launch.mesh import make_matcher_mesh
from repro.streaming import merge, segment_result
from repro.streaming.cursor import MatchCursor

PATTERNS = [".*(ab|ba){2}", ".*[0-9]{3}", ".*x+y"]
ALPHABET = list(b"abxy0189")

BACKENDS = [("local", None), ("pallas", None),
            ("sharded", (1, 1)), ("sharded", (2, 4)), ("sharded", (8, 1))]


def _matcher(backend, shape, **kw):
    if backend == "sharded":
        n = shape[0] * shape[1]
        if len(jax.devices()) < n:
            pytest.skip(f"needs {n} host devices (conftest forces 8)")
        kw["mesh"] = make_matcher_mesh(shape=shape)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    return Matcher(dfas, backend=backend, batch_tile=8, **kw)


def _identity_cursor(m, cls):
    """Zero-byte candidate-keyed cursor keyed on class ``cls``: its lane map
    is the identity on the Eq. 11 candidate row itself."""
    lanes = m.dev.tables.candidates[cls].astype(np.int32)
    return MatchCursor(lane_states=lanes.copy(), entry_class=int(cls),
                      absorbed=m.dev.absorbing[lanes].all(axis=1),
                      byte_count=0, last_class=int(cls))


def _drive(m, rng, n_streams=6, n_steps=3, max_len=400):
    """B streams, each doc split into 1 exact prefix + n_steps candidate-keyed
    segments; device lanes must equal the host merge chain bit-for-bit at
    every step, and the collapsed finals must equal whole-doc matching."""
    docs, splits = [], []
    for _ in range(n_streams):
        doc = bytes(rng.choice(ALPHABET,
                               size=int(rng.integers(2, max_len))).astype(np.uint8))
        cuts = sorted(2 + int(rng.integers(0, len(doc) - 1))
                      for _ in range(n_steps - 1))
        bounds = [0] + cuts + [len(doc)]
        parts = [doc[a:b] for a, b in zip(bounds[:-1], bounds[1:])]
        # the exact prefix must span >= 2 bytes so every stream has a full
        # boundary key under any r; later segments may be empty (identity)
        docs.append(doc)
        splits.append(parts)

    entry = np.tile(m.packed.starts, (n_streams, 1))
    r0 = m.advance_segments([sp[0] for sp in splits], entry)
    c0 = np.array([m.dev.advance_key(-1, sp[0]) for sp in splits], np.int32)
    assert (c0 >= 0).all()
    host = [_identity_cursor(m, c) for c in c0]
    lanes = np.stack([h.lane_states for h in host])
    last = c0.copy()

    for step in range(1, n_steps):
        segs = [sp[step] for sp in splits]
        res = m.advance_cursors(segs, lanes, last)
        for i, seg in enumerate(segs):
            if not seg:
                continue
            sr = segment_result(m.dev, seg, int(host[i].last_class))
            host[i] = merge(host[i], sr, tables=m.dev)
        host_lanes = np.stack([h.lane_states for h in host])
        np.testing.assert_array_equal(res.lane_states, host_lanes,
                                      err_msg=f"step {step}")
        np.testing.assert_array_equal(
            res.absorbed, m.dev.absorbing[host_lanes].all(axis=2))
        lanes = res.lane_states
        last = np.array([m.dev.advance_key(int(last[i]), segs[i])
                         for i in range(n_streams)], np.int32)

    # collapse onto the exact prefix (one more host composition) and compare
    # against one-shot whole-document matching
    whole = m.membership_batch(docs)
    cidx = m.dev.tables.cand_index
    sinks = m.packed.sinks
    for i in range(n_streams):
        q0 = r0.final_states[i]
        lane = cidx[c0[i], q0]
        hit = np.take_along_axis(lanes[i], np.maximum(lane, 0)[:, None],
                                 axis=1)[:, 0]
        fin = np.where(lane < 0, np.where(sinks >= 0, sinks, q0), hit)
        np.testing.assert_array_equal(fin, whole.final_states[i],
                                      err_msg=f"stream {i}")


@pytest.mark.parametrize("backend,shape", BACKENDS)
def test_device_merge_matches_host_merge(backend, shape):
    rng = np.random.default_rng(60 + (0 if shape is None else sum(shape)))
    m = _matcher(backend, shape, num_chunks=4)
    _drive(m, rng)


def test_device_merge_matches_host_merge_hypothesis():
    """Any segmentation, any byte content (hypothesis), local backend."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    m = _matcher("local", None, num_chunks=4)

    @hyp.settings(max_examples=20, deadline=None)
    @hyp.given(doc=st.binary(min_size=2, max_size=200),
               cuts=st.lists(st.integers(min_value=2, max_value=200),
                             min_size=1, max_size=4))
    def check(doc, cuts):
        bounds = [0] + sorted(min(c, len(doc)) for c in cuts) + [len(doc)]
        parts = [doc[a:b] for a, b in zip(bounds[:-1], bounds[1:])]
        if len(parts[0]) < 2:  # the exact prefix supplies the boundary key
            parts = [doc[:2], doc[2:]]
        entry = m.packed.starts[None, :]
        r0 = m.advance_segments([parts[0]], entry)
        c0 = m.dev.advance_key(-1, parts[0])
        host = _identity_cursor(m, c0)
        lanes = host.lane_states[None]
        last = np.array([c0], np.int32)
        for seg in parts[1:]:
            res = m.advance_cursors([seg], lanes, last)
            if seg:
                host = merge(host, segment_result(m.dev, seg,
                                                  int(host.last_class)),
                             tables=m.dev)
            last = np.array([m.dev.advance_key(int(last[0]), seg)], np.int32)
            np.testing.assert_array_equal(res.lane_states[0],
                                          host.lane_states)
            lanes = res.lane_states
        # collapse and compare to one-shot
        cidx = m.dev.tables.cand_index
        sinks = m.packed.sinks
        q0 = r0.final_states[0]
        lane = cidx[c0, q0]
        hit = np.take_along_axis(lanes[0], np.maximum(lane, 0)[:, None],
                                 axis=1)[:, 0]
        fin = np.where(lane < 0, np.where(sinks >= 0, sinks, q0), hit)
        np.testing.assert_array_equal(fin, m.packed.run_all(doc))

    check()


def test_compose_cursor_matches_ref_on_random_lanes():
    """The executor's jnp composition stage == kernels.ref.cursor_merge_ref
    on raw arrays (including pad-class passthrough rows)."""
    rng = np.random.default_rng(61)
    m = _matcher("local", None, num_chunks=4)
    t = m.dev
    k, s, q = m.n_patterns, m.tables.i_max, m.packed.n_states
    cidx_pad = np.asarray(t.cidx_pad_j)
    for _ in range(5):
        b = int(rng.integers(1, 9))
        cur = rng.integers(0, q, size=(b, k, s)).astype(np.int32)
        seg = rng.integers(0, q, size=(b, k, s)).astype(np.int32)
        ec = rng.integers(0, t.n_keys + 1, size=b).astype(np.int32)
        want = kref.cursor_merge_ref(cur, seg, ec, cidx_pad,
                                     m.packed.sinks, pad_cls=t.pad_key)
        got = np.asarray(m.executor._compose_cursor(
            np.asarray(cur), np.asarray(seg), np.asarray(ec)))
        np.testing.assert_array_equal(got, want)


def test_advance_cursors_rejects_bad_inputs():
    m = _matcher("local", None, num_chunks=4)
    k, s = m.n_patterns, m.tables.i_max
    lanes = np.zeros((2, k, s), np.int32)
    with pytest.raises(ValueError):  # wrong lane shape
        m.advance_cursors([b"ab", b"ba"], lanes[:, :, :1], np.zeros(2, np.int32))
    with pytest.raises(ValueError):  # fresh streams belong in advance_segments
        m.advance_cursors([b"ab", b"ba"], lanes,
                          np.array([-1, 0], np.int32))
    empty = m.advance_cursors([], np.zeros((0, k, s), np.int32),
                              np.zeros(0, np.int32))
    assert empty.lane_states.shape == (0, k, s)


# --------------------------------------------------------------------------
# LanePlan lowering contract
# --------------------------------------------------------------------------

def test_one_lowering_per_plan_key():
    """Each distinct plan lowers exactly once; repeated dispatches reuse the
    compiled program (the sticky-bucket retrace bound, per plan)."""
    rng = np.random.default_rng(62)
    m = _matcher("local", None, num_chunks=4, max_buckets=2)
    docs = [bytes(rng.choice(ALPHABET, size=n).astype(np.uint8))
            for n in (5, 40, 300, 200, 37)]
    m.membership_batch(docs)
    n_lowered = len(m.executor._lowered)
    m.membership_batch(docs)
    assert len(m.executor._lowered) == n_lowered  # cache hit, no relowering
    keys = set(m.executor._lowered)
    assert all(k[0] in ("seq", "spec") for k in keys)
    # segment traffic of the same shapes adds entry-mode plans, not forks
    entry = np.tile(m.packed.starts, (len(docs), 1))
    m.advance_segments(docs, entry)
    assert all(k[3] in ("starts", "states", "lanes")
               for k in m.executor._lowered)


def test_lane_plan_validation():
    with pytest.raises(ValueError):
        LanePlan(kind="bogus", width=8, chunk_len=0, entry=ENTRY_STARTS)
    with pytest.raises(ValueError):
        LanePlan(kind="seq", width=8, chunk_len=0, entry="bogus")
    p = LanePlan(kind="spec", width=32, chunk_len=8, entry=ENTRY_STARTS)
    assert p.key == ("spec", 32, 8, ENTRY_STARTS, True, 1, 0)
    p_epoch = LanePlan(kind="spec", width=32, chunk_len=8,
                       entry=ENTRY_STARTS, table_epoch=1)
    assert p_epoch.key != p.key  # swapped tables fork the program
    p2 = LanePlan(kind="spec", width=32, chunk_len=8, entry=ENTRY_STARTS,
                  spec_r=2)
    assert p2.key != p.key  # the r choice forks the compiled program


# --------------------------------------------------------------------------
# Pallas all-absorbed bucket early exit
# --------------------------------------------------------------------------

def test_pallas_all_absorbed_bucket_early_exit():
    """A bucket whose every row is already absorbed skips the kernel: the
    entry states come back verbatim and every non-empty row reports an
    absorbed position (the local backend's in-scan exit now has a Pallas
    counterpart at bucket granularity)."""
    dfa = make_search_dfa(compile_regex(".*(hit)"))
    m = Matcher(dfa, num_chunks=4, backend="pallas", batch_tile=4)
    # drive real streams into absorption, then feed more bytes
    docs = [b"x hit y" * 40, b"z hit w" * 40]
    first = m.membership_batch(docs)
    assert m.dev.absorbing[first.final_states].all()
    more = [b"anything at all, long enough for the spec path " * 8] * 2
    res = m.advance_segments(more, first.final_states)
    np.testing.assert_array_equal(res.final_states, first.final_states)
    assert res.early_exits == len(more)  # kernel skipped, rows retired at 0
    # mixed buckets (one live row) must still run the kernel and stay exact
    live_entry = np.tile(m.packed.starts, (2, 1))
    mixed_entry = np.vstack([first.final_states[:1], live_entry[:1]])
    res2 = m.advance_segments(more, mixed_entry)
    want = Matcher(dfa, num_chunks=4, batch_tile=4).advance_segments(
        more, mixed_entry)
    np.testing.assert_array_equal(res2.final_states, want.final_states)
    assert res2.early_exits == 0  # kernel ran start-to-end
