"""Shared test configuration: default to 8 simulated host devices.

The mesh-sharded executor tests (tests/test_sharded_executor.py) need more
than one device, and jax locks the device count at first init — so the flag
must be in the environment before any test module imports jax.  conftest.py
imports before collection, which is early enough; setting it here means
plain ``pytest -x -q`` covers the sharded executor with no extra env setup.

An already-present ``xla_force_host_platform_device_count`` in XLA_FLAGS
wins (so CI can pin a different count), and subprocess-based tests that
replace XLA_FLAGS outright (dryrun's 512-device sweep, the distributed
suite) are unaffected.
"""

import os

_FLAG = "xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
if _FLAG not in _flags:
    os.environ["XLA_FLAGS"] = f"--{_FLAG}=8 {_flags}".strip()

import pytest  # noqa: E402  (env flag above must precede any jax import)


@pytest.fixture(autouse=True)
def _isolate_merge_counter():
    """Reset streaming.cursor's module-global host-merge counter per test.

    The counter exists to guard the scheduler tick path (zero host merges);
    without a reset, tests asserting on ``merge_calls()`` would couple
    through import-lifetime state and depend on execution order.  The import
    happens lazily inside the fixture so collecting tests never forces jax.
    """
    from repro.streaming.cursor import reset_merge_calls
    reset_merge_calls()
    yield
