"""Shared test configuration: default to 8 simulated host devices.

The mesh-sharded executor tests (tests/test_sharded_executor.py) need more
than one device, and jax locks the device count at first init — so the flag
must be in the environment before any test module imports jax.  conftest.py
imports before collection, which is early enough; setting it here means
plain ``pytest -x -q`` covers the sharded executor with no extra env setup.

An already-present ``xla_force_host_platform_device_count`` in XLA_FLAGS
wins (so CI can pin a different count), and subprocess-based tests that
replace XLA_FLAGS outright (dryrun's 512-device sweep, the distributed
suite) are unaffected.
"""

import os

_FLAG = "xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
if _FLAG not in _flags:
    os.environ["XLA_FLAGS"] = f"--{_FLAG}=8 {_flags}".strip()

import pytest  # noqa: E402  (env flag above must precede any jax import)


@pytest.fixture(autouse=True)
def _isolate_merge_counter():
    """Reset streaming.cursor's module-global host-merge counter per test.

    The counter exists to guard the scheduler tick path (zero host merges);
    without a reset, tests asserting on ``merge_calls()`` would couple
    through import-lifetime state and depend on execution order.  The import
    happens lazily inside the fixture so collecting tests never forces jax.
    """
    from repro.streaming.cursor import reset_merge_calls
    reset_merge_calls()
    yield

# -- deterministic hypothesis profiles (docs/architecture.md, "Testing") ----
# CI runs derandomized with a bounded example budget so conformance failures
# reproduce exactly from the printed blob; local runs keep hypothesis's
# random exploration but drop its wall-clock deadline (device dispatch
# latency is noisy under jit).  hypothesis is an optional dev dependency —
# when absent the property-based half of tests/test_conformance.py skips
# itself (pytest.importorskip) and this block is a no-op.
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro-ci", derandomize=True, deadline=None, max_examples=30,
        print_blob=True,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.register_profile("repro-dev", deadline=None)
    settings.load_profile("repro-ci" if os.environ.get("CI") else "repro-dev")
except ImportError:  # pragma: no cover - optional dev dependency
    pass
