"""Shared test configuration: default to 8 simulated host devices.

The mesh-sharded executor tests (tests/test_sharded_executor.py) need more
than one device, and jax locks the device count at first init — so the flag
must be in the environment before any test module imports jax.  conftest.py
imports before collection, which is early enough; setting it here means
plain ``pytest -x -q`` covers the sharded executor with no extra env setup.

An already-present ``xla_force_host_platform_device_count`` in XLA_FLAGS
wins (so CI can pin a different count), and subprocess-based tests that
replace XLA_FLAGS outright (dryrun's 512-device sweep, the distributed
suite) are unaffected.
"""

import os

_FLAG = "xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
if _FLAG not in _flags:
    os.environ["XLA_FLAGS"] = f"--{_FLAG}=8 {_flags}".strip()
