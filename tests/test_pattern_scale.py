"""Pattern-set scale tier: K-blocked plans, prefilter gate, hot swap.

The acceptance bar (ISSUE 9): a K=2048 pattern set runs through K-blocked
plans with verdicts bit-identical — accepted *and* global final states — to
an unblocked reference on the shared pattern prefix, with the required-
literal prefilter skipping the blocks whose literals are absent; and a hot
``swap_patterns`` rebuilds only the changed blocks, with at least one
bucket lowering cache-hit surviving the swap (asserted on the executors'
trace counters below).
"""

import numpy as np
import pytest

from repro.core import (BlockedMatcher, Matcher, PatternSet, Prefilter,
                        compile_regex, required_literal, window_fingerprints)
from repro.streaming import BlockedStreamMatcher, StreamMatcher, TickPolicy
from repro.streaming.ooo.fingerprint import segment_fingerprint

KW = dict(num_chunks=4, lookahead_r=1, batch_tile=16)
LAZY = TickPolicy(max_batch=1 << 30, max_delay=1 << 30)


# --------------------------------------------------------------------------
# tentpole acceptance: K=2048 blocked == unblocked K=32 on the shared prefix


def test_k2048_blocked_prefix_identity():
    pats = [f"K{i:03x}" for i in range(2048)]
    bm = BlockedMatcher(pats, k_blk=32, **KW)
    assert (bm.n_blocks, bm.n_patterns) == (64, 2048)
    docs = [b"xx K000 yy", b"K7ff at end", b"nothing here", b"K020 K021"]
    res = bm.membership_batch(docs)
    # the gate leaves exactly the blocks whose literals occur: 0, 1, 63
    assert bm.prefilter_skipped_blocks == 61
    hits = np.flatnonzero(res.accepted.any(axis=0))
    assert hits.tolist() == [0, 0x20, 0x21, 0x7FF]
    # bit-identity on the shared prefix against an unblocked K=32 reference
    ref = Matcher(PatternSet(pats[:32], k_blk=1 << 30, search=True), **KW)
    rres = ref.membership_batch(docs)
    assert (res.accepted[:, :32] == rres.accepted).all()
    assert (res.final_states[:, :32] == rres.final_states).all()


def test_blocked_full_bit_identity_no_prefilter():
    """K=64 / k_blk=16, gate off: the whole [B, K] result — accepted and
    re-based global final states — equals one unblocked pack."""
    pats = [f"p{i:02d}x" for i in range(60)] + \
           ["(ab|ba)+", "[0-9]{2}", "zz.?q", "w+"]
    rng = np.random.default_rng(3)
    docs = [bytes(rng.choice(np.frombuffer(b"abp019 zqwx", np.uint8),
                             size=int(rng.integers(1, 48))).astype(np.uint8))
            for _ in range(24)] + [b"p07x", b"abab 42 zzq www"]
    bm = BlockedMatcher(pats, k_blk=16, prefilter=False, **KW)
    ref = Matcher(PatternSet(pats, k_blk=1 << 30, search=True), **KW)
    res, rres = bm.membership_batch(docs), ref.membership_batch(docs)
    assert (res.accepted == rres.accepted).all()
    assert (res.final_states == rres.final_states).all()
    assert rres.accepted.any()


def test_prefilter_soundness():
    """The gate never changes a verdict, only skips guaranteed non-matches."""
    pats = {f"n{i}": f"lit{i:02d}" for i in range(12)}
    pats["free"] = "[xy]+z"  # no literal -> its block stays ungated
    rng = np.random.default_rng(5)
    frags = [f"lit{i:02d}".encode() for i in range(12)] + [b"xyz", b"qq "]
    docs = [b"".join(frags[j] for j in rng.integers(0, len(frags), size=4))
            for _ in range(32)]
    on = BlockedMatcher(pats, k_blk=4, prefilter=True, **KW)
    off = BlockedMatcher(pats, k_blk=4, prefilter=False, **KW)
    assert (on.accepts_batch(docs) == off.accepts_batch(docs)).all()
    assert off.prefilter_skipped_blocks == 0


# --------------------------------------------------------------------------
# prefilter building blocks


def test_required_literal_units():
    assert required_literal("foobar") == b"foobar"
    assert required_literal(".*(foobar)") == b"foobar"       # search wrapper
    assert required_literal("a[0-9]+barbaz[xy]?") == b"barbaz"
    assert required_literal("(ab){3}") == b"ababab"           # exact repeat
    assert required_literal("x(ab)+y") == b"ab"               # lo>=1 repeat
    assert required_literal("[ab]+") is None                  # class, no run
    assert required_literal("abc|abd") is None                # alternation
    assert required_literal("(abc)end") == b"abcend"          # 1-option Alt


def test_window_fingerprints_match_segment_fingerprint():
    rng = np.random.default_rng(9)
    data = rng.integers(0, 256, size=64).astype(np.uint8)
    for length in (1, 3, 8):
        got = window_fingerprints(data, length)
        want = np.array([segment_fingerprint(bytes(data[i:i + length]))
                         for i in range(len(data) - length + 1)],
                        np.uint64)
        assert (got == want).all()


def test_prefilter_gating_matrix():
    ps = PatternSet({"a": "needle", "b": "[ab]+"}, k_blk=1, search=True)
    pf = Prefilter.from_pattern_set(ps)
    assert pf.gated.tolist() == [True, False]  # block 1 has no literal
    arrs = [np.frombuffer(b"hay needle hay", np.uint8),
            np.frombuffer(b"no match", np.uint8)]
    can = pf.can_match(arrs)
    assert can.tolist() == [[True, True], [False, True]]


# --------------------------------------------------------------------------
# hot swap: partial rebuild, lowering-cache survival, epochs


def test_swap_preserves_lowering_cache():
    pats = {f"q{i:02d}": f"pat{i:02d}" for i in range(8)}
    bm = BlockedMatcher(pats, k_blk=2, **KW)
    docs = [b"xx pat03 pat06", b"pat00", b"none"]
    before = bm.accepts_batch(docs)
    traces0 = [m.executor.traces for m in bm.matchers]
    info = bm.swap_patterns(bm.pattern_set.with_patterns({"q06": "NEW[0-9]"}))
    assert info == {"reused": [0, 1, 2], "rebuilt": [3], "dropped": 0}
    after = bm.accepts_batch(docs + [b"NEW7!"])
    traces1 = [m.executor.traces for m in bm.matchers]
    # acceptance bar: unchanged blocks' compiled lowerings survive the swap
    # — re-running the same shapes through blocks 0..2 traces nothing new
    assert traces1[:3] == traces0[:3]
    assert traces1[3] > traces0[3]  # the rebuilt block really retraced
    want = before.copy()
    want[:, 6] = False  # q06 no longer matches "pat06"
    assert (after[:3] == want).all()
    assert after[3, 6] and after[3].sum() == 1  # new pattern live
    epochs = bm.perf_report()["table_epochs"]
    assert epochs == [0, 0, 0, 1]


def test_matcher_swap_unit():
    m = Matcher(compile_regex("ab+"), **KW)
    assert m.accepts_batch([b"abb"])[0, 0]
    assert m.swap_patterns(compile_regex("ab+")) is False  # signature-equal
    assert m.planner.table_epoch == 0
    assert m.swap_patterns(compile_regex("cd?")) is True
    assert m.planner.table_epoch == 1
    assert m.perf_report()["table_epoch"] == 1
    assert m.perf_report()["prefilter_skipped_blocks"] is None
    got = m.accepts_batch([b"abb", b"cd", b"c"])
    assert got[:, 0].tolist() == [False, True, True]


def test_matcher_refuses_multiblock_pattern_set():
    ps = PatternSet(["aa", "bb", "cc"], k_blk=2, search=True)
    with pytest.raises(ValueError, match="BlockedMatcher"):
        Matcher(ps, **KW)
    assert Matcher(PatternSet(["aa"], k_blk=2, search=True),
                   **KW).accepts_batch([b"aa"])[0, 0]


def test_stream_swap_carries_unchanged_blocks():
    """Mid-stream hot swap: untouched blocks keep their cursors (and their
    full byte history) bit-identically; swapped ones see post-swap bytes."""
    ps = PatternSet({"a": "hello", "b": "wor", "c": "abc", "d": "wld"},
                    k_blk=2, search=True)
    sm = BlockedStreamMatcher(ps, policy=LAZY, **KW)
    sess = sm.open()
    sess.feed(b"hello wor")
    sm.flush()
    keep = sess.parts[0].cursor.lane_states.copy()
    info = sm.swap_patterns(ps.with_patterns({"d": "world"}))
    assert info["reused"] == [0] and info["rebuilt"] == [1]
    # unchanged block 0: cursor untouched by the swap, bit for bit
    assert (sess.parts[0].cursor.lane_states == keep).all()
    sess.feed(b"ld!")
    res = sess.close()
    # "hello" matched pre-swap history; swapped "world" only saw "ld!"
    assert res.accepted.tolist() == [True, True, False, False]
    assert res.byte_count == 12


def test_stream_swap_refuses_candidate_sessions():
    """A [K, S] restricted map cannot be re-keyed onto new tables."""
    m = Matcher(compile_regex(".*(ab)"), **KW)
    sm = StreamMatcher(m, policy=LAZY, lane_ticks=True)
    sess = sm.open_at(entry_class=0)
    sess.feed(b"ab")
    with pytest.raises(ValueError, match="candidate-keyed"):
        sm.swap_patterns(compile_regex(".*(cd)"))
    sm.close_map(sess)  # once closed, the swap goes through
    assert sm.swap_patterns(compile_regex(".*(cd)")) is True
