"""Differential conformance: the engine vs Python's ``re`` as oracle.

Every fixture pattern (tests/fixtures/pattern_corpus.json — PCRE-style +
PROSITE, each with ``re``-verified positive/negative examples) and seeded
random documents are matched by the full engine stack and compared
decision-for-decision against ``re.fullmatch`` / ``re.search``:

* ``search=True`` pattern sets (absorbing search DFAs) must agree with
  ``re.search(pattern, doc, re.DOTALL)`` — the corpus-filter semantics;
* bare ``compile_regex`` DFAs must agree with ``re.fullmatch`` — the
  membership-test semantics of the paper;
* the verdicts are identical on every backend (local / pallas / sharded on
  1x1, 2x4 and 8x1 meshes), with and without K-blocking, with and without
  the prefilter gate, and after a hot ``swap_patterns``.

Oracle convention: documents are bytes; the oracle decodes latin-1 (a
byte-transparent bijection) and compiles with ``re.DOTALL`` because the
engine's ``.`` and negated classes match any byte including newline.

The property-based half (random chunk splits, random documents) runs only
when ``hypothesis`` is installed — the profiles live in conftest.py; CI is
derandomized ("repro-ci") so failures replay exactly.
"""

import re

import numpy as np
import pytest

import jax

from repro.core import (BlockedMatcher, Matcher, PatternSet, compile_regex)
from repro.data import load_pattern_fixtures
from repro.launch.mesh import make_matcher_mesh

FIXTURES = load_pattern_fixtures()
ALL_PATTERNS = {e["name"]: e["pattern"] for e in FIXTURES}
ALL_DOCS = sorted({s.encode() for e in FIXTURES
                   for s in e["positive"] + e["negative"]})
# compact cross-backend slice: half pcre / half prosite, short docs
SMALL_PATTERNS = {e["name"]: e["pattern"]
                  for e in (FIXTURES[:4] + FIXTURES[-4:])}
SMALL_DOCS = [d for d in ALL_DOCS if len(d) <= 24][:48]

ENGINE_KW = dict(num_chunks=4, batch_tile=16, max_buckets=2,
                 lookahead_r="auto")

BACKENDS = [
    pytest.param(("local", None), id="local"),
    pytest.param(("pallas", None), id="pallas"),
    pytest.param(("sharded", (1, 1)), id="sharded-1x1"),
    pytest.param(("sharded", (2, 4)), id="sharded-2x4"),
    pytest.param(("sharded", (8, 1)), id="sharded-8x1"),
]


def _matcher(source, backend_spec, **kw):
    backend, shape = backend_spec
    kwargs = {**ENGINE_KW, **kw}
    if backend == "sharded":
        if len(jax.devices()) < shape[0] * shape[1]:
            pytest.skip(f"needs {shape[0] * shape[1]} host devices")
        kwargs.update(mesh=make_matcher_mesh(shape=shape))
    return Matcher(source, backend=backend, **kwargs)


def _search_oracle(patterns, docs):
    """[B, K] bool: does pattern k occur anywhere in doc b?"""
    rxs = [re.compile(p, re.DOTALL) for p in patterns]
    return np.array([[rx.search(d.decode("latin-1")) is not None
                      for rx in rxs] for d in docs])


def _fullmatch_oracle(patterns, docs):
    rxs = [re.compile(p, re.DOTALL) for p in patterns]
    return np.array([[rx.fullmatch(d.decode("latin-1")) is not None
                      for rx in rxs] for d in docs])


def _random_docs(rng, n, alphabet, max_len=64):
    return [bytes(rng.choice(alphabet, size=int(rng.integers(0, max_len + 1)))
                  .astype(np.uint8)) for _ in range(n)]


# --------------------------------------------------------------------------
# fixture corpus, full pattern sweep (local) and slice (every backend)


def test_fixture_corpus_search_local():
    """All 34 fixture patterns x all fixture docs on the local backend."""
    ps = PatternSet(ALL_PATTERNS, k_blk=1 << 30, search=True)
    got = Matcher(ps, **ENGINE_KW).accepts_batch(ALL_DOCS)
    want = _search_oracle(list(ALL_PATTERNS.values()), ALL_DOCS)
    assert (got == want).all()
    # the fixtures promise at least one positive per pattern
    assert want.any(axis=0).all()


@pytest.mark.parametrize("backend_spec", BACKENDS)
def test_fixture_corpus_search_backends(backend_spec):
    ps = PatternSet(SMALL_PATTERNS, k_blk=1 << 30, search=True)
    got = _matcher(ps, backend_spec).accepts_batch(SMALL_DOCS)
    want = _search_oracle(list(SMALL_PATTERNS.values()), SMALL_DOCS)
    assert (got == want).all()


@pytest.mark.parametrize("backend_spec", BACKENDS)
def test_seeded_random_fullmatch(backend_spec):
    """Bare DFAs == re.fullmatch on seeded random docs (every backend)."""
    patterns = ["(ab|ba){2,6}", "[0-9]+", "a[ab]*b", "x+y",
                "([a-y]0)*", "b.y"]
    dfas = [compile_regex(p) for p in patterns]
    rng = np.random.default_rng(7)
    docs = _random_docs(rng, 48, np.frombuffer(b"ab01xy", np.uint8))
    got = _matcher(dfas, backend_spec).accepts_batch(docs)
    want = _fullmatch_oracle(patterns, docs)
    assert (got == want).all()
    assert want.any()  # the alphabet is chosen so some docs do match


@pytest.mark.parametrize("backend_spec", BACKENDS)
def test_seeded_random_search(backend_spec):
    """search=True PatternSet == re.search on seeded random docs."""
    patterns = {"p0": "(ab|ba){2}", "p1": "[0-9]{3}", "p2": "x+y"}
    ps = PatternSet(patterns, k_blk=1 << 30, search=True)
    rng = np.random.default_rng(11)
    docs = _random_docs(rng, 48, np.frombuffer(b"abxy0189", np.uint8))
    got = _matcher(ps, backend_spec).accepts_batch(docs)
    want = _search_oracle(list(patterns.values()), docs)
    assert (got == want).all()
    assert want.any()


# --------------------------------------------------------------------------
# K-blocking and the prefilter gate preserve conformance


@pytest.mark.parametrize("prefilter", [True, False],
                         ids=["prefilter", "noprefilter"])
def test_blocked_conformance(prefilter):
    bm = BlockedMatcher(ALL_PATTERNS, k_blk=4, prefilter=prefilter,
                        **ENGINE_KW)
    assert bm.n_blocks > 1
    got = bm.accepts_batch(ALL_DOCS)
    want = _search_oracle(list(ALL_PATTERNS.values()), ALL_DOCS)
    assert (got == want).all()


def test_conformance_after_hot_swap():
    """The oracle still agrees after swap_patterns rebuilt changed blocks."""
    names = list(ALL_PATTERNS)
    bm = BlockedMatcher(ALL_PATTERNS, k_blk=4, **ENGINE_KW)
    swapped = {names[0]: "zz[0-9]+zz", names[9]: "(qu)+x"}
    new_ps = bm.pattern_set.with_patterns(swapped)
    info = bm.swap_patterns(new_ps)
    assert info["reused"] and info["rebuilt"]  # partial rebuild, not full
    new_patterns = {**ALL_PATTERNS, **swapped}
    docs = ALL_DOCS + [b"zz123zz", b"ququx yes", b"zz zz"]
    got = bm.accepts_batch(docs)
    want = _search_oracle(list(new_patterns.values()), docs)
    assert (got == want).all()
    assert want[len(ALL_DOCS):, [0, 9]].any()  # swapped patterns exercised


def test_streaming_conformance():
    """Chunk-fed streams agree with the oracle (and with batch)."""
    from repro.streaming import BlockedStreamMatcher, TickPolicy

    bm = BlockedMatcher(SMALL_PATTERNS, k_blk=3, **ENGINE_KW)
    sm = BlockedStreamMatcher(
        bm, policy=TickPolicy(max_batch=4, max_delay=2))
    docs = [d for d in SMALL_DOCS if d][:12]
    sessions = [sm.open() for _ in docs]
    # interleaved chunk arrival: every doc lands in two rounds, split at a
    # per-row offset, so ticks coalesce partial segments of many streams
    for rnd in range(2):
        for i, (s, d) in enumerate(zip(sessions, docs)):
            cut = 1 + i % max(1, len(d) - 1)
            piece = d[:cut] if rnd == 0 else d[cut:]
            if piece:
                s.feed(piece)
    got = np.stack([s.close().accepted for s in sessions])
    want = _search_oracle(list(SMALL_PATTERNS.values()), docs)
    assert (got == want).all()


# --------------------------------------------------------------------------
# property-based half (requires hypothesis; profiles in conftest.py)


def _hyp():
    hyp = pytest.importorskip("hypothesis")
    return hyp, pytest.importorskip("hypothesis.strategies")


_HYP_PS = None


def _fixture_matcher():
    global _HYP_PS
    if _HYP_PS is None:
        _HYP_PS = Matcher(PatternSet(SMALL_PATTERNS, k_blk=1 << 30,
                                     search=True), **ENGINE_KW)
    return _HYP_PS


def test_hypothesis_random_documents():
    hyp, st = _hyp()

    @hyp.given(st.lists(st.binary(max_size=64), min_size=1, max_size=8))
    def check(docs):
        got = _fixture_matcher().accepts_batch(docs)
        want = _search_oracle(list(SMALL_PATTERNS.values()), docs)
        assert (got == want).all()

    check()


def test_hypothesis_chunk_split_invariance():
    """Any chunking of a doc streams to the same verdict as one batch call."""
    hyp, st = _hyp()
    from repro.streaming import StreamMatcher, TickPolicy

    m = _fixture_matcher()
    sm = StreamMatcher(m, policy=TickPolicy(max_batch=1, max_delay=0))

    @hyp.given(st.binary(min_size=1, max_size=64),
               st.lists(st.integers(1, 63), max_size=4))
    def check(doc, cuts):
        sess = sm.open()
        last = 0
        for c in sorted(set(min(c, len(doc)) for c in cuts)):
            if c > last:
                sess.feed(doc[last:c])
                last = c
        if last < len(doc):
            sess.feed(doc[last:])
        got = sess.close().accepted
        want = m.accepts_batch([doc])[0]
        assert (got == want).all()

    check()
