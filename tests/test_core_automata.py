"""Unit + property tests for regex -> NFA -> DFA -> minimal DFA pipeline."""

import re

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (compile_prosite, compile_regex, make_search_dfa, minimize,
                        nfa_to_dfa, prosite_to_regex, random_dfa, regex_to_nfa)

# Patterns chosen to exercise classes, alternation, bounded/unbounded repeats.
PATTERNS = [
    r"a*bc*",
    r"(ab|ba){2,4}",
    r"[0-9]{2,3}-[a-z]+",
    r"x?y+z*",
    r"(foo|bar|baz)+",
    r"[^a-m]n{1,3}",
    r"a.c",
    r"\d+\.\d+",
    r"(a|b)*abb",
]

ALPHABET = b"abcfonrz019.xm-"


def _random_strings(rng, n=200, maxlen=12):
    for _ in range(n):
        ln = rng.integers(0, maxlen)
        yield bytes(rng.choice(list(ALPHABET), size=ln))


@pytest.mark.parametrize("pattern", PATTERNS)
def test_regex_dfa_matches_python_re(pattern):
    dfa = compile_regex(pattern)
    rng = np.random.default_rng(42)
    checked = 0
    for s in _random_strings(rng):
        want = re.fullmatch(pattern, s.decode("latin-1")) is not None
        assert dfa.accepts(s) == want, (pattern, s)
        checked += 1
    assert checked == 200


@pytest.mark.parametrize("pattern", PATTERNS)
def test_minimization_preserves_language_and_shrinks(pattern):
    raw = nfa_to_dfa(regex_to_nfa(pattern))
    mini = minimize(raw)
    assert mini.n_states <= raw.n_states
    rng = np.random.default_rng(7)
    for s in _random_strings(rng, n=100):
        assert raw.accepts(s) == mini.accepts(s)


def test_dfa_is_complete_with_sink():
    dfa = compile_regex("abc")
    assert dfa.sink >= 0
    # sink is absorbing and non-accepting
    assert (dfa.table[dfa.sink] == dfa.sink).all()
    assert not dfa.accepting[dfa.sink]


def test_search_semantics_absorbing_accept():
    dfa = make_search_dfa(compile_regex(".*abc"))
    assert dfa.accepts(b"xxabcyy")     # match found mid-string stays accepted
    assert dfa.accepts(b"abc")
    assert not dfa.accepts(b"ababab")


def test_prosite_translation():
    assert prosite_to_regex("N-{P}-[ST]-{P}") == "N[^P][ST][^P]"
    assert prosite_to_regex("[RK](2)-x-[ST]") == "[RK]{2}[A-Z][ST]"
    assert prosite_to_regex("C-x(2,4)-C") == "C[A-Z]{2,4}C"
    dfa = compile_prosite("[AG]-x(4)-G-K-[ST]")  # P-loop PS00017
    assert dfa.accepts(b"AXXXXGKS")
    assert not dfa.accepts(b"AXXXXGKX")


def test_byte_class_compression_consistency():
    dfa = compile_regex("[a-f]+[0-9]*")
    # bytes inside one leaf set must share a class
    c = dfa.byte_to_class
    assert len({int(c[b]) for b in b"abcdef"}) == 1
    assert len({int(c[b]) for b in b"0123456789"}) == 1
    assert int(c[ord("a")]) != int(c[ord("0")])


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 24), st.integers(2, 8), st.integers(0, 2**31 - 1))
def test_random_dfa_minimize_equiv(n_states, n_classes, seed):
    rng = np.random.default_rng(seed)
    dfa = random_dfa(n_states, n_classes, rng=rng)
    mini = minimize(dfa)
    assert mini.n_states <= dfa.n_states
    for _ in range(25):
        s = rng.integers(0, n_classes, size=rng.integers(0, 30)).astype(np.int32)
        # feed class streams directly via run on raw bytes mapped through b2c:
        st1, st2 = dfa.start, mini.start
        for cls in s:
            st1 = int(dfa.table[st1, cls])
            st2 = int(mini.table[st2, cls])
        assert bool(dfa.accepting[st1]) == bool(mini.accepting[st2])
