"""Batched multi-pattern pipeline: equality with sequential, retrace bounds.

Covers the tentpole guarantees:
  * ``membership_batch`` is bit-identical to per-document sequential matching
    on ragged corpora (including docs shorter than 4 * num_chunks that fall
    back to the batched sequential scan, and empty docs);
  * a packed K-pattern table answers exactly like K independent engines;
  * the fused Pallas kernel matches the pure-jnp reference;
  * shape bucketing compiles at most ``max_buckets`` speculative shapes
    across a ragged corpus (trace counters);
  * the batched consumers (CorpusFilter, GrammarConstraint) agree with their
    per-document paths.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (BatchMatcher, SpecDFAEngine, build_packed_lookahead_tables,
                        compile_regex, make_search_dfa, pack_dfas, random_dfa)
from repro.data.filter import CorpusFilter
from repro.kernels import ops, ref
from repro.serving.constrained import GrammarConstraint

PATTERNS = [".*(ab|ba){2}", ".*[0-9]{3}", ".*x+y"]
ALPHABET = b"abxy0189"


def _docs(rng, sizes):
    return [bytes(rng.choice(list(ALPHABET), size=int(n)).astype(np.uint8))
            for n in sizes]


# --------------------------------------------------------------------------
# packed representation
# --------------------------------------------------------------------------

def test_pack_dfas_matches_independent_runs():
    rng = np.random.default_rng(0)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS[:2]]
    dfas.append(random_dfa(7, 4, rng=rng))
    packed = pack_dfas(dfas)
    assert packed.n_states == sum(d.n_states for d in dfas)
    for _ in range(40):
        data = rng.integers(0, 256, size=int(rng.integers(0, 80)), dtype=np.uint8)
        got = packed.accepts_all(data)
        want = np.array([d.accepts(data) for d in dfas])
        assert (got == want).all()


def test_packed_lookahead_candidate_invariant():
    """delta(q, c) is always a candidate of class c unless it is the sink."""
    rng = np.random.default_rng(1)
    packed = pack_dfas([random_dfa(9, 5, rng=rng), random_dfa(5, 3, rng=rng)])
    t = build_packed_lookahead_tables(packed)
    for c in range(packed.n_classes):
        for k in range(packed.n_patterns):
            for q in range(packed.offsets[k], packed.offsets[k + 1]):
                tgt = int(packed.table[q, c])
                if tgt == packed.sinks[k]:
                    assert t.cand_index[c, tgt] == -1
                else:
                    j = t.cand_index[c, tgt]
                    assert j >= 0 and int(t.candidates[c, k, j]) == tgt


# --------------------------------------------------------------------------
# batch path == sequential
# --------------------------------------------------------------------------

def test_membership_batch_equals_sequential_ragged():
    rng = np.random.default_rng(2)
    dfa = make_search_dfa(compile_regex(PATTERNS[0]))
    eng = SpecDFAEngine(dfa, num_chunks=8)
    # ragged: empty, shorter than 4 * num_chunks (sequential fallback),
    # boundary, and long
    docs = _docs(rng, [0, 1, 3, 10, 31, 32, 33, 100, 255, 256, 513, 1024])
    res = eng.membership_batch(docs)
    assert res.accepted.shape == (len(docs), 1)
    for i, d in enumerate(docs):
        want = eng.membership_sequential(d)
        assert int(res.final_states[i, 0]) == want.final_state, (i, len(d))
        assert bool(res.accepted[i, 0]) == want.accepted


def test_membership_batch_random_dfa_property():
    rng = np.random.default_rng(3)
    for trial in range(5):
        dfa = random_dfa(int(rng.integers(3, 24)), int(rng.integers(2, 8)),
                         rng=rng)
        bm = BatchMatcher(dfa, num_chunks=int(rng.integers(2, 7)))
        docs = [rng.integers(0, 256, size=int(n), dtype=np.uint8)
                for n in rng.integers(0, 400, size=12)]
        res = bm.membership_batch(docs)
        for i, d in enumerate(docs):
            assert int(res.final_states[i, 0]) == dfa.run(d), (trial, i)


def test_packed_k_patterns_equal_independent_engines():
    rng = np.random.default_rng(4)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    bm = BatchMatcher(dfas, num_chunks=8)
    engines = [SpecDFAEngine(d, num_chunks=8) for d in dfas]
    docs = _docs(rng, rng.integers(0, 800, size=30))
    res = bm.membership_batch(docs)
    assert res.accepted.shape == (len(docs), len(dfas))
    for i, d in enumerate(docs):
        for k, e in enumerate(engines):
            want = e.membership_sequential(d)
            off = int(bm.packed.offsets[k])
            assert int(res.final_states[i, k]) - off == want.final_state
            assert bool(res.accepted[i, k]) == want.accepted


def test_batch_kernel_path_equals_jnp_path():
    rng = np.random.default_rng(5)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS[:2]]
    docs = _docs(rng, rng.integers(0, 300, size=10))
    res_j = BatchMatcher(dfas, num_chunks=4).membership_batch(docs)
    res_k = BatchMatcher(dfas, num_chunks=4, use_kernel=True,
                         batch_tile=8).membership_batch(docs)
    assert (res_j.final_states == res_k.final_states).all()
    assert (res_j.accepted == res_k.accepted).all()


# --------------------------------------------------------------------------
# retracing / bucketing policy
# --------------------------------------------------------------------------

def test_retrace_bound_on_ragged_corpus():
    """<= 2 compiled speculative shapes across a 100-doc ragged corpus."""
    rng = np.random.default_rng(6)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    bm = BatchMatcher(dfas, num_chunks=8, max_buckets=2)
    corpus = _docs(rng, rng.integers(40, 3000, size=100))
    r1 = bm.membership_batch(corpus[:64])
    r2 = bm.membership_batch(corpus[64:])
    assert bm.trace_count <= 2, bm.trace_count
    assert len(bm._spec_keys) <= 2
    # sticky buckets stay correct
    eng = SpecDFAEngine(dfas[0], num_chunks=8)
    finals = np.concatenate([r1.final_states, r2.final_states])
    for i, d in enumerate(corpus):
        assert int(finals[i, 0]) == eng.membership_sequential(d).final_state


def test_batch_result_work_model():
    rng = np.random.default_rng(7)
    dfa = make_search_dfa(compile_regex(PATTERNS[1]))
    bm = BatchMatcher(dfa, num_chunks=8)
    docs = _docs(rng, [512] * 4)
    res = bm.membership_batch(docs)
    assert (res.work_sequential == np.array([512] * 4)).all()
    assert res.lane_speedup > 1.0  # amortization must beat sequential model


# --------------------------------------------------------------------------
# fused kernel vs reference oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 4, 8), (3, 2, 16), (1, 8, 32)])
def test_spec_match_merge_kernel_matches_ref(shape):
    b, c, lc = shape
    rng = np.random.default_rng(8)
    packed = pack_dfas([random_dfa(8, 4, rng=rng), random_dfa(5, 3, rng=rng)])
    t = build_packed_lookahead_tables(packed)
    k, s = packed.n_patterns, t.i_max
    pad_cls = packed.n_classes
    q = packed.n_states
    table = np.concatenate(
        [packed.table, np.arange(q, dtype=np.int32).reshape(-1, 1)], axis=1)
    cidx = np.concatenate([t.cand_index, np.full((1, q), -1, np.int32)])
    cand = np.concatenate([t.candidates, t.candidates[:1]])

    docs = [rng.integers(0, 256, size=int(n), dtype=np.uint8)
            for n in rng.integers(c * lc // 2, c * lc + 1, size=b)]
    chunks = np.full((b, c, lc), pad_cls, np.int32)
    for i, d in enumerate(docs):
        cls = packed.classes_of(d)
        chunks.reshape(b, -1)[i, :len(cls)] = cls
    la = np.zeros((b, c), np.int32)
    la[:, 1:] = chunks[:, :-1, -1]
    init = np.zeros((b, c, k, s), np.int32)
    init[:, 0] = np.broadcast_to(packed.starts[:, None], (k, s))
    init[:, 1:] = cand[la[:, 1:]]
    init = init.reshape(b, c, k * s)

    absorbing = (packed.table == np.arange(q)[:, None]).all(axis=1)
    args = (jnp.asarray(table), jnp.asarray(chunks), jnp.asarray(init),
            jnp.asarray(la), jnp.asarray(cidx), jnp.asarray(packed.sinks))
    want = np.stack([packed.run_all(d) for d in docs])
    got_ref = np.asarray(ref.spec_match_merge_ref(*args, pad_cls=pad_cls))
    for early_exit in (False, True):
        got_ker, skipped, l_blk = ops.spec_match_merge(
            *args, jnp.asarray(absorbing.astype(np.int32)), pad_cls=pad_cls,
            early_exit=early_exit)
        assert (np.asarray(got_ker) == want).all()
        if not early_exit:
            assert (np.asarray(skipped) == 0).all()
    assert (got_ref == want).all()


# --------------------------------------------------------------------------
# consumers
# --------------------------------------------------------------------------

def test_corpus_filter_batch_equals_per_doc():
    rng = np.random.default_rng(9)
    filt = CorpusFilter([r"SECRET-[0-9]+", r"key=[a-z]{4}"], num_chunks=4)
    docs = []
    for n in rng.integers(5, 400, size=24):
        d = bytearray(rng.choice(list(b"abc 01xyz"), size=int(n)).astype(np.uint8))
        if rng.random() < 0.4:
            ins = b"SECRET-77" if rng.random() < 0.5 else b"key=abcd"
            pos = int(rng.integers(0, len(d) + 1))
            d[pos:pos] = ins
        docs.append(bytes(d))
    keep_batch = filt.scan_batch(docs)
    keep_doc = np.array([filt.document_ok(d) for d in docs])
    assert (keep_batch == keep_doc).all()
    assert filt.stats.scanned == 2 * len(docs)
    # early-exit accounting: per-doc path never scans more patterns than K*B
    assert filt.stats.patterns_scanned <= 2 * 2 * len(docs)
    assert filt.stats.batch_calls >= 1


def test_corpus_filter_no_patterns_keeps_everything():
    filt = CorpusFilter([])
    assert filt.document_ok(b"anything goes")
    assert filt.scan_batch([b"a", b"b"]).all()
    assert list(filt.filter([b"x", b"y"])) == [b"x", b"y"]
    assert filt.stats.dropped == 0


def test_corpus_filter_early_exit_stats():
    filt = CorpusFilter([r"AAA", r"BBB"], num_chunks=4)
    assert not filt.document_ok(b"xx AAA yy" * 20)  # first pattern hits
    assert filt.stats.patterns_scanned == 1         # second engine never ran
    assert filt.stats.early_exits == 1
    assert filt.document_ok(b"clean text " * 20)
    assert filt.stats.patterns_scanned == 3         # both engines ran


def test_grammar_constraint_advance_tokens_matches_loop():
    dfa = compile_regex("(ab)*a?")
    gc = GrammarConstraint(dfa, vocab_size=300)
    rng = np.random.default_rng(10)
    toks = rng.integers(0, 300, size=(5, 12)).astype(np.int32)
    states = gc.init_states(5)
    want = states
    for t in range(toks.shape[1]):
        want = gc.advance(want, jnp.asarray(toks[:, t]))
    got = gc.advance_tokens(gc.init_states(5), toks)
    assert (np.asarray(got) == np.asarray(want)).all()
    # empty prompt is the identity
    got0 = gc.advance_tokens(gc.init_states(5), np.zeros((5, 0), np.int32))
    assert (np.asarray(got0) == np.asarray(gc.init_states(5))).all()
