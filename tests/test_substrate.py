"""Integration tests: data pipeline, training loop, checkpointing, serving."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec, get_config, reduce_for_smoke
from repro.core import compile_regex, make_search_dfa
from repro.data import (ByteTokenizer, CorpusConfig, CorpusFilter,
                        LoaderConfig, data_stream, generate_documents,
                        host_shard)
from repro.distributed.fault_tolerance import RestartManager, StragglerPolicy
from repro.models import api
from repro.serving import GrammarConstraint, ServeConfig, ServingEngine
from repro.training import (AdamWConfig, CheckpointManager, TrainOptions,
                            init_train_state, make_train_step)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_corpus_filter_drops_contaminated_docs():
    cfg = CorpusConfig(n_documents=40, contaminant=b"SECRET-123",
                       contaminant_rate=0.3, seed=1)
    docs = list(generate_documents(cfg))
    filt = CorpusFilter([r"SECRET-[0-9]+"], num_chunks=4)
    kept = list(filt.filter(docs))
    # every kept doc clean, every dropped doc contaminated
    assert all(b"SECRET-123" not in d for d in kept)
    assert filt.stats.dropped == sum(b"SECRET-123" in d for d in docs)
    assert filt.stats.scanned == 40


def test_filter_failure_freedom_at_pipeline_level():
    cfg = CorpusConfig(n_documents=10, seed=2)
    filt = CorpusFilter([r"SECRET-[0-9]+"], num_chunks=8, partition="balanced")
    list(filt.filter(generate_documents(cfg)))
    # balanced partitioning: parallel work per processor <= sequential total
    assert filt.stats.work_parallel <= filt.stats.work_sequential * 1.01


def test_data_stream_packs_batches():
    cfg = CorpusConfig(n_documents=30, seed=3)
    lcfg = LoaderConfig(batch_size=4, seq_len=128)
    batches = list(data_stream(generate_documents(cfg), lcfg))
    assert len(batches) >= 2
    for b in batches:
        assert b["tokens"].shape == (4, 128)
        assert b["labels"].shape == (4, 128)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_shard_weighted():
    start_fast, end_fast = host_shard(10_000, [2.0, 1.0, 1.0], 0)
    start_slow, end_slow = host_shard(10_000, [2.0, 1.0, 1.0], 1)
    assert (end_fast - start_fast) > (end_slow - start_slow)
    assert start_slow == end_fast


# --------------------------------------------------------------------------
# training loop + checkpointing + restart
# --------------------------------------------------------------------------

def _tiny_setup():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    shape = ShapeSpec("t", "train", 64, 4)
    batch = api.make_inputs(cfg, shape, seed=0)
    opts = TrainOptions(num_microbatches=2,
                        optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                              total_steps=20))
    state = init_train_state(cfg, jax.random.PRNGKey(0), opts=opts)
    step = jax.jit(make_train_step(cfg, None, opts))
    return cfg, state, step, batch


def test_train_loss_decreases():
    cfg, state, step, batch = _tiny_setup()
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses  # memorizes the fixed batch


def test_microbatching_matches_full_batch():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    shape = ShapeSpec("t", "train", 64, 4)
    batch = api.make_inputs(cfg, shape, seed=0)
    s1 = init_train_state(cfg, jax.random.PRNGKey(0))
    s2 = jax.tree.map(lambda x: x, s1)
    step1 = jax.jit(make_train_step(cfg, None, TrainOptions(num_microbatches=1)))
    step4 = jax.jit(make_train_step(cfg, None, TrainOptions(num_microbatches=4)))
    s1, m1 = step1(s1, batch)
    s2, m2 = step4(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-3)
    a = jax.tree.leaves(s1["params"])[0]
    b = jax.tree.leaves(s2["params"])[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_checkpoint_roundtrip(tmp_path):
    cfg, state, step, batch = _tiny_setup()
    state, _ = step(state, batch)
    mgr = CheckpointManager(str(tmp_path), keep=2, use_async=True)
    mgr.save(state, 1)
    mgr.wait()
    restored, at = mgr.restore(like=jax.tree.map(np.asarray, state))
    assert at == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_manager_recovers_from_fault(tmp_path):
    cfg, state, step, batch = _tiny_setup()
    mgr = CheckpointManager(str(tmp_path), use_async=False)
    like = jax.tree.map(np.asarray, state)
    mgr.save(state, 0)

    crashed = {"done": False}

    def step_fn(st, i):
        if i == 3 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")
        st, _ = step(st, batch)
        return st

    rm = RestartManager(save_fn=mgr.save,
                        restore_fn=lambda: mgr.restore(like))
    final, at = rm.run(state, 0, 6, step_fn, checkpoint_every=2)
    assert at == 6
    assert rm.restarts == 1
    assert rm.failures and "injected" in rm.failures[0][1]


def test_straggler_policy_triggers_and_rebalances():
    pol = StragglerPolicy(n_workers=4, threshold=1.3)
    assert not pol.update(np.array([1.0, 1.0, 1.0, 1.05]))
    fired = False
    for _ in range(10):
        fired = pol.update(np.array([1.0, 1.0, 1.0, 2.0])) or fired
    assert fired
    part = pol.rebalanced_shards(10_000)
    sizes = part.sizes
    assert sizes[3] < sizes[0]  # slow worker gets less data


# --------------------------------------------------------------------------
# serving + constrained decoding
# --------------------------------------------------------------------------

def test_serving_greedy_generation():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    params = api.init(cfg, jax.random.PRNGKey(1))
    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=8))
    prompts = np.full((2, 5), 65, np.int32)
    out = eng.generate(prompts)
    assert out.shape == (2, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_constrained_decoding_respects_grammar():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    params = api.init(cfg, jax.random.PRNGKey(2))
    # grammar: only lowercase a-d allowed, ever
    dfa = make_search_dfa(compile_regex(r"[a-d]*"))
    # use membership semantics: build DFA accepting [a-d]* directly
    dfa = compile_regex(r"[a-d]+")
    con = GrammarConstraint(dfa, cfg.padded_vocab)
    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=6),
                        constraint=con)
    prompts = np.asarray([[ord("a"), ord("b")]], np.int32)
    out = eng.generate(prompts)
    # every generated byte obeys the grammar; EOS is legal on accepting states
    assert all(t == 258 or chr(t) in "abcd" for t in out[0])
    assert any(t != 258 for t in out[0]) or True


def test_draft_verification_matches_sequential():
    dfa = compile_regex(r"[a-d]+x")
    con = GrammarConstraint(dfa, 512)
    n_ok, traj = con.verify_draft(dfa.start, np.frombuffer(b"abz", np.uint8))
    assert n_ok == 2  # 'z' kills it
    n_ok2, _ = con.verify_draft(dfa.start, np.frombuffer(b"abcdx", np.uint8))
    assert n_ok2 == 5
