"""Deprecation shims stay covered: ``BatchMatcher``/``SpecDFAEngine``.

The examples and ROADMAP now demo the PR 2 ``Matcher`` facade (and the PR 3
streaming runtime), but the pre-refactor entry points must keep working —
and keep agreeing with the facade — until callers migrate.  This module is
their dedicated coverage; the examples themselves are import-checked so API
drift in either shim or facade breaks the build, not the demo.
"""

import importlib.util
import pathlib

import numpy as np
import pytest

from repro.core import (BatchMatcher, Matcher, SpecDFAEngine, compile_regex,
                        make_search_dfa)

PATTERNS = [".*(ab|ba){2}", ".*[0-9]{3}"]
EXAMPLES = pathlib.Path(__file__).resolve().parents[1] / "examples"


def _dfas():
    return [make_search_dfa(compile_regex(p)) for p in PATTERNS]


def test_batch_matcher_shim_warns_and_matches_facade():
    rng = np.random.default_rng(50)
    docs = [bytes(rng.choice(list(b"abxy0189"), size=int(n)).astype(np.uint8))
            for n in [0, 5, 40, 300]]
    want = Matcher(_dfas(), num_chunks=8).membership_batch(docs)
    with pytest.deprecated_call():
        bm = BatchMatcher(_dfas(), num_chunks=8)
    assert bm.backend == "local" and bm.use_kernel is False
    np.testing.assert_array_equal(bm.membership_batch(docs).final_states,
                                  want.final_states)
    with pytest.deprecated_call():
        bmk = BatchMatcher(_dfas(), num_chunks=8, use_kernel=True)
    assert bmk.backend == "pallas" and bmk.use_kernel is True
    np.testing.assert_array_equal(bmk.membership_batch(docs).final_states,
                                  want.final_states)


def test_spec_dfa_engine_agrees_with_facade():
    rng = np.random.default_rng(51)
    dfa = _dfas()[0]
    eng = SpecDFAEngine(dfa, num_chunks=8)
    m = Matcher(dfa, num_chunks=8)
    for n in (0, 3, 64, 500):
        doc = rng.choice(list(b"abxy0189"), size=n).astype(np.uint8)
        res = eng.membership(doc)
        batch = m.membership_batch([doc])
        assert res.accepted == bool(batch.accepted[0, 0])
        assert res.final_state == int(batch.final_states[0, 0])
        # the shim path is still failure-free vs its own sequential oracle
        assert res.final_state == eng.membership_sequential(doc).final_state


@pytest.mark.parametrize("name", ["quickstart", "corpus_filter",
                                  "constrained_serving"])
def test_examples_import_cleanly(name):
    """Examples must track the current API (import-time check; their mains
    run real workloads and are exercised manually / in docs)."""
    path = EXAMPLES / f"{name}.py"
    spec = importlib.util.spec_from_file_location(f"examples_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert callable(mod.main)
