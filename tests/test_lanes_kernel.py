"""Raw-speed tier: the fused lane-carrying kernel, in-kernel early exit,
r=2 boundary keys, block padding and the shape autotuner.

Covers the PR's guarantees end to end:

  * ``ops.spec_match_merge_lanes`` (the fused Pallas kernel carrying the
    full [K, S] lane axis through the chunk scan *and* the Eq. 8 fold) is
    bit-identical to ``ref.spec_merge_lanes_ref`` on raw arrays, under both
    r=1 and r=2 boundary keys, with the in-kernel early exit on and off;
  * ``Matcher.advance_cursors`` on the pallas backend rides that kernel
    (no jnp-stage fallback) and matches the local backend bit-for-bit —
    seeded and under hypothesis when installed (the cross-backend / mesh
    sweep lives in tests/test_device_merge.py);
  * the early-exit scratch flag actually skips grid steps on all-absorbed
    documents and never on live ones (``kernel_skipped_steps``);
  * ``ops._pad_to_block`` pads prime/odd lengths to a block multiple
    instead of degenerating to symbol-at-a-time grids;
  * r=2 candidate tables satisfy the Eq. 13 feasibility invariant, shrink
    the lane width when they should, and ``DeviceTables.advance_key``
    maintains the 2-byte suffix window across any segmentation;
  * ``autotune_spec_shapes`` picks by measured cost (``time_fn`` injection)
    and round-trips its on-disk cache.
"""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (Matcher, build_packed_lookahead_tables, compile_regex,
                        make_search_dfa, pack_dfas, random_dfa)
from repro.core.engine.plan import DeviceTables
from repro.core.profiling import (TunedShape, autotune_spec_shapes,
                                  clear_autotune_cache)
from repro.kernels import ops, ref

PATTERNS = [".*(ab|ba){2}", ".*[0-9]{3}", ".*x+y"]
ALPHABET = list(b"abxy0189")


# --------------------------------------------------------------------------
# fused lanes kernel vs the host reference (raw arrays)
# --------------------------------------------------------------------------

def _host_scan(table_pad, chunks, init):
    """[B, C, n] lane states after scanning each chunk's symbols."""
    st = np.asarray(init, np.int32).copy()
    for pos in range(chunks.shape[-1]):
        st = table_pad[st, chunks[:, :, pos][:, :, None]]
    return st


def _boundary_keys(dev, chunks):
    """[B, C] entry keys per chunk: chunk i keyed on chunk i-1's suffix."""
    b, c, _ = chunks.shape
    last1 = chunks[:, :-1, -1]
    if dev.spec_r == 2:
        key = chunks[:, :-1, -2] * dev.pad_cls + last1
        key = np.where(last1 == dev.pad_cls, dev.pad_key, key)
    else:
        key = last1
    la = np.zeros((b, c), np.int32)
    la[:, 1:] = key
    return la


@pytest.mark.parametrize("r", [1, 2])
@pytest.mark.parametrize("shape", [(2, 4, 8), (3, 2, 16), (1, 8, 32)])
def test_lanes_kernel_matches_ref(shape, r):
    b, c, lc = shape
    rng = np.random.default_rng(70 + r)
    packed = pack_dfas([random_dfa(8, 4, rng=rng), random_dfa(5, 3, rng=rng)])
    dev = DeviceTables.build(packed, lookahead_r=r)
    t = dev.tables
    k, s, q = packed.n_patterns, t.i_max, packed.n_states
    table_pad = np.concatenate(
        [packed.table, np.arange(q, dtype=np.int32).reshape(-1, 1)], axis=1)
    cidx_pad = np.concatenate([t.cand_index, np.full((1, q), -1, np.int32)])
    absorbing = (packed.table == np.arange(q)[:, None]).all(axis=1)

    docs = [rng.integers(0, 256, size=int(n), dtype=np.uint8)
            for n in rng.integers(c * lc // 2, c * lc + 1, size=b)]
    chunks = np.full((b, c, lc), dev.pad_cls, np.int32)
    for i, d in enumerate(docs):
        cls = packed.classes_of(d)
        chunks.reshape(b, -1)[i, :len(cls)] = cls
    la = _boundary_keys(dev, chunks)
    entry_keys = rng.integers(0, dev.n_keys, size=b)
    init = np.zeros((b, c, k, s), np.int32)
    init[:, 0] = t.candidates[entry_keys]
    init[:, 1:] = np.concatenate([t.candidates, t.candidates[:1]]
                                 )[np.minimum(la[:, 1:], dev.n_keys)]

    lvecs = _host_scan(table_pad, chunks, init.reshape(b, c, k * s))
    want = np.asarray(ref.spec_merge_lanes_ref(
        jnp.asarray(lvecs.reshape(b, c, k, s)), jnp.asarray(la),
        jnp.asarray(cidx_pad), jnp.asarray(packed.sinks),
        pad_cls=dev.pad_key))

    args = (jnp.asarray(table_pad), jnp.asarray(chunks),
            jnp.asarray(init.reshape(b, c, k * s)), jnp.asarray(la),
            jnp.asarray(cidx_pad), jnp.asarray(packed.sinks),
            jnp.asarray(absorbing.astype(np.int32)))
    for early_exit in (False, True):
        got, skipped, l_blk = ops.spec_match_merge_lanes(
            *args, pad_cls=dev.pad_cls, pad_key=dev.pad_key,
            early_exit=early_exit, l_blk=8)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"early_exit={early_exit}")
        if not early_exit:
            assert (np.asarray(skipped) == 0).all()


# --------------------------------------------------------------------------
# facade: the pallas cursor tick rides the fused lanes kernel
# --------------------------------------------------------------------------

def _cursor_traffic(m, rng, n_streams=5, seg_len=120):
    prefixes = [bytes(rng.choice(ALPHABET, size=8).astype(np.uint8))
                for _ in range(n_streams)]
    entry = np.tile(m.packed.starts, (n_streams, 1))
    r0 = m.advance_segments(prefixes, entry)
    keys = np.array([m.dev.advance_key(-1, p) for p in prefixes], np.int32)
    lanes = m.dev.tables.candidates[keys].astype(np.int32)
    segs = [bytes(rng.choice(ALPHABET, size=seg_len).astype(np.uint8))
            for _ in range(n_streams)]
    return segs, lanes, keys, r0


def test_advance_cursors_pallas_rides_lanes_kernel():
    rng = np.random.default_rng(71)
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    mp = Matcher(dfas, backend="pallas", num_chunks=4, batch_tile=8)
    ml = Matcher(dfas, backend="local", num_chunks=4, batch_tile=8)
    segs, lanes, keys, _ = _cursor_traffic(mp, rng)
    got = mp.advance_cursors(segs, lanes, keys)
    want = ml.advance_cursors(segs, lanes, keys)
    np.testing.assert_array_equal(got.lane_states, want.lane_states)
    np.testing.assert_array_equal(got.absorbed, want.absorbed)
    # the acceptance criterion: the candidate-keyed tick lowered to the
    # fused lanes kernel, not a jnp-stage fallback
    kinds = set(mp.executor.lowering_kinds.values())
    assert "spec-kernel-lanes" in kinds, kinds
    assert not any(k == "spec-jnp" for k in kinds), kinds


def test_advance_cursors_pallas_matches_local_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS[:2]]
    mp = Matcher(dfas, backend="pallas", num_chunks=4, batch_tile=4)
    ml = Matcher(dfas, backend="local", num_chunks=4, batch_tile=4)

    @hyp.settings(max_examples=15, deadline=None)
    @hyp.given(prefix=st.binary(min_size=2, max_size=20),
               seg=st.binary(min_size=0, max_size=150))
    def check(prefix, seg):
        key = mp.dev.advance_key(-1, prefix)
        lanes = mp.dev.tables.candidates[key][None].astype(np.int32)
        keys = np.array([key], np.int32)
        got = mp.advance_cursors([seg], lanes, keys)
        want = ml.advance_cursors([seg], lanes, keys)
        np.testing.assert_array_equal(got.lane_states, want.lane_states)

    check()


# --------------------------------------------------------------------------
# in-kernel early exit: grid steps skipped iff a document is all-absorbed
# --------------------------------------------------------------------------

def _skip_probe_matcher():
    dfa = make_search_dfa(compile_regex(".*(hit)"))
    m = Matcher(dfa, num_chunks=4, backend="pallas", batch_tile=4)
    m.executor.spec_l_blk[0] = 64  # several grid steps per 200-byte chunk
    return m


def test_early_exit_skips_grid_steps_on_absorbed_docs():
    m = _skip_probe_matcher()
    # every chunk of this doc sees "hit" inside its first 64-symbol block,
    # so every lane absorbs there and the remaining blocks must be skipped
    hot = b"hit " * 200
    live = b"xyz " * 200  # keeps the bucket live so the kernel actually runs
    before = m.executor.kernel_skipped_steps()
    res = m.membership_batch([hot, live])
    skipped = m.executor.kernel_skipped_steps() - before
    assert skipped > 0, "absorbed doc must skip symbol blocks in-kernel"
    assert bool(res.accepted[0, 0]) and not bool(res.accepted[1, 0])
    # bit-identity is not bought with the skips
    want = Matcher(make_search_dfa(compile_regex(".*(hit)")),
                   num_chunks=4).membership_batch([hot, live])
    np.testing.assert_array_equal(res.final_states, want.final_states)


def test_early_exit_never_skips_on_live_docs():
    m = _skip_probe_matcher()
    docs = [b"xyz " * 200, b"abc " * 200]  # never absorb
    before = m.executor.kernel_skipped_steps()
    m.membership_batch(docs)
    assert m.executor.kernel_skipped_steps() == before


# --------------------------------------------------------------------------
# block padding: prime/odd lengths keep real block sizes
# --------------------------------------------------------------------------

def test_pad_to_block_units():
    assert ops._pad_to_block(512, 512) == (512, 512)
    assert ops._pad_to_block(513, 512) == (512, 1024)
    assert ops._pad_to_block(127, 512) == (127, 127)   # short axis: one block
    assert ops._pad_to_block(1021, 256) == (256, 1024) # prime L: padded, not 1
    assert ops._pad_to_block(0, 8) == (1, 0)
    blk, padded = ops._pad_to_block(509, 128)
    assert blk == 128 and padded % blk == 0 and padded >= 509


@pytest.mark.parametrize("n", [127, 509, 1021])
def test_spec_match_prime_lengths_stay_exact(n):
    """The old divisor search fell to block 1 on prime L; the padded path
    must stay bit-identical to the reference at full block sizes."""
    rng = np.random.default_rng(72)
    packed = pack_dfas([random_dfa(6, 4, rng=rng)])
    t = build_packed_lookahead_tables(packed)
    q = packed.n_states
    table_pad = np.concatenate(
        [packed.table, np.arange(q, dtype=np.int32).reshape(-1, 1)], axis=1)
    c, s = 4, t.i_max
    chunks = rng.integers(0, packed.n_classes, size=(c, n)).astype(np.int32)
    init = np.broadcast_to(t.candidates[0, 0][None, :], (c, s)).copy()
    init = init.astype(np.int32)
    got = np.asarray(ops.spec_match(jnp.asarray(table_pad),
                                    jnp.asarray(chunks), jnp.asarray(init)))
    want = np.asarray(ref.spec_match_ref(jnp.asarray(packed.table),
                                         jnp.asarray(chunks),
                                         jnp.asarray(init)))
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# r=2 boundary keys: Eq. 13 tables and the host-side key window
# --------------------------------------------------------------------------

def test_r2_candidate_invariant():
    """The state reached after any suffix (c1, c2) is a candidate of the
    pair key c1 * n + c2 — or the pattern's sink (Eq. 13 feasibility)."""
    rng = np.random.default_rng(73)
    packed = pack_dfas([random_dfa(9, 4, rng=rng), random_dfa(5, 3, rng=rng)])
    t2 = build_packed_lookahead_tables(packed, r=2)
    n = packed.n_classes
    for c1 in range(n):
        for c2 in range(n):
            key = c1 * n + c2
            tgt = packed.table[packed.table[:, c1], c2]
            for k in range(packed.n_patterns):
                lo, hi = packed.offsets[k], packed.offsets[k + 1]
                for q in set(int(x) for x in tgt[lo:hi]):
                    if q == packed.sinks[k]:
                        assert t2.cand_index[key, q] == -1
                    else:
                        j = t2.cand_index[key, q]
                        assert j >= 0 and int(t2.candidates[key, k, j]) == q


def test_r2_shrinks_lane_width_and_auto_choice():
    rng = np.random.default_rng(74)
    packed = pack_dfas([random_dfa(16, 6, rng=rng)])
    t1 = build_packed_lookahead_tables(packed, r=1)
    t2 = build_packed_lookahead_tables(packed, r=2)
    assert t2.i_max <= t1.i_max  # pair keys only ever restrict the image
    assert t2.n_keys == packed.n_classes ** 2 and t2.r == 2
    dev = DeviceTables.build(packed, lookahead_r="auto")
    if t2.i_max < t1.i_max and t1.i_max > 1:
        assert dev.spec_r == 2 and dev.i_max == t2.i_max
    else:
        assert dev.spec_r == 1
    assert dev.pad_key == dev.n_keys
    # forcing a depth overrides the auto choice
    assert DeviceTables.build(packed, lookahead_r=1).spec_r == 1


def test_advance_key_maintains_suffix_window():
    """advance_key over any segmentation == the key of the full suffix."""
    rng = np.random.default_rng(75)
    packed = pack_dfas([make_search_dfa(compile_regex(p)) for p in PATTERNS])
    for r in (1, 2):
        dev = DeviceTables.build(packed, lookahead_r=r)
        data = rng.integers(0, 256, size=64, dtype=np.uint8)
        b2c = packed.byte_to_class
        full = (int(b2c[data[-1]]) if r == 1 else
                int(b2c[data[-2]]) * packed.n_classes + int(b2c[data[-1]]))
        for trial in range(10):
            cuts = np.sort(rng.integers(0, len(data) + 1, size=3))
            key = -1
            for a, b in zip([0, *cuts], [*cuts, len(data)]):
                key = dev.advance_key(key, data[a:b])
            assert key == full, (r, trial)
        # insufficient history stays conservative
        assert dev.advance_key(-1, data[:1]) == (-1 if r == 2
                                                 else int(b2c[data[0]]))
        assert dev.advance_key(-1, b"") == -1


# --------------------------------------------------------------------------
# shape autotuner
# --------------------------------------------------------------------------

def _toy_packed():
    return pack_dfas([make_search_dfa(compile_regex(".*ab+c"))])


def test_autotune_picks_measured_winner_and_caches():
    clear_autotune_cache()
    packed = _toy_packed()
    seen = []

    def fake(cfg):
        seen.append(cfg)
        return {4: 300.0, 8: 100.0, 16: 200.0}[cfg["num_chunks"]]

    t = autotune_spec_shapes(packed, backend="local",
                             num_chunks_candidates=[4, 8, 16], time_fn=fake)
    assert isinstance(t, TunedShape)
    assert t.num_chunks == 8 and t.us_per_call == 100.0
    assert t.l_blk == 0 and t.source == "measured"  # local: no l_blk search
    assert {c["num_chunks"] for c in seen} == {4, 8, 16}
    # second call is a pure in-process cache hit
    n_calls = len(seen)
    t2 = autotune_spec_shapes(packed, backend="local",
                              num_chunks_candidates=[4, 8, 16], time_fn=fake)
    assert len(seen) == n_calls and t2.source == "cache"
    assert t2.num_chunks == 8
    assert dataclasses.asdict(t2)["num_chunks"] == 8
    clear_autotune_cache()


def test_autotune_searches_l_blk_on_pallas_and_mesh_on_sharded():
    clear_autotune_cache()
    packed = _toy_packed()
    t = autotune_spec_shapes(packed, backend="pallas",
                             num_chunks_candidates=[4],
                             l_blk_candidates=[128, 256, 512],
                             time_fn=lambda c: float(c["l_blk"]))
    assert t.l_blk == 128
    ts = autotune_spec_shapes(
        packed, backend="sharded", num_chunks_candidates=[4],
        mesh_shape="auto", devices=8,
        # prefer wide chunk axes: (1, 8) must win over near-square (2, 4)
        time_fn=lambda c: float(c["mesh_shape"][0]))
    assert ts.mesh_shape == (1, 8)
    clear_autotune_cache()


def test_autotune_disk_cache_roundtrip(tmp_path, monkeypatch):
    clear_autotune_cache()
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    packed = _toy_packed()
    calls = []

    def fake(cfg):
        calls.append(cfg)
        return 50.0

    t = autotune_spec_shapes(packed, backend="pallas",
                             num_chunks_candidates=[4], time_fn=fake)
    assert t.source == "measured" and path.is_file()
    clear_autotune_cache()  # drop in-process memory: force the disk path
    n_calls = len(calls)
    t2 = autotune_spec_shapes(packed, backend="pallas",
                              num_chunks_candidates=[4], time_fn=fake)
    assert t2.source == "disk" and len(calls) == n_calls
    assert (t2.num_chunks, t2.l_blk) == (t.num_chunks, t.l_blk)
    # refresh re-measures and overwrites
    t3 = autotune_spec_shapes(packed, backend="pallas",
                              num_chunks_candidates=[4], time_fn=fake,
                              refresh=True)
    assert t3.source == "measured" and len(calls) > n_calls
    clear_autotune_cache()


def test_matcher_autotune_applies_tuned_shape(monkeypatch):
    clear_autotune_cache()
    import repro.core.profiling as prof

    def fake_tune(packed, **kw):
        assert kw["backend"] == "pallas"
        return prof.TunedShape(num_chunks=4, mesh_shape=None, l_blk=256,
                               us_per_call=1.0, source="measured")

    monkeypatch.setattr(prof, "autotune_spec_shapes", fake_tune)
    m = Matcher(_toy_packed(), backend="pallas", num_chunks=8, autotune=True)
    assert m.num_chunks == 4
    assert m.executor.spec_l_blk[0] == 256
    assert m.perf_report()["autotune"]["l_blk"] == 256
    clear_autotune_cache()
