"""Dry-run regression: one cheap cell must lower+compile on the production
meshes (subprocess — dryrun.py forces 512 host devices before importing jax).

This keeps the multi-pod deliverable from rotting; the full 64-cell sweep is
run via ``python -m repro.launch.dryrun --all --mesh both`` (artifacts/).
"""

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(arch: str, shape: str, mesh: str, tmpdir: str) -> dict:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", tmpdir],
        capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    with open(os.path.join(tmpdir, mesh, f"{arch}--{shape}.json")) as f:
        return json.load(f)


def test_dryrun_cell_single_and_multi(tmp_path):
    for mesh, devices in (("single", 256), ("multi", 512)):
        rec = _run("seamless-m4t-medium", "decode_32k", mesh, str(tmp_path))
        assert rec["ok"], rec.get("error")
        assert rec["n_devices"] == devices
        assert rec["memory"]["temp_bytes"] > 0
        assert rec["census"]["dot_flops"] > 0
        assert rec["hlo_bytes"] > 0


def test_dryrun_artifacts_complete_and_green():
    """The committed artifact sweep must cover all 32 cells x 2 meshes, all ok."""
    art = os.path.join(REPO, "artifacts", "dryrun")
    if not os.path.isdir(art):
        import pytest
        pytest.skip("artifact sweep not present")
    for mesh in ("single", "multi"):
        files = [f for f in os.listdir(os.path.join(art, mesh))
                 if f.endswith(".json")]
        assert len(files) == 32, (mesh, len(files))
        for f in files:
            with open(os.path.join(art, mesh, f)) as fh:
                assert json.load(fh).get("ok"), (mesh, f)
