"""Edge cases and invariants not covered by the main property suites."""

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (SpecDFAEngine, compile_prosite, compile_regex,
                        i_max_r, make_search_dfa, random_dfa)
from repro.core.engine import VPU_LANES
from repro.core.patterns import PCRE_PATTERNS, PROSITE_PATTERNS


def test_empty_input():
    dfa = compile_regex("a*")
    for mode in ("lookahead", "basic", "holub"):
        eng = SpecDFAEngine(dfa, num_chunks=4, mode=mode)
        res = eng.membership(b"")
        assert res.accepted  # a* accepts empty
        assert res.final_state == dfa.start


def test_input_shorter_than_chunks():
    dfa = compile_regex("ab*")
    eng = SpecDFAEngine(dfa, num_chunks=16, mode="lookahead")
    res = eng.membership(b"abb")
    assert res.accepted
    assert res.mode == "sequential"  # tiny input falls back


def test_single_chunk_degenerates_to_sequential():
    dfa = compile_regex("[ab]+")
    eng = SpecDFAEngine(dfa, num_chunks=1)
    res = eng.membership(b"abab" * 100)
    assert res.accepted
    assert res.work_parallel == res.work_sequential


def test_weights_must_match_chunks():
    dfa = compile_regex("a")
    with pytest.raises(ValueError):
        SpecDFAEngine(dfa, num_chunks=4, weights=np.ones(3))


def test_weighted_engine_balanced_correctness():
    dfa = make_search_dfa(compile_regex(r".*ab{2,4}c"))
    rng = np.random.default_rng(0)
    data = rng.choice(np.frombuffer(b"abcx", np.uint8), size=9999)
    w = np.array([2.0, 1.0, 1.0, 0.5])
    w = w / w.mean()
    eng = SpecDFAEngine(dfa, num_chunks=4, weights=w, partition="balanced")
    assert eng.membership(data).final_state == \
        eng.membership_sequential(data).final_state


def test_all_suite_patterns_compile_and_roundtrip():
    """Every shipped pattern compiles; engines agree with the DFA oracle."""
    rng = np.random.default_rng(2)
    for name, pat in list(PCRE_PATTERNS.items()):
        dfa = compile_regex(pat)
        assert dfa.n_states >= 2, name
    for name, pat in list(PROSITE_PATTERNS.items())[:8]:
        dfa = compile_prosite(pat)
        data = rng.choice(np.frombuffer(b"ACDEFGHIKLMNPQRSTVWY", np.uint8),
                          size=2000)
        eng = SpecDFAEngine(dfa, num_chunks=5)
        assert eng.membership(data).final_state == dfa.run(data), name


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 12), st.integers(2, 5), st.integers(0, 2**31 - 1))
def test_imax_r1_equals_direct_count(n_states, n_classes, seed):
    """I_max,1 from i_max_r matches the direct Eq. 12 computation."""
    from repro.core import build_lookahead_tables
    rng = np.random.default_rng(seed)
    dfa = random_dfa(n_states, n_classes, rng=rng)
    tabs = build_lookahead_tables(dfa)
    assert i_max_r(dfa, 1)[0] == tabs.i_max


def test_vpu_lane_constant_documented():
    assert VPU_LANES == 1024  # 8 sublanes x 128 lanes int32


def test_gamma_bounds():
    for pat in ["a", "[ab]{3}", "(ab|cd)+x"]:
        dfa = compile_regex(pat)
        eng = SpecDFAEngine(dfa)
        assert 0 < eng.gamma <= 1.0


def test_mxu_crossover_heuristic():
    from repro.kernels.ops import mxu_profitable
    assert mxu_profitable(q=64, s=64)        # tiny DFA, wide speculation
    assert not mxu_profitable(q=2048, s=16)  # big DFA, narrow speculation
    assert not mxu_profitable(q=64, s=2)     # narrow speculation -> gather
