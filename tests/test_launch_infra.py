"""Tests for the dry-run/roofline infrastructure (census math, mesh, specs)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_census import hlo_census
from repro.launch.roofline import wire_bytes, tokens_of


def test_census_counts_while_trip_multipliers():
    """A jitted double-scan program must census flops = trips * body flops."""
    n_outer, n_inner, d = 3, 4, 32

    def prog(w, x):
        def outer(x, _):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner, x, None, length=n_inner)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=n_outer)
        return x

    w = jnp.eye(d, dtype=jnp.float32)
    x = jnp.ones((8, d), jnp.float32)
    compiled = jax.jit(prog).lower(w, x).compile()
    census = hlo_census(compiled.as_text(), 1)
    expect = 2 * 8 * d * d * n_outer * n_inner
    assert census["dot_flops"] == pytest.approx(expect, rel=0.01), census
    assert census["max_multiplier"] == n_outer * n_inner


def test_census_collectives_on_forced_devices():
    """Collective census sees the psum inserted by a sharded reduction."""
    import subprocess, sys, os, textwrap
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(repo, "src"))
    body = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.jax_compat import set_mesh
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_census import hlo_census
        mesh = jax.make_mesh((8,), ("data",))
        def f(x):
            return x.sum()
        sh = NamedSharding(mesh, P("data"))
        x = jax.ShapeDtypeStruct((64, 4), jnp.float32)
        with set_mesh(mesh):
            compiled = jax.jit(f, in_shardings=sh).lower(x).compile()
        c = hlo_census(compiled.as_text(), 8)
        total = sum(v["count"] for v in c["collectives"].values())
        assert total >= 1, c["collectives"]
        print("census collectives OK")
    """)
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr


def test_wire_bytes_ring_factors():
    coll = {
        "all-gather": {"bytes": 100.0, "group_sizes": [4]},
        "all-reduce": {"bytes": 100.0, "group_sizes": [4]},
        "reduce-scatter": {"bytes": 100.0, "group_sizes": [4]},
        "all-to-all": {"bytes": 0.0, "group_sizes": []},
        "collective-permute": {"bytes": 100.0, "group_sizes": [2]},
    }
    got = wire_bytes(coll)
    expect = 100 * 3 / 4 + 2 * 100 * 3 / 4 + 100 * 3 + 100
    assert got == pytest.approx(expect)


def test_tokens_of_shapes():
    assert tokens_of("train_4k") == (4096 * 256, 6.0)
    assert tokens_of("prefill_32k") == (32768 * 32, 2.0)
    assert tokens_of("decode_32k") == (128, 2.0)


def test_make_local_mesh_and_dp_axes():
    from repro.launch.mesh import dp_axes, make_local_mesh, mesh_info
    mesh = make_local_mesh(1, 1)
    assert dp_axes(mesh) == ("data",)
    info = mesh_info(mesh)
    assert info["n_devices"] == 1


def test_param_specs_divisibility_fallback():
    """Sharding rules must degrade to replication for non-dividing dims."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shr
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    params = {"layers": {"attn": {
        "wq": jnp.zeros((64, 10, 16)),   # 10 heads never divide
        "wk": jnp.zeros((64, 2, 16)),
        "wo": jnp.zeros((10, 16, 64)),
    }}}
    specs = shr.param_specs(params, mesh)
    # mesh axes of size 1 -> everything replicated (still valid specs)
    for leaf in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert isinstance(leaf, P)


def test_roofline_count_params_moe_active():
    from repro.launch.roofline import count_params
    total, active = count_params("phi3.5-moe-42b-a6.6b")
    # 42B-class total, ~6.6B-class active + embeddings
    assert 38e9 < total < 46e9, total
    assert active < total / 3, (total, active)


def test_roofline_count_params_dense():
    from repro.launch.roofline import count_params
    total, active = count_params("llama3-8b")
    assert 7e9 < total < 9.5e9, total
    assert total == active
