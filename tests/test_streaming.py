"""Streaming match runtime: segment-split invariance, Eq. 8 composition,
micro-batching scheduler policies, and the streaming consumers.

The tentpole guarantee under test: feeding a document through
``StreamMatcher`` in *any* segmentation — empty segments, 1-byte dribbles,
arbitrary random splits — is bit-identical to ``Matcher.membership_batch``
on the whole document, on every backend and on every simulated mesh shape:
1x1, 2x4, 4x2 and 8x1 (doc x chunk), uniform and capacity-weighted
(tests/conftest.py forces 8 host devices).  A hypothesis property test
drives the same invariant when hypothesis is installed; the seeded random
sweep below always runs.
"""

import numpy as np
import pytest

import jax

from repro.core import (Matcher, compile_regex, make_search_dfa, pack_dfas,
                        random_dfa, synthetic_capacities)
from repro.launch.mesh import make_matcher_mesh
from repro.streaming import (ENTRY_EXACT, StreamMatcher, TickPolicy, merge,
                             open_cursor, segment_result)

PATTERNS = [".*(ab|ba){2}", ".*[0-9]{3}", ".*x+y"]
ALPHABET = list(b"abxy0189")


def _mesh_or_skip(shape):
    if isinstance(shape, int):
        shape = (1, shape)
    n = shape[0] * shape[1]
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} host devices (conftest forces 8)")
    return make_matcher_mesh(shape=shape)


def _docs(rng, sizes):
    return [bytes(rng.choice(ALPHABET, size=int(n)).astype(np.uint8))
            for n in sizes]


def _random_splits(rng, doc, n_cuts):
    cuts = sorted(rng.integers(0, len(doc) + 1, size=n_cuts).tolist())
    bounds = [0] + cuts + [len(doc)]
    return [doc[a:b] for a, b in zip(bounds[:-1], bounds[1:])]


def _feed_stream(sm, doc, segments):
    s = sm.open()
    for seg in segments:
        s.feed(seg)
    return s.close()


# --------------------------------------------------------------------------
# tentpole: segment-split invariance on every backend, 1 and 8 devices
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend,shape", [
    ("local", None), ("pallas", None),
    ("sharded", (1, 1)), ("sharded", (2, 4)), ("sharded", (4, 2)),
    ("sharded", (8, 1))])
def test_segment_split_invariance(backend, shape):
    devices = 1 if shape is None else shape[0] * shape[1]
    rng = np.random.default_rng(40 + devices + (0 if shape is None
                                                else shape[0]))
    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    kwargs = {}
    if backend == "sharded":
        # capacity profile skewed within mesh rows: the 2-D weighted layouts
        # genuinely differ per doc row-block
        caps = np.random.default_rng(5).uniform(0.6, 1.8, size=devices)
        kwargs = {"mesh": _mesh_or_skip(shape), "capacities": caps}
    m = Matcher(dfas, num_chunks=8, batch_tile=8, backend=backend, **kwargs)
    docs = _docs(rng, [0, 1, 2, 31, 32, 100, 400, 999])
    want = m.membership_batch(docs)
    sm = StreamMatcher(m, policy=TickPolicy(max_batch=4, max_delay=3))
    for i, doc in enumerate(docs):
        segments = _random_splits(rng, doc, int(rng.integers(0, 8)))
        res = _feed_stream(sm, doc, segments)
        np.testing.assert_array_equal(res.final_states, want.final_states[i],
                                      err_msg=f"doc {i} split {len(segments)}")
        np.testing.assert_array_equal(res.accepted, want.accepted[i])
        assert res.byte_count == len(doc)


def test_empty_and_single_byte_segments():
    rng = np.random.default_rng(41)
    m = Matcher([make_search_dfa(compile_regex(p)) for p in PATTERNS],
                num_chunks=4)
    doc = bytes(rng.choice(ALPHABET, size=73).astype(np.uint8))
    want = m.membership_batch([doc])
    sm = StreamMatcher(m)  # eager flush: every feed is its own tick
    # 1-byte dribble interleaved with empty feeds
    s = sm.open()
    for i, b in enumerate(doc):
        s.feed(b"")
        s.feed(doc[i:i + 1])
    res = s.close()
    np.testing.assert_array_equal(res.final_states, want.final_states[0])
    # a stream closed with zero bytes decides on the start states
    empty = sm.open().close()
    np.testing.assert_array_equal(
        empty.accepted, m.packed.accepting[m.packed.starts])
    assert empty.byte_count == 0


def test_streaming_random_dfa_property():
    rng = np.random.default_rng(42)
    for trial in range(3):
        packed = pack_dfas([random_dfa(int(rng.integers(3, 16)),
                                       int(rng.integers(2, 6)), rng=rng)
                            for _ in range(int(rng.integers(1, 4)))])
        m = Matcher(packed, num_chunks=4, batch_tile=4)
        sm = StreamMatcher(m, policy=TickPolicy(max_batch=3, max_delay=2))
        for n in (0, 1, 17, 300):
            doc = bytes(rng.integers(0, 256, size=n, dtype=np.uint8))
            segments = _random_splits(rng, doc, int(rng.integers(0, 5)))
            res = _feed_stream(sm, doc, segments)
            np.testing.assert_array_equal(res.final_states,
                                          packed.run_all(doc),
                                          err_msg=str((trial, n)))


def test_segment_split_invariance_hypothesis():
    """Any random split of a document into 1..N segments (hypothesis)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    dfas = [make_search_dfa(compile_regex(p)) for p in PATTERNS]
    m = Matcher(dfas, num_chunks=4, batch_tile=4)
    sm = StreamMatcher(m, policy=TickPolicy(max_batch=2, max_delay=1))

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(
        doc=st.binary(max_size=200),
        cuts=st.lists(st.integers(min_value=0, max_value=200), max_size=6))
    def check(doc, cuts):
        bounds = [0] + sorted(min(c, len(doc)) for c in cuts) + [len(doc)]
        segments = [doc[a:b] for a, b in zip(bounds[:-1], bounds[1:])]
        res = _feed_stream(sm, doc, segments)
        np.testing.assert_array_equal(res.final_states, m.packed.run_all(doc))

    check()


# --------------------------------------------------------------------------
# pure Eq. 8 composition (independently matched segment maps)
# --------------------------------------------------------------------------

def test_host_merge_composes_independent_segments():
    """Segments matched independently (candidate-keyed lane maps) compose
    via the pure ``merge`` to the exact whole-document answer — the SFA-style
    transition-function composition, with no device in the loop."""
    rng = np.random.default_rng(43)
    m = Matcher([make_search_dfa(compile_regex(p)) for p in PATTERNS])
    dev = m.dev
    for trial in range(5):
        doc = bytes(rng.choice(ALPHABET,
                               size=int(rng.integers(1, 300))).astype(np.uint8))
        segments = _random_splits(rng, doc, int(rng.integers(0, 6)))
        cur = open_cursor(dev)
        for seg_bytes in segments:
            ec = ENTRY_EXACT if cur.byte_count == 0 else cur.last_class
            # the map depends only on (bytes, entry class): it could have
            # been computed before any earlier segment was seen
            seg = segment_result(dev, seg_bytes, ec)
            cur = merge(cur, seg, tables=dev)
        np.testing.assert_array_equal(cur.states, m.packed.run_all(doc),
                                      err_msg=str(trial))
        assert cur.byte_count == len(doc)


def test_merge_rejects_mismatched_entry_class():
    m = Matcher([make_search_dfa(compile_regex(PATTERNS[0]))])
    dev = m.dev
    cur = merge(open_cursor(dev), segment_result(dev, b"ab"), tables=dev)
    wrong = (cur.last_class + 1) % m.packed.n_classes
    with pytest.raises(ValueError):
        merge(cur, segment_result(dev, b"ba", wrong), tables=dev)
    with pytest.raises(ValueError):  # exact segments need a pristine cursor
        merge(cur, segment_result(dev, b"ba"), tables=dev)


# --------------------------------------------------------------------------
# scheduler: tick policies, coalescing, occupancy, absorbed early exit
# --------------------------------------------------------------------------

def test_eager_policy_ticks_every_feed():
    # a non-matching stream: ".*[0-9]{3}" never absorbs on letters, so every
    # feed really is matched (no absorbed skip interfering with the counts)
    m = Matcher([make_search_dfa(compile_regex(PATTERNS[1]))])
    sm = StreamMatcher(m)  # default TickPolicy: max_delay=0 -> eager
    s = sm.open()
    for _ in range(5):
        s.feed(b"abba")
    assert sm.stats.ticks == 5 and sm.stats.segments == 5
    s.close()


def test_max_batch_policy_coalesces():
    m = Matcher([make_search_dfa(compile_regex(PATTERNS[1]))])
    sm = StreamMatcher(m, policy=TickPolicy(max_batch=4, max_delay=100))
    streams = [sm.open() for _ in range(4)]
    for s in streams[:3]:
        s.feed(b"ab" * 10)
    assert sm.stats.ticks == 0          # below max_batch, within max_delay
    streams[3].feed(b"ba" * 10)
    assert sm.stats.ticks == 1          # 4th pending stream trips the batch
    assert sm.stats.segments == 4
    # several feeds to one stream coalesce into one scanned segment
    streams[0].feed(b"ab")
    streams[0].feed(b"b8")
    streams[0].feed(b"ab")
    sm.flush()
    assert sm.stats.segments == 5
    assert sm.stats.coalescing > 1.0
    doc = b"ab" * 10 + b"ab" + b"b8" + b"ab"
    np.testing.assert_array_equal(
        streams[0].close().final_states,
        m.membership_batch([doc]).final_states[0])


def test_max_delay_policy_bounds_latency():
    m = Matcher([make_search_dfa(compile_regex(PATTERNS[1]))])
    sm = StreamMatcher(m, policy=TickPolicy(max_batch=100, max_delay=2))
    s0, s1 = sm.open(), sm.open()
    s0.feed(b"ab")                       # waits...
    s1.feed(b"ba")                       # 1 subsequent feed: still waiting
    assert sm.stats.ticks == 0
    s1.feed(b"ab")                       # 2nd subsequent feed: forced tick
    assert sm.stats.ticks == 1
    s0.close(), s1.close()


def test_max_delay_s_policy_wall_clock_deadline():
    """The wall-clock deadline dispatches once the oldest pending segment has
    waited ``max_delay_s`` seconds (evaluated at admission; a fake clock
    keeps the test deterministic)."""
    m = Matcher([make_search_dfa(compile_regex(PATTERNS[1]))])
    policy = TickPolicy(max_batch=100, max_delay=0, max_delay_s=10.0)
    assert not policy.eager          # wall-clock deadline disables eager
    now = [0.0]
    sm = StreamMatcher(m, policy=policy, clock=lambda: now[0])
    s0, s1 = sm.open(), sm.open()
    s0.feed(b"ab")                   # pending since t=0
    now[0] = 9.0
    s1.feed(b"ba")                   # 9s < 10s: still waiting
    assert sm.stats.ticks == 0
    now[0] = 10.5
    s1.feed(b"ab")                   # oldest has waited 10.5s: forced tick
    assert sm.stats.ticks == 1
    # decisions stay exact across the deadline-driven ticks
    np.testing.assert_array_equal(
        s0.close().final_states, m.membership_batch([b"ab"]).final_states[0])
    np.testing.assert_array_equal(
        s1.close().final_states,
        m.membership_batch([b"baab"]).final_states[0])
    # event-count and wall-clock deadlines compose: whichever trips first
    sm2 = StreamMatcher(m, policy=TickPolicy(max_batch=100, max_delay=2,
                                             max_delay_s=1e9))
    t0, t1 = sm2.open(), sm2.open()
    t0.feed(b"ab")
    t1.feed(b"ba")
    assert sm2.stats.ticks == 0
    t1.feed(b"ab")                   # 2nd subsequent feed event
    assert sm2.stats.ticks == 1
    t0.close(), t1.close()
    with pytest.raises(ValueError):
        TickPolicy(max_delay_s=-1.0)


def test_full_tiles_reach_full_occupancy():
    m = Matcher([make_search_dfa(compile_regex(PATTERNS[1]))],
                num_chunks=8, batch_tile=16)
    sm = StreamMatcher(m, policy=TickPolicy(max_batch=32, max_delay=1000))
    streams = [sm.open() for _ in range(32)]
    for r in range(3):
        for s in streams:
            s.feed(b"abxy0a1b" * 16)     # 128 B, same bucket, never absorbs
    sm.flush()
    assert sm.stats.occupancy == 1.0     # full 16-row tiles every tick
    assert sm.stats.segments == 96
    for s in streams:
        s.close()


def test_absorbed_streams_skip_the_device():
    """Once every pattern of a stream is absorbing, further segments are
    accounted but never matched — and the decision stays exact.  The session
    is *evicted* from admission: it never re-enters the queue and never
    triggers another tick (stream-aware eviction)."""
    m = Matcher(make_search_dfa(compile_regex(".*(hit)")))
    sm = StreamMatcher(m)
    s = sm.open()
    s.feed(b"xx hit xx", flush=True)
    assert bool(s.cursor.absorbed.all())
    before = sm.stats.segments
    ticks_before = sm.stats.ticks
    for _ in range(4):
        s.feed(b"more bytes that cannot change anything")
    assert sm.stats.segments == before
    assert sm.stats.absorbed_skips == 4
    # evicted once, counted once; eager policy would have ticked 4 more
    # times without eviction — the queue never even saw the session
    assert sm.stats.evicted == 1
    assert sm.stats.ticks == ticks_before
    assert sm.scheduler.pending_streams == 0
    res = s.close()
    assert bool(res.accepted[0])
    assert res.byte_count == len(b"xx hit xx") + 4 * len(
        b"more bytes that cannot change anything")
    np.testing.assert_array_equal(
        res.final_states,
        m.membership_batch([b"xx hit xx" + b"more bytes that cannot change "
                            b"anything" * 4]).final_states[0])


def test_evicted_feeds_still_advance_policy_deadlines():
    """An absorbed stream's feeds are evicted at admission but still count
    as feed events for *other* streams' max_delay deadline — eviction must
    not un-bound a live stream's latency."""
    # single-pattern absorbed stream + live stream under an event deadline
    m1 = Matcher(make_search_dfa(compile_regex(".*(hit)")))
    sm = StreamMatcher(m1, policy=TickPolicy(max_batch=100, max_delay=2))
    dead, live = sm.open(), sm.open()
    dead.feed(b"a hit b")
    sm.flush()
    assert bool(dead.cursor.absorbed.all())
    live.feed(b"pending...")            # queued, waiting on the deadline
    assert sm.stats.ticks == 1          # only the flush so far
    dead.feed(b"x")                     # evicted, but a feed event
    dead.feed(b"y")                     # 2nd event: live's deadline trips
    assert sm.stats.ticks == 2
    assert sm.stats.evicted == 1
    live.close(), dead.close()


def test_session_lifecycle_errors():
    m = Matcher(make_search_dfa(compile_regex(".*(ab)")))
    sm, sm2 = StreamMatcher(m), StreamMatcher(Matcher(
        make_search_dfa(compile_regex(".*(ab)"))))
    s = sm.open()
    with pytest.raises(ValueError):
        sm2.feed(s, b"x")                # wrong owner
    s.close()
    with pytest.raises(ValueError):
        s.feed(b"x")                     # closed
    with pytest.raises(ValueError):
        s.close()                        # double close
    with pytest.raises(ValueError):
        StreamMatcher(m, backend="local")  # kwargs conflict with a Matcher


# --------------------------------------------------------------------------
# facade-level segment API
# --------------------------------------------------------------------------

def test_advance_segments_matches_concatenation():
    rng = np.random.default_rng(44)
    m = Matcher([make_search_dfa(compile_regex(p)) for p in PATTERNS],
                num_chunks=4, batch_tile=4)
    b, k = 6, m.n_patterns
    entry = np.tile(m.packed.starts, (b, 1))
    prefixes = _docs(rng, [0, 3, 50, 200, 64, 17])
    res1 = m.advance_segments(prefixes, entry)
    suffixes = _docs(rng, [10, 0, 1, 128, 300, 33])
    res2 = m.advance_segments(suffixes, res1.final_states)
    whole = m.membership_batch([p + s for p, s in zip(prefixes, suffixes)])
    np.testing.assert_array_equal(res2.final_states, whole.final_states)
    assert res2.padded_rows >= b
    assert res2.absorbed.shape == (b, k)


# --------------------------------------------------------------------------
# consumers
# --------------------------------------------------------------------------

def test_corpus_filter_scan_stream_matches_scan_batch():
    from repro.data.filter import CorpusFilter
    rng = np.random.default_rng(45)
    pats = [r"SECRET-[0-9]+", r"key=[a-z]{4}"]
    docs = {}
    for i in range(10):
        d = bytearray(rng.choice(list(b"abc 01xyz"),
                                 size=int(rng.integers(0, 300))).astype(np.uint8))
        if rng.random() < 0.5:
            d[1:1] = b"SECRET-9"
        docs[i] = bytes(d)
    want = CorpusFilter(pats).scan_batch(list(docs.values()))

    # interleaved chunk arrivals across all documents
    events, cursors, live = [], {i: 0 for i in docs}, list(docs)
    while live:
        i = live[int(rng.integers(len(live)))]
        if cursors[i] >= len(docs[i]):
            events.append((i, None))
            live.remove(i)
        else:
            step = int(rng.integers(1, 50))
            events.append((i, docs[i][cursors[i]:cursors[i] + step]))
            cursors[i] += step
    filt = CorpusFilter(pats)
    got = dict(filt.scan_stream(iter(events), max_batch=4, max_delay=6))
    assert got == {i: bool(want[j]) for j, i in enumerate(docs)}
    assert filt.stats.scanned == len(docs)
    assert filt.stats.bytes_scanned == sum(len(d) for d in docs.values())


def test_decode_stream_matches_one_shot_prefill():
    from repro.serving import GrammarConstraint
    rng = np.random.default_rng(46)
    gc = GrammarConstraint(compile_regex(r"[a-d]+x"), vocab_size=300)
    toks = rng.integers(0, 300, size=(4, 12)).astype(np.int32)
    want = np.asarray(gc.advance_tokens(gc.init_states(4), toks))
    ds = gc.open_decode(4)
    for lo in range(0, 12, 3):           # chunked upload, 3 tokens at a time
        got = ds.feed_tokens(toks[:, lo:lo + 3])
    np.testing.assert_array_equal(np.asarray(got), want)
    # each 4-row round coalesces into at most one tick; rounds whose every
    # stream is already absorbed (random tokens hit the sink fast) are
    # evicted at admission and dispatch nothing at all
    stats = ds.stream.stats
    assert 1 <= stats.ticks <= 4
    assert stats.ticks + stats.absorbed_skips // 4 >= 4 - 1
    assert stats.evicted <= 4
